"""Three-tier page lifecycle: online migration vs static placement (§12).

The question this suite answers: when traffic *moves* — each stream walks a
region homed on somebody else's NIC, and jumps to a new region mid-run —
can any static page placement keep prefetches timely, and does online
trend-driven migration recover what the statics lose?

**Phase-shifting strided traffic.** Stream ``s`` walks pages at stride 3
starting deep inside another shard's block, and its offset jumps twice
(at ``T/3`` and ``2T/3``). The fabric's far delay is set *beyond the
prefetch window* (``FAR > pw_max``): a cross-shard candidate can never
land before its demand arrives, so the best a far page achieves is a
partial hit — covered, but the faulting stream still blocked on the
residual. That is the regime the paper's §5/§7 arbitration cannot fix by
scheduling alone: the page is simply homed on the wrong side of the
fabric.

Three runs over the identical schedules:

* ``static block`` / ``static interleave`` — the two §7 placements,
  two-tier scan (no migration). Timely rate collapses toward the
  fraction of pages that happen to sit near (~1/G ≈ 0.25).
* ``migration`` — the §12 three-tier scan: the Leap trend proposes each
  stream's *upcoming* pages (``page + trend·(pw_max+lead+j)``), the §5
  arbiter grants moves from leftover per-NIC budget, and by the time the
  prefetch window reaches a granted page it is near. After each offset
  jump the trend re-locks and migration follows — the *online* part no
  oracle static placement gets.

Headline: ``timely_rate = (prefetch_hits - partial_hits) / faults`` — the
fraction of accesses covered by a prefetch that *fully* landed in time.
Statics collapse to ~0.3; migration recovers ≥ 0.85 (full sizes).

**Demand is never displaced.** Migration rides the third, lowest
arbitration class. The witness runs an *equal-delay* fabric
(``near == far``, so re-homing cannot change any deadline — the only
thing migration could do is consume link capacity) at a budget tight
enough that the NICs saturate: per-step per-NIC
``demand + prefetch + migration`` grants reach the budget exactly.
Even then the per-stream demand-fetch counts ``info["fetched"]`` are
bit-equal with migration on vs off — migration traffic is squeezed into
leftover capacity, never the other way around.

**Capacity sweep (compressed cold tier).** With ``compressed`` on, the
uncompressed far tier is capped and the coldest pages round-trip through
the int8 page codec; promotes pay ``decompress_delay`` extra steps on
the wire deadline. The sweep shows the §12.3 trade: the prefetch *hit
rate* (coverage — ``prefetch_hits / faults``) holds bit-for-bit as the
uncompressed budget shrinks 4x (compressed pages are still there and
still prefetchable, unlike an eviction scheme that would drop them),
while the *timely* rate degrades gracefully as more landings pay the
codec surcharge — compression trades latency headroom, not coverage.

Derived rows cross-validate the jitted migration counts against the
lock-step twin (``repro.fabric.run_shardstep``) — the §8 zero-divergence
pin at benchmark scale.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.fabric.shardstep import run_shardstep
from repro.paging.lifecycle import MigrationCfg
from repro.paging.prefetch_serving import PrefetchedStream, stream_stats_at
from repro.paging.sharded_pool import (ShardedPoolCfg,
                                       sharded_multi_stream_consume)

from .common import sized, write_csv

N_PAGES = sized(512, 256)
PAGE_ELEMS = 4
T = sized(360, 120)
N_STREAMS = 4
N_SHARDS = 4
STRIDE = 3
NEAR, FAR = 1, 12               # FAR > pw_max: far candidates never timely
BUDGET = 6                      # per-NIC pages/step (finite: exercises §5)
EQ_DELAY = 4                    # equal-delay fabric for the demand witness
WITNESS_BUDGET = 3              # tight enough that the NICs saturate
PW_MAX = 8
MIG = MigrationCfg(mig_per_stream=2, lead=1, cooldown=16)


def _schedules() -> np.ndarray:
    """Phase-shifting stride-3 walks, starting deep off-home.

    Stream ``s`` starts in the middle of shard ``(s+1) % G``'s block and
    jumps by ~1/3 of the pool at ``T/3`` and ``2T/3`` — each phase is a
    fresh region a static placement was never tuned for.
    """
    block = N_PAGES // N_SHARDS
    jump = (N_PAGES // 3) | 1
    t = np.arange(T)
    phase = t // max(T // 3, 1)
    return np.stack([
        (((s + 1) % N_SHARDS) * block + block // 2
         + STRIDE * t + jump * phase) % N_PAGES
        for s in range(N_STREAMS)]).astype(np.int32)


def _agg(st) -> dict:
    per = [stream_stats_at(st, i) for i in range(N_STREAMS)]
    keys = ("faults", "prefetch_hits", "partial_hits", "deferred",
            "ring_drops", "pollution")
    out = {k: sum(p[k] for p in per) for k in keys}
    out["hit_rate"] = out["prefetch_hits"] / max(1, out["faults"])
    out["timely_rate"] = ((out["prefetch_hits"] - out["partial_hits"])
                          / max(1, out["faults"]))
    return out


def _run(scheds, placement: str, migration: MigrationCfg | None,
         budget: int | None = BUDGET, near: int = NEAR, far: int = FAR):
    pool = jnp.arange(N_PAGES * PAGE_ELEMS,
                      dtype=jnp.float32).reshape(N_PAGES, PAGE_ELEMS)
    geom = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES,
                            page_elems=PAGE_ELEMS, ring_size=16,
                            pw_max=PW_MAX)
    fab = ShardedPoolCfg(n_shards=N_SHARDS, placement=placement,
                         link_budget=budget, near_delay=near, far_delay=far)
    st, _, info = sharded_multi_stream_consume(
        pool, jnp.asarray(scheds), geom, fab, migration=migration)
    return st, info, geom, fab


def _crossval(scheds, geom, fab, migration) -> bool:
    """Jitted per-stream counts (incl. migrations) == lock-step twin."""
    st, _, info = sharded_multi_stream_consume(
        jnp.zeros((N_PAGES, PAGE_ELEMS), jnp.float32), jnp.asarray(scheds),
        geom, fab, migration=migration)
    rep = run_shardstep(scheds, N_PAGES, fab.n_shards, fab.placement,
                        fab.link_budget, ring_size=geom.ring_size,
                        near_delay=fab.near_delay, far_delay=fab.far_delay,
                        pw_max=geom.pw_max, h_size=geom.h_size,
                        n_split=geom.n_split, migration=migration)
    migd = np.asarray(info["migrated"]).sum(axis=1)
    promd = np.asarray(info["promoted"]).sum(axis=1)
    for i in range(len(scheds)):
        j = dict(stream_stats_at(st, i),
                 migrations=int(migd[i]), promotions=int(promd[i]))
        r = rep.stream_summary(i)
        if any(j[k] != r[k] for k in r):
            return False
    return int(np.asarray(info["demoted"]).sum()) == (rep.demotions or 0)


def run() -> tuple[list[dict], dict]:
    scheds = _schedules()
    rows, derived = [], {}

    # -- headline: statics collapse, online migration recovers ---------------
    acc = {}
    for name, placement, mig in (("static", "block", None),
                                 ("static", "interleave", None),
                                 ("migration", "block", MIG)):
        st, info, geom, fab = _run(scheds, placement, mig)
        a = _agg(st)
        acc[(name, placement)] = a
        rows.append({
            "mode": name, "placement": placement,
            "prefetch_hits": a["prefetch_hits"],
            "partial_hits": a["partial_hits"],
            "deferred": a["deferred"],
            "hit_rate": round(a["hit_rate"], 3),
            "timely_rate": round(a["timely_rate"], 3),
            "migrations": (int(np.asarray(info["migrated"]).sum())
                           if mig is not None else 0),
            "demotions": 0, "promotions": 0})

    statics = [acc[("static", p)]["timely_rate"]
               for p in ("block", "interleave")]
    mig_rate = acc[("migration", "block")]["timely_rate"]
    derived["static_best_timely"] = round(max(statics), 3)
    derived["migration_timely"] = round(mig_rate, 3)
    # smoke phases are too short to amortize the trend re-lock warmup, so
    # the absolute bars only bind at full sizes; the ordering always must
    derived["statics_collapse"] = bool(max(statics) <= sized(0.45, 0.7))
    derived["migration_recovers"] = bool(mig_rate >= sized(0.85, 0.4))
    derived["migration_beats_statics"] = bool(mig_rate > max(statics))

    # -- demand is never displaced by the migration class --------------------
    # Equal-delay fabric: near == far, so a granted move cannot change any
    # deadline — displacement is the *only* channel migration could affect
    # demand through.  WITNESS_BUDGET saturates the NICs (per-step per-NIC
    # demand + prefetch + migration grants reach the budget), yet demand
    # fetches stay bit-equal and the migration class still moves pages.
    wit_on = _run(scheds, "block", MIG, budget=WITNESS_BUDGET,
                  near=EQ_DELAY, far=EQ_DELAY)[1]
    wit_off = _run(scheds, "block", None, budget=WITNESS_BUDGET,
                   near=EQ_DELAY, far=EQ_DELAY)[1]
    wit_migs = int(np.asarray(wit_on["migrated"]).sum())
    per_nic = (np.asarray(wit_on["shard_demand_fetches"])
               + np.asarray(wit_on["pf_on_shard"])
               + np.asarray(wit_on["mig_on_shard"]))
    derived["demand_bit_equal_on_off"] = bool(
        (np.asarray(wit_on["fetched"])
         == np.asarray(wit_off["fetched"])).all() and wit_migs > 0)
    derived["witness_migrations"] = wit_migs
    derived["witness_nic_saturated"] = bool(per_nic.max() >= WITNESS_BUDGET)

    # -- capacity sweep: compressed tier holds the hit rate ------------------
    for cap_frac, label in ((1, "uncapped"), (2, "half"), (4, "quarter")):
        cap = N_PAGES // cap_frac
        mig_c = MigrationCfg(mig_per_stream=2, lead=1, cooldown=16,
                             compressed=True, far_capacity=cap,
                             decompress_delay=2)
        st, info, _, _ = _run(scheds, "block", mig_c)
        a = _agg(st)
        acc[("compressed", label)] = a
        rows.append({"mode": f"compressed/{label}", "placement": "block",
                     "prefetch_hits": a["prefetch_hits"],
                     "partial_hits": a["partial_hits"],
                     "deferred": a["deferred"],
                     "hit_rate": round(a["hit_rate"], 3),
                     "timely_rate": round(a["timely_rate"], 3),
                     "migrations": int(np.asarray(info["migrated"]).sum()),
                     "demotions": int(np.asarray(info["demoted"]).sum()),
                     "promotions": int(np.asarray(info["promoted"]).sum())})
    base_hit = acc[("compressed", "uncapped")]["hit_rate"]
    derived["compressed_quarter_hit_rate"] = round(
        acc[("compressed", "quarter")]["hit_rate"], 3)
    derived["compressed_quarter_timely"] = round(
        acc[("compressed", "quarter")]["timely_rate"], 3)
    derived["compressed_holds_hit_rate"] = bool(
        acc[("compressed", "quarter")]["hit_rate"] >= 0.95 * base_hit)
    derived["demotions_at_quarter"] = int(
        sum(r.get("demotions", 0) for r in rows
            if r["mode"] == "compressed/quarter"))

    # -- §8 zero-divergence pin at benchmark scale ---------------------------
    geom = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES,
                            page_elems=PAGE_ELEMS, ring_size=16,
                            pw_max=PW_MAX)
    fab = ShardedPoolCfg(n_shards=N_SHARDS, placement="block",
                         link_budget=BUDGET, near_delay=NEAR, far_delay=FAR)
    derived["crossval_counts_match"] = _crossval(scheds, geom, fab, MIG)

    write_csv("migration", rows)
    return rows, derived
