"""Benchmark entry point: one module per paper figure + roofline.

``PYTHONPATH=src python -m benchmarks.run [--only fig2,...]``
Writes CSVs under results/bench/, prints tables + derived headline numbers
(the quantities EXPERIMENTS.md cites against the paper's claims).
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (datapath_overlap, fabric_scale, fig2_microbenchmark,
               fig3_patterns, fig8_slow_storage, fig9_10_prefetchers,
               fig11_apps, fig12_cache_size, fig13_multiapp, jax_stream,
               link_contention, roofline, sharded_pool, tiered_kv)
from .common import fmt_table

SUITES = {
    "fig2_7": fig2_microbenchmark.run,
    "fig3": fig3_patterns.run,
    "fig8": fig8_slow_storage.run,
    "fig9_10": fig9_10_prefetchers.run,
    "fig11": fig11_apps.run,
    "fig12": fig12_cache_size.run,
    "fig13": fig13_multiapp.run,
    "fabric_scale": fabric_scale.run,
    "jax_stream": jax_stream.run,
    "datapath_overlap": datapath_overlap.run,
    "link_contention": link_contention.run,
    "sharded_pool": sharded_pool.run,
    "tiered_kv": tiered_kv.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = []
    for name, fn in SUITES.items():
        if only and name not in only:
            continue
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.time()
        try:
            rows, derived = fn()
        except Exception as e:        # keep the suite running
            failures.append((name, repr(e)))
            print(f"FAILED: {e!r}")
            continue
        print(fmt_table(rows))
        if derived:
            print("\nderived:")
            for k, v in derived.items():
                print(f"  {k} = {v}")
        print(f"[{time.time() - t0:.1f}s]")

    if failures:
        print("\nFAILURES:", failures)
        sys.exit(1)
    print("\nall benchmark suites completed")


if __name__ == "__main__":
    main()
