"""Benchmark entry point: one module per paper figure + roofline.

``PYTHONPATH=src python -m benchmarks.run [--only fig2,...] [--json OUT.json]``
Writes CSVs under results/bench/, prints tables + derived headline numbers
(the quantities EXPERIMENTS.md cites against the paper's claims).
``--json`` additionally writes a ``repro-bench/v1`` document: per-suite rows,
derived metrics, wall time, plus git sha / smoke flag — the machine-readable
results CI archives and regression tooling diffs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from . import (chaos, datapath_overlap, fabric_scale, fig2_microbenchmark,
               fig3_patterns, fig8_slow_storage, fig9_10_prefetchers,
               fig11_apps, fig12_cache_size, fig13_multiapp, jax_stream,
               link_contention, migration, roofline, serving, sharded_pool,
               tiered_kv)
from .common import bench_json_doc, fmt_table, validate_bench_json

SUITES = {
    "fig2_7": fig2_microbenchmark.run,
    "fig3": fig3_patterns.run,
    "fig8": fig8_slow_storage.run,
    "fig9_10": fig9_10_prefetchers.run,
    "fig11": fig11_apps.run,
    "fig12": fig12_cache_size.run,
    "fig13": fig13_multiapp.run,
    "fabric_scale": fabric_scale.run,
    "jax_stream": jax_stream.run,
    "datapath_overlap": datapath_overlap.run,
    "link_contention": link_contention.run,
    "sharded_pool": sharded_pool.run,
    "chaos": chaos.run,
    "migration": migration.run,
    "tiered_kv": tiered_kv.run,
    "serving": serving.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="write machine-readable repro-bench/v1 results "
                         "(e.g. BENCH_main.json)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = []
    suite_docs = []
    for name, fn in SUITES.items():
        if only and name not in only:
            continue
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.time()
        try:
            rows, derived = fn()
        except Exception as e:        # keep the suite running
            failures.append((name, repr(e)))
            print(f"FAILED: {e!r}")
            continue
        wall = time.time() - t0
        suite_docs.append({"suite": name, "wall_s": round(wall, 3),
                           "rows": rows, "derived": derived or {}})
        print(fmt_table(rows))
        if derived:
            print("\nderived:")
            for k, v in derived.items():
                print(f"  {k} = {v}")
        print(f"[{wall:.1f}s]")

    if args.json:
        tag = os.path.splitext(os.path.basename(args.json))[0]
        if tag.startswith("BENCH_"):
            tag = tag[len("BENCH_"):]
        doc = bench_json_doc(tag, suite_docs, failures)
        errs = validate_bench_json(doc)
        if errs:            # a suite returned malformed rows/derived
            print("\nBENCH JSON INVALID:", errs)
            sys.exit(1)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"\nwrote {args.json} ({len(suite_docs)} suites)")

    if failures:
        print("\nFAILURES:", failures)
        sys.exit(1)
    print("\nall benchmark suites completed")


if __name__ == "__main__":
    main()
