"""Paper Fig. 2 + Fig. 7: 4KB page access latency, Sequential vs Stride-10,
across (disk | rdma) x (default block path + read-ahead | Leap lean path).

Reproduces the headline claims: read-ahead serves Sequential well but
collapses on Stride-10 (every access misses); Leap's detector makes Stride
behave like Sequential, and the lean data path pulls the medians down to
fabric latency. Reported: p50/p99 per cell + the paper's improvement ratios.
"""

from __future__ import annotations

from repro.core import traces
from repro.core.cache import PageCache
from repro.core.prefetcher import make_prefetcher
from repro.core.simulator import simulate

from .common import sized, write_csv

N = sized(20000, 400)


def run() -> tuple[list[dict], dict]:
    rows = []
    latency = {}
    for pattern, tr in (("sequential", traces.sequential(N)),
                        ("stride10", traces.stride(N, 10))):
        for medium in ("rdma", "disk"):
            cells = {
                "default": (make_prefetcher("read_ahead"),
                            PageCache(256, eviction="lru"), f"{medium}_block"),
                "leap": (make_prefetcher("leap"),
                         PageCache(256, eviction="eager"), f"{medium}_lean"),
            }
            for path, (pf, cache, model) in cells.items():
                # ~3us of app compute per page access: prefetched pages can
                # arrive ahead of consumption (the paper's timeliness axis).
                r = simulate(tr, pf, cache, model=model, think_time=3.0)
                p = r.stats.latency_percentiles()
                rows.append({"pattern": pattern, "medium": medium,
                             "path": path, "p50_us": round(p["p50"], 2),
                             "p99_us": round(p["p99"], 2),
                             "avg_us": round(p["avg"], 2),
                             "hit_rate": round(r.stats.hit_rate, 3)})
                latency[(pattern, medium, path)] = p
    derived = {
        "stride_rdma_p50_improvement":
            round(latency[("stride10", "rdma", "default")]["p50"]
                  / latency[("stride10", "rdma", "leap")]["p50"], 1),
        "stride_rdma_p99_improvement":
            round(latency[("stride10", "rdma", "default")]["p99"]
                  / latency[("stride10", "rdma", "leap")]["p99"], 1),
        "seq_rdma_p50_improvement":
            round(latency[("sequential", "rdma", "default")]["p50"]
                  / latency[("sequential", "rdma", "leap")]["p50"], 1),
        "seq_rdma_p99_improvement":
            round(latency[("sequential", "rdma", "default")]["p99"]
                  / latency[("sequential", "rdma", "leap")]["p99"], 1),
    }
    write_csv("fig2_7_microbenchmark", rows)
    return rows, derived
