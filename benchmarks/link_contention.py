"""Shared-link contention: demand-first budget arbitration vs blocking batch.

The paper's §4.4/Fig. 13 concern, in-model: all fetches of all streams
serialize on one RDMA link, so an over-aggressive prefetcher "wastes I/O
bandwidth" and delays everyone's demand fetches. The budgeted jitted path
(``multi_stream_consume(..., link_budget=B)``, DESIGN.md §5) arbitrates a
per-step page budget across streams with demand fetches strictly first —
surplus prefetches arrive late (``deferred``) instead of sitting in front
of a faulting consumer.

The sweep crosses streams x link budget x data path and prices each
access's demand latency with the ``rdma_lean`` model, where a step's
priority traffic needs ``q`` link rounds of ``B`` pages:

* **sync** (read-ahead-style baseline): every issued candidate rides the
  blocking batch, so ``q = ceil((demands + prefetches) / B)`` — prefetch
  volume multiplies every consumer's queueing, and even a hit costs the
  full batch when the stream issued candidates alongside it.
* **async + budget** (demand-first): prefetches only ever get leftover
  budget, so ``q = ceil(demands / B)``; full hits cost ``t_hit`` and
  partial hits the expected residual of the in-flight transfer.

Headline: demand latency on the demand-first path stays strictly below
the read-ahead-style baseline at every finite budget and degrades
gracefully as the budget shrinks, while the baseline collapses (its
prefetch traffic sits in front of every demand). A derived row
cross-checks the jitted per-stream counts against the lock-step fabric
reference (``repro.fabric.run_linkstep``) at the tightest budget.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.simulator import LATENCY_MODELS
from repro.fabric.linkstep import run_linkstep
from repro.paging.prefetch_serving import (PrefetchedStream,
                                           multi_stream_consume,
                                           stream_stats_at)

from .common import sized, write_csv

N_PAGES = sized(512, 64)
N_SLOTS = N_PAGES                      # eviction-free: match the linkstep twin
PAGE_ELEMS = sized(32, 4)
T = sized(240, 40)
N_STREAMS = sized(4, 2)
BUDGETS = sized((None, 8, 4, 2, 1), (None, 2))
MODEL = LATENCY_MODELS["rdma_lean"]
_INF_BUDGET = 1 << 20                  # "infinite": bit-equivalent to None


def _schedules(n_streams: int) -> np.ndarray:
    """Mixed per-stream patterns: trend-friendly strides + one random."""
    rng = np.random.default_rng(0)
    rows = []
    for s in range(n_streams):
        if s == n_streams - 1 and n_streams > 1:
            rows.append(rng.integers(0, N_PAGES, T))
        else:
            rows.append((np.arange(T) * (s + 1) + 37 * s) % N_PAGES)
    return np.stack(rows).astype(np.int32)


def _rounds(pages_per_step: np.ndarray, budget: int | None) -> np.ndarray:
    """Link rounds needed to move ``pages_per_step`` at ``budget`` pages/round."""
    if budget is None:
        return (pages_per_step > 0).astype(np.float64)
    return np.ceil(pages_per_step / budget)


def _mean_access_us(info: dict, budget: int | None, sync: bool) -> float:
    """Model-priced mean per-access demand latency (critical-path bytes)."""
    fetched = np.asarray(info["fetched"])              # [S, T]
    partial = np.asarray(info["partial_hit"])
    issued = np.asarray(info["issued"])
    d_t = fetched.sum(0).astype(np.float64)            # [T]
    p_t = issued.sum(0).astype(np.float64)
    if sync:
        # prefetches ride the blocking batch: they queue in front of demands,
        # and a stream that issued candidates blocks on the batch even on a hit
        q = _rounds(d_t + p_t, budget)[None]
        lat = np.where(fetched | (issued > 0), q * MODEL.t_fabric, MODEL.t_hit)
    else:
        # demand-first: only demand traffic queues; a partial hit pays the
        # expected residual of its in-flight transfer at the queue's rate
        q = _rounds(d_t, budget)[None]
        lat = np.where(partial, MODEL.t_hit + 0.5 * MODEL.t_fabric * q,
                       np.where(fetched, q * MODEL.t_fabric, MODEL.t_hit))
    return float(lat.mean())


def _agg(st) -> dict:
    """Aggregate per-stream pool counters of a stacked multi-stream state."""
    per = [stream_stats_at(st, i) for i in range(st["hot"].shape[0])]
    keys = ("hits", "misses", "prefetch_hits", "partial_hits", "deferred",
            "pollution", "ring_drops", "prefetch_issued")
    out = {k: sum(p[k] for p in per) for k in keys}
    out["coverage"] = (out["prefetch_hits"]
                       / max(1, out["hits"] + out["misses"]))
    return out


def _crossval(scheds: np.ndarray, geom: PrefetchedStream, budget: int) -> bool:
    """Jitted per-stream counts == lock-step fabric reference counts?"""
    st, _, _ = multi_stream_consume(
        jnp.zeros((N_PAGES, PAGE_ELEMS), jnp.float32), jnp.asarray(scheds),
        geom, async_datapath=True, link_budget=budget)
    rep = run_linkstep(scheds, N_PAGES, budget, ring_size=geom.ring_size,
                       arrival_delay=geom.arrival_delay, pw_max=geom.pw_max,
                       h_size=geom.h_size, n_split=geom.n_split)
    for i in range(len(scheds)):
        j = stream_stats_at(st, i)
        r = rep.stream_summary(i)
        if any(j[k] != r[k] for k in r):
            return False
    return True


def run() -> tuple[list[dict], dict]:
    pool = jnp.arange(N_PAGES * PAGE_ELEMS,
                      dtype=jnp.float32).reshape(N_PAGES, PAGE_ELEMS)
    scheds = _schedules(N_STREAMS)
    geom = PrefetchedStream(n_pages=N_PAGES, n_slots=N_SLOTS,
                            page_elems=PAGE_ELEMS, ring_size=8)
    rows, derived = [], {}
    stall = {}
    for budget in BUDGETS:
        for path in ("sync", "async"):
            if path == "sync":
                st, _, info = multi_stream_consume(
                    pool, jnp.asarray(scheds), geom, async_datapath=False,
                    link_budget=budget if budget is not None else _INF_BUDGET)
            else:
                st, _, info = multi_stream_consume(
                    pool, jnp.asarray(scheds), geom, async_datapath=True,
                    link_budget=budget if budget is not None else _INF_BUDGET)
            a = _agg(st)
            us = _mean_access_us(info, budget, sync=(path == "sync"))
            stall[(path, budget)] = us
            rows.append({
                "streams": N_STREAMS, "budget": budget or "inf", "path": path,
                "coverage": round(a["coverage"], 3),
                "partial_hits": a["partial_hits"],
                "deferred": a["deferred"],
                "ring_drops": a["ring_drops"],
                "pollution": a["pollution"],
                "demand_us_per_access": round(us, 2),
            })

    # headline: demand-first degrades gracefully — its *added* latency under
    # contention (vs its own uncontended baseline) stays below the blocking
    # batch's, and its absolute latency wins at every finite budget
    tight = min(b for b in BUDGETS if b is not None)
    added_sync = stall[("sync", tight)] - stall[("sync", None)]
    added_async = stall[("async", tight)] - stall[("async", None)]
    derived["tight_budget"] = tight
    derived["sync_added_us_at_tight"] = round(added_sync, 2)
    derived["async_added_us_at_tight"] = round(added_async, 2)
    derived["demand_first_graceful"] = bool(added_async < added_sync)
    derived["async_beats_sync_at_every_budget"] = bool(all(
        stall[("async", b)] < stall[("sync", b)]
        for b in BUDGETS if b is not None))
    derived["crossval_counts_match"] = _crossval(scheds, geom, tight)
    write_csv("link_contention", rows)
    return rows, derived
