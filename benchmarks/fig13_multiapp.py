"""Paper Fig. 13: four applications accessing remote memory concurrently.

Leap isolates each application's access stream (per-process tracker §4.1);
the baseline funnels all faults through one shared detector + shared cache.
We interleave the four app traces round-robin and compare per-app completion
under (a) one shared read-ahead detector (Linux swap behavior) and (b)
per-stream Leap detectors with isolated caches.
"""

from __future__ import annotations

import numpy as np

from repro.core import traces
from repro.core.cache import PageCache
from repro.core.prefetcher import make_prefetcher
from repro.core.simulator import simulate

from .common import write_csv

APPS = ("powergraph", "numpy", "voltdb", "memcached")


def run() -> tuple[list[dict], dict]:
    n = 6000
    app_traces = {a: traces.TRACES[a](n=n) for a in APPS}
    # offset each app's pages so they share one swap space w/o colliding
    shared = np.empty(n * 4, dtype=np.int64)
    for i, a in enumerate(APPS):
        shared[i::4] = app_traces[a] + (i << 40)

    base = simulate(shared, make_prefetcher("read_ahead"),
                    PageCache(512, eviction="lru"), "rdma_block")
    base_per_fault = base.total_time / len(shared)

    rows, derived = [], {}
    for a in APPS:
        iso = simulate(app_traces[a], make_prefetcher("leap"),
                       PageCache(128, eviction="eager"), "rdma_lean")
        sp = (base_per_fault * len(app_traces[a])) / iso.total_time
        rows.append({"app": a,
                     "shared_default_ms": round(
                         base_per_fault * n / 1e3, 1),
                     "leap_isolated_ms": round(iso.total_time / 1e3, 1),
                     "speedup": round(sp, 2),
                     "coverage": round(iso.stats.coverage, 3)})
        derived[f"{a}_multiapp_speedup"] = round(sp, 2)
    write_csv("fig13_multiapp", rows)
    return rows, derived
