"""Paper Fig. 13: four applications accessing remote memory concurrently.

Runs on the multi-tenant fabric engine (``repro.fabric``): the four app
traces execute as *concurrent tenants* contending for one fabric link,
instead of the old round-robin interleave through the sequential
simulator.

* **Baseline** — the stock shared data path: one communal read-ahead
  detector + one LRU swap cache + a shared-FIFO link, default block
  layer (``rdma_block``). One app's prefetch burst head-of-line blocks
  every other app's demand fetches.
* **Leap** — per-application isolated trackers + eager caches (§4.1)
  over per-tenant async queue pairs (§4.4) on the lean data path.

Reported per app: completion time, p50/p99 fault latency, speedup, and
coverage — the paper's Fig. 13 direction is Leap winning on *both*
completion time and tail latency for every app.
"""

from __future__ import annotations

from repro.core import traces
from repro.fabric import FabricScenario, TenantSpec, run_fabric

from .common import sized, write_csv

APPS = ("powergraph", "numpy", "voltdb", "memcached")


def _specs(n: int) -> list[TenantSpec]:
    # offset each app's pages so the shared baseline's communal cache
    # sees one swap space without page-id collisions
    return [TenantSpec(a, traces.TRACES[a](n=n) + (i << 40),
                       policy="leap", cache_capacity=128, eviction="eager",
                       model="rdma_lean")
            for i, a in enumerate(APPS)]


def run() -> tuple[list[dict], dict]:
    n = sized(6000, 300)
    shared = run_fabric(FabricScenario(
        _specs(n), data_path="shared", shared_policy="read_ahead",
        shared_cache_capacity=512, shared_eviction="lru",
        shared_model="rdma_block"))
    leap = run_fabric(FabricScenario(_specs(n), data_path="isolated",
                                     arbitration="per_tenant_qp"))

    rows, derived = [], {}
    for a in APPS:
        b, lp = shared.tenant(a), leap.tenant(a)
        sp = b.completion_time / lp.completion_time
        rows.append({"app": a,
                     "shared_default_ms": round(b.completion_time / 1e3, 1),
                     "leap_isolated_ms": round(lp.completion_time / 1e3, 1),
                     "speedup": round(sp, 2),
                     "shared_p99_us": round(b.latency["p99"], 1),
                     "leap_p99_us": round(lp.latency["p99"], 1),
                     "coverage": round(lp.coverage, 3)})
        derived[f"{a}_multiapp_speedup"] = round(sp, 2)
    derived["shared_fairness"] = round(shared.fairness, 3)
    derived["leap_fairness"] = round(leap.fairness, 3)
    derived["link_util_shared"] = round(
        shared.link_stats["rdma"]["utilization"], 3)
    write_csv("fig13_multiapp", rows)
    return rows, derived
