"""Tiered paged-KV decode: does the async sweep take prefetch DMA off-step?

The serving-side claim of DESIGN.md §6: with decode attention fed from the
Leap-managed hot pool, the *sync* tiered sweep fetches every prefetch
candidate inside the chunk step that issued it (blocking the sweep), while
the *async* issue/wait sweep lands candidates during the next chunk step —
same controller, same demand schedule, so the hit rates match and the
difference is what sits on the sweep's critical path:

* sync:  demand misses AND every issued candidate (blocking batch);
* async: demand misses, plus the residual transfer of partial hits.

The consume-latency column prices those critical-path pages with the
``rdma_lean`` model (as ``datapath_overlap``). The sweep crosses
hot-fraction {small, full} x {sync, async} over several decode steps
(steady-state re-sweeps after the cold first step), checks the tiered/flat
bit-equivalence pin on every configuration, and reports the headline
"async tiered decode strictly faster than sync tiered at equal hit rate".
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import LATENCY_MODELS
from repro.paging.kv_cache import linear_page_table, paged_decode_attention
from repro.paging.tiered_kv import (TieredKV, tiered_attention, tiered_init,
                                    tiered_min_slots, tiered_stats,
                                    tiered_sweep)

from .common import sized, write_csv

B, PS, HKV, HQ, DH = 2, 4, 2, 4, 8
NPPS = sized(24, 6)
DECODE_STEPS = sized(4, 2)
N_PAGES = B * NPPS
MODEL = LATENCY_MODELS["rdma_lean"]


def _consume_us_per_access(s: dict, sync: bool) -> float:
    full_hits = s["hits"] - s["partial_hits"]
    blocking = s["misses"] + (s["prefetch_issued"] if sync else 0)
    us = (full_hits * MODEL.t_hit
          + s["partial_hits"] * (MODEL.t_hit + 0.5 * MODEL.t_fabric)
          + blocking * MODEL.t_fabric)
    return us / max(s["faults"], 1)


def _run_one(cold, pt, q, lengths, flat, geom, async_dp):
    st = tiered_init(geom, B, jnp.float32)
    equiv = True
    dt = 0.0
    for _ in range(DECODE_STEPS):
        # time only the serving path; the pin check runs off the clock
        t0 = time.perf_counter()
        st, info = tiered_sweep(st, cold, pt, geom, async_datapath=async_dp)
        out, resident = tiered_attention(q, st, pt, lengths)
        jax.block_until_ready(out)
        dt += time.perf_counter() - t0
        equiv &= bool(resident) and bool(
            (np.asarray(out) == np.asarray(flat)).all())
    agg: dict = {}
    for s in (tiered_stats(st, i) for i in range(B)):
        for k, v in s.items():
            agg[k] = agg.get(k, 0) + (v if isinstance(v, int) else 0)
    return agg, equiv, dt


def run() -> tuple[list[dict], dict]:
    cold = {"k": jax.random.normal(jax.random.PRNGKey(0),
                                   (N_PAGES, PS, HKV, DH), jnp.float32),
            "v": jax.random.normal(jax.random.PRNGKey(1),
                                   (N_PAGES, PS, HKV, DH), jnp.float32)}
    pt = linear_page_table(B, NPPS)
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, HQ, DH), jnp.float32)
    lengths = jnp.full((B,), NPPS * PS - 3, jnp.int32)
    flat = paged_decode_attention(
        q, {"k": cold["k"][None], "v": cold["v"][None]}, jnp.int32(0), pt,
        lengths)

    rows, derived, consume, hitrate = [], {}, {}, {}
    small = tiered_min_slots(NPPS, TieredKV(N_PAGES, 1, PS, HKV, DH,
                                            chunk=2, pw_max=4))
    for hot_name, n_slots in (("small", small), ("full", N_PAGES)):
        for path, async_dp in (("sync", False), ("async", True)):
            geom = TieredKV(N_PAGES, n_slots, PS, HKV, DH, chunk=2,
                            pw_max=4, ring_size=8)
            s, equiv, dt = _run_one(cold, pt, q, lengths, flat, geom,
                                    async_dp)
            c = _consume_us_per_access(s, sync=not async_dp)
            consume[(hot_name, path)] = c
            hitrate[(hot_name, path)] = s["hits"] / max(s["faults"], 1)
            rows.append({
                "hot": hot_name, "path": path,
                "hot_frac": round(B * n_slots / N_PAGES, 2),
                "hit_rate": round(hitrate[(hot_name, path)], 3),
                "prefetch_hits": s["prefetch_hits"],
                "partial_hits": s["partial_hits"],
                "pollution": s["pollution"],
                "bit_identical": equiv,
                "consume_us_per_access": round(c, 2),
                "wall_ms_per_decode_step": round(1e3 * dt / DECODE_STEPS, 1),
            })

    for hot_name in ("small", "full"):
        sync_c, async_c = consume[(hot_name, "sync")], consume[(hot_name,
                                                                "async")]
        derived[f"{hot_name}_hit_rate_sync"] = round(
            hitrate[(hot_name, "sync")], 3)
        derived[f"{hot_name}_hit_rate_async"] = round(
            hitrate[(hot_name, "async")], 3)
        derived[f"{hot_name}_consume_sync_us"] = round(sync_c, 2)
        derived[f"{hot_name}_consume_async_us"] = round(async_c, 2)
        derived[f"{hot_name}_async_speedup"] = round(sync_c / async_c, 2)
        derived[f"{hot_name}_async_strictly_faster"] = bool(async_c < sync_c)
    derived["all_bit_identical"] = all(r["bit_identical"] for r in rows)
    write_csv("tiered_kv", rows)
    return rows, derived
