"""Tiered paged-KV decode: sweep overlap + the fused attention consumer.

Two suites over the DESIGN.md §6 serving path:

**Sweep overlap** (rows with a ``path`` column): with decode attention fed
from the Leap-managed hot pool, the *sync* tiered sweep fetches every
prefetch candidate inside the chunk step that issued it (blocking the
sweep), while the *async* issue/wait sweep lands candidates during the
next chunk step — same controller, same demand schedule, so the hit rates
match and the difference is what sits on the sweep's critical path:

* sync:  demand misses AND every issued candidate (blocking batch);
* async: demand misses, plus the residual transfer of partial hits.

The consume-latency column prices those critical-path pages with the
``rdma_lean`` model (as ``datapath_overlap``), crossed over hot-fraction
{small, full} x {sync, async}, with the tiered/flat bit-equivalence pin
checked on every configuration.

**Fused consumer** (rows with an ``attn`` column): prices the attention
consumer itself — the unfused stacked path re-materializes the whole
``[S, n_slots, ...] -> [S*n_slots, ...]`` hot pool (k and v, read+write)
every decode step before the flat kernel reads the context, while the
fused ``paged_attention_hot_slots`` kernel reads the hot slots in place
through the slot table, moving only the context pages. Per point
(hot-fraction x S x npps) the suite reports the analytic per-step
bytes-moved for each path, the time those bytes cost at the HBM roofline
(``benchmarks.roofline.HBM_BW`` — wall-clock on the CPU interpret path is
reported but not asserted), the fusion-blind jaxpr bytes
(``flop_count.count_fn``), and a jaxpr structure check that the
``[S*n_slots, ...]`` stacked reshape exists on the unfused trace and is
**absent** on the fused one. Both consumers are pinned bit-identical to
the flat-pool kernel on every point.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import LATENCY_MODELS
from repro.paging.kv_cache import linear_page_table, paged_decode_attention
from repro.paging.tiered_kv import (TieredKV, tiered_attention, tiered_init,
                                    tiered_min_slots, tiered_stats,
                                    tiered_sweep)

from .common import sized, write_csv
from .flop_count import count_fn
from .roofline import HBM_BW

B, PS, HKV, HQ, DH = 2, 4, 2, 4, 8
NPPS = sized(24, 6)
DECODE_STEPS = sized(4, 2)
N_PAGES = B * NPPS
MODEL = LATENCY_MODELS["rdma_lean"]

# fused-consumer sweep axes (engine-default sweep geometry: chunk=4,
# pw_max=8, ring=8 — the npps=8/12 points are the small-context serving
# shape where the stacked copy dominates hardest)
FUSED_NPPS = sized((8, 12, 24), (6,))
FUSED_S = sized((2, 4), (2,))
FUSED_REPS = sized(5, 2)


def _consume_us_per_access(s: dict, sync: bool) -> float:
    full_hits = s["hits"] - s["partial_hits"]
    blocking = s["misses"] + (s["prefetch_issued"] if sync else 0)
    us = (full_hits * MODEL.t_hit
          + s["partial_hits"] * (MODEL.t_hit + 0.5 * MODEL.t_fabric)
          + blocking * MODEL.t_fabric)
    return us / max(s["faults"], 1)


def _run_one(cold, pt, q, lengths, flat, geom, async_dp):
    st = tiered_init(geom, B, jnp.float32)
    equiv = True
    dt = 0.0
    for _ in range(DECODE_STEPS):
        # time only the serving path; the pin check runs off the clock
        t0 = time.perf_counter()
        st, info = tiered_sweep(st, cold, pt, geom, async_datapath=async_dp)
        out, resident = tiered_attention(q, st, pt, lengths)
        jax.block_until_ready(out)
        dt += time.perf_counter() - t0
        equiv &= bool(resident) and bool(
            (np.asarray(out) == np.asarray(flat)).all())
    agg: dict = {}
    for s in (tiered_stats(st, i) for i in range(B)):
        for k, v in s.items():
            agg[k] = agg.get(k, 0) + (v if isinstance(v, int) else 0)
    return agg, equiv, dt


def _has_stacked_reshape(jaxpr, stacked_dim: int) -> bool:
    """Recursively scan a jaxpr (through pjit/scan/cond sub-jaxprs) for a
    reshape whose output is a pool-like ``[stacked_dim, ...]`` array —
    the stacked hot-pool materialization signature."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "reshape":
            shp = eqn.outvars[0].aval.shape
            if len(shp) >= 3 and shp[0] == stacked_dim:
                return True
        for p in eqn.params.values():
            sub = getattr(p, "jaxpr", None)
            if sub is not None and _has_stacked_reshape(sub, stacked_dim):
                return True
            if isinstance(p, (list, tuple)):
                for b in p:
                    sub = getattr(b, "jaxpr", None)
                    if sub is not None and _has_stacked_reshape(sub,
                                                                stacked_dim):
                        return True
    return False


def _time_consumer(fn, q, reps: int) -> float:
    jax.block_until_ready(fn(q))                     # compile off the clock
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(q)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _fused_point(hot_name: str, S: int, npps: int) -> dict:
    """One fused-vs-unfused point: sweep once to residency, then price the
    two attention consumers on the same hot state."""
    geom0 = TieredKV(1 << 30, 1, PS, HKV, DH)        # engine-default knobs
    floor = tiered_min_slots(npps, geom0)
    n_pages = 2 * S * npps + 2 * floor               # headroom: small < full
    n_slots = floor if hot_name == "small" else n_pages
    geom = TieredKV(n_pages, n_slots, PS, HKV, DH)
    cold = {"k": jax.random.normal(jax.random.PRNGKey(0),
                                   (n_pages, PS, HKV, DH), jnp.float32),
            "v": jax.random.normal(jax.random.PRNGKey(1),
                                   (n_pages, PS, HKV, DH), jnp.float32)}
    pt = linear_page_table(S, npps)
    q = jax.random.normal(jax.random.PRNGKey(2), (S, 1, HQ, DH), jnp.float32)
    lengths = jnp.full((S,), npps * PS - 3, jnp.int32)
    st = tiered_init(geom, S, jnp.float32)
    st, _ = tiered_sweep(st, cold, pt, geom)

    flat = paged_decode_attention(
        q, {"k": cold["k"][None], "v": cold["v"][None]}, jnp.int32(0), pt,
        lengths, use_kernel=True)
    unfused = lambda qq: tiered_attention(qq, st, pt, lengths,
                                          attn_kernel="kernel")[0]
    fused = lambda qq: tiered_attention(qq, st, pt, lengths,
                                        attn_kernel="fused")[0]
    bit_ok = all(bool((np.asarray(f(q)) == np.asarray(flat)).all())
                 for f in (unfused, fused))

    # analytic per-step bytes at the consumer: the unfused path pays the
    # stacked k+v hot-pool copy (read + write) before the context read;
    # the fused path reads only the context pages through the slot table
    pb = PS * HKV * DH * 4                           # bytes per f32 page
    ctx = 2 * S * npps * pb                          # k+v context read
    stack = 4 * S * n_slots * pb                     # k+v copy, rd+wr
    unf_us = (stack + ctx) / HBM_BW * 1e6
    fus_us = ctx / HBM_BW * 1e6

    return {
        "attn": "fused_vs_unfused", "hot": hot_name, "S": S, "npps": npps,
        "n_slots": n_slots,
        "hot_frac": round(S * n_slots / n_pages, 2),
        "bit_identical": bit_ok,
        "unfused_bytes_per_step": stack + ctx,
        "fused_bytes_per_step": ctx,
        "bytes_saved": stack,
        "hot_pool_bytes": 2 * S * n_slots * pb,      # k+v payload
        "unfused_roofline_us": round(unf_us, 3),
        "fused_roofline_us": round(fus_us, 3),
        "roofline_speedup": round(unf_us / fus_us, 2),
        "unfused_jaxpr_bytes": int(count_fn(unfused, q)["bytes"]),
        "fused_jaxpr_bytes": int(count_fn(fused, q)["bytes"]),
        "stacked_reshape_unfused": _has_stacked_reshape(
            jax.make_jaxpr(unfused)(q).jaxpr, S * n_slots),
        "stacked_reshape_fused": _has_stacked_reshape(
            jax.make_jaxpr(fused)(q).jaxpr, S * n_slots),
        "unfused_wall_us": round(1e6 * _time_consumer(unfused, q,
                                                      FUSED_REPS), 1),
        "fused_wall_us": round(1e6 * _time_consumer(fused, q,
                                                    FUSED_REPS), 1),
    }


def run() -> tuple[list[dict], dict]:
    cold = {"k": jax.random.normal(jax.random.PRNGKey(0),
                                   (N_PAGES, PS, HKV, DH), jnp.float32),
            "v": jax.random.normal(jax.random.PRNGKey(1),
                                   (N_PAGES, PS, HKV, DH), jnp.float32)}
    pt = linear_page_table(B, NPPS)
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, HQ, DH), jnp.float32)
    lengths = jnp.full((B,), NPPS * PS - 3, jnp.int32)
    flat = paged_decode_attention(
        q, {"k": cold["k"][None], "v": cold["v"][None]}, jnp.int32(0), pt,
        lengths)

    rows, derived, consume, hitrate = [], {}, {}, {}
    small = tiered_min_slots(NPPS, TieredKV(N_PAGES, 1, PS, HKV, DH,
                                            chunk=2, pw_max=4))
    for hot_name, n_slots in (("small", small), ("full", N_PAGES)):
        for path, async_dp in (("sync", False), ("async", True)):
            geom = TieredKV(N_PAGES, n_slots, PS, HKV, DH, chunk=2,
                            pw_max=4, ring_size=8)
            s, equiv, dt = _run_one(cold, pt, q, lengths, flat, geom,
                                    async_dp)
            c = _consume_us_per_access(s, sync=not async_dp)
            consume[(hot_name, path)] = c
            hitrate[(hot_name, path)] = s["hits"] / max(s["faults"], 1)
            rows.append({
                "hot": hot_name, "path": path,
                "hot_frac": round(B * n_slots / N_PAGES, 2),
                "hit_rate": round(hitrate[(hot_name, path)], 3),
                "prefetch_hits": s["prefetch_hits"],
                "partial_hits": s["partial_hits"],
                "pollution": s["pollution"],
                "bit_identical": equiv,
                "consume_us_per_access": round(c, 2),
                "wall_ms_per_decode_step": round(1e3 * dt / DECODE_STEPS, 1),
            })

    for hot_name in ("small", "full"):
        sync_c, async_c = consume[(hot_name, "sync")], consume[(hot_name,
                                                                "async")]
        derived[f"{hot_name}_hit_rate_sync"] = round(
            hitrate[(hot_name, "sync")], 3)
        derived[f"{hot_name}_hit_rate_async"] = round(
            hitrate[(hot_name, "async")], 3)
        derived[f"{hot_name}_consume_sync_us"] = round(sync_c, 2)
        derived[f"{hot_name}_consume_async_us"] = round(async_c, 2)
        derived[f"{hot_name}_async_speedup"] = round(sync_c / async_c, 2)
        derived[f"{hot_name}_async_strictly_faster"] = bool(async_c < sync_c)
    # -- fused attention consumer: hot-fraction x S x npps ------------------
    fused_rows = [_fused_point(hot_name, S, npps)
                  for hot_name in ("small", "full")
                  for S in FUSED_S
                  for npps in FUSED_NPPS]
    rows.extend(fused_rows)
    small_rows = [r for r in fused_rows if r["hot"] == "small"]
    derived["fused_strictly_faster_all_points"] = all(
        r["fused_roofline_us"] < r["unfused_roofline_us"]
        and r["fused_jaxpr_bytes"] < r["unfused_jaxpr_bytes"]
        for r in fused_rows)
    derived["fused_speedup_small_min"] = min(
        r["roofline_speedup"] for r in small_rows)
    # headline: >=5x on the small-context serving shape (the configuration
    # the stacked copy hurt most)
    derived["fused_speedup_small_max"] = max(
        r["roofline_speedup"] for r in small_rows)
    derived["fused_speedup_max"] = max(
        r["roofline_speedup"] for r in fused_rows)
    # bytes saved per step == the stacked k+v hot-pool copy (read + write),
    # i.e. exactly 2x the hot-pool payload the unfused path re-materializes
    derived["fused_bytes_saved_over_hot_pool"] = round(
        float(np.mean([r["bytes_saved"] / r["hot_pool_bytes"]
                       for r in fused_rows])), 2)
    derived["fused_stacked_reshape_gone"] = all(
        r["stacked_reshape_unfused"] and not r["stacked_reshape_fused"]
        for r in fused_rows)
    derived["all_bit_identical"] = all(r["bit_identical"] for r in rows)
    write_csv("tiered_kv", rows[:len(rows) - len(fused_rows)])
    write_csv("tiered_kv_fused", fused_rows)
    return rows, derived
