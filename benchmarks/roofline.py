"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Reads ``results/dryrun/<arch>__<shape>__<mesh>.json`` and derives, per cell:

  compute_term    = flops_per_chip / PEAK_FLOPS            [s]
  memory_term     = hbm_bytes_per_chip / HBM_BW            [s]
  collective_term = sum_op w_op * bytes_op / ICI_BW        [s]

All inputs are *per-chip* quantities (the compiled module is the SPMD
per-device program): ``cost_analysis()['flops'/'bytes accessed']`` and the
collective output bytes parsed from the partitioned HLO. Conventions:

* v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (one
  link-worth as the conservative per-chip collective bandwidth).
* per-type weights w_op: all-reduce 2.0 (ring: reduce-scatter+all-gather
  pass ~2x the payload over a link), all-gather/all-to-all/
  collective-permute 1.0, reduce-scatter 1.0.
* CPU-lowering caveat: XLA CPU upcasts bf16 compute to f32, so
  'bytes accessed' over-counts bf16 traffic by up to 2x. We report the raw
  value and a bf16-corrected memory term (x0.5) — the truth lies between.
* MODEL_FLOPS = 6 N_active D (train) / 2 N_active tokens (inference) per
  the brief; the ratio MODEL_FLOPS / HLO_FLOPS exposes remat/dispatch waste
  (full remat alone caps train at ~6/8 = 0.75).
"""

from __future__ import annotations

import glob
import json
import os

from repro import configs as cfglib

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (conservative single-link)
COLLECTIVE_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0,
                     "reduce-scatter": 1.0, "all-to-all": 1.0,
                     "collective-permute": 1.0}


def model_flops_per_chip(arch: str, shape: str, n_chips: int) -> float:
    cfg = cfglib.get_config(arch)
    sp = cfglib.SHAPES[shape]
    _, n_active = cfg.param_count()
    if sp.kind == "train":
        tokens = sp.global_batch * sp.seq_len
        total = 6.0 * n_active * tokens
    elif sp.kind == "prefill":
        tokens = sp.global_batch * sp.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * sp.global_batch
    return total / n_chips


def _jaxpr_stats(arch: str, shape: str) -> dict | None:
    path = os.path.join("results/jaxpr", f"{arch}__{shape}.json")
    if os.path.exists(path):
        st = json.load(open(path))
        if "flops" in st:
            return st
    return None


def analyze_record(rec: dict) -> dict | None:
    """One cell's roofline terms.

    FLOPs come from the loop-aware jaxpr counter (XLA cost_analysis counts
    while bodies once — verified; see flop_count.py). HLO bytes/collectives
    share that under-count, so both are rescaled by the per-cell factor
    jaxpr_flops / hlo_flops (boundary collectives like the final grad
    all-reduce get over-scaled by this — documented approximation; the raw
    unscaled value is reported alongside).
    """
    if "error" in rec or "skip" in rec:
        return None
    n = rec["n_chips"]
    js = _jaxpr_stats(rec["arch"], rec["shape"])
    if js:
        flops = js["flops"] / n                   # per chip, loop-aware
    else:                                          # fallback: HLO (undercounts)
        flops = rec["cost"]["flops"]
    coll = rec.get("collectives_loop_aware") or rec.get("collectives", {})
    coll_bytes = sum(COLLECTIVE_WEIGHT.get(op, 1.0) * d["bytes"]
                     for op, d in coll.items())
    # HBM traffic: loop-scaled per-op output bytes from the partitioned HLO;
    # x2 for reads ~ writes; /2 for the CPU bf16->f32 upcast artifact.
    hbm = rec.get("hbm_write_bytes", rec["cost"]["bytes_accessed"])
    compute = flops / PEAK_FLOPS
    memory = 2 * hbm / 2 / HBM_BW
    collective = coll_bytes / ICI_BW
    terms = {"compute": compute, "memory": memory,
             "collective": collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_chip(rec["arch"], rec["shape"], n)
    step_time = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "x".join(str(v) for v in rec["mesh"].values()),
        "kind": rec["kind"],
        "compute_s": f"{compute:.3e}",
        "memory_s": f"{memory:.3e}",
        "collective_s": f"{collective:.3e}",
        "dominant": dominant,
        "model_flops_ratio": round(mf / flops, 3) if flops else 0.0,
        "roofline_frac": round(compute / step_time, 3) if step_time else 0.0,
        "step_time_bound_s": f"{step_time:.3e}",
        "mem_gib": round((rec["memory"]["argument_bytes"]
                          + rec["memory"]["temp_bytes"]) / 2**30, 2),
    }


def run(dryrun_dir: str = "results/dryrun", mesh_tag: str = "pod1",
        ) -> tuple[list[dict], dict]:
    rows = []
    skips = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              f"*__{mesh_tag}.json"))):
        rec = json.load(open(path))
        if "skip" in rec:
            skips.append(f"{rec['arch']}/{rec['shape']}: {rec['skip']}")
            continue
        if "error" in rec:
            skips.append(f"{rec['arch']}/{rec['shape']}: ERROR {rec['error']}")
            continue
        row = analyze_record(rec)
        if row:
            rows.append(row)
    from .common import write_csv
    write_csv(f"roofline_{mesh_tag}", rows)
    derived = {"cells_analyzed": len(rows), "cells_skipped": len(skips)}
    # headline hillclimb candidates
    if rows:
        worst = min(rows, key=lambda r: r["roofline_frac"])
        coll = max(rows, key=lambda r: float(r["collective_s"]))
        derived["worst_roofline"] = f"{worst['arch']}/{worst['shape']}"
        derived["most_collective_bound"] = f"{coll['arch']}/{coll['shape']}"
    return rows, derived
