"""Loop-aware analytic FLOP/byte counter over jaxprs.

XLA's ``cost_analysis()`` counts a ``while`` body **once**, so any scanned
model (all of ours: period-scan trunks, chunked attention/SSM scans, CE
chunks) under-reports FLOPs by the trip count (verified: a 10-step
``lax.scan`` of matmuls reports 1/10th of the unrolled flops). This walker
traverses the *jaxpr*, where ``scan`` still carries its static ``length``,
and multiplies sub-jaxpr costs through — giving the true per-device step
FLOPs the roofline needs.

Counted: dot_general / conv (2*M*N*K-style), elementwise & reductions
(1 flop per output element; transcendentals weighted 1), gather/scatter as
data movement only. Bytes = sum over primitives of (inputs + outputs) —
fusion-blind, so an *upper* bound on HBM traffic (reported alongside the
compiled estimate; the roofline memory term uses HLO bytes rescaled by the
flops ratio — see benchmarks.roofline docstring).
"""

from __future__ import annotations

import math
from functools import reduce

import jax
import numpy as np

ELEMWISE_SKIP = {"broadcast_in_dim", "reshape", "transpose", "squeeze",
                 "convert_element_type", "slice", "dynamic_slice",
                 "dynamic_update_slice", "concatenate", "gather", "scatter",
                 "iota", "copy", "pad", "rev", "bitcast_convert_type",
                 "stop_gradient", "select_n", "split"}


def _nelems(aval) -> int:
    return int(np.prod(aval.shape)) if aval.shape else 1


def _nbytes(aval) -> int:
    try:
        return _nelems(aval) * aval.dtype.itemsize
    except Exception:
        return _nelems(aval) * 4


def _dot_flops(eqn) -> float:
    d = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = d
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = reduce(lambda x, y: x * y, (a.shape[i] for i in lb), 1)
    k = reduce(lambda x, y: x * y, (a.shape[i] for i in lc), 1)
    m = _nelems(a) // max(1, batch * k)
    n = _nelems(b) // max(1, batch * k)
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    groups = eqn.params.get("feature_group_count", 1)
    kernel_elems = _nelems(rhs) // max(1, rhs.shape[
        eqn.params["dimension_numbers"].rhs_spec[0]])
    return 2.0 * _nelems(out) * kernel_elems / max(1, groups)


def count_jaxpr(jaxpr) -> dict:
    """Returns {'flops', 'bytes', 'dot_flops'} for one (sub)jaxpr."""
    flops = dot_flops = byts = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            f = _dot_flops(eqn)
            flops += f
            dot_flops += f
        elif prim in ("conv_general_dilated",):
            f = _conv_flops(eqn)
            flops += f
            dot_flops += f
        elif prim == "scan":
            sub = count_jaxpr(eqn.params["jaxpr"].jaxpr)
            n = eqn.params["length"]
            flops += n * sub["flops"]
            dot_flops += n * sub["dot_flops"]
            byts += n * sub["bytes"]
            continue
        elif prim == "while":
            # bounded whiles only appear via lax loops we don't use in models;
            # count one iteration (conservative) if it shows up.
            sub = count_jaxpr(eqn.params["body_jaxpr"].jaxpr)
            flops += sub["flops"]
            dot_flops += sub["dot_flops"]
            byts += sub["bytes"]
        elif prim == "cond":
            subs = [count_jaxpr(b.jaxpr) for b in eqn.params["branches"]]
            worst = max(subs, key=lambda s: s["flops"])
            flops += worst["flops"]
            dot_flops += worst["dot_flops"]
            byts += worst["bytes"]
            continue
        elif "jaxpr" in eqn.params or "call_jaxpr" in eqn.params:
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            inner = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            sub = count_jaxpr(inner)
            flops += sub["flops"]
            dot_flops += sub["dot_flops"]
            byts += sub["bytes"]
            continue
        elif prim == "sort":
            n = _nelems(eqn.invars[0].aval)
            flops += n * max(1, int(math.log2(max(2, n))))
        elif prim not in ELEMWISE_SKIP:
            flops += sum(_nelems(v.aval) for v in eqn.outvars)
        byts += (sum(_nbytes(v.aval) for v in eqn.invars
                     if hasattr(v, "aval"))
                 + sum(_nbytes(v.aval) for v in eqn.outvars))
    return {"flops": flops, "bytes": byts, "dot_flops": dot_flops}


def count_fn(fn, *args) -> dict:
    """Trace ``fn`` abstractly (ShapeDtypeStruct-friendly) and count."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return count_jaxpr(jaxpr.jaxpr)
