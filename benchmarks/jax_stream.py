"""Beyond-paper: the jitted in-model Leap stream (TPU-side integration).

Measures the jittable controller+pool+gather path (repro.paging) on page
schedules mirroring the serving access patterns: sequential KV-page sweeps
(long-context chunked processing), strided sweeps (interleaved batch
layouts), cyclic expert routing, and uniform-random routing. Reports
prefetch hit rates / pollution (algorithmic — platform-independent) and
CPU wall time per step (indicative only).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pool import pool_stats
from repro.paging.prefetch_serving import PrefetchedStream, stream_consume

from .common import sized, write_csv

GEOM = PrefetchedStream(n_pages=512, n_slots=48, page_elems=64)


def _schedules():
    T = sized(400, 80)
    rng = np.random.default_rng(0)
    return {
        "kv_sequential_sweep": np.arange(T) % 512,
        "kv_strided_batch": (np.arange(T) * 4) % 512,
        "expert_cyclic": np.tile(np.arange(8), T // 8),
        "expert_random": rng.integers(0, 512, T),
        "phase_shift": np.concatenate([np.arange(T // 2) * 2,
                                       20000 - np.arange(T // 2) * 3]) % 512,
    }


def run() -> tuple[list[dict], dict]:
    pool = jnp.arange(512 * 64, dtype=jnp.float32).reshape(512, 64)
    rows, derived = [], {}
    for name, sched in _schedules().items():
        sched = jnp.asarray(sched, jnp.int32)
        st, sums, info = stream_consume(pool, sched, GEOM)   # compile
        t0 = time.perf_counter()
        st, sums, info = stream_consume(pool, sched, GEOM)
        jax.block_until_ready(sums)
        dt = time.perf_counter() - t0
        s = pool_stats(st["pool_meta"])
        warm = float(info["pref_hit"][len(sched) // 4:].mean())
        rows.append({"schedule": name,
                     "warm_prefetch_hit_rate": round(warm, 3),
                     "accuracy": round(s["accuracy"], 3),
                     "pollution": s["pollution"],
                     "issued": s["prefetch_issued"],
                     "us_per_access_cpu": round(1e6 * dt / len(sched), 1)})
        derived[f"{name}_hit"] = round(warm, 3)
    write_csv("jax_stream", rows)
    return rows, derived
