"""Sharded cold pool: placement x per-NIC budget under strided traffic.

The rack-scale question (DESIGN.md §7): once the cold pool is sharded over
``n_shards`` NICs with ``link_budget`` pages/step each, *where pages live*
decides how much of the fabric's aggregate bandwidth a workload can
actually use. The sweep drives S streams of strided traffic whose phases
start close together — the common case of co-scheduled requests walking
their contexts — through
``repro.paging.sharded_pool.sharded_multi_stream_consume`` across
shards x placement x per-NIC budget:

* **block** placement keeps contiguous page ranges on one shard, so the
  co-phased streams all hammer the *same* NIC for long stretches: its §5
  arbiter runs out of leftover budget, prefetch landings defer, and
  demand catches up with the in-flight entries (partial hits instead of
  timely full hits).
* **interleave** spreads consecutive ids round-robin, so every step's
  demand + prefetch traffic splits across all NICs and each per-NIC
  arbiter almost always has leftover landing budget.

Headline: at equal per-NIC budget on strided multi-stream traffic,
interleave placement beats block on timely (full) prefetch hits and
defers less — the disaggregation-era restatement of "spread your pages
over the fabric". A derived row cross-validates the jitted per-stream
counts against the lock-step sharded fabric reference
(``repro.fabric.run_shardstep``) at the tightest budget.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.fabric.shardstep import run_shardstep
from repro.paging.prefetch_serving import PrefetchedStream, stream_stats_at
from repro.paging.sharded_pool import (ShardedPoolCfg,
                                       sharded_multi_stream_consume)

from .common import sized, write_csv

N_PAGES = sized(256, 32)
PAGE_ELEMS = sized(16, 4)
T = sized(240, 30)
N_STREAMS = sized(4, 2)
SHARDS = sized((2, 4), (2,))
# finite budgets sit in the regime where the fabric can sustain steady
# prefetching at all (aggregate capacity >= the streams' consumption rate):
# below ~2 pages/step/NIC *both* placements starve into all-partial
# collapse and the comparison is noise, above ~6 every NIC saturates and
# placement stops mattering — 3..4 is where topology decides
BUDGETS = sized((None, 4, 3), (None, 2))
NEAR_DELAY, FAR_DELAY = 1, 2


def _schedules() -> np.ndarray:
    """Co-phased strided walks: stream s reads (t*3 + 7*s) % N_PAGES —
    stride 3 is coprime with every shard count swept, and the small phase
    offsets keep all streams inside the same block-placement range."""
    return np.stack([(np.arange(T) * 3 + 7 * s) % N_PAGES
                     for s in range(N_STREAMS)]).astype(np.int32)


def _agg(st) -> dict:
    per = [stream_stats_at(st, i) for i in range(N_STREAMS)]
    keys = ("faults", "hits", "misses", "prefetch_hits", "partial_hits",
            "deferred", "ring_drops", "pollution")
    out = {k: sum(p[k] for p in per) for k in keys}
    out["full_hits"] = out["prefetch_hits"] - out["partial_hits"]
    out["full_hit_rate"] = out["full_hits"] / max(1, out["faults"])
    return out


def _crossval(scheds: np.ndarray, geom: PrefetchedStream,
              fab: ShardedPoolCfg) -> bool:
    st, _, _ = sharded_multi_stream_consume(
        jnp.zeros((N_PAGES, PAGE_ELEMS), jnp.float32), jnp.asarray(scheds),
        geom, fab)
    rep = run_shardstep(scheds, N_PAGES, fab.n_shards, fab.placement,
                        fab.link_budget, ring_size=geom.ring_size,
                        near_delay=fab.near_delay, far_delay=fab.far_delay,
                        pw_max=geom.pw_max, h_size=geom.h_size,
                        n_split=geom.n_split)
    for i in range(len(scheds)):
        j = stream_stats_at(st, i)
        r = rep.stream_summary(i)
        if any(j[k] != r[k] for k in r):
            return False
    return True


def run() -> tuple[list[dict], dict]:
    pool = jnp.arange(N_PAGES * PAGE_ELEMS,
                      dtype=jnp.float32).reshape(N_PAGES, PAGE_ELEMS)
    scheds = _schedules()
    geom = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES,
                            page_elems=PAGE_ELEMS, ring_size=8)
    rows, derived = [], {}
    acc = {}
    for n_shards in SHARDS:
        for placement in ("block", "interleave"):
            for budget in BUDGETS:
                fab = ShardedPoolCfg(n_shards=n_shards, placement=placement,
                                     link_budget=budget,
                                     near_delay=NEAR_DELAY,
                                     far_delay=FAR_DELAY)
                st, _, info = sharded_multi_stream_consume(
                    pool, jnp.asarray(scheds), geom, fab)
                a = _agg(st)
                shard_d = np.asarray(info["shard_demand_fetches"]).sum(0)
                # NIC hotspotting: peak/mean demand traffic across shards
                imbalance = float(shard_d.max() / max(1.0, shard_d.mean()))
                acc[(n_shards, placement, budget)] = a
                rows.append({
                    "shards": n_shards, "placement": placement,
                    "budget": budget or "inf",
                    "full_hits": a["full_hits"],
                    "full_hit_rate": round(a["full_hit_rate"], 3),
                    "partial_hits": a["partial_hits"],
                    "deferred": a["deferred"],
                    "ring_drops": a["ring_drops"],
                    "nic_imbalance": round(imbalance, 2),
                })

    # headline: interleave > block on strided multi-stream traffic at every
    # equal finite per-NIC budget (more timely hits, fewer deferrals)
    finite = [b for b in BUDGETS if b is not None]
    derived["interleave_beats_block_full_hits"] = bool(all(
        acc[(g, "interleave", b)]["full_hits"]
        > acc[(g, "block", b)]["full_hits"]
        for g in SHARDS for b in finite))
    derived["interleave_defers_less"] = bool(all(
        acc[(g, "interleave", b)]["deferred"]
        <= acc[(g, "block", b)]["deferred"]
        for g in SHARDS for b in finite))
    tight = min(finite)
    g0 = SHARDS[-1]
    derived["tight_budget"] = tight
    derived["block_full_hit_rate_at_tight"] = round(
        acc[(g0, "block", tight)]["full_hit_rate"], 3)
    derived["interleave_full_hit_rate_at_tight"] = round(
        acc[(g0, "interleave", tight)]["full_hit_rate"], 3)
    derived["crossval_counts_match"] = _crossval(
        scheds, geom, ShardedPoolCfg(n_shards=g0, placement="interleave",
                                     link_budget=tight,
                                     near_delay=NEAR_DELAY,
                                     far_delay=FAR_DELAY))
    write_csv("sharded_pool", rows)
    return rows, derived
