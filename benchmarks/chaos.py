"""Chaos fabric: deadline adaptation under stragglers (DESIGN.md §9).

The question this suite answers: when part of the fabric degrades mid-run
(a straggler NIC, a congested rack switch, a budget cut), do prefetch
deadlines track reality or collapse into wall-to-wall deferrals?

Two fault scenarios, each run through the chaos-enabled mesh-sharded path
(``repro.paging.sharded_pool.sharded_multi_stream_consume``):

* **straggler** — every NIC's physical transfer time doubles at ``ONSET``
  and stays dilated (uniform 1-step base delay, unlimited budget): pure
  latency dilation, the fabric still moves every page.
* **degraded** — per-NIC landing budget halves over the same window
  (distance-dependent 1/2-step delays, finite budget): landings queue up
  behind the §5 demand-first arbiter and arrive late.

Each scenario runs twice:

* **static** deadlines: the clean-fabric expectation. Once the fault
  window opens, landings arrive past their deadline — prefetches still
  *land* (the data plane is fine) but they are not *timely*, which is
  exactly the signal a latency-SLO serving stack pages an operator for.
* **adaptive** deadlines: the per-(stream, shard) integer EWMA estimator
  (``repro.fabric.chaos.est_step``) feeds issue-time deadlines from
  observed landings. After a few landings the estimate converges to the
  degraded latency and deferrals fall back to the warmup transient.

Headline: ``timely_rate = (prefetch_hits - deferred) / faults`` — the
fraction of slow-tier accesses covered by a prefetch that arrived when
the controller said it would. Adaptive holds near the clean-fabric rate;
static collapses for the duration of the fault window. Derived rows
cross-validate the jitted chaos counts against the lock-step twin
(``repro.fabric.run_shardstep``) and check the final estimator state
tracks the true dilated delay.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.fabric.chaos import EST_ONE, ChaosSpec
from repro.fabric.shardstep import run_shardstep
from repro.paging.prefetch_serving import PrefetchedStream, stream_stats_at
from repro.paging.sharded_pool import (ShardedPoolCfg,
                                       sharded_multi_stream_consume)

from .common import sized, write_csv

N_PAGES = sized(128, 32)
PAGE_ELEMS = sized(8, 4)
T = sized(200, 40)
N_STREAMS = sized(3, 2)
N_SHARDS = 2
ONSET = T // 5                  # clean prefix long enough to warm the trend


def _schedules() -> np.ndarray:
    """Strided walks (stride 3 coprime with both shards' interleave)."""
    return np.stack([(np.arange(T) * 3 + 7 * s) % N_PAGES
                     for s in range(N_STREAMS)]).astype(np.int32)


def _scenarios() -> dict[str, dict]:
    """Scenario -> fabric config + fault entries (all NICs, step onset)."""
    all_nics = lambda cap: tuple((g, cap, ONSET, T) for g in range(N_SHARDS))
    return {
        # uniform base delay, unlimited budget: latency dilation only.
        # factor 2 keeps the dilated delay within the trend's steady
        # coverage depth so prefetches still land (and get observed).
        "straggler": {"near": 1, "far": 1, "budget": None, "factor": 2,
                      "slowdown": all_nics(2), "degradation": ()},
        # distance-dependent delays, finite budget halved mid-run: the §5
        # arbiter backlogs landings past their nominal arrival.
        "degraded": {"near": 1, "far": 2, "budget": 4, "factor": 1,
                     "slowdown": (), "degradation": all_nics(2)},
    }


def _agg(st) -> dict:
    per = [stream_stats_at(st, i) for i in range(N_STREAMS)]
    keys = ("faults", "prefetch_hits", "partial_hits", "deferred",
            "ring_drops", "pollution")
    out = {k: sum(p[k] for p in per) for k in keys}
    out["timely_rate"] = ((out["prefetch_hits"] - out["deferred"])
                          / max(1, out["faults"]))
    return out


def _run_one(pool, scheds, geom, fab, chaos):
    st, _, info = sharded_multi_stream_consume(
        pool, jnp.asarray(scheds), geom, fab, chaos=chaos)
    return _agg(st), info


def _crossval(scheds, geom, fab, chaos) -> bool:
    st, _, _ = sharded_multi_stream_consume(
        jnp.zeros((N_PAGES, PAGE_ELEMS), jnp.float32), jnp.asarray(scheds),
        geom, fab, chaos=chaos)
    rep = run_shardstep(scheds, N_PAGES, fab.n_shards, fab.placement,
                        fab.link_budget, ring_size=geom.ring_size,
                        near_delay=fab.near_delay, far_delay=fab.far_delay,
                        pw_max=geom.pw_max, h_size=geom.h_size,
                        n_split=geom.n_split, chaos=chaos)
    for i in range(len(scheds)):
        j = stream_stats_at(st, i)
        r = rep.stream_summary(i)
        if any(j[k] != r[k] for k in r):
            return False
    return True


def _est_rel_err(info, near: int, far: int, factor: int) -> float:
    """Mean relative error of the final estimate vs the dilated truth."""
    est = np.asarray(info["est_q"], dtype=np.float64) / EST_ONE
    home = np.arange(N_STREAMS) % N_SHARDS
    base = np.where(np.arange(N_SHARDS)[None, :] == home[:, None], near, far)
    true = base * factor
    return float(np.mean(np.abs(est - true) / true))


def run() -> tuple[list[dict], dict]:
    pool = jnp.arange(N_PAGES * PAGE_ELEMS,
                      dtype=jnp.float32).reshape(N_PAGES, PAGE_ELEMS)
    scheds = _schedules()
    geom = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES,
                            page_elems=PAGE_ELEMS, ring_size=8)
    rows, derived = [], {}
    acc = {}
    for scen, cfg in _scenarios().items():
        fab = ShardedPoolCfg(n_shards=N_SHARDS, placement="interleave",
                             link_budget=cfg["budget"],
                             near_delay=cfg["near"], far_delay=cfg["far"])
        runs = {"clean": None}
        for mode, adaptive in (("static", False), ("adaptive", True)):
            runs[mode] = ChaosSpec(slowdown=cfg["slowdown"],
                                   degradation=cfg["degradation"],
                                   adaptive_deadline=adaptive)
        for mode, spec in runs.items():
            a, info = _run_one(pool, scheds, geom, fab, spec)
            acc[(scen, mode)] = a
            rows.append({"scenario": scen, "deadlines": mode,
                         "prefetch_hits": a["prefetch_hits"],
                         "partial_hits": a["partial_hits"],
                         "deferred": a["deferred"],
                         "timely_rate": round(a["timely_rate"], 3)})
            if mode == "adaptive" and scen == "straggler":
                derived["est_rel_err_at_end"] = round(
                    _est_rel_err(info, cfg["near"], cfg["far"],
                                 cfg["factor"]), 3)
        for mode in runs:
            derived[f"{scen}_{mode}_timely"] = round(
                acc[(scen, mode)]["timely_rate"], 3)

    # the headline pair: adaptive degrades gracefully, static collapses
    scens = list(_scenarios())
    # strict improvement wherever static actually deferred anything (at
    # smoke sizes a fault window can be too short to bite), never worse
    derived["adaptive_beats_static"] = bool(all(
        acc[(s, "adaptive")]["timely_rate"]
        > acc[(s, "static")]["timely_rate"]
        if acc[(s, "static")]["deferred"] else
        acc[(s, "adaptive")]["timely_rate"]
        >= acc[(s, "static")]["timely_rate"] for s in scens))
    derived["static_collapses"] = bool(
        acc[("straggler", "static")]["timely_rate"]
        < 0.5 * acc[("straggler", "clean")]["timely_rate"])
    derived["adaptive_holds"] = bool(all(
        acc[(s, "adaptive")]["timely_rate"]
        >= 0.8 * acc[(s, "clean")]["timely_rate"] for s in scens))
    cfg = _scenarios()["straggler"]
    derived["crossval_counts_match"] = _crossval(
        scheds, geom,
        ShardedPoolCfg(n_shards=N_SHARDS, placement="interleave",
                       link_budget=cfg["budget"], near_delay=cfg["near"],
                       far_delay=cfg["far"]),
        ChaosSpec(slowdown=cfg["slowdown"], adaptive_deadline=True))
    write_csv("chaos", rows)
    return rows, derived
