"""Shared benchmark plumbing: runners, CSV writing, result tables, smoke."""

from __future__ import annotations

import csv
import math
import os
import subprocess
import time

RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT", "results/bench")

#: machine-readable bench result schema version (``benchmarks.run --json``)
BENCH_SCHEMA = "repro-bench/v1"

# CI smoke mode: every suite registered in benchmarks.run executes end-to-end
# at tiny sizes so new benchmarks cannot rot unexercised. Headline numbers are
# meaningless at smoke sizes — the gate is "runs and writes its CSV".
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def sized(full, smoke):
    """Pick the benchmark's driving size: ``smoke`` under REPRO_BENCH_SMOKE=1."""
    return smoke if SMOKE else full


def write_csv(name: str, rows: list[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".csv")
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return path


def fmt_table(rows: list[dict], cols: list[str] | None = None) -> str:
    if not rows:
        return "(empty)"
    cols = cols or list(rows[0].keys())
    widths = {c: max(len(str(c)), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    line = "  ".join(str(c).ljust(widths[c]) for c in cols)
    out = [line, "-" * len(line)]
    for r in rows:
        out.append("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
    return "\n".join(out)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0


# -- machine-readable bench results (benchmarks.run --json) -------------------
def git_sha() -> str:
    """Current commit sha, or ``"unknown"`` outside a git checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def jsonable(v):
    """Coerce a result value to plain JSON types (NaN -> None, numpy ->
    python scalars, nested containers recursively)."""
    if isinstance(v, dict):
        return {str(k): jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [jsonable(x) for x in v]
    if isinstance(v, bool) or v is None or isinstance(v, (int, str)):
        return v
    if isinstance(v, float):
        return None if math.isnan(v) or math.isinf(v) else v
    if hasattr(v, "item"):                 # numpy / jax scalar
        return jsonable(v.item())
    if hasattr(v, "tolist"):               # numpy / jax array
        return jsonable(v.tolist())
    return str(v)


def bench_json_doc(tag: str, suites: list[dict],
                   failures: list[tuple]) -> dict:
    """The ``repro-bench/v1`` document ``benchmarks.run --json`` writes.

    ``suites`` entries carry ``{"suite", "wall_s", "rows", "derived"}``.
    """
    return {
        "schema": BENCH_SCHEMA,
        "tag": tag,
        "git_sha": git_sha(),
        "created_unix": time.time(),
        "smoke": SMOKE,
        "suites": jsonable(suites),
        "failures": [[name, err] for name, err in failures],
    }


def validate_bench_json(doc: dict) -> list[str]:
    """Schema check for a ``repro-bench/v1`` document; returns a list of
    violations (empty = valid). Hand-rolled on purpose: no jsonschema
    dependency, and CI's bench-smoke job gates on it."""
    errs = []
    if not isinstance(doc, dict):
        return ["document is not an object"]
    if doc.get("schema") != BENCH_SCHEMA:
        errs.append(f"schema != {BENCH_SCHEMA!r}: {doc.get('schema')!r}")
    for key, typ in (("tag", str), ("git_sha", str),
                     ("created_unix", (int, float)), ("smoke", bool),
                     ("suites", list), ("failures", list)):
        if not isinstance(doc.get(key), typ):
            errs.append(f"missing/ill-typed field {key!r}")
    for i, s in enumerate(doc.get("suites") or []):
        if not isinstance(s, dict):
            errs.append(f"suites[{i}] is not an object")
            continue
        if not isinstance(s.get("suite"), str):
            errs.append(f"suites[{i}].suite missing")
        if not isinstance(s.get("wall_s"), (int, float)):
            errs.append(f"suites[{i}].wall_s missing")
        if not isinstance(s.get("rows"), list):
            errs.append(f"suites[{i}].rows missing")
        elif any(not isinstance(r, dict) for r in s["rows"]):
            errs.append(f"suites[{i}].rows has non-object entries")
        if not isinstance(s.get("derived"), dict):
            errs.append(f"suites[{i}].derived missing")
    return errs
