"""Shared benchmark plumbing: runners, CSV writing, result tables, smoke."""

from __future__ import annotations

import csv
import os
import time

RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT", "results/bench")

# CI smoke mode: every suite registered in benchmarks.run executes end-to-end
# at tiny sizes so new benchmarks cannot rot unexercised. Headline numbers are
# meaningless at smoke sizes — the gate is "runs and writes its CSV".
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def sized(full, smoke):
    """Pick the benchmark's driving size: ``smoke`` under REPRO_BENCH_SMOKE=1."""
    return smoke if SMOKE else full


def write_csv(name: str, rows: list[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".csv")
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return path


def fmt_table(rows: list[dict], cols: list[str] | None = None) -> str:
    if not rows:
        return "(empty)"
    cols = cols or list(rows[0].keys())
    widths = {c: max(len(str(c)), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    line = "  ".join(str(c).ljust(widths[c]) for c in cols)
    out = [line, "-" * len(line)]
    for r in rows:
        out.append("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
    return "\n".join(out)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
