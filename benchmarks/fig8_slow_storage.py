"""Paper Fig. 8b: Leap's prefetcher on *slow storage* (default data path).

Swap the prefetching algorithm only — Linux read-ahead vs Leap — while
keeping the block-layer data path and LRU cache, paging to HDD- and
SSD-class latency. Paper: 1.61x (HDD) and 1.25x (SSD) completion-time
improvement on PowerGraph.
"""

from __future__ import annotations


from repro.core import traces
from repro.core.cache import PageCache
from repro.core.prefetcher import make_prefetcher
from repro.core.simulator import LATENCY_MODELS, LatencyModel, simulate

from .common import sized, write_csv

SSD = LatencyModel("ssd_block", 0.8, 120.0, 40.0, 34.0, 0.9, 0.01)
HDD = LATENCY_MODELS["disk_block"]


def run() -> tuple[list[dict], dict]:
    tr = traces.powergraph_like(sized(20000, 500))
    rows, totals = [], {}
    for medium, model in (("hdd", HDD), ("ssd", SSD)):
        for name in ("read_ahead", "leap"):
            r = simulate(tr, make_prefetcher(name),
                         PageCache(256, eviction="lru"), model=model)
            rows.append({"medium": medium, "prefetcher": name,
                         "completion_ms": round(r.total_time / 1e3, 1),
                         "hit_rate": round(r.stats.hit_rate, 3),
                         "coverage": round(r.stats.coverage, 3)})
            totals[(medium, name)] = r.total_time
    derived = {
        "hdd_speedup": round(totals[("hdd", "read_ahead")]
                             / totals[("hdd", "leap")], 2),
        "ssd_speedup": round(totals[("ssd", "read_ahead")]
                             / totals[("ssd", "leap")], 2),
    }
    write_csv("fig8_slow_storage", rows)
    return rows, derived
