"""Paper Fig. 12: performance under constrained prefetch-cache sizes.

Leap's timeliness means prefetched pages are consumed (and eagerly freed)
quickly, so shrinking the cache to O(1) MB-equivalent slots costs only a few
percent. Sweep cache capacity; report completion time relative to unlimited.
"""

from __future__ import annotations

from repro.core import traces
from repro.core.cache import PageCache
from repro.core.prefetcher import make_prefetcher
from repro.core.simulator import simulate

from .common import sized, write_csv

APPS = ("powergraph", "numpy", "voltdb", "memcached")
SIZES = (8, 16, 64, 4096)       # slots; 4096 ~ "unlimited"


def run() -> tuple[list[dict], dict]:
    rows, derived = [], {}
    for app in APPS:
        tr = traces.TRACES[app](n=sized(12000, 400))
        base_t = None
        for cap in sorted(SIZES, reverse=True):
            r = simulate(tr, make_prefetcher("leap"),
                         PageCache(cap, eviction="eager"), "rdma_lean")
            if base_t is None:
                base_t = r.total_time
            drop = 100 * (r.total_time - base_t) / base_t
            rows.append({"app": app, "cache_slots": cap,
                         "completion_ms": round(r.total_time / 1e3, 1),
                         "drop_vs_unlimited_pct": round(drop, 2)})
            if cap == min(SIZES):
                derived[f"{app}_min_cache_drop_pct"] = round(drop, 2)
    write_csv("fig12_cache_size", rows)
    return rows, derived
