"""Paper Fig. 3: sequential/stride/other fractions in fault windows of
length X in {2,4,8} for the four app-like traces, plus the majority-vote
detectability gain at X=8 (the paper's 11.3-29.7% argument: a strict
all-X-equal test misses windows a Boyer-Moore majority still catches).
"""

from __future__ import annotations

import numpy as np

from repro.core import traces
from repro.core.traces import classify_windows
from repro.core.trend import boyer_moore

from .common import sized, write_csv

APPS = ("powergraph", "numpy", "voltdb", "memcached")


def majority_detectable(pages: np.ndarray, x: int) -> float:
    """Fraction of length-x windows whose deltas have a Boyer-Moore majority."""
    d = np.diff(pages)
    n = len(d) - x + 1
    if n <= 0:
        return 0.0
    hits = sum(boyer_moore(d[i:i + x])[1] for i in range(0, n))
    return hits / n


def run() -> tuple[list[dict], dict]:
    rows = []
    derived = {}
    for app in APPS:
        tr = traces.TRACES[app](n=sized(8000, 400))
        for x in (2, 4, 8):
            c = classify_windows(tr, x)
            rows.append({"app": app, "X": x,
                         "sequential": round(c["sequential"], 3),
                         "stride": round(c["stride"], 3),
                         "other": round(c["other"], 3)})
        strict8 = classify_windows(tr, 8)
        maj8 = majority_detectable(tr, 8)
        strict_detect = strict8["sequential"] + strict8["stride"]
        rows.append({"app": app, "X": "maj8",
                     "sequential": round(maj8, 3), "stride": "",
                     "other": round(1 - maj8, 3)})
        derived[f"{app}_majority_gain_pct"] = round(
            100 * (maj8 - strict_detect), 1)
    write_csv("fig3_patterns", rows)
    return rows, derived
