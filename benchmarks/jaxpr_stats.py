"""Per-cell analytic jaxpr stats (no compilation, no device forcing).

Traces the *unsharded* step function of every (arch x shape) cell with
ShapeDtypeStructs and counts loop-aware FLOPs/bytes (benchmarks.flop_count).
SPMD splits these ~evenly, so per-chip = global / n_chips. Results land in
results/jaxpr/<arch>__<shape>.json and are merged by benchmarks.roofline.

Run: PYTHONPATH=src python -m benchmarks.jaxpr_stats [--arch A] [--shape S]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro import configs as cfglib
from repro.models.model import build_model
from repro.optim import make_optimizer
from repro.launch.steps import OPT_FOR_ARCH

from .flop_count import count_fn

OUT_DIR = "results/jaxpr"


def cell_stats(arch: str, shape: str) -> dict | None:
    spec = cfglib.input_specs(arch, shape)
    if spec["skip"]:
        return None
    cfg, sp = spec["cfg"], spec["shape"]
    model = build_model(cfg)
    pshapes = jax.eval_shape(lambda k: model.init_params(k)[0],
                             jax.random.PRNGKey(0))
    if sp.kind == "train":
        opt_name = OPT_FOR_ARCH.get(cfglib.canonical(arch), "adamw")
        opt_init, opt_update = make_optimizer(opt_name, 1e-4)
        oshapes = jax.eval_shape(opt_init, pshapes)

        def step(params, opt_state, batch, i):
            loss, grads = jax.value_and_grad(model.train_forward)(params, batch)
            return opt_update(grads, opt_state, params, i)

        stats = count_fn(step, pshapes, oshapes, spec["batch"],
                         jax.ShapeDtypeStruct((), jnp.int32))
    elif sp.kind == "prefill":
        stats = count_fn(lambda p, b: model.prefill(p, b, sp.seq_len),
                         pshapes, spec["batch"])
    else:
        stats = count_fn(model.decode_step, pshapes,
                         spec["batch"]["token"], spec["batch"]["state"])
    stats["arch"], stats["shape"], stats["kind"] = arch, shape, sp.kind
    return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)
    archs = [args.arch] if args.arch else cfglib.ARCHS
    shapes = [args.shape] if args.shape else list(cfglib.SHAPES)
    for a in archs:
        for s in shapes:
            path = os.path.join(OUT_DIR, f"{a}__{s}.json")
            if os.path.exists(path) and not args.force:
                continue
            try:
                st = cell_stats(a, s)
            except Exception as e:
                st = {"arch": a, "shape": s, "error": repr(e)}
            if st is None:
                st = {"arch": a, "shape": s, "skip": True}
            with open(path, "w") as f:
                json.dump(st, f)
            if "flops" in st:
                print(f"{a:24s} {s:12s} flops={st['flops']:.3e} "
                      f"dot={st['dot_flops']:.3e}")
            else:
                print(f"{a:24s} {s:12s} {st.get('error', 'skip')}")


if __name__ == "__main__":
    main()
