"""Fabric scaling sweep: tenant count × arbitration × cache pressure.

Scales the multi-tenant fabric from 2 to 8 concurrent tenants (a mixed
fleet: regular Leap streams alongside irregular tenants on the stock
read-ahead + LRU config, plus bursty and churning arrivals) and compares
the shared-FIFO link against Leap-style per-tenant queue pairs under low
and high cache pressure. One extra scenario routes half the tenants to a
disk tier (heterogeneous disk+RDMA fabric).

Reported per configuration: makespan, worst/mean per-tenant p99,
Jain fairness over per-tenant throughput, and link utilization — the
scaling story behind the paper's Fig. 13 (§4.1/§4.4): isolation keeps
tail latency flat as tenants are added, the shared queue does not.
"""

from __future__ import annotations

from repro.core import traces
from repro.fabric import FabricScenario, TenantSpec, run_fabric, slowdowns

from .common import sized, write_csv

# tenant archetypes cycled to build an N-tenant population
_KINDS = ("sequential", "powergraph", "stride10", "voltdb",
          "numpy", "memcached", "interleaved", "phase_shift")


_LRU_KINDS = ("voltdb", "memcached", "interleaved")   # stock-path tenants


def _population(n_tenants: int, n: int, capacity: int,
                hetero: bool = False) -> list[TenantSpec]:
    """Mixed fleet: regular streams run Leap (eager cache), irregular
    streams run the stock read-ahead + background-LRU config — the LRU
    caches are what make the ``capacity`` axis bind (eager caches only
    hold unconsumed prefetches and rarely fill)."""
    specs = []
    for i in range(n_tenants):
        kind = _KINDS[i % len(_KINDS)]
        stock = kind in _LRU_KINDS
        spec = TenantSpec(
            f"t{i}_{kind}", traces.TRACES[kind](n=n) + (i << 40),
            policy="read_ahead" if stock else "leap",
            cache_capacity=capacity,
            eviction="lru" if stock else "eager",
            model="disk_lean" if hetero and i % 2 else "rdma_lean",
            seed=i)   # pinned so solo slowdown baselines replay identically
        if kind == "memcached":                  # the noisy neighbor
            spec.arrival = "bursty"
            spec.burst_len = 128
            spec.idle_time = 150.0
        if kind == "voltdb":                     # arriving/departing app
            spec.arrival = "churn"
            spec.churn_every = n // 3
            spec.churn_downtime = 400.0
        specs.append(spec)
    return specs


def _row(tag: str, n_tenants: int, arb: str, capacity: int,
         hetero: bool = False, n: int = sized(2500, 250)) -> dict:
    specs = _population(n_tenants, n, capacity, hetero)
    rep = run_fabric(FabricScenario(
        specs, data_path="isolated", arbitration=arb, seed=42))
    tiers = ",".join(sorted(rep.link_stats))
    util = max(v["utilization"] for v in rep.link_stats.values())
    # victim tail: worst p99 among the *regular* streams — the paper's
    # isolation claim is that heavy/irregular neighbors pay for their own
    # traffic instead of inflating the well-behaved tenants' tails
    victims = [s.name for s in specs
               if ("sequential" in s.name or "stride10" in s.name)]
    victim_p99 = max(rep.tenant(v).latency["p99"] for v in victims)
    return {"scenario": tag, "tenants": n_tenants, "arbitration": arb,
            "cache": capacity, "tiers": tiers,
            "makespan_ms": round(rep.makespan / 1e3, 1),
            "worst_p99_us": round(rep.worst_p99(), 2),
            "victim_p99_us": round(victim_p99, 2),
            "mean_p99_us": round(rep.mean_p99(), 2),
            "fairness": round(rep.fairness, 3),
            "link_util": round(util, 3)}


def run() -> tuple[list[dict], dict]:
    rows = []
    for n_tenants in (2, 4, 8):
        for arb in ("fifo", "per_tenant_qp"):
            for capacity in (8, 128):
                rows.append(_row("scale", n_tenants, arb, capacity))
    rows.append(_row("hetero_disk_rdma", 4, "per_tenant_qp", 128,
                     hetero=True))

    def _sel(n, arb, cap):
        return next(r for r in rows if r["scenario"] == "scale"
                    and r["tenants"] == n and r["arbitration"] == arb
                    and r["cache"] == cap)

    # interference cost at 4 tenants: contended completion vs solo runs
    n4 = sized(2500, 250)
    specs4 = _population(4, n4, 128)
    contended = run_fabric(FabricScenario(specs4, data_path="isolated",
                                          arbitration="per_tenant_qp",
                                          seed=42))
    solo = {s.name: run_fabric(FabricScenario(
        [s], data_path="isolated", arbitration="per_tenant_qp",
        seed=42)).tenants[0].completion_time for s in _population(4, n4, 128)}
    sd = slowdowns(contended, solo)

    fifo8, qp8 = _sel(8, "fifo", 128), _sel(8, "per_tenant_qp", 128)
    derived = {
        "mean_slowdown_4t_qp": round(sum(sd.values()) / len(sd), 2),
        "max_slowdown_4t_qp": round(max(sd.values()), 2),
        "qp_vs_fifo_victim_p99_gain_8t":
            round(fifo8["victim_p99_us"] / max(qp8["victim_p99_us"], 1e-9), 2),
        "qp_vs_fifo_makespan_gain_8t":
            round(fifo8["makespan_ms"] / max(qp8["makespan_ms"], 1e-9), 2),
        "qp_fairness_8t": qp8["fairness"],
        "fifo_fairness_8t": fifo8["fairness"],
    }
    write_csv("fabric_scale", rows)
    return rows, derived
