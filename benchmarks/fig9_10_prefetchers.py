"""Paper Fig. 9 + Fig. 10: prefetcher-only comparison on PowerGraph.

Same data path (block layer, LRU cache, disk) for all four algorithms —
isolating the prefetching algorithm's contribution. Reports cache pollution,
cache-miss events, accuracy, coverage, timeliness (p50/p99), and completion
time, plus the paper's headline ratios (Leap vs each baseline).
"""

from __future__ import annotations

from repro.core import traces
from repro.core.cache import PageCache
from repro.core.prefetcher import make_prefetcher
from repro.core.simulator import simulate

from .common import sized, write_csv

POLICIES = ("leap", "next_n_line", "stride", "read_ahead")


def run() -> tuple[list[dict], dict]:
    tr = traces.powergraph_like(sized(20000, 500))
    rows, res = [], {}
    for name in POLICIES:
        cache = PageCache(256, eviction="eager" if name == "leap" else "lru")
        r = simulate(tr, make_prefetcher(name), cache, model="disk_block")
        t = r.stats.timeliness_percentiles()
        rows.append({
            "prefetcher": name,
            "pollution": r.stats.pollution,
            "cache_misses": r.stats.misses,
            "accuracy": round(r.stats.accuracy, 3),
            "coverage": round(r.stats.coverage, 3),
            "timeliness_p50_us": round(t["p50"], 1),
            "timeliness_p99_us": round(t["p99"], 1),
            "completion_ms": round(r.total_time / 1e3, 1),
            "cache_adds": r.stats.prefetch_issued,
        })
        res[name] = r
    leap = res["leap"]
    derived = {}
    for base in POLICIES[1:]:
        b = res[base]
        derived[f"miss_reduction_vs_{base}"] = round(
            b.stats.misses / max(1, leap.stats.misses), 2)
        derived[f"completion_ratio_vs_{base}"] = round(
            b.total_time / leap.total_time, 2)
        derived[f"pollution_ratio_vs_{base}"] = round(
            b.stats.pollution / max(1, leap.stats.pollution), 2)
    derived["coverage_gain_vs_best_baseline_pct"] = round(100 * (
        leap.stats.coverage - max(res[b].stats.coverage
                                  for b in POLICIES[1:])), 1)
    write_csv("fig9_10_prefetchers", rows)
    return rows, derived
