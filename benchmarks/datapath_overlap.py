"""Sync vs async data path: does issue/wait take prefetch DMA off the step?

The paper's §4.2–4.4 claim in-model: with the synchronous batched path every
prefetch candidate is fetched inside the step that issued it (blocking the
consumer), while the async issue/wait ring lands candidates during the
*next* step's compute. Both paths run the same controller on the same
schedules, so their hit rates match; the difference is what sits on the
per-step critical path:

* sync:  demand misses AND every issued candidate (one blocking batch);
* async: demand misses, plus the *residual* transfer of partial hits
  (pages consumed while still in flight).

The consume-latency column prices those critical-path bytes with the
``rdma_lean`` latency model (fetch = ``t_fabric``, hit = ``t_hit``, partial
residual = ``t_fabric / 2`` in expectation under a 1-step deadline). The
sweep crosses path x access pattern x in-flight ring size; ``ring=0``
degenerates to sync (pinned bit-equivalent in tests). CPU wall time is
indicative only — the algorithmic columns are platform-independent.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import LATENCY_MODELS
from repro.paging.prefetch_serving import (PrefetchedStream, stream_consume,
                                           stream_stats)

from .common import sized, write_csv

N_PAGES, N_SLOTS, PAGE_ELEMS, T = 512, 48, 64, sized(400, 80)
RING_SIZES = sized((2, 8, 16), (2, 8))
MODEL = LATENCY_MODELS["rdma_lean"]


def _schedules() -> dict:
    rng = np.random.default_rng(0)
    return {
        "sequential": np.arange(T) % N_PAGES,
        "strided": (np.arange(T) * 4) % N_PAGES,
        "random": rng.integers(0, N_PAGES, T),
        "phase_shift": np.concatenate([np.arange(T // 2) * 2,
                                       20000 - np.arange(T // 2) * 3]) % N_PAGES,
    }


def _consume_us_per_step(s: dict) -> float:
    """Model-priced per-step consume latency of the critical-path bytes."""
    full_hits = s["hits"] - s["partial_hits"]
    blocking_fetches = s["misses"] + s.get("sync_prefetch_fetches", 0)
    us = (full_hits * MODEL.t_hit
          + s["partial_hits"] * (MODEL.t_hit + 0.5 * MODEL.t_fabric)
          + blocking_fetches * MODEL.t_fabric)
    return us / max(s["faults"], 1)


def _run_one(sched: jnp.ndarray, geom: PrefetchedStream,
             async_datapath: bool) -> tuple[dict, float]:
    pool = jnp.arange(N_PAGES * PAGE_ELEMS,
                      dtype=jnp.float32).reshape(N_PAGES, PAGE_ELEMS)
    st, sums, info = stream_consume(pool, sched, geom,
                                    async_datapath=async_datapath)  # compile
    t0 = time.perf_counter()
    st, sums, info = stream_consume(pool, sched, geom,
                                    async_datapath=async_datapath)
    jax.block_until_ready(sums)
    dt = time.perf_counter() - t0
    s = stream_stats(st)
    if not async_datapath:
        # sync: every issued candidate was fetched inside the blocking batch
        s["sync_prefetch_fetches"] = s["prefetch_issued"]
    s["warm_hit_rate"] = float(np.asarray(
        info["hit"] | info["partial_hit"])[T // 4:].mean())
    s["wall_us_per_step"] = 1e6 * dt / len(sched)
    return s, dt


def run() -> tuple[list[dict], dict]:
    rows, derived = [], {}
    consume = {}
    for name, sched_np in _schedules().items():
        sched = jnp.asarray(sched_np, jnp.int32)
        base = dict(n_pages=N_PAGES, n_slots=N_SLOTS, page_elems=PAGE_ELEMS)
        s, _ = _run_one(sched, PrefetchedStream(**base), async_datapath=False)
        consume[(name, "sync")] = _consume_us_per_step(s)
        rows.append({"pattern": name, "path": "sync", "ring": 0,
                     "warm_hit_rate": round(s["warm_hit_rate"], 3),
                     "coverage": round(s["coverage"], 3),
                     "partial_hits": 0, "latency_hidden_frac": 1.0,
                     "pollution": s["pollution"], "ring_drops": 0,
                     "consume_us_per_step": round(consume[(name, "sync")], 2),
                     "wall_us_per_step": round(s["wall_us_per_step"], 1)})
        for ring in RING_SIZES:
            geom = PrefetchedStream(**base, ring_size=ring)
            s, _ = _run_one(sched, geom, async_datapath=True)
            c = _consume_us_per_step(s)
            consume[(name, "async", ring)] = c
            rows.append({"pattern": name, "path": "async", "ring": ring,
                         "warm_hit_rate": round(s["warm_hit_rate"], 3),
                         "coverage": round(s["coverage"], 3),
                         "partial_hits": s["partial_hits"],
                         "latency_hidden_frac":
                             round(s["latency_hidden_frac"], 3),
                         "pollution": s["pollution"],
                         "ring_drops": s["ring_drops"],
                         "consume_us_per_step": round(c, 2),
                         "wall_us_per_step": round(s["wall_us_per_step"], 1)})

    # headline: async must strictly beat sync at matched hit rate on the
    # trend-friendly patterns (the paper's latency-hiding claim, in-model)
    for name in ("sequential", "strided"):
        best_async = min(consume[(name, "async", r)] for r in RING_SIZES)
        sync_c = consume[(name, "sync")]
        derived[f"{name}_consume_sync_us"] = round(sync_c, 2)
        derived[f"{name}_consume_async_us"] = round(best_async, 2)
        derived[f"{name}_async_speedup"] = round(sync_c / best_async, 2)
        derived[f"{name}_async_strictly_faster"] = bool(best_async < sync_c)
    write_csv("datapath_overlap", rows)
    return rows, derived
