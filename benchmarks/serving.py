"""Continuous batching vs lock-step gang serving: TTFT under load.

The serving-engine claim of DESIGN.md §10: with admission decoupled from
the batch boundary, a request starts prefilling the moment a slot and its
page reservation free up, instead of waiting for the whole previous batch
to drain. Same arrival schedule, same slot count, same page pool, same
tiered data path (§6.4 pin checked on every engine step) — the only
difference is the admission discipline, so the TTFT gap is pure
scheduling.

The sweep crosses arrival shape {constant, bursty} x offered load
{light, heavy} x admission {continuous, gang} with the synthetic executor
(PRNG K/V: scheduling and paging are real, model compute is not priced).
Request lengths are jittered (seeded, identical across the two admission
modes) — with uniform lengths every gang drains in lock step anyway and
the two disciplines coincide; heterogeneous service times are exactly
where continuous batching earns its keep.
TTFT is measured in engine *steps* — deterministic per seed, no wall-clock
noise. Headline: continuous admission has strictly lower mean TTFT than
the gang baseline at equal load, at every point of the sweep.
"""

from __future__ import annotations

from repro.serving import ServeConfig, ServingEngine, SyntheticExecutor

from .common import sized, write_csv

REQUESTS = sized(16, 6)
SLOTS = sized(4, 2)
PROMPT_LEN = sized(24, 8)
GEN = sized(12, 4)
ARRIVALS = ("constant", "bursty")
#: offered load: mean inter-arrival gap in µs (1 engine step = 1000 µs)
LOADS = (("heavy", 500.0),) if sized(False, True) else (
    ("light", 4000.0), ("heavy", 500.0))


def _run_one(arrival: str, think_time: float, gang: bool) -> dict:
    cfg = ServeConfig(requests=REQUESTS, slots=SLOTS, prompt_len=PROMPT_LEN,
                      gen=GEN, length_jitter=0.5, page_size=4,
                      prefill_chunk=8, arrival=arrival,
                      think_time=think_time, burst_len=max(2, SLOTS),
                      idle_time=6 * think_time, seed=0, gang=gang)
    engine = ServingEngine(cfg, SyntheticExecutor(n_kv_heads=2, head_dim=8))
    return engine.run()


def run() -> tuple[list[dict], dict]:
    rows, derived = [], {}
    mean_ttft: dict[tuple, float] = {}
    for arrival in ARRIVALS:
        for load, think in LOADS:
            for mode, gang in (("continuous", False), ("gang", True)):
                r = _run_one(arrival, think, gang)
                assert r["tiered_equiv_ok"], "§6.4 pin broke mid-benchmark"
                assert r["alloc_in_use_end"] == 0, "page leak"
                mean_ttft[(arrival, load, mode)] = r["mean_ttft_steps"]
                tokens = r["tokens_decoded"]
                rows.append({
                    "arrival": arrival, "load": load, "admission": mode,
                    "requests": REQUESTS, "slots": SLOTS,
                    "steps": r["steps"],
                    "mean_ttft_steps": r["mean_ttft_steps"],
                    "p99_ttft_steps": round(r["ttft_steps"]["p99"], 2),
                    "max_ttft_steps": round(r["ttft_steps"]["max"], 2),
                    "tok_per_step": round(tokens / r["steps"], 2),
                    "occupancy_peak": r["alloc_occupancy_peak"],
                    "bit_identical": r["tiered_equiv_ok"],
                })

    wins = []
    for arrival in ARRIVALS:
        for load, _ in LOADS:
            cont = mean_ttft[(arrival, load, "continuous")]
            gang = mean_ttft[(arrival, load, "gang")]
            key = f"{arrival}_{load}"
            derived[f"{key}_ttft_continuous"] = round(cont, 2)
            derived[f"{key}_ttft_gang"] = round(gang, 2)
            derived[f"{key}_ttft_speedup"] = round(gang / max(cont, 1e-9), 2)
            wins.append(cont < gang)
    derived["continuous_strictly_lower_ttft_everywhere"] = all(wins)
    derived["all_bit_identical"] = all(r["bit_identical"] for r in rows)
    write_csv("serving", rows)
    return rows, derived
