"""Paper Fig. 11: end-to-end application benefit of the full Leap stack.

Four application workloads (Fig. 3 access mixes) under two memory limits.
"Infiniswap default" = block-layer data path + Linux read-ahead + LRU cache;
"Leap" = lean path + majority-trend prefetcher + eager eviction. The memory
limit maps to fault density: at 25% the resident set is smaller, so the
slow-tier trace is denser and more irregular (1.5x events, extra working-set
jumps) — calibration documented in EXPERIMENTS.md.
"""

from __future__ import annotations

import numpy as np

from repro.core import traces
from repro.core.cache import PageCache
from repro.core.prefetcher import make_prefetcher
from repro.core.simulator import simulate

from .common import sized, write_csv

APPS = ("powergraph", "numpy", "voltdb", "memcached")


def _trace(app: str, limit: str) -> np.ndarray:
    n = sized(16000, 400) if limit == "50" else sized(24000, 600)
    tr = traces.TRACES[app](n=n)
    if limit == "25":
        rng = np.random.default_rng(9)
        extra = rng.integers(0, 1 << 22, size=len(tr) // 4)
        idx = np.sort(rng.choice(len(tr), len(extra), replace=False))
        tr = np.insert(tr, idx, extra)
    return tr


def run() -> tuple[list[dict], dict]:
    rows, derived = [], {}
    for app in APPS:
        for limit in ("50", "25"):
            tr = _trace(app, limit)
            base = simulate(tr, make_prefetcher("read_ahead"),
                            PageCache(256, eviction="lru"), "rdma_block")
            leap = simulate(tr, make_prefetcher("leap"),
                            PageCache(256, eviction="eager"), "rdma_lean")
            sp = base.total_time / leap.total_time
            p99 = (base.stats.latency_percentiles()["p99"]
                   / leap.stats.latency_percentiles()["p99"])
            rows.append({"app": app, "mem_limit_pct": limit,
                         "default_ms": round(base.total_time / 1e3, 1),
                         "leap_ms": round(leap.total_time / 1e3, 1),
                         "speedup": round(sp, 2),
                         "p99_improvement": round(p99, 2),
                         "leap_coverage": round(leap.stats.coverage, 3)})
            derived[f"{app}_{limit}_speedup"] = round(sp, 2)
    write_csv("fig11_apps", rows)
    return rows, derived
