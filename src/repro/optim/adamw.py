"""AdamW with fp32 moments and decoupled weight decay (pytree-native).

Returns ``(init_fn, update_fn)``:
  state = init_fn(params)                    # m, v fp32; step counter
  params, state = update_fn(grads, state, params, step)
Weight decay skips 1-D leaves (norm scales, biases) — standard practice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import clip_by_global_norm, resolve_lr


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float = 1.0):
    def init_fn(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(f32, params),
                "v": jax.tree.map(f32, params)}

    def update_fn(grads, state, params, step):
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.float32(0)
        t = step.astype(jnp.float32) + 1.0
        lr_t = resolve_lr(lr, step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p.ndim > 1:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m, v

        # flatten/unflatten (not tree.map over result tuples): params trees
        # legitimately contain tuples (period stacks), so tuple-is-leaf
        # tricks would truncate the tree.
        pl, treedef = jax.tree.flatten(params)
        gl = treedef.flatten_up_to(grads)
        ml = treedef.flatten_up_to(state["m"])
        vl = treedef.flatten_up_to(state["v"])
        outs = [upd(g, m, v, p) for g, m, v, p in zip(gl, ml, vl, pl)]
        new_p = treedef.unflatten([o[0] for o in outs])
        new_m = treedef.unflatten([o[1] for o in outs])
        new_v = treedef.unflatten([o[2] for o in outs])
        return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm}

    return init_fn, update_fn
