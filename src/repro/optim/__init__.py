"""Optimizers from scratch on pytrees: AdamW, Adafactor, schedules, clipping.

AdamW keeps fp32 moments (+ optional fp32 master copy of bf16 params);
Adafactor keeps a factored second moment — the 400B MoE config uses it so
optimizer state fits the 16 GB/chip budget (see DESIGN.md §5).
"""

from .adamw import adamw
from .adafactor import adafactor
from .schedules import cosine_warmup, linear_warmup
from .common import clip_by_global_norm, global_norm

OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor}


def make_optimizer(name: str, lr, **kw):
    return OPTIMIZERS[name](lr, **kw)


__all__ = ["adamw", "adafactor", "cosine_warmup", "linear_warmup",
           "clip_by_global_norm", "global_norm", "make_optimizer",
           "OPTIMIZERS"]
