"""Adafactor (factored second moment, no momentum by default).

State per 2-D+ leaf is one row + one column accumulator instead of a full
second moment — ~N/d memory. This is what makes the 400B llama4 config's
optimizer state fit 256 chips (DESIGN.md §5 napkin math).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import clip_by_global_norm, resolve_lr


def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_norm: float = 1.0, min_dim_factored: int = 2):
    def factored(p):
        return p.ndim >= min_dim_factored

    def init_fn(params):
        def one(p):
            if factored(p):
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                         jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"acc": jax.tree.map(one, params)}

    def update_fn(grads, state, params, step):
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.float32(0)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = resolve_lr(lr, step)

        def upd(g, acc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                row = beta * acc["row"] + (1 - beta) * g2.mean(-1)
                col = beta * acc["col"] + (1 - beta) * g2.mean(-2)
                rfac = row / jnp.maximum(row.mean(-1, keepdims=True), eps)
                denom = jnp.sqrt(rfac[..., None] * col[..., None, :])
                u = g / jnp.maximum(denom, 1e-12)
                new_acc = {"row": row, "col": col}
            else:
                v = beta * acc["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(v, eps))
                new_acc = {"v": v}
            # relative step size (update clipping at RMS 1)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_acc

        # flatten/unflatten against the params treedef (see adamw.py note);
        # each leaf's acc dict arrives whole via flatten_up_to.
        pl, treedef = jax.tree.flatten(params)
        gl = treedef.flatten_up_to(grads)
        al = treedef.flatten_up_to(state["acc"])
        outs = [upd(g, a, p) for g, a, p in zip(gl, al, pl)]
        new_p = treedef.unflatten([o[0] for o in outs])
        new_acc = treedef.unflatten([o[1] for o in outs])
        return new_p, {"acc": new_acc}, {"grad_norm": gnorm}

    return init_fn, update_fn
