"""LR schedules as step -> lr callables (traceable)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(peak: float, warmup_steps: int):
    def lr(step):
        s = step.astype(jnp.float32)
        return peak * jnp.minimum(1.0, (s + 1) / max(1, warmup_steps))
    return lr


def cosine_warmup(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = (s + 1) / max(1, warmup_steps)
        frac = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps),
                        0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak * jnp.minimum(warm, cos)
    return lr
