"""llama4-maverick-400b-a17b — MoE 128e top-1, interleaved dense/MoE.

[hf:meta-llama/Llama-4-*; unverified]. With the assigned dims (48L, d=5120,
ff=8192, 128 experts) an MoE on every layer would be ~780B total; published
Maverick interleaves MoE every 2nd layer with one shared expert and top-1
routing, which lands at ~397B total / ~17.6B active — matching the
400b-a17b name. Derivation: 24 MoE layers x 128 experts x 3*5120*8192
= 386B routed + shared/dense/attn/embed ~ 11B.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048, rope_theta=500_000.0,
    moe_every=2, moe_offset=1, n_experts=128, top_k=1, n_shared_experts=1,
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, rope_theta=500_000.0,
    moe_every=2, moe_offset=1, n_experts=4, top_k=1, n_shared_experts=1,
    capacity_factor=2.0, dtype="float32",
)
