"""Architecture registry: 10 assigned archs + the paper's own Leap config.

``get_config(arch)`` returns the exact published dims; ``get_smoke_config``
returns a family-preserving reduction (same layer pattern, tiny widths) for
CPU smoke tests. ``SHAPES`` carries the assigned input-shape set and
``input_specs(arch, shape)`` builds the ShapeDtypeStruct stand-ins the
multi-pod dry-run lowers — no allocation ever happens for full configs.

``long_500k`` requires sub-quadratic attention: it runs for SSM/hybrid/SWA
archs and is skipped (with the reason recorded) for pure full-attention
archs — see DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCHS = [
    "qwen2_vl_72b", "jamba_v01_52b", "llama4_maverick_400b",
    "phi35_moe_42b", "stablelm_12b", "qwen2_72b", "qwen2_5_3b",
    "h2o_danube3_4b", "seamless_m4t_medium", "xlstm_350m",
]

# accept dashed ids from the assignment table too
ALIASES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "stablelm-12b": "stablelm_12b",
    "qwen2-72b": "qwen2_72b",
    "qwen2.5-3b": "qwen2_5_3b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "xlstm-350m": "xlstm_350m",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def canonical(arch: str) -> str:
    return ALIASES.get(arch, arch)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.SMOKE


def supports_long_context(cfg: ModelConfig) -> bool:
    """Sub-quadratic decode state: SSM/hybrid families or SWA."""
    return cfg.family in ("ssm", "hybrid") or cfg.sliding_window > 0


def skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    if shape == "long_500k" and not supports_long_context(cfg):
        return "pure full-attention arch: 500K KV decode needs sub-quadratic attention"
    return None


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; dry-run lowers these)
# --------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, B: int, S: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    specs = {
        "tokens": _sds((B, S), jnp.int32),
        "targets": _sds((B, S), jnp.int32),
        "mask": _sds((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        specs["frames"] = _sds((B, S, cfg.d_model), dt)   # audio stub
    if cfg.rope_type == "mrope":
        specs["embeds"] = _sds((B, S, cfg.d_model), dt)   # patch/text stub
        specs["positions3"] = _sds((3, B, S), jnp.int32)
    return specs


def decode_input_specs(cfg: ModelConfig, B: int, S: int) -> dict:
    """Token + decode-state specs for serve_step lowering at context S."""
    from repro.models.model import build_model
    model = build_model(cfg)
    state = jax.eval_shape(
        lambda: model.init_decode_state(B, S, S))
    return {"token": _sds((B,), jnp.int32), "state": state}


def prefill_input_specs(cfg: ModelConfig, B: int, S: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    specs = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.family == "encdec":
        specs["frames"] = _sds((B, S, cfg.d_model), dt)
    if cfg.rope_type == "mrope":
        specs["embeds"] = _sds((B, S, cfg.d_model), dt)
        specs["positions3"] = _sds((3, B, S), jnp.int32)
    return specs


def input_specs(arch: str, shape: str, smoke: bool = False) -> dict:
    """Everything the dry-run needs for one (arch x shape) cell."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    sp = SHAPES[shape]
    reason = skip_reason(cfg, shape)
    out = {"cfg": cfg, "shape": sp, "skip": reason}
    if reason:
        return out
    if sp.kind == "train":
        out["batch"] = train_batch_specs(cfg, sp.global_batch, sp.seq_len)
    elif sp.kind == "prefill":
        out["batch"] = prefill_input_specs(cfg, sp.global_batch, sp.seq_len)
    else:
        out["batch"] = decode_input_specs(cfg, sp.global_batch, sp.seq_len)
    return out
