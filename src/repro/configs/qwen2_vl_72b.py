"""qwen2-vl-72b — 80L d8192 64H (GQA kv=8) ff29568 vocab152064, M-RoPE.

[arXiv:2409.12191; hf]. Vision frontend is a stub: ``input_specs`` provides
precomputed patch/text embeddings plus (t,h,w) position ids; the backbone
implements M-RoPE (3-section rotary, sections 16/24/24 over head_dim/2=64).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, qkv_bias=True,
    rope_type="mrope", rope_theta=1_000_000.0, mrope_sections=(16, 24, 24),
)

SMOKE = ModelConfig(
    name="qwen2-vl-72b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, qkv_bias=True,
    rope_type="mrope", rope_theta=1_000_000.0, mrope_sections=(4, 2, 2),
    dtype="float32",
)
