"""phi3.5-moe-42b-a6.6b — 16 experts, top-2, MoE on every layer.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]. 32L x 16e x 3*4096*6400 = 40.3B
routed + attention/embed ~ 1.6B => ~42B total; top-2 active ~ 6.6B.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab_size=32064,
    moe_every=1, moe_offset=0, n_experts=16, top_k=2,
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512,
    moe_every=1, moe_offset=0, n_experts=4, top_k=2, capacity_factor=2.0,
    dtype="float32",
)
