"""jamba-v0.1-52b — hybrid Mamba+attention 1:7, MoE 16e top-2.

[arXiv:2403.19887; hf]. 32 layers in 4 Jamba blocks of 8: attention at
offset 4 of each block, Mamba elsewhere; MoE replaces the MLP on every 2nd
layer (offset 1). No positional embeddings (attention relies on Mamba for
order) -> rope_type='none'.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, rope_type="none",
    attn_every=8, attn_offset=4,
    moe_every=2, moe_offset=1, n_experts=16, top_k=2,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, rope_type="none",
    attn_every=8, attn_offset=4,
    moe_every=2, moe_offset=1, n_experts=4, top_k=2, capacity_factor=2.0,
    mamba_d_state=8, mamba_d_conv=4, mamba_expand=2,
    dtype="float32",
)
