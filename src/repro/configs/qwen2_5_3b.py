"""qwen2.5-3b — dense GQA (kv=2), QKV bias, tied embeddings. [hf:Qwen; hf]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
    vocab_size=151936, qkv_bias=True, rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2.5-3b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, qkv_bias=True, tie_embeddings=True, dtype="float32",
)
