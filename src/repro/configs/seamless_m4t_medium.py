"""seamless-m4t-medium — encoder-decoder, audio frontend stubbed.

[arXiv:2308.11596; hf]. 12 encoder + 12 decoder layers, MHA (kv=16),
d_ff=4096, vocab 256206. The speech frontend is a stub: input_specs provides
precomputed frame embeddings [B,S,d_model]. Relative position bias replaced
with rotary (noted in DESIGN.md); FFN is gated (SwiGLU) rather than ReLU.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206, vocab_pad=50,   # 256256 = 16-divisible TP
    norm="layernorm", act="gelu",
)

SMOKE = ModelConfig(
    name="seamless-m4t-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, norm="layernorm", act="gelu", dtype="float32",
)
