"""stablelm-12b — dense, LayerNorm trunk. [hf:stabilityai; hf].

Published model uses per-head qk-norm and 25% partial rotary; we implement
full rotary + LayerNorm (deviation noted in DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab_size=100352, norm="layernorm",
)

SMOKE = ModelConfig(
    name="stablelm-12b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, norm="layernorm", dtype="float32",
)
