"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]. head_dim = 3840/32 = 120 (non-128; the TP
rules shard the flattened head axis so this stays divisible). SWA window
4096 (mistral-style rolling buffer) makes this the SWA representative and
long_500k-capable: decode state is O(window), not O(context).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab_size=32000, sliding_window=4096, rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="h2o-danube-3-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, sliding_window=8, dtype="float32",
)
