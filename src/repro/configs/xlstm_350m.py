"""xlstm-350m — sLSTM + mLSTM blocks, 7:1 ratio. [arXiv:2405.04517; unverified].

24 layers in 3 groups of 8 (sLSTM at offset 4), d=1024, 4 heads, no separate
FFN (d_ff=0; the mLSTM block carries its own 2x up/down projection),
block-diagonal per-head q/k/v => ~337M params with tied embeddings.
Recurrent O(1) state: runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, rope_type="none", tie_embeddings=True,
    slstm_every=8, slstm_offset=4, xlstm_proj_factor=2.0, xlstm_conv=4,
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke", family="ssm",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=512, rope_type="none", tie_embeddings=True,
    slstm_every=8, slstm_offset=4, dtype="float32",
)
