"""Jit'd public wrapper: layout/padding glue around the Pallas kernel.

Accepts the model-side [B,S,H,dh] layout, pads dh to a multiple of 128 (MXU
lane width) and S to the block size, dispatches the kernel (interpret=True
off-TPU), and unpads. ``flash_attention(..., use_kernel=False)`` routes to
the jnp oracle — the dry-run lowers that path so cost_analysis sees real
FLOPs instead of an opaque callback.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import flash_attention_ref


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_k", "interpret",
    "use_kernel"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=512, block_k=512, interpret=None,
                    use_kernel=True):
    """q [B,Sq,Hq,dh], k/v [B,Sk,Hkv,dh] -> [B,Sq,Hq,dh]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, Hq, dh = q.shape
    Sk = k.shape[1]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if not use_kernel:
        return flash_attention_ref(qt, kt, vt, causal=causal, window=window,
                                   q_offset=q_offset).transpose(0, 2, 1, 3)

    # dh padding: zero-padded q/k leave scores unchanged; padded v columns
    # produce zero output columns that we slice away.
    qt, _ = _pad_to(qt, 128, 3)
    kt, _ = _pad_to(kt, 128, 3)
    vt, _ = _pad_to(vt, 128, 3)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    while Sq % bq:
        bq //= 2
    while Sk % bk:
        bk //= 2
    o = flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                            q_offset=q_offset, block_q=bq, block_k=bk,
                            sm_scale=1.0 / (dh ** 0.5), interpret=interpret)
    return o[..., :dh].transpose(0, 2, 1, 3)
