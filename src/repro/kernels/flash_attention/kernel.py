"""Pallas TPU flash-attention forward (GQA, causal / sliding-window).

Grid = (B*Hq, Sq/bq, Sk/bk) with the KV dimension innermost: TPU grids
iterate sequentially, so the (m, l, acc) online-softmax state lives in VMEM
scratch and persists across the KV sweep for one (head, q-block); the output
tile is written once on the last KV step. K/V tiles for a q-head map to its
GQA group's KV head via the BlockSpec index_map — no materialized
head-broadcast of K/V (that is the kernel-level point: HBM->VMEM traffic is
per-KV-head, not per-Q-head).

VMEM budget per step (fp32): q/k/v tiles + acc ≈ (3·bk + 2·bq)·dh·4 bytes —
with bq=bk=512, dh=128 ≈ 1.3 MB, comfortably inside a v5e core's ~16 MB
VMEM with double buffering. MXU alignment: bq, bk multiples of 128 (the
wrapper pads dh to 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_kv_blocks: int,
                  q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * sm_scale          # [bq, dh]
    k = k_ref[0].astype(jnp.float32)                     # [bk, dh]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # [bq, bk]

    qpos = (iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            + q_offset)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * corr
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_scr[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _write():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        q_offset: int = 0, block_q: int = 512,
                        block_k: int = 512, sm_scale: float | None = None,
                        interpret: bool = True) -> jax.Array:
    """q [B,Hq,Sq,dh], k/v [B,Hkv,Sk,dh] -> o [B,Hq,Sq,dh].

    dh must be a multiple of 128 and block sizes must divide Sq/Sk (the
    ops.py wrapper pads/derives these — sm_scale uses the *unpadded* dh).
    """
    B, Hq, Sq, dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq, nk = Sq // block_q, Sk // block_k
    qf = q.reshape(B * Hq, Sq, dh)
    kf = k.reshape(B * Hkv, Sk, dh)
    vf = v.reshape(B * Hkv, Sk, dh)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, ik, 0)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale or 1.0 / (dh ** 0.5), causal=causal,
        window=window, block_q=block_q, block_k=block_k, n_kv_blocks=nk,
        q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), q_map),
            pl.BlockSpec((1, block_k, dh), kv_map),
            pl.BlockSpec((1, block_k, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, dh)
