"""Pure-jnp oracle for the flash-attention kernel (exact softmax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        q_offset: int = 0) -> jax.Array:
    """Same contract as kernel.flash_attention_fwd ([B,H,S,dh] layout)."""
    B, Hq, Sq, dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kx = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32), kx)
    s = s / jnp.sqrt(jnp.float32(dh))
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)          # fully-masked rows -> 0, not NaN
    return jnp.einsum("bhqs,bhsd->bhqd", p, vx).astype(q.dtype)
