"""Jit'd wrapper for paged decode attention ([B,1,Hq,dh] model layout)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import paged_attention_fwd
from .ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def paged_attention(q, k_pool, v_pool, page_table, lengths, *,
                    interpret: bool | None = None, use_kernel: bool = True):
    """q [B,1,Hq,dh] (model layout) -> [B,1,Hq,dh]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, one, Hq, dh = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    qg = q[:, 0].reshape(B, Hkv, G, dh)
    fn = paged_attention_fwd if use_kernel else paged_attention_ref
    kw = {"interpret": interpret} if use_kernel else {}
    o = fn(qg, k_pool, v_pool, page_table.astype(jnp.int32),
           lengths.astype(jnp.int32), sm_scale=1.0 / (dh ** 0.5), **kw)
    return o.reshape(B, 1, Hq, dh)
