"""Jit'd wrappers for paged decode attention ([B,1,Hq,dh] model layout)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import (
    paged_attention_fwd,
    paged_attention_hot_slots_async_fwd,
    paged_attention_hot_slots_fwd,
)
from .ref import paged_attention_hot_slots_ref, paged_attention_ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def paged_attention(q, k_pool, v_pool, page_table, lengths, *,
                    interpret: bool | None = None, use_kernel: bool = True):
    """q [B,1,Hq,dh] (model layout) -> [B,1,Hq,dh].

    Invalid page-table entries (< 0 or >= n_pages) are masked out of the
    softmax by both the kernel and the ref — a poisoned table never
    silently contributes page 0's bytes.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, one, Hq, dh = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    qg = q[:, 0].reshape(B, Hkv, G, dh)
    fn = paged_attention_fwd if use_kernel else paged_attention_ref
    kw = {"interpret": interpret} if use_kernel else {}
    o = fn(qg, k_pool, v_pool, page_table.astype(jnp.int32),
           lengths.astype(jnp.int32), sm_scale=1.0 / (dh ** 0.5), **kw)
    return o.reshape(B, 1, Hq, dh)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "use_kernel", "async_copy"))
def paged_attention_hot_slots(q, k_hot, v_hot, slot_table, lengths, *,
                              interpret: bool | None = None,
                              use_kernel: bool = True,
                              async_copy: bool = False):
    """Fused hot-slot decode attention: q [S,1,Hq,dh] (model layout) vs the
    tiered hot pools [S,n_slots,page,Hkv,dh] read in place through the
    *per-stream* slot_table [S,npps] — no stacked [S*n_slots,...] pool.

    Entries < 0 or >= n_slots (non-resident / poisoned) are masked out of
    the softmax. ``async_copy=True`` selects the explicit make_async_copy
    double-buffered kernel; both kernel variants are bit-identical to each
    other and to the flat-pool kernel on equivalent bytes.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S, one, Hq, dh = q.shape
    Hkv = k_hot.shape[3]
    G = Hq // Hkv
    qg = q[:, 0].reshape(S, Hkv, G, dh)
    if use_kernel:
        fn = (paged_attention_hot_slots_async_fwd if async_copy
              else paged_attention_hot_slots_fwd)
        kw = {"interpret": interpret}
    else:
        fn, kw = paged_attention_hot_slots_ref, {}
    o = fn(qg, k_hot, v_hot, slot_table.astype(jnp.int32),
           lengths.astype(jnp.int32), sm_scale=1.0 / (dh ** 0.5), **kw)
    return o.reshape(S, 1, Hq, dh)
