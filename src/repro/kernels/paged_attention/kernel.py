"""Pallas TPU paged-attention decode: one query token vs a paged KV pool.

vLLM-style paged KV adapted to TPU: KV lives in a page pool
[n_pages, page_size, Hkv, dh]; each sequence's logical context is a
page_table row. The kernel fuses Leap's data path with the consumer: the
page_table is a scalar-prefetch operand, so each (batch, kv-head, page) grid
step DMAs exactly the page the table names — gather and attention in one
pass, no [B, T, ...] contiguous cache ever materializes (that contiguous
copy is the "block layer" overhead this kernel deletes).

Online softmax state (m, l, acc) for the G grouped q-heads lives in VMEM
scratch across the page sweep (pages innermost). Padded/unused trailing
pages are masked by the sequence length (also scalar-prefetched); invalid
page-table entries (negative, or past the pool edge) are masked the same
way — the DMA is clamped onto a real page so it stays well-formed, but the
masked scores guarantee those bytes never reach the output (no silent
garbage reads from a poisoned table).

VMEM per step: k/v page tiles 2 x page_size x dh x 4 B (+ q tile G x dh) —
page_size 64, dh 128 ≈ 64 KB: DMA-latency-bound, exactly the regime where
prefetch-ahead (issuing the next page's DMA early) pays, mirroring the
paper's timeliness axis.

Three entry points share one per-page online-softmax update
(:func:`_attend_page` — identical op sequence, which is what keeps their
outputs **bit-identical** on the same bytes):

* :func:`paged_attention_fwd` — flat pool ``[n_pages, page, Hkv, dh]``.
* :func:`paged_attention_hot_slots_fwd` — the tiered hot tier
  ``[S, n_slots, page, Hkv, dh]`` read *in place* through a per-stream
  slot table: the BlockSpec index map composes the ``[S, npps] -> slot``
  indirection (stream s, slot ``slot_table[s, j]``) so the demand sweep
  lands pages and attention consumes them with **no stacked
  ``[S * n_slots, ...]`` hot-pool materialization** (the per-step copy the
  unfused path pays). Non-resident entries (slot < 0) are masked, never
  silently read.
* :func:`paged_attention_hot_slots_async_fwd` — same contract, but the
  hot pools stay in HBM (memory_space=ANY) and the kernel itself
  double-buffers the K/V page tiles with explicit ``pltpu.make_async_copy``
  issue/wait pairs in the style of ``gather_pages_async``: page j+1's
  tiles are in flight while page j is attended.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attend_page(q, k, v, mask, m_prev, l_prev, acc_prev):
    """One page's online-softmax update for G grouped q-heads.

    ``q [G, dh]`` (pre-scaled), ``k/v [page_size, dh]`` (float32),
    ``mask [G, page_size]``; returns the updated ``(m, l, acc)``. Every
    kernel variant funnels through this exact op sequence, so two variants
    fed the same bytes in the same page order produce bit-identical
    outputs — the property the tiered/flat equivalence pin leans on.
    """
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, page_size]
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_new = l_prev * corr + p.sum(-1, keepdims=True)
    acc_new = (acc_prev * corr
               + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    return m_new, l_new, acc_new


def _page_mask(shape, j, page_size, length, valid):
    """Token mask for page j: inside the sequence length AND a valid table
    entry (``valid`` False masks the whole page — poisoned/non-resident)."""
    tpos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    return (tpos < length) & valid


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  sm_scale: float, page_size: int, n_pages_per_seq: int,
                  n_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # [G, dh]
    k = k_ref[0, :, 0].astype(jnp.float32)               # [page_size, dh]
    v = v_ref[0, :, 0].astype(jnp.float32)
    pt = pt_ref[b * n_pages_per_seq + j]
    mask = _page_mask((q.shape[0], page_size), j, page_size, len_ref[b],
                      (pt >= 0) & (pt < n_pages))
    m_scr[...], l_scr[...], acc_scr[...] = _attend_page(
        q, k, v, mask, m_scr[...], l_scr[...], acc_scr[...])

    @pl.when(j == n_pages_per_seq - 1)
    def _write():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attention_fwd(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        page_table: jax.Array, lengths: jax.Array, *,
                        sm_scale: float | None = None,
                        interpret: bool = True) -> jax.Array:
    """q [B,Hkv,G,dh]; pools [n_pages,page_size,Hkv,dh];
    page_table [B,n_pages_per_seq] int32; lengths [B] int32 -> [B,Hkv,G,dh].

    Invalid table entries (< 0 or >= n_pages) are masked out of the
    softmax; the in-range DMA clamp only keeps the access well-formed.
    """
    B, Hkv, G, dh = q.shape
    n_pages, page_size = k_pool.shape[0], k_pool.shape[1]
    npps = page_table.shape[1]
    pt_flat = page_table.reshape(-1)          # raw: the body masks invalid

    def q_map(b, h, j, pt, ln):
        return (b, h, 0, 0)

    def kv_map(b, h, j, pt, ln):
        return (jnp.clip(pt[b * npps + j], 0, n_pages - 1), 0, h, 0)

    kernel = functools.partial(
        _paged_kernel, sm_scale=sm_scale or 1.0 / (dh ** 0.5),
        page_size=page_size, n_pages_per_seq=npps, n_pages=n_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, npps),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), q_map),
            pl.BlockSpec((1, page_size, 1, dh), kv_map),
            pl.BlockSpec((1, page_size, 1, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(pt_flat, lengths.astype(jnp.int32), q, k_pool, v_pool)


# --------------------------------------------------------------------------
# Fused hot-slot variants: attention straight through the tiered hot pool
# --------------------------------------------------------------------------
def _hot_slots_kernel(st_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                      m_scr, l_scr, acc_scr, *,
                      sm_scale: float, page_size: int, n_pages_per_seq: int,
                      n_slots: int):
    s = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # [G, dh]
    k = k_ref[0, 0, :, 0].astype(jnp.float32)            # [page_size, dh]
    v = v_ref[0, 0, :, 0].astype(jnp.float32)
    slot = st_ref[s * n_pages_per_seq + j]
    mask = _page_mask((q.shape[0], page_size), j, page_size, len_ref[s],
                      (slot >= 0) & (slot < n_slots))
    m_scr[...], l_scr[...], acc_scr[...] = _attend_page(
        q, k, v, mask, m_scr[...], l_scr[...], acc_scr[...])

    @pl.when(j == n_pages_per_seq - 1)
    def _write():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attention_hot_slots_fwd(q: jax.Array, k_hot: jax.Array,
                                  v_hot: jax.Array, slot_table: jax.Array,
                                  lengths: jax.Array, *,
                                  sm_scale: float | None = None,
                                  interpret: bool = True) -> jax.Array:
    """q [S,Hkv,G,dh]; hot pools [S,n_slots,page_size,Hkv,dh];
    slot_table [S,npps] int32 *per-stream* slot ids; lengths [S] int32
    -> [S,Hkv,G,dh].

    The BlockSpec index map composes the slot indirection — grid step
    (s, h, j) DMAs hot tile ``[s, slot_table[s, j], :, h, :]`` straight out
    of the stacked per-stream hot pool, so no flattened ``[S*n_slots, ...]``
    pool is ever materialized. Non-resident entries (slot < 0, or past the
    slot count) are masked out of the softmax, never silently read.
    """
    S, Hkv, G, dh = q.shape
    n_slots, page_size = k_hot.shape[1], k_hot.shape[2]
    npps = slot_table.shape[1]
    st_flat = slot_table.reshape(-1)          # raw: the body masks invalid

    def q_map(s, h, j, st, ln):
        return (s, h, 0, 0)

    def kv_map(s, h, j, st, ln):
        return (s, jnp.clip(st[s * npps + j], 0, n_slots - 1), 0, h, 0)

    kernel = functools.partial(
        _hot_slots_kernel, sm_scale=sm_scale or 1.0 / (dh ** 0.5),
        page_size=page_size, n_pages_per_seq=npps, n_slots=n_slots)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, Hkv, npps),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), q_map),
            pl.BlockSpec((1, 1, page_size, 1, dh), kv_map),
            pl.BlockSpec((1, 1, page_size, 1, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(st_flat, lengths.astype(jnp.int32), q, k_hot, v_hot)


def _hot_slots_async_kernel(st_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                            k_scr, v_scr, sem_ref, *,
                            sm_scale: float, page_size: int,
                            n_pages_per_seq: int, n_slots: int):
    """Manual issue/wait hot-slot attention (``gather_pages_async`` style).

    ``k_ref``/``v_ref`` stay in HBM; each page tile ``[page_size, dh]`` is
    DMA'd into one of two VMEM slots via ``pltpu.make_async_copy``, and the
    copy for page j+1 is *issued* before page j's is *waited* on — the
    in-flight ring collapsed to depth 2, so page j's attend overlaps page
    j+1's transfer. Softmax state rides the fori_loop carry (pages are a
    loop here, not a grid dim), through the same :func:`_attend_page`
    update as every other variant.
    """
    s = pl.program_id(0)
    h = pl.program_id(1)
    G, dh = q_ref.shape[2], q_ref.shape[3]
    npps = n_pages_per_seq

    def dma(hbm, scr, buf, j, which):
        slot = jnp.clip(st_ref[s * npps + j], 0, n_slots - 1)
        return pltpu.make_async_copy(hbm.at[s, slot, :, h],
                                     scr.at[buf], sem_ref.at[buf, which])

    dma(k_ref, k_scr, 0, 0, 0).start()       # warm-up: issue page 0
    dma(v_ref, v_scr, 0, 0, 1).start()
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # [G, dh]

    def body(j, carry):
        m_prev, l_prev, acc_prev = carry
        cur = jax.lax.rem(j, 2)
        nxt = jax.lax.rem(j + 1, 2)

        @pl.when(j + 1 < npps)
        def _():
            dma(k_ref, k_scr, nxt, j + 1, 0).start()  # prefetch page j+1
            dma(v_ref, v_scr, nxt, j + 1, 1).start()

        dma(k_ref, k_scr, cur, j, 0).wait()           # page j has landed
        dma(v_ref, v_scr, cur, j, 1).wait()
        k = k_scr[cur].astype(jnp.float32)            # [page_size, dh]
        v = v_scr[cur].astype(jnp.float32)
        slot = st_ref[s * npps + j]
        mask = _page_mask((G, page_size), j, page_size, len_ref[s],
                          (slot >= 0) & (slot < n_slots))
        return _attend_page(q, k, v, mask, m_prev, l_prev, acc_prev)

    m0 = jnp.full((G, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G, 1), jnp.float32)
    acc0 = jnp.zeros((G, dh), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, npps, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def paged_attention_hot_slots_async_fwd(q: jax.Array, k_hot: jax.Array,
                                        v_hot: jax.Array,
                                        slot_table: jax.Array,
                                        lengths: jax.Array, *,
                                        sm_scale: float | None = None,
                                        interpret: bool = True) -> jax.Array:
    """Same contract as :func:`paged_attention_hot_slots_fwd`, issue/wait
    form: the hot pools stay in HBM and the kernel double-buffers K/V page
    tiles with explicit ``make_async_copy`` pairs. VMEM footprint: 4 page
    tiles in flight (k+v, double-buffered) + the q/o blocks.
    """
    S, Hkv, G, dh = q.shape
    n_slots, page_size = k_hot.shape[1], k_hot.shape[2]
    npps = slot_table.shape[1]
    st_flat = slot_table.reshape(-1)

    def q_map(s, h, st, ln):
        return (s, h, 0, 0)

    kernel = functools.partial(
        _hot_slots_async_kernel, sm_scale=sm_scale or 1.0 / (dh ** 0.5),
        page_size=page_size, n_pages_per_seq=npps, n_slots=n_slots)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), q_map),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), q_map),
        scratch_shapes=[
            pltpu.VMEM((2, page_size, dh), k_hot.dtype),
            pltpu.VMEM((2, page_size, dh), v_hot.dtype),
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(st_flat, lengths.astype(jnp.int32), q, k_hot, v_hot)
