"""Pallas TPU paged-attention decode: one query token vs a paged KV pool.

vLLM-style paged KV adapted to TPU: KV lives in a page pool
[n_pages, page_size, Hkv, dh]; each sequence's logical context is a
page_table row. The kernel fuses Leap's data path with the consumer: the
page_table is a scalar-prefetch operand, so each (batch, kv-head, page) grid
step DMAs exactly the page the table names — gather and attention in one
pass, no [B, T, ...] contiguous cache ever materializes (that contiguous
copy is the "block layer" overhead this kernel deletes).

Online softmax state (m, l, acc) for the G grouped q-heads lives in VMEM
scratch across the page sweep (pages innermost). Padded/unused trailing
pages are masked by the sequence length (also scalar-prefetched).

VMEM per step: k/v page tiles 2 x page_size x dh x 4 B (+ q tile G x dh) —
page_size 64, dh 128 ≈ 64 KB: DMA-latency-bound, exactly the regime where
prefetch-ahead (issuing the next page's DMA early) pays, mirroring the
paper's timeliness axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  sm_scale: float, page_size: int, n_pages_per_seq: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # [G, dh]
    k = k_ref[0, :, 0].astype(jnp.float32)               # [page_size, dh]
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, page_size]

    tpos = (j * page_size
            + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
    mask = tpos < len_ref[b]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * corr
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_scr[...] = m_new

    @pl.when(j == n_pages_per_seq - 1)
    def _write():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attention_fwd(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        page_table: jax.Array, lengths: jax.Array, *,
                        sm_scale: float | None = None,
                        interpret: bool = True) -> jax.Array:
    """q [B,Hkv,G,dh]; pools [n_pages,page_size,Hkv,dh];
    page_table [B,n_pages_per_seq] int32; lengths [B] int32 -> [B,Hkv,G,dh].
    """
    B, Hkv, G, dh = q.shape
    n_pages, page_size = k_pool.shape[0], k_pool.shape[1]
    npps = page_table.shape[1]
    pt_flat = jnp.clip(page_table.reshape(-1), 0, n_pages - 1)

    def q_map(b, h, j, pt, ln):
        return (b, h, 0, 0)

    def kv_map(b, h, j, pt, ln):
        return (pt[b * npps + j], 0, h, 0)

    kernel = functools.partial(
        _paged_kernel, sm_scale=sm_scale or 1.0 / (dh ** 0.5),
        page_size=page_size, n_pages_per_seq=npps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, npps),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), q_map),
            pl.BlockSpec((1, page_size, 1, dh), kv_map),
            pl.BlockSpec((1, page_size, 1, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(pt_flat, lengths.astype(jnp.int32), q, k_pool, v_pool)
