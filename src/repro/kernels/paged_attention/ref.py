"""Pure-jnp oracle for paged decode attention: gather pages, exact softmax.

Both oracles mask *invalid table entries* (negative, or past the pool/slot
edge) out of the softmax, matching the kernels: the gather index is clipped
only so it stays in range, but a poisoned entry contributes nothing to the
output instead of silently reading page 0's bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _masked_softmax_attend(q, k, v, mask, sm_scale):
    """q [B,Hkv,G,dh]; k/v [B,T,Hkv,dh] f32; mask [B,T] -> [B,Hkv,G,dh]."""
    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32), k) * sm_scale
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgt,bthd->bhgd", p, v).astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        page_table: jax.Array, lengths: jax.Array,
                        sm_scale: float | None = None) -> jax.Array:
    """Same contract as kernel.paged_attention_fwd."""
    B, Hkv, G, dh = q.shape
    n_pages, page_size = k_pool.shape[0], k_pool.shape[1]
    valid = (page_table >= 0) & (page_table < n_pages)   # [B, npps]
    pt = jnp.clip(page_table, 0, n_pages - 1)
    k = k_pool[pt]                                  # [B,npps,page,Hkv,dh]
    v = v_pool[pt]
    B_, npps = pt.shape
    T = npps * page_size
    k = k.reshape(B, T, Hkv, dh).astype(jnp.float32)
    v = v.reshape(B, T, Hkv, dh).astype(jnp.float32)
    mask = jnp.arange(T)[None, :] < lengths[:, None]
    mask = mask & jnp.repeat(valid, page_size, axis=1)
    return _masked_softmax_attend(q, k, v, mask,
                                  sm_scale or 1.0 / (dh ** 0.5))


def paged_attention_hot_slots_ref(q: jax.Array, k_hot: jax.Array,
                                  v_hot: jax.Array, slot_table: jax.Array,
                                  lengths: jax.Array,
                                  sm_scale: float | None = None) -> jax.Array:
    """Same contract as kernel.paged_attention_hot_slots_fwd.

    q [S,Hkv,G,dh]; hot pools [S,n_slots,page,Hkv,dh]; slot_table [S,npps]
    per-stream slot ids (-1 or out-of-range = masked); lengths [S].
    """
    S, Hkv, G, dh = q.shape
    n_slots, page_size = k_hot.shape[1], k_hot.shape[2]
    valid = (slot_table >= 0) & (slot_table < n_slots)   # [S, npps]
    st = jnp.clip(slot_table, 0, n_slots - 1)
    k = jnp.take_along_axis(k_hot, st[:, :, None, None, None], axis=1)
    v = jnp.take_along_axis(v_hot, st[:, :, None, None, None], axis=1)
    S_, npps = st.shape
    T = npps * page_size
    k = k.reshape(S, T, Hkv, dh).astype(jnp.float32)
    v = v.reshape(S, T, Hkv, dh).astype(jnp.float32)
    mask = jnp.arange(T)[None, :] < lengths[:, None]
    mask = mask & jnp.repeat(valid, page_size, axis=1)
    return _masked_softmax_attend(q, k, v, mask,
                                  sm_scale or 1.0 / (dh ** 0.5))
