"""Pure-jnp oracle for paged decode attention: gather pages, exact softmax."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        page_table: jax.Array, lengths: jax.Array,
                        sm_scale: float | None = None) -> jax.Array:
    """Same contract as kernel.paged_attention_fwd."""
    B, Hkv, G, dh = q.shape
    n_pages, page_size = k_pool.shape[0], k_pool.shape[1]
    pt = jnp.clip(page_table, 0, n_pages - 1)
    k = k_pool[pt]                                  # [B,npps,page,Hkv,dh]
    v = v_pool[pt]
    B_, npps = pt.shape
    T = npps * page_size
    k = k.reshape(B, T, Hkv, dh).astype(jnp.float32)
    v = v.reshape(B, T, Hkv, dh).astype(jnp.float32)
    scale = sm_scale or 1.0 / (dh ** 0.5)
    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32), k) * scale
    mask = jnp.arange(T)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgt,bthd->bhgd", p, v).astype(q.dtype)
