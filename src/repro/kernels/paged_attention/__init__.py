from .ops import paged_attention
from .ref import paged_attention_ref

__all__ = ["paged_attention", "paged_attention_ref"]
