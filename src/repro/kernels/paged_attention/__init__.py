from .ops import paged_attention, paged_attention_hot_slots
from .ref import paged_attention_hot_slots_ref, paged_attention_ref

__all__ = [
    "paged_attention",
    "paged_attention_hot_slots",
    "paged_attention_hot_slots_ref",
    "paged_attention_ref",
]
