"""Pure-jnp oracle for gather_pages."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_pages_ref(pool: jax.Array, indices: jax.Array) -> jax.Array:
    idx = jnp.clip(indices, 0, pool.shape[0] - 1)
    return jnp.take(pool, idx, axis=0)
