"""Jit'd wrapper for the page-gather kernel (arbitrary page payload shape)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import gather_pages_fwd
from .ref import gather_pages_ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def gather_pages(pool: jax.Array, indices: jax.Array, *,
                 interpret: bool | None = None,
                 use_kernel: bool = True) -> jax.Array:
    """pool [n_pages, ...page shape], indices [K] -> [K, ...page shape]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not use_kernel:
        return gather_pages_ref(pool.reshape(pool.shape[0], -1),
                                indices).reshape((indices.shape[0],)
                                                 + pool.shape[1:])
    flat = pool.reshape(pool.shape[0], -1)
    out = gather_pages_fwd(flat, indices.astype(jnp.int32),
                           interpret=interpret)
    return out.reshape((indices.shape[0],) + pool.shape[1:])
