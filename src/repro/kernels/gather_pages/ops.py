"""Jit'd wrapper for the page-gather kernel (arbitrary page payload shape)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import gather_pages_async_fwd, gather_pages_fwd
from .ref import gather_pages_ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def gather_pages(pool: jax.Array, indices: jax.Array, *,
                 interpret: bool | None = None,
                 use_kernel: bool = True) -> jax.Array:
    """pool [n_pages, ...page shape], indices [K] -> [K, ...page shape].

    Synchronous pipelined gather: the Pallas emitter double-buffers the
    HBM->VMEM page DMAs behind the scenes. ``interpret=None`` auto-selects
    interpret mode off-TPU; ``use_kernel=False`` falls back to the jnp
    oracle. Out-of-range indices are clamped.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not use_kernel:
        return gather_pages_ref(pool.reshape(pool.shape[0], -1),
                                indices).reshape((indices.shape[0],)
                                                 + pool.shape[1:])
    flat = pool.reshape(pool.shape[0], -1)
    out = gather_pages_fwd(flat, indices.astype(jnp.int32),
                           interpret=interpret)
    return out.reshape((indices.shape[0],) + pool.shape[1:])


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def gather_pages_async(pool: jax.Array, indices: jax.Array, *,
                       interpret: bool | None = None,
                       use_kernel: bool = True) -> jax.Array:
    """Issue/wait gather: explicit ``make_async_copy`` pairs in the kernel.

    Same contract as :func:`gather_pages` (same shapes, dtypes, clamping);
    the difference is *who* overlaps the copies — the kernel issues the DMA
    for page k+1 before waiting on page k, the depth-2 collapse of the
    async data path's in-flight ring (DESIGN.md §4). Off-TPU
    (``interpret=None``) this runs in interpret mode, which emulates the
    semaphore waits — semantics preserved, no real overlap.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not use_kernel:
        return gather_pages_ref(pool.reshape(pool.shape[0], -1),
                                indices).reshape((indices.shape[0],)
                                                 + pool.shape[1:])
    flat = pool.reshape(pool.shape[0], -1)
    out = gather_pages_async_fwd(flat, indices.astype(jnp.int32),
                                 interpret=interpret)
    return out.reshape((indices.shape[0],) + pool.shape[1:])
