from .ops import gather_pages, gather_pages_async
from .ref import gather_pages_ref

__all__ = ["gather_pages", "gather_pages_async", "gather_pages_ref"]
