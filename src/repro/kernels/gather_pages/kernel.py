"""Pallas TPU page-gather: Leap's lean data path, kernel form.

The paper's C4 contribution — bypass the block layer's staging/batching and
stream pages directly with per-core async queues — maps on TPU to a
scalar-prefetch-driven gather: the page-index list (what Leap's prefetcher
decided to fetch) is a scalar-prefetch operand, so the BlockSpec index_map
redirects each grid step's HBM->VMEM DMA straight at the requested page.
Pallas' pipeline emitter double-buffers those DMAs: page i+1 is in flight
while page i is written out — the "async RDMA queue" analogue, with zero
intermediate staging in HBM.

Block = one page (page_elems flattened). VMEM per step = 2 pages in flight
x page bytes; a 32 KB KV page (16 tok x 8 kv-heads x 128 dim x 2 B) uses
64 KB — far under v5e's ~16 MB VMEM, so the pipeline stays DMA-bound, which
is the point (roofline: pure memory term).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, pool_ref, out_ref):
    # idx_ref is scalar-prefetch (drives the index_map); body is a pure copy.
    out_ref[...] = pool_ref[...]


def _gather_async_kernel(idx_ref, pool_ref, out_ref, scratch_ref, sem_ref):
    """Manual issue/wait gather: explicit double-buffered async copies.

    ``pool_ref`` stays in HBM (memory_space=ANY); each requested page is
    DMA'd into one of two VMEM scratch slots via ``pltpu.make_async_copy``.
    The copy for page k+1 is *issued* before the copy for page k is
    *waited* on — the in-flight ring of the async data path (DESIGN.md §4)
    collapsed to depth 2, so the consumer's write-out of page k overlaps
    page k+1's transfer.
    """
    K = out_ref.shape[0]

    def get_dma(slot, k):
        return pltpu.make_async_copy(
            pool_ref.at[idx_ref[k]],     # HBM page row
            scratch_ref.at[slot],        # VMEM landing buffer
            sem_ref.at[slot])

    get_dma(0, 0).start()                # warm-up: issue page 0

    def body(k, carry):
        cur = jax.lax.rem(k, 2)
        nxt = jax.lax.rem(k + 1, 2)

        @pl.when(k + 1 < K)
        def _():
            get_dma(nxt, k + 1).start()  # issue k+1 while k is in flight

        get_dma(cur, k).wait()           # wait: k's page has landed
        out_ref[pl.ds(k, 1), :] = scratch_ref[cur][None, :]
        return carry

    jax.lax.fori_loop(0, K, body, None)


def gather_pages_fwd(pool: jax.Array, indices: jax.Array, *,
                     interpret: bool = True) -> jax.Array:
    """pool [n_pages, E], indices [K] int32 -> out [K, E].

    Out-of-range indices are clamped (callers mask invalid requests; the
    Leap controller emits candidates that may run off the pool edge).
    """
    n_pages, E = pool.shape
    K = indices.shape[0]
    idx = jnp.clip(indices, 0, n_pages - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(K,),
        in_specs=[pl.BlockSpec((1, E), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, E), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, E), pool.dtype),
        interpret=interpret,
    )(idx, pool)


def gather_pages_async_fwd(pool: jax.Array, indices: jax.Array, *,
                           interpret: bool = True) -> jax.Array:
    """pool [n_pages, E], indices [K] int32 -> out [K, E], issue/wait form.

    Functionally identical to :func:`gather_pages_fwd` (out-of-range indices
    clamped) but the HBM->VMEM page copies are explicit
    ``pltpu.make_async_copy`` issue/wait pairs driven by the kernel itself,
    not the pipeline emitter — the kernel-level mirror of the
    ``pool_issue``/``pool_wait`` data path. VMEM footprint: 2 pages in
    flight + the [K, E] output block.
    """
    n_pages, E = pool.shape
    K = indices.shape[0]
    idx = jnp.clip(indices, 0, n_pages - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)],
        out_specs=pl.BlockSpec((K, E), lambda i, idx_ref: (0, 0)),
        scratch_shapes=[pltpu.VMEM((2, E), pool.dtype),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    return pl.pallas_call(
        _gather_async_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, E), pool.dtype),
        interpret=interpret,
    )(idx, pool)
