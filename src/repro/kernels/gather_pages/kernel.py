"""Pallas TPU page-gather: Leap's lean data path, kernel form.

The paper's C4 contribution — bypass the block layer's staging/batching and
stream pages directly with per-core async queues — maps on TPU to a
scalar-prefetch-driven gather: the page-index list (what Leap's prefetcher
decided to fetch) is a scalar-prefetch operand, so the BlockSpec index_map
redirects each grid step's HBM->VMEM DMA straight at the requested page.
Pallas' pipeline emitter double-buffers those DMAs: page i+1 is in flight
while page i is written out — the "async RDMA queue" analogue, with zero
intermediate staging in HBM.

Block = one page (page_elems flattened). VMEM per step = 2 pages in flight
x page bytes; a 32 KB KV page (16 tok x 8 kv-heads x 128 dim x 2 B) uses
64 KB — far under v5e's ~16 MB VMEM, so the pipeline stays DMA-bound, which
is the point (roofline: pure memory term).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, pool_ref, out_ref):
    # idx_ref is scalar-prefetch (drives the index_map); body is a pure copy.
    out_ref[...] = pool_ref[...]


def gather_pages_fwd(pool: jax.Array, indices: jax.Array, *,
                     interpret: bool = True) -> jax.Array:
    """pool [n_pages, E], indices [K] int32 -> out [K, E].

    Out-of-range indices are clamped (callers mask invalid requests; the
    Leap controller emits candidates that may run off the pool edge).
    """
    n_pages, E = pool.shape
    K = indices.shape[0]
    idx = jnp.clip(indices, 0, n_pages - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(K,),
        in_specs=[pl.BlockSpec((1, E), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, E), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, E), pool.dtype),
        interpret=interpret,
    )(idx, pool)
