"""Pallas TPU kernels for the perf-critical data-path hot spots.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, interpret=True off-TPU), ref.py (pure-jnp oracle for tests).
"""

from .flash_attention import flash_attention, flash_attention_ref
from .gather_pages import gather_pages, gather_pages_async, gather_pages_ref
from .paged_attention import paged_attention, paged_attention_ref
from .selective_scan import selective_scan, selective_scan_ref

__all__ = ["flash_attention", "flash_attention_ref", "gather_pages",
           "gather_pages_async", "selective_scan", "selective_scan_ref",
           "gather_pages_ref", "paged_attention", "paged_attention_ref"]
