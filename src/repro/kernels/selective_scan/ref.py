"""Pure-jnp oracle for the fused selective scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(dt, b, c, x, a):
    """dt/x [B,S,di], b/c [B,S,N], a [di,N] -> y [B,S,di]."""
    B, S, di = dt.shape
    dtf, bf, cf, xf, af = (t.astype(jnp.float32) for t in (dt, b, c, x, a))

    def step(h, t):
        da = jnp.exp(dtf[:, t][..., None] * af)         # [B,di,N]
        h = da * h + (dtf[:, t] * xf[:, t])[..., None] * bf[:, t][:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, cf[:, t])
        return h, y

    h0 = jnp.zeros((B, di, a.shape[-1]), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return ys.swapaxes(0, 1).astype(dt.dtype)


def selective_scan_state_ref(dt, b, c, x, a):
    """Final state h_S of the reference recurrence (decode carry)."""
    B, S, di = dt.shape
    dtf, bf, xf, af = (t.astype(jnp.float32) for t in (dt, b, x, a))

    def step(h, t):
        da = jnp.exp(dtf[:, t][..., None] * af)
        h = da * h + (dtf[:, t] * xf[:, t])[..., None] * bf[:, t][:, None, :]
        return h, None

    h0 = jnp.zeros((B, di, a.shape[-1]), jnp.float32)
    h, _ = jax.lax.scan(step, h0, jnp.arange(S))
    return h
