"""Jit'd wrapper for the fused selective scan."""

from __future__ import annotations

import functools

import jax

from .kernel import selective_scan_fwd
from .ref import selective_scan_ref


@functools.partial(jax.jit, static_argnames=("block_t", "block_d",
                                             "interpret", "use_kernel",
                                             "return_state"))
def selective_scan(dt, b, c, x, a, *, block_t=128, block_d=128,
                   interpret=None, use_kernel=True, return_state=False):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not use_kernel:
        y = selective_scan_ref(dt, b, c, x, a)
        if not return_state:
            return y
        # oracle state via one extra step of the reference recurrence
        from .ref import selective_scan_state_ref
        return y, selective_scan_state_ref(dt, b, c, x, a)
    y, h = selective_scan_fwd(dt, b, c, x, a, block_t=block_t,
                              block_d=block_d, interpret=interpret)
    return (y, h) if return_state else y
