"""Pallas TPU fused selective scan (Mamba S6) — h lives in VMEM.

The §Perf hillclimb on jamba/train_4k refuted "shrink the (Δ,B,C) inputs"
(H3): the dominant HBM traffic is the per-step state carry
``h [B,di,N]`` that a jnp ``lax.scan`` writes back every token (~34 GB per
layer per sweep at 4K seq). This kernel is the structural fix: the time
dimension is the innermost grid axis, so ``h`` persists in a VMEM scratch
across the whole sweep and HBM sees only the inputs once and ``y`` once.

Grid = (B, di/bd, S/bt) — time innermost (TPU grids iterate sequentially,
scratch persists); channel blocks are independent scans (S6's recurrence
is elementwise over di). VMEM per step: dt/x tiles [bt, bd], b/c tiles
[bt, N], h [bd, N], y [bt, bd] ≈ (2·bt·bd + 2·bt·N + bd·N)·4 B — with
bt=bd=128, N=16 ≈ 160 KB, far under ~16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sscan_kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, y_ref, h_out_ref,
                  h_scr, *, block_t: int, n_t_blocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    dt = dt_ref[0].astype(jnp.float32)          # [bt, bd]
    bmat = b_ref[0].astype(jnp.float32)         # [bt, N]
    cmat = c_ref[0].astype(jnp.float32)         # [bt, N]
    x = x_ref[0].astype(jnp.float32)            # [bt, bd]
    a = a_ref[...].astype(jnp.float32)          # [bd, N]

    def step(t, h):
        da = jnp.exp(dt[t][:, None] * a)                     # [bd, N]
        h = da * h + (dt[t] * x[t])[:, None] * bmat[t][None, :]
        y_ref[0, t, :] = jnp.sum(h * cmat[t][None, :], axis=1).astype(
            y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, block_t, step, h_scr[...])

    @pl.when(it == n_t_blocks - 1)
    def _emit_state():
        h_out_ref[0] = h_scr[...]               # decode carry (prefill)


def selective_scan_fwd(dt: jax.Array, b: jax.Array, c: jax.Array,
                       x: jax.Array, a: jax.Array, *,
                       block_t: int = 128, block_d: int = 128,
                       interpret: bool = True):
    """dt/x [B,S,di], b/c [B,S,N], a [di,N] ->
    (y [B,S,di], h_final [B,di,N]) with h_0 = 0."""
    B, S, di = dt.shape
    N = b.shape[-1]
    while S % block_t:
        block_t //= 2
    while di % block_d:
        block_d //= 2
    nt, nd = S // block_t, di // block_d

    kernel = functools.partial(_sscan_kernel, block_t=block_t, n_t_blocks=nt)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_d),
                         lambda ib, idd, it: (ib, it, idd)),
            pl.BlockSpec((1, block_t, N), lambda ib, idd, it: (ib, it, 0)),
            pl.BlockSpec((1, block_t, N), lambda ib, idd, it: (ib, it, 0)),
            pl.BlockSpec((1, block_t, block_d),
                         lambda ib, idd, it: (ib, it, idd)),
            pl.BlockSpec((block_d, N), lambda ib, idd, it: (idd, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d),
                         lambda ib, idd, it: (ib, it, idd)),
            pl.BlockSpec((1, block_d, N), lambda ib, idd, it: (ib, idd, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), dt.dtype),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(dt, b, c, x, a)
