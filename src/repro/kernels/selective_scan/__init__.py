from .ops import selective_scan
from .ref import selective_scan_ref

__all__ = ["selective_scan", "selective_scan_ref"]
