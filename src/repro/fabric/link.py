"""Fabric links: queue pairs, bandwidth arbitration, heterogeneous tiers.

A :class:`FabricLink` models one transfer substrate (an RDMA NIC, a disk
queue, an ICI hop): ``width`` parallel channels, each moving one page in
``request.t_xfer`` µs, fed from queue pairs under an arbitration policy:

* ``"fifo"`` — the shared-data-path baseline (paper §2.3/Fig. 13): one
  queue pair, strict arrival order across *all* tenants and request
  kinds. A tenant's prefetch burst head-of-line blocks every other
  tenant's demand fetch — exactly the interference Leap §4.4 removes.
* ``"per_tenant_qp"`` — Leap's lean path: each tenant registers its own
  queue pair (or shares one modulo ``n_qps``); channels round-robin over
  non-empty QPs, and within a QP *demand* fetches go before *prefetch*
  fills (the async prefetch queues of §4.4: prefetches consume spare
  bandwidth but never sit in front of a faulting process).

Heterogeneous tiers (mixed disk + RDMA deployments) are modeled by
instantiating one link per tier and routing each tenant to the tier its
latency model names — see ``sim.run_fabric``.
"""

from __future__ import annotations

import dataclasses
from collections import deque

ARBITRATIONS = ("fifo", "per_tenant_qp")


@dataclasses.dataclass
class Request:
    """One page transfer over the fabric."""

    tenant: str                 # tenant name (QP routing key)
    page: int
    kind: str                   # "demand" | "prefetch" | "migrate"
    t_xfer: float               # channel occupancy (µs)
    on_complete: object         # callback(t_done)
    t_submit: float = 0.0
    t_start: float = -1.0
    t_done: float = -1.0

    @property
    def queue_wait(self) -> float:
        return self.t_start - self.t_submit


class _QueuePair:
    """Three sub-queues in strict priority: demand fetches first, then
    prefetch fills, then background page migrations (DESIGN.md §12's third,
    lowest §5 arbitration class — migration only ever rides capacity left
    after both foreground kinds)."""

    __slots__ = ("demand", "prefetch", "migrate")

    def __init__(self):
        self.demand: deque[Request] = deque()
        self.prefetch: deque[Request] = deque()
        self.migrate: deque[Request] = deque()

    def push(self, req: Request) -> None:
        if req.kind == "demand":
            self.demand.append(req)
        elif req.kind == "migrate":
            self.migrate.append(req)
        else:
            self.prefetch.append(req)

    def pop(self) -> Request:
        if self.demand:
            return self.demand.popleft()
        if self.prefetch:
            return self.prefetch.popleft()
        return self.migrate.popleft()

    def __len__(self) -> int:
        return len(self.demand) + len(self.prefetch) + len(self.migrate)


class FabricLink:
    """One fabric tier: ``width`` channels + QPs under an arbitration policy."""

    def __init__(self, engine, name: str = "rdma", width: int = 1,
                 arbitration: str = "fifo", n_qps: int | None = None):
        if arbitration not in ARBITRATIONS:
            raise ValueError(
                f"arbitration must be one of {ARBITRATIONS}, got {arbitration!r}")
        self.engine = engine
        self.name = name
        self.width = int(width)
        self.arbitration = arbitration
        self.n_qps = n_qps              # None: one QP per registered tenant
        self._fifo: deque[Request] = deque()          # fifo mode
        self._qps: list[_QueuePair] = []              # per_tenant_qp mode
        self._qp_of: dict[str, int] = {}
        self._rr = 0                    # round-robin pointer over QPs
        self.busy = 0                   # channels currently transferring
        self.busy_time = 0.0            # sum of completed transfer durations
        self.completed = 0
        self.queue_waits: list[float] = []
        self.dilation = 1.0             # chaos straggler factor (DESIGN.md §9)

    # -- tenant registration (per_tenant_qp) --------------------------------
    def register_tenant(self, tenant: str) -> int:
        """Assign ``tenant`` a queue pair; QPs are shared modulo ``n_qps``."""
        if tenant in self._qp_of:
            return self._qp_of[tenant]
        if self.n_qps is None:
            qp = len(self._qps)
            self._qps.append(_QueuePair())
        else:
            qp = len(self._qp_of) % int(self.n_qps)
            while len(self._qps) <= qp:
                self._qps.append(_QueuePair())
        self._qp_of[tenant] = qp
        return qp

    # -- submission / service ------------------------------------------------
    def submit(self, req: Request) -> None:
        req.t_submit = self.engine.now
        if self.arbitration == "fifo":
            self._fifo.append(req)
        else:
            self._qps[self._qp_of[req.tenant]].push(req)
        self._maybe_start()

    def _next_request(self) -> Request | None:
        if self.arbitration == "fifo":
            return self._fifo.popleft() if self._fifo else None
        n = len(self._qps)
        for k in range(n):
            qp = self._qps[(self._rr + k) % n]
            if qp:
                self._rr = (self._rr + k + 1) % n     # rotate past served QP
                return qp.pop()
        return None

    def _maybe_start(self) -> None:
        while self.busy < self.width:
            req = self._next_request()
            if req is None:
                return
            req.t_start = self.engine.now
            self.busy += 1
            # Chaos slowdown: an in-progress straggler window stretches the
            # channel occupancy of every transfer *started* inside it.
            dur = req.t_xfer * self.dilation
            self.engine.schedule(dur, lambda r=req, d=dur: self._complete(r, d))

    def _complete(self, req: Request, dur: float | None = None) -> None:
        req.t_done = self.engine.now
        self.busy -= 1
        self.busy_time += req.t_xfer if dur is None else dur
        self.completed += 1
        self.queue_waits.append(req.queue_wait)
        self._maybe_start()
        req.on_complete(req.t_done)

    # -- chaos hooks (DESIGN.md §9) ------------------------------------------
    def set_dilation(self, factor: float) -> None:
        """Stretch (or restore, ``factor=1``) this link's transfer times.

        Applies to transfers *starting* from now on; in-flight transfers
        keep their already-scheduled completion.
        """
        if factor <= 0:
            raise ValueError(f"dilation factor must be > 0, got {factor}")
        self.dilation = float(factor)

    def drain(self) -> list[Request]:
        """Remove and return every queued-but-unstarted request (node death:
        the caller re-homes and resubmits them elsewhere). In-flight
        transfers are not touched — their bytes are already moving."""
        drained: list[Request] = list(self._fifo)
        self._fifo.clear()
        for qp in self._qps:
            drained.extend(qp.demand)
            drained.extend(qp.prefetch)
            drained.extend(qp.migrate)
            qp.demand.clear()
            qp.prefetch.clear()
            qp.migrate.clear()
        return drained

    # -- reporting -----------------------------------------------------------
    def utilization(self, horizon: float) -> float:
        """Fraction of channel-time spent transferring over ``horizon``."""
        if horizon <= 0:
            return 0.0
        return self.busy_time / (self.width * horizon)
