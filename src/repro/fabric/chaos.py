"""Fault injection for the fabric: chaos specs and deadline adaptation.

``ChaosSpec`` describes the four fault axes the rack-scale surveys call out
(DESIGN.md §9): per-shard *slowdown* (stragglers), transient per-NIC *budget
degradation*, *node loss* with deterministic page re-homing, and *elastic
tenant grants* that grow/shrink mid-run.  The spec is a frozen, hashable
dataclass of plain-int tuples so it can ride into jit as a static argument —
one recompile per spec, zero tracing overhead per step.

``compile_chaos`` lowers a spec into dense per-step arrays shared *verbatim*
by the jitted scan (``paging/sharded_pool.py``) and the Python lock-step twin
(``fabric/shardstep.py``): a single source of truth means the mirrors cannot
drift on fault timing.

The deadline estimator is an integer fixed-point EWMA (Q8, alpha = 1/4).
Integer arithmetic is deliberate: ``jnp.floor_divide`` on int32 and Python's
``//`` both round toward -inf, so the jitted scan-carried estimator and the
twin's per-stream Python ints stay bit-identical — the property every chaos
pin in ``tests/test_chaos.py`` rests on.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

# Sentinel for "no limit" in budget / grant tables.  Fits int32 with headroom
# for ``INF - demand`` style arithmetic.
INF = 1 << 30

# Q8 fixed point: one step of delay == 256 estimator units.
EST_ONE = 256
# EWMA smoothing alpha = EST_A / EST_D.
EST_A = 1
EST_D = 4


def est_step(est, obs_sum, cnt):
    """One EWMA update from a batch of ``cnt`` landings summing to ``obs_sum``.

    ``est' = est + alpha * (mean_obs - est)`` in Q8 fixed point, evaluated so
    Python ints and int32 arrays produce identical bit patterns (both ``//``
    and ``jnp.floor_divide`` floor).  Caller guarantees ``cnt >= 1``.
    """
    return est + (EST_A * (obs_sum * EST_ONE - cnt * est)) // (EST_D * cnt)


def est_delay(est):
    """Round a Q8 estimate to whole steps, clamped to >= 1."""
    d = (est + EST_ONE // 2) // EST_ONE
    return max(1, d) if isinstance(d, int) else d  # jnp callers clamp themselves


def est_init(n_streams: int, n_shards: int, near: int, far: int) -> np.ndarray:
    """Initial per-(stream, shard) Q8 estimates seeded from the static delays.

    Stream ``s`` is homed on shard ``s % n_shards`` (DESIGN.md §7), so its
    prior is ``near`` for its home NIC and ``far`` everywhere else.
    """
    home = np.arange(n_streams, dtype=np.int64) % max(1, n_shards)
    base = np.where(np.arange(n_shards)[None, :] == home[:, None], near, far)
    return (base * EST_ONE).astype(np.int32)


def rehome_shard(page: int, home0: int, dead: int, n_shards: int) -> int:
    """Deterministic re-home rule: pages on the dead shard move to
    ``alive[page % (n_shards - 1)]`` where ``alive`` is the sorted list of
    surviving shards.  Both mirrors and the event engine use this rule."""
    if home0 != dead:
        return home0
    alive = [g for g in range(n_shards) if g != dead]
    return alive[page % (n_shards - 1)]


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Declarative fault schedule.  All fields are tuples of plain ints so the
    spec is hashable and can be a static jit argument.

    * ``slowdown``: ``(shard, factor, onset, recovery)`` — physical transfer
      time from ``shard`` is multiplied by ``factor`` for steps in
      ``[onset, recovery)``.  Later entries override earlier ones on overlap
      (this is what lets a ramp be written as successive entries).
    * ``degradation``: ``(shard, budget, onset, recovery)`` — the per-NIC
      prefetch budget of ``shard`` is capped at ``budget`` during the window.
    * ``node_loss``: ``(shard, step)`` or ``None`` — at the top of ``step``
      the shard dies: its resident prefetches are invalidated (pollution) and
      its pages are re-homed by :func:`rehome_shard` for all scheduling
      decisions from that step on.  Bytes keep flowing from the original
      placement (the survivor holds a replica), so the data plane is
      unchanged — re-homing is scheduling metadata only.
    * ``grants``: ``(stream, grant, onset, recovery)`` — elastic tenant
      memory: stream's unconsumed-prefetch + in-flight footprint is capped at
      ``grant`` pages during the window; issues beyond it are drops.
    * ``adaptive_deadline``: when true, prefetch *deadlines* come from the
      EWMA estimator instead of the static near/far delay.  Classification
      only: it never changes when bytes move, just whether a landing counts
      as deferred.
    """

    slowdown: tuple = ()
    degradation: tuple = ()
    node_loss: tuple | None = None
    grants: tuple = ()
    adaptive_deadline: bool = False

    def __post_init__(self):
        object.__setattr__(self, "slowdown", tuple(tuple(int(x) for x in e) for e in self.slowdown))
        object.__setattr__(
            self, "degradation", tuple(tuple(int(x) for x in e) for e in self.degradation))
        object.__setattr__(self, "grants", tuple(tuple(int(x) for x in e) for e in self.grants))
        if self.node_loss is not None:
            object.__setattr__(self, "node_loss", tuple(int(x) for x in self.node_loss))
        for name, width in (("slowdown", 4), ("degradation", 4), ("grants", 4)):
            for e in getattr(self, name):
                if len(e) != width:
                    raise ValueError(f"{name} entries are {width}-tuples, got {e}")
        if self.node_loss is not None and len(self.node_loss) != 2:
            raise ValueError(f"node_loss is (shard, step), got {self.node_loss}")
        for _, factor, onset, recovery in self.slowdown:
            if factor < 1 or onset < 0 or recovery <= onset:
                raise ValueError("slowdown needs factor >= 1 and onset < recovery")
        for _, budget, onset, recovery in self.degradation:
            if budget < 0 or onset < 0 or recovery <= onset:
                raise ValueError("degradation needs budget >= 0 and onset < recovery")
        for _, grant, onset, recovery in self.grants:
            if grant < 0 or onset < 0 or recovery <= onset:
                raise ValueError("grants need grant >= 0 and onset < recovery")

    @property
    def any_faults(self) -> bool:
        return bool(self.slowdown or self.degradation or self.grants
                    or self.node_loss is not None)

    def to_json(self) -> str:
        return json.dumps({
            "slowdown": [list(e) for e in self.slowdown],
            "degradation": [list(e) for e in self.degradation],
            "node_loss": list(self.node_loss) if self.node_loss is not None else None,
            "grants": [list(e) for e in self.grants],
            "adaptive_deadline": self.adaptive_deadline,
        }, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "ChaosSpec":
        d = json.loads(text)
        return cls(
            slowdown=tuple(tuple(e) for e in d.get("slowdown", ())),
            degradation=tuple(tuple(e) for e in d.get("degradation", ())),
            node_loss=tuple(d["node_loss"]) if d.get("node_loss") else None,
            grants=tuple(tuple(e) for e in d.get("grants", ())),
            adaptive_deadline=bool(d.get("adaptive_deadline", False)),
        )


def compile_chaos(spec: ChaosSpec, *, n_steps: int, n_streams: int, n_shards: int,
                  n_pages: int, placement: str, base_budget: int | None) -> dict:
    """Lower a spec to dense numpy tables for ``n_steps`` steps.

    Returns a dict with:

    * ``dilation``  int32 ``[T, G]`` — physical-delay multiplier, default 1.
    * ``budget``    int32 ``[T, G]`` — per-NIC budget, ``INF`` when unlimited
      (``base_budget`` is the clean-run value; ``None`` means unlimited).
    * ``grant``     int32 ``[T, S]`` — per-stream footprint cap, default INF.
    * ``home``      int32 ``[2, n_pages]`` — row 0 the physical placement
      home, row 1 the post-death re-homed map (== row 0 when no node loss).
    * ``dead_pages`` int32 ``[n_dead]`` — pages homed on the lost shard.
    * ``t_fail``    int — death step, or ``None``.

    Both the jitted scan and the shardstep twin consume *these arrays*, never
    the raw spec, so fault timing cannot diverge between mirrors.
    """
    T, S, G = int(n_steps), int(n_streams), int(n_shards)
    dilation = np.ones((T, G), dtype=np.int32)
    for shard, factor, onset, recovery in spec.slowdown:
        if not (0 <= shard < G):
            raise ValueError(f"slowdown shard {shard} out of range for {G} shards")
        dilation[min(onset, T):min(recovery, T), shard] = factor

    base = INF if base_budget is None else int(base_budget)
    budget = np.full((T, G), base, dtype=np.int32)
    for shard, cap, onset, recovery in spec.degradation:
        if not (0 <= shard < G):
            raise ValueError(f"degradation shard {shard} out of range for {G} shards")
        budget[min(onset, T):min(recovery, T), shard] = min(cap, base)

    grant = np.full((T, S), INF, dtype=np.int32)
    for stream, cap, onset, recovery in spec.grants:
        if not (0 <= stream < S):
            raise ValueError(f"grant stream {stream} out of range for {S} streams")
        grant[min(onset, T):min(recovery, T), stream] = cap

    # Pure-numpy mirror of repro.core.pool.page_home (this runs inside jit
    # traces where calling the jnp version would capture tracers).
    pages = np.arange(n_pages, dtype=np.int64)
    if placement == "interleave":
        home0 = (pages % G).astype(np.int32)
    elif placement == "block":
        home0 = (pages // (n_pages // G)).astype(np.int32)
    else:
        raise ValueError(f"unknown placement {placement!r}")
    home1 = home0.copy()
    dead_pages = np.zeros((0,), dtype=np.int32)
    t_fail = None
    if spec.node_loss is not None:
        dead, t_fail = spec.node_loss
        if G < 2:
            raise ValueError("node_loss needs at least 2 shards")
        if not (0 <= dead < G):
            raise ValueError(f"node_loss shard {dead} out of range for {G} shards")
        dead_pages = np.nonzero(home0 == dead)[0].astype(np.int32)
        for p in dead_pages:
            home1[p] = rehome_shard(int(p), dead, dead, G)
        t_fail = int(t_fail)

    return {
        "dilation": dilation,
        "budget": budget,
        "grant": grant,
        "home": np.stack([home0, home1]),
        "dead_pages": dead_pages,
        "t_fail": t_fail,
    }
