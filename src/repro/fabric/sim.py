"""Fabric scenario runner: N tenants, shared links, one event loop.

Per-access semantics are the legacy single-stream simulator's, lifted
into discrete events so that streams genuinely contend (DESIGN.md §3.2):

* A fault looks up the tenant's cache at the moment it happens. A hit
  costs ``t_hit``; a page whose transfer is still in flight *defers* the
  access to the transfer-completion event (the swap-cache partial-hit:
  the fault blocks only on the residual transfer time).
* A miss draws its data-path cost, inserts the demand fill, submits a
  transfer to the tenant's fabric tier, and resumes the tenant
  ``datapath + (t_fabric − t_xfer) + alloc-stall`` after the transfer
  completes.
* The policy reacts to every fault (§4.1 tracker semantics); accepted
  prefetch candidates are submitted as *async* transfers the tenant does
  not wait on. They occupy link bandwidth — under ``"fifo"`` arbitration
  they head-of-line block other tenants, under ``"per_tenant_qp"`` they
  only ever sit behind their own tenant's traffic.

A single tenant on a width-1 FIFO link reproduces the legacy
``simulate()`` loop operation-for-operation (same rng stream, same cache
call order), which is what lets ``repro.core.simulate`` be a thin
wrapper over this engine — pinned by ``tests/test_fabric.py``.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.cache import PageCache
from ..core.pool import PLACEMENTS
from ..core.prefetcher import make_prefetcher
from .engine import EventEngine
from .link import FabricLink, Request
from .metrics import FabricReport, TenantReport, percentile_summary
from .shardstep import home_of
from .tenants import Tenant, TenantSpec, tier_of

_PENDING = math.inf     # ready_t of an entry whose transfer is in flight


class _Transfer:
    """In-flight tracked cache fill: entry identity + deferred accesses."""

    __slots__ = ("entry", "waiters")

    def __init__(self, entry):
        self.entry = entry
        self.waiters: list = []


@dataclasses.dataclass
class FabricScenario:
    """Declarative description of one multi-tenant run.

    ``data_path="isolated"`` gives every tenant its own tracker + cache +
    queue pair (Leap §4.1/§4.4); ``"shared"`` funnels all tenants through
    one communal prefetcher + cache + FIFO link under one latency model
    (the stock kernel swap path of Fig. 13's baseline).
    """

    tenants: list
    data_path: str = "isolated"          # "isolated" | "shared"
    arbitration: str | None = None       # default: per data_path
    link_width: int = 1
    n_qps: int | None = None             # per_tenant_qp: QPs shared modulo this
    shared_policy: str = "read_ahead"
    shared_policy_kwargs: dict = dataclasses.field(default_factory=dict)
    shared_cache_capacity: int = 512
    shared_eviction: str = "lru"
    shared_model: object = "rdma_block"
    seed: int = 0
    # -- multi-node fabric (DESIGN.md §7's event-driven mirror) --------------
    # n_nodes > 1 splits every tier into one link per memory node: a page
    # lives on home node page_home(page) (same block/interleave rule as the
    # jitted sharded pool) and every transfer of it — demand or prefetch —
    # rides that node's NIC. A tenant whose spec.home_node differs from the
    # page's home pays far_factor on the transfer time (near/far asymmetry).
    n_nodes: int = 1
    n_pages: int = 0                     # required when n_nodes > 1
    placement: str = "block"             # "block" | "interleave"
    far_factor: float = 1.0
    # -- fault injection (DESIGN.md §9) --------------------------------------
    # A repro.fabric.chaos.ChaosSpec, interpreted on the engine's continuous
    # clock (onset/recovery/death steps are sim times): slowdown dilates the
    # shard's link transfer times, degradation narrows its channel count
    # (floored at 1 — a width-0 link would strand queued transfers forever),
    # node loss drains the dead node's queued requests and resubmits them to
    # the surviving re-homed links, grants resize tenant cache capacity.
    chaos: object = None
    # -- three-tier lifecycle (DESIGN.md §12) --------------------------------
    # A repro.paging.lifecycle.MigrationCfg (or None / enabled=False: off,
    # bit-exact two-tier behavior). On a multi-node fabric each tenant's
    # trend proposes moving upcoming pages to its own home node; the move
    # rides the page's *current* home NIC as a kind="migrate" request — the
    # third, lowest arbitration class under per_tenant_qp — and re-homes the
    # page only when the transfer completes. Continuous-clock analogue of
    # the lock-step mirrors: sanity-checked, not bit-pinned (same stance as
    # chaos above).
    migration: object = None


def _resolve_model(model):
    from ..core.simulator import LATENCY_MODELS
    return LATENCY_MODELS[model] if isinstance(model, str) else model


class _FabricSim:
    """Event handlers wiring tenants, caches and links together."""

    def __init__(self, engine: EventEngine, n_nodes: int = 1,
                 n_pages: int = 0, placement: str = "block",
                 far_factor: float = 1.0, recorder=None, migration=None):
        self.engine = engine
        self.links: dict[str, FabricLink] = {}
        # (cache id, page) -> _Transfer for every *tracked* in-flight fill
        self.inflight: dict[tuple[int, int], _Transfer] = {}
        self.n_nodes = int(n_nodes)
        self.n_pages = int(n_pages)
        self.placement = placement
        self.far_factor = float(far_factor)
        # §8 page-lifecycle tracing (repro.obs.trace.TraceRecorder): the
        # engine runs on a continuous clock, so events are stamped with
        # floor(sim time) and the tenant's index as the stream id
        self._rec = recorder.emit if recorder is not None \
            else (lambda *a, **k: None)
        self.stream_ids: dict[int, int] = {}    # id(tenant) -> index
        # accesses that blocked on an in-flight fill: their wake-time hit
        # is the partial hit (one fault, one demand event)
        self._waited: set = set()
        self.dead_node: int | None = None     # chaos node loss (DESIGN.md §9)
        # §12 online migration: home_override is the event-engine analogue
        # of the jitted pool's time-varying tier table — it rebinds a page's
        # scheduling home when (and only when) a migrate transfer completes.
        from ..paging.lifecycle import resolve
        self.migration = resolve(migration)
        self.home_override: dict[int, int] = {}
        self.last_mig: dict[int, float] = {}    # hysteresis (submit-time claim)
        self.migrations = 0                     # completed re-homes
        self.dropped_migrations = 0             # dest died before completion

    def _sid(self, ten: Tenant) -> int:
        return self.stream_ids.get(id(ten), ten.rank)

    # -- multi-node routing (no-ops at n_nodes == 1) -------------------------
    def _node_of(self, page: int) -> int:
        home = self.home_override.get(int(page))
        if home is None:
            home = home_of(page, self.n_pages, self.n_nodes, self.placement)
        if self.dead_node is not None and home == self.dead_node:
            from .chaos import rehome_shard
            home = rehome_shard(
                min(max(int(page), 0), self.n_pages - 1), home,
                self.dead_node, self.n_nodes)
        return home

    def kill_node(self, node: int) -> None:
        """Chaos node death: re-home the node's pages (same deterministic
        rule as the lock-step mirrors) and move its queued-but-unstarted
        transfers to the surviving links. In-flight transfers complete —
        their bytes were already moving when the node died."""
        if self.n_nodes <= 1:
            raise ValueError("node loss needs a multi-node fabric")
        self.dead_node = int(node)
        for name in sorted(self.links):
            if not name.endswith(f"@n{node}"):
                continue
            tier = name.rsplit("@n", 1)[0]
            for req in self.links[name].drain():
                if req.kind == "migrate":
                    # §12: a queued move whose source NIC just died is moot
                    # (the death rule already re-homed the page) — dropped
                    # and counted, the engine analogue of the lock-step
                    # twins' dead-shard migration drop
                    self.dropped_migrations += 1
                    continue
                target = self.links[f"{tier}@n{self._node_of(req.page)}"]
                target.submit(req)

    def _link_for(self, ten: Tenant, page: int) -> FabricLink:
        if self.n_nodes <= 1:
            return self.links[ten.tier]
        return self.links[f"{ten.tier}@n{self._node_of(page)}"]

    def _xfer_time(self, ten: Tenant, page: int) -> float:
        if self.n_nodes <= 1:
            return ten.model.t_xfer
        far = self._node_of(page) != ten.spec.home_node
        return ten.model.t_xfer * (self.far_factor if far else 1.0)

    def start_tenant(self, ten: Tenant) -> None:
        t0 = float(ten.spec.start_time)
        self.engine.schedule_at(t0, lambda: self._access(ten, t0),
                                rank=ten.rank)

    # -- fault path ----------------------------------------------------------
    def _access(self, ten: Tenant, t_start: float) -> None:
        if ten.finished:
            ten.done_time = self.engine.now
            return
        page = ten.current_page()
        cache = ten.cache
        key = (id(cache), page)
        rec = self.inflight.get(key)
        if rec is not None and cache.entries.get(page) is rec.entry:
            self._waited.add((id(ten), t_start))
            rec.waiters.append((ten, t_start))   # block on residual transfer
            return
        waited = (id(ten), t_start) in self._waited
        self._waited.discard((id(ten), t_start))
        stats = cache.stats
        stats.faults += 1
        ten.faults += 1
        # cache ops are stamped with the fault's *start* time: a deferred
        # access (in-flight page) logically faulted at t_start and blocked
        # on the residual transfer, exactly like the legacy loop's partial
        # hit — lookup's wait term then covers the whole deferral
        hit, pf_hit, wait = cache.lookup(page, t_start)
        if hit:
            stats.cache_hits += 1
            ten.cache_hits += 1
            if pf_hit:
                ten.prefetch_hits += 1
            self._rec("partial" if waited else "hit", int(t_start),
                      self._sid(ten), page=page,
                      shard=self._node_of(page) if self.n_nodes > 1 else -1,
                      pref=pf_hit or waited)
            latency = ten.model.t_hit + wait
            self._issue_prefetches(ten, page, pf_hit, t_start)
            self._finish_access(ten, t_start, latency)
            return
        stats.misses += 1
        ten.misses += 1
        self._rec("miss", int(t_start), self._sid(ten), page=page,
                  shard=self._node_of(page) if self.n_nodes > 1 else -1)
        stall = cache.insert_demand(page, t_start, _PENDING)
        dp = ten.model.datapath_cost(ten.rng)
        entry = cache.entries.get(page)          # tracked only under LRU
        drec = None
        if entry is not None:
            drec = _Transfer(entry)
            self.inflight[key] = drec
        self._link_for(ten, page).submit(Request(
            ten.name, page, "demand", self._xfer_time(ten, page),
            lambda t_done, ten=ten, page=page, key=key, drec=drec,
            t_start=t_start, dp=dp, stall=stall:
                self._demand_done(ten, page, key, drec, t_start, dp,
                                  stall, t_done)))
        self._issue_prefetches(ten, page, False, t_start)

    def _demand_done(self, ten: Tenant, page: int, key, drec, t_start: float,
                     dp: float, stall: float, t_done: float) -> None:
        waiters = self._settle(ten.cache, page, key, drec, t_done)
        m = ten.model
        latency = (t_done - t_start) + dp + (m.t_fabric - m.t_xfer) \
            + stall * m.t_scan_unit
        self._finish_access(ten, t_start, latency)
        self._wake(waiters)

    def _prefetch_done(self, ten: Tenant, page: int, key, rec,
                       t_done: float) -> None:
        self._rec("land", int(t_done), self._sid(ten), page=page)
        self._wake(self._settle(ten.cache, page, key, rec, t_done))

    def _settle(self, cache, page: int, key, rec, t_done: float) -> list:
        """Patch the entry's arrival time and detach the in-flight record."""
        if rec is None:
            return []
        if cache.entries.get(page) is rec.entry:
            rec.entry.ready_t = t_done
        if self.inflight.get(key) is rec:
            del self.inflight[key]
        waiters, rec.waiters = rec.waiters, []
        return waiters

    def _wake(self, waiters: list) -> None:
        for w_ten, w_start in waiters:
            self._access(w_ten, w_start)

    def _issue_prefetches(self, ten: Tenant, page: int, pf_hit: bool,
                          t_fault: float) -> None:
        cache = ten.cache
        for cand in ten.prefetcher.on_fault(page, pf_hit):
            if cand < 0 or cand in cache:
                continue
            if not cache.insert_prefetch(cand, t_fault, _PENDING):
                continue
            cand = int(cand)
            self._rec("issue", int(t_fault), self._sid(ten), page=cand)
            key = (id(cache), cand)
            rec = _Transfer(cache.entries[cand])
            self.inflight[key] = rec
            self._link_for(ten, cand).submit(Request(
                ten.name, cand, "prefetch", self._xfer_time(ten, cand),
                lambda t_done, ten=ten, cand=cand, key=key, rec=rec:
                    self._prefetch_done(ten, cand, key, rec, t_done)))
        self._maybe_migrate(ten, page, t_fault)

    # -- §12 online migration (event-engine mirror) --------------------------
    def _maybe_migrate(self, ten: Tenant, page: int, t_fault: float) -> None:
        """Propose hot-ward moves from the tenant's trend (lock-step rule:
        ``page + trend * (pw_max + lead + j)`` toward the tenant's home
        node). A granted proposal becomes a kind="migrate" request on the
        page's *current* home NIC — it only ever occupies capacity behind
        demand and prefetch — and re-homes the page at completion."""
        cfg = self.migration
        if cfg is None or self.n_nodes <= 1:
            return
        trend = getattr(ten.prefetcher, "current_trend", None)
        if not trend:
            return
        dest = int(ten.spec.home_node)
        if self.dead_node is not None and dest == self.dead_node:
            return                       # moving toward a dead node is moot
        pw = int(getattr(ten.prefetcher, "pw_max", 0))
        for j in range(cfg.mig_per_stream):
            cand = int(page) + int(trend) * (pw + cfg.lead + j)
            if not 0 <= cand < self.n_pages:
                continue
            if self._node_of(cand) == dest:
                continue
            if t_fault - self.last_mig.get(cand, -math.inf) < cfg.cooldown:
                continue
            # hysteresis claim at submit time: the cooldown stamp also
            # dedupes concurrent proposals for the same page
            self.last_mig[cand] = t_fault
            self._link_for(ten, cand).submit(Request(
                ten.name, cand, "migrate", self._xfer_time(ten, cand),
                lambda t_done, ten=ten, cand=cand, dest=dest:
                    self._migration_done(ten, cand, dest, t_done)))

    def _migration_done(self, ten: Tenant, page: int, dest: int,
                        t_done: float) -> None:
        if self.dead_node is not None and dest == self.dead_node:
            self.dropped_migrations += 1  # dest died while the move queued
            return
        self.home_override[page] = dest
        self.migrations += 1
        self._rec("migrate", int(t_done), self._sid(ten), page=page,
                  shard=dest)

    def _finish_access(self, ten: Tenant, t_start: float,
                       latency: float) -> None:
        ten.latencies.append(latency)
        ten.cache.stats.latencies.append(latency)
        ten.advance()
        done = t_start + latency
        resume = done + ten.gap_after_access(done)
        if ten.finished:
            ten.done_time = resume
            return
        self.engine.schedule_at(resume, lambda: self._access(ten, resume),
                                rank=ten.rank)


def _schedule_chaos(scenario: FabricScenario, sim: "_FabricSim",
                    engine: EventEngine, tenants: list) -> None:
    """Install a :class:`repro.fabric.chaos.ChaosSpec` as engine events.

    The spec's step numbers are read as engine times. This is the
    continuous-clock analogue of the lock-step chaos semantics — sanity-
    checked (dilation stretches completions, death re-homes traffic), not
    bit-pinned like the linkstep/shardstep mirrors.
    """
    spec = scenario.chaos
    if spec is None:
        return

    def links_of_shard(g: int):
        if scenario.n_nodes <= 1:
            return list(sim.links.values())
        return [ln for name, ln in sim.links.items()
                if name.endswith(f"@n{g}")]

    for g, factor, onset, recovery in spec.slowdown:
        for link in links_of_shard(g):
            engine.schedule_at(
                float(onset), lambda ln=link, f=factor: ln.set_dilation(f))
            engine.schedule_at(
                float(recovery), lambda ln=link: ln.set_dilation(1.0))
    for g, cap, onset, recovery in spec.degradation:
        for link in links_of_shard(g):
            # width floor of 1: a zero-width link would strand queued
            # transfers (and their blocked tenants) forever
            w0 = link.width
            engine.schedule_at(
                float(onset),
                lambda ln=link, c=cap: setattr(ln, "width",
                                               max(1, min(ln.width, c))))
            engine.schedule_at(
                float(recovery),
                lambda ln=link, w=w0: setattr(ln, "width", w))
    if spec.node_loss is not None:
        g, t_fail = spec.node_loss
        if scenario.n_nodes <= 1:
            raise ValueError("chaos node_loss needs n_nodes > 1")
        engine.schedule_at(float(t_fail), lambda: sim.kill_node(g))
    for i, grant, onset, recovery in spec.grants:
        if not 0 <= i < len(tenants):
            raise ValueError(f"chaos grant stream {i} outside the "
                             f"{len(tenants)} tenants")
        cache = tenants[i].cache
        c0 = cache.capacity
        engine.schedule_at(
            float(onset),
            lambda c=cache, v=grant: setattr(c, "capacity", int(v)))
        engine.schedule_at(
            float(recovery), lambda c=cache, v=c0: setattr(c, "capacity", v))


# -- entry points -------------------------------------------------------------
def run_fabric(scenario: FabricScenario, recorder=None) -> FabricReport:
    """Run a multi-tenant scenario; returns the per-tenant/fabric report.

    ``recorder`` (a :class:`repro.obs.trace.TraceRecorder`) receives
    page-level ``hit``/``partial``/``miss``/``issue``/``land`` events with
    ``step = floor(sim time)`` and the tenant's scenario index as the
    stream id (DESIGN.md §8).
    """
    if scenario.data_path not in ("isolated", "shared"):
        raise ValueError(f"data_path must be 'isolated' or 'shared', "
                         f"got {scenario.data_path!r}")
    if scenario.n_nodes > 1:
        if scenario.n_pages <= 0:
            raise ValueError("n_nodes > 1 needs n_pages for page placement")
        if scenario.n_pages % scenario.n_nodes:
            # same up-front rejection as every other §7 entry point — a
            # ragged block split would compute home nodes >= n_nodes
            raise ValueError(f"n_pages={scenario.n_pages} not divisible by "
                             f"n_nodes={scenario.n_nodes}")
        if scenario.placement not in PLACEMENTS:
            # home_of would silently fall through to block on a typo
            raise ValueError(f"placement must be one of {PLACEMENTS}, "
                             f"got {scenario.placement!r}")
        for spec in scenario.tenants:
            if not 0 <= spec.home_node < scenario.n_nodes:
                # an out-of-range home never equals any page's home node,
                # so every transfer would silently pay far_factor
                raise ValueError(
                    f"tenant {spec.name!r}: home_node={spec.home_node} "
                    f"outside [0, {scenario.n_nodes})")
    engine = EventEngine(scenario.seed)
    sim = _FabricSim(engine, n_nodes=scenario.n_nodes,
                     n_pages=scenario.n_pages,
                     placement=scenario.placement,
                     far_factor=scenario.far_factor, recorder=recorder,
                     migration=scenario.migration)
    if sim.migration is not None and scenario.n_nodes <= 1:
        raise ValueError("migration needs a multi-node fabric (n_nodes > 1)")
    arb = scenario.arbitration or (
        "per_tenant_qp" if scenario.data_path == "isolated" else "fifo")

    shared_pf = shared_cache = shared_tier = None
    if scenario.data_path == "shared":
        shared_pf = make_prefetcher(scenario.shared_policy,
                                    **scenario.shared_policy_kwargs)
        shared_cache = PageCache(scenario.shared_cache_capacity,
                                 eviction=scenario.shared_eviction)
        shared_model = _resolve_model(scenario.shared_model)
        # the communal path is one link on the communal model's tier,
        # whatever tier the specs would have picked for themselves
        shared_tier = tier_of(shared_model.name)

    ranks = engine.actor_ranks(len(scenario.tenants))
    tenants: list[Tenant] = []
    for i, spec in enumerate(scenario.tenants):
        if shared_cache is not None:
            pf, cache, model = shared_pf, shared_cache, shared_model
        else:
            pf = make_prefetcher(spec.policy, **spec.policy_kwargs)
            cache = PageCache(spec.cache_capacity, eviction=spec.eviction)
            model = _resolve_model(spec.model)
        rng = np.random.default_rng(
            spec.seed if spec.seed is not None else [scenario.seed, i])
        tenants.append(Tenant(spec, pf, cache, model, rng, rank=ranks[i],
                              shared=shared_cache is not None,
                              tier=shared_tier))

    # one link per tier — or per (tier, memory node) on a multi-node fabric:
    # each node's NIC is its own width/arbitration domain (DESIGN.md §7)
    node_tags = ([""] if scenario.n_nodes <= 1
                 else [f"@n{g}" for g in range(scenario.n_nodes)])
    for tier in sorted({t.tier for t in tenants}):
        for tag in node_tags:
            sim.links[tier + tag] = FabricLink(
                engine, tier + tag, width=scenario.link_width,
                arbitration=arb, n_qps=scenario.n_qps)
    sim.stream_ids = {id(t): i for i, t in enumerate(tenants)}
    for ten in tenants:
        if arb == "per_tenant_qp":
            for tag in node_tags:
                sim.links[ten.tier + tag].register_tenant(ten.name)
        sim.start_tenant(ten)
    _schedule_chaos(scenario, sim, engine, tenants)
    engine.run()

    makespan = max((t.done_time or 0.0 for t in tenants), default=0.0)
    for cache in {id(t.cache): t.cache for t in tenants}.values():
        cache.drain_unconsumed(makespan)
    # async prefetches may still drain after the last tenant finishes;
    # utilization is over the full busy horizon so it stays <= 1
    horizon = max(makespan, engine.now)
    reports = [TenantReport(
        name=t.name, faults=t.faults, cache_hits=t.cache_hits,
        misses=t.misses, prefetch_hits=t.prefetch_hits,
        completion_time=(t.done_time or 0.0) - t.spec.start_time,
        latency=percentile_summary(t.latencies)) for t in tenants]
    link_stats = {tier: {"busy_time": link.busy_time,
                         "utilization": link.utilization(horizon),
                         "completed": link.completed,
                         "avg_queue_wait": float(np.mean(link.queue_waits))
                         if link.queue_waits else 0.0,
                         "p99_queue_wait": float(np.percentile(
                             link.queue_waits, 99))
                         if link.queue_waits else 0.0}
                  for tier, link in sim.links.items()}
    mig_summary = None
    if sim.migration is not None:
        mig_summary = {"migrations": sim.migrations,
                       "dropped": sim.dropped_migrations,
                       "rehomed_pages": len(sim.home_override)}
    return FabricReport(reports, makespan, link_stats, scenario.seed,
                        migration=mig_summary)


def run_single_stream(trace, prefetcher, cache, model="rdma_lean",
                      think_time: float = 0.0, seed: int = 0):
    """Legacy-compatible single stream on the fabric engine.

    Backs ``repro.core.simulate``: one tenant, width-1 FIFO link, rng
    seeded exactly as the legacy loop. Returns a ``SimResult``.
    """
    from ..core.simulator import SimResult
    model = _resolve_model(model)
    engine = EventEngine(seed)
    sim = _FabricSim(engine)
    spec = TenantSpec("stream0", trace, model=model, think_time=think_time)
    ten = Tenant(spec, prefetcher, cache, model,
                 np.random.default_rng(seed), rank=0)
    sim.links[ten.tier] = FabricLink(engine, ten.tier, width=1,
                                     arbitration="fifo")
    sim.start_tenant(ten)
    engine.run()
    cache.drain_unconsumed(ten.done_time or 0.0)
    return SimResult(prefetcher.name, model.name, cache.stats,
                     ten.done_time or 0.0, sim.links[ten.tier].busy_time,
                     cache.scanned_entries)
