"""Per-tenant state: isolated prefetcher + page cache + arrival process.

A :class:`TenantSpec` declares a tenant (trace, policy, cache, latency
model, arrival behavior); :class:`Tenant` is its runtime instantiated by
``sim.run_fabric``. Arrival processes model the workload shapes that
stress a shared fabric:

* ``"constant"`` — a fixed ``think_time`` between accesses (the legacy
  single-stream semantics; ``think_time=0`` is a closed loop).
* ``"bursty"``   — on/off: bursts of ``burst_len`` back-to-back accesses
  separated by exponential idle gaps of mean ``idle_time`` µs drawn from
  the tenant's seeded rng. The "noisy neighbor" of Fig. 13.
* ``"churn"``    — every ``churn_every`` accesses the tenant cold-restarts:
  its prefetcher state resets and its (isolated) cache is dropped, then
  it idles ``churn_downtime`` µs — arriving/departing applications.

Tenants on a *shared* data path reference one communal cache+prefetcher,
so per-tenant effectiveness is tracked here (faults, hits, latencies)
independently of the communal ``PrefetchStats``.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def tier_of(model_name: str) -> str:
    """Fabric tier a latency model rides on (single source of the rule).

    Disk models share the "disk" tier, RDMA models the "rdma" tier, and
    each TPU interconnect is its own substrate ("tpu_ici", "tpu_dcn") —
    ICI and DCN traffic never contend with RDMA links.
    """
    if "disk" in model_name:
        return "disk"
    if model_name.startswith("tpu_"):
        return model_name
    return "rdma"


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Seeded arrival-gap generator shared by tenants and the serving engine.

    One pure description of the three workload shapes (constant / bursty /
    churn) with two consumers:

    * :meth:`gap` — the *access*-level semantics ``Tenant.gap_after_access``
      delegates to: extra idle time after access ``idx - 1`` completed (the
      cursor has already advanced to ``idx``), plus a restart flag when a
      churn boundary was crossed. Draws come from the caller's rng so the
      event-engine behavior is bit-identical to the pre-factored code.
    * :meth:`arrival_times` / :meth:`arrival_steps` — the *request*-level
      semantics the continuous-batching serving engine consumes
      (:mod:`repro.serving`): absolute arrival times of ``n`` requests
      (request 0 at ``t = 0``, then cumulative gaps), without instantiating
      fabric ``Tenant``s. Deterministic given ``seed``.
    """

    kind: str = "constant"              # constant | bursty | churn
    think_time: float = 0.0
    burst_len: int = 64
    idle_time: float = 200.0            # mean off-period (µs)
    churn_every: int = 0
    churn_downtime: float = 500.0

    def __post_init__(self):
        if self.kind not in ("constant", "bursty", "churn"):
            raise ValueError(f"unknown arrival kind {self.kind!r}; expected "
                             "constant | bursty | churn")

    def gap(self, rng: np.random.Generator, idx: int,
            n_total: int) -> tuple[float, bool]:
        """``(extra idle time before item idx, churn-restart flag)``.

        ``idx`` is the *next* item's index (the cursor after the completed
        access / the arriving request's ordinal); boundary draws only
        happen while ``idx < n_total`` so a finished stream never burns an
        rng draw.
        """
        gap = self.think_time
        restart = False
        if self.kind == "bursty" and idx < n_total \
                and idx % max(1, self.burst_len) == 0:
            gap += float(rng.exponential(self.idle_time))
        if self.kind == "churn" and self.churn_every > 0 \
                and idx < n_total and idx % self.churn_every == 0:
            restart = True
            gap += self.churn_downtime
        return gap, restart

    def arrival_times(self, n: int, seed: int = 0) -> np.ndarray:
        """Absolute arrival times (µs) of ``n`` requests; ``t[0] == 0``."""
        rng = np.random.default_rng(seed)
        times = np.zeros(n, np.float64)
        for i in range(1, n):
            g, _ = self.gap(rng, i, n)
            times[i] = times[i - 1] + g
        return times

    def arrival_steps(self, n: int, seed: int = 0,
                      step_us: float = 1000.0) -> np.ndarray:
        """Arrival times quantized onto the engine's step clock."""
        return np.floor(self.arrival_times(n, seed) / max(step_us, 1e-9)
                        ).astype(np.int64)


@dataclasses.dataclass
class TenantSpec:
    name: str
    trace: object                       # sequence of page ids
    policy: str = "leap"
    policy_kwargs: dict = dataclasses.field(default_factory=dict)
    cache_capacity: int = 128
    eviction: str = "eager"
    model: object = "rdma_lean"         # LatencyModel or name; names its tier
    tier: str | None = None             # default: "disk" if model says so
    think_time: float = 0.0
    arrival: str = "constant"           # constant | bursty | churn
    burst_len: int = 64
    idle_time: float = 200.0            # mean off-period (µs)
    churn_every: int = 0
    churn_downtime: float = 500.0
    start_time: float = 0.0
    seed: int | None = None             # None: derived from scenario seed
    home_node: int = 0                  # fabric node this tenant runs on —
    # under a multi-node scenario (FabricScenario.n_nodes > 1) a page access
    # rides the NIC of the *page's* home node and cross-node transfers pay
    # the scenario's far_factor (DESIGN.md §7's event-driven mirror)

    def resolved_tier(self) -> str:
        if self.tier is not None:
            return self.tier
        return tier_of(self.model if isinstance(self.model, str)
                       else self.model.name)

    def arrival_process(self) -> ArrivalProcess:
        """The spec's arrival behavior as a reusable :class:`ArrivalProcess`."""
        return ArrivalProcess(kind=self.arrival, think_time=self.think_time,
                              burst_len=self.burst_len,
                              idle_time=self.idle_time,
                              churn_every=self.churn_every,
                              churn_downtime=self.churn_downtime)


class Tenant:
    """Runtime tenant: trace cursor, per-tenant metrics, arrival process.

    ``shared=True`` marks a tenant on the communal data path: its
    prefetcher and cache are shared infrastructure that churn restarts
    must not clear. ``tier`` overrides the spec's tier (the shared path
    routes everyone over the communal model's tier).
    """

    def __init__(self, spec: TenantSpec, prefetcher, cache, model,
                 rng: np.random.Generator, rank: int = 0,
                 shared: bool = False, tier: str | None = None):
        self.spec = spec
        self.name = spec.name
        self.prefetcher = prefetcher
        self.cache = cache
        self.model = model
        self.rng = rng
        self.rank = rank
        self.shared = shared
        self.tier = tier if tier is not None else spec.resolved_tier()
        self.trace = np.asarray(spec.trace, dtype=np.int64)
        self.idx = 0
        # per-tenant effectiveness (valid even when cache/prefetcher shared)
        self.faults = 0
        self.cache_hits = 0
        self.misses = 0
        self.prefetch_hits = 0
        self.latencies: list[float] = []
        self.done_time: float | None = None   # when the next access would start

    # -- trace cursor --------------------------------------------------------
    @property
    def finished(self) -> bool:
        return self.idx >= len(self.trace)

    def current_page(self) -> int:
        return int(self.trace[self.idx])

    def advance(self) -> None:
        self.idx += 1

    # -- arrival process -----------------------------------------------------
    def gap_after_access(self, now: float | None = None) -> float:
        """Extra idle time *after* the access just completed (on top of
        the latency already charged); also flags churn restarts. ``now``
        is the completion time of the access, used to classify in-flight
        prefetches discarded by a churn restart."""
        gap, restart = self.spec.arrival_process().gap(
            self.rng, self.idx, len(self.trace))
        if restart:
            self.cold_restart(now)
        return gap

    def cold_restart(self, now: float | None = None) -> None:
        """Drop prefetcher state and cache contents — a tenant departing
        and re-arriving with nothing warm. On the shared data path the
        tracker and cache are communal infrastructure serving everyone
        else, so a churning tenant leaves both alone. With ``now`` given,
        prefetches whose transfer had not completed by the restart count
        as ``inflight_at_end`` rather than pollution (they never landed —
        the pollution/in-flight taxonomy of DESIGN.md §4.3)."""
        if self.shared:
            return
        self.prefetcher.reset()
        self.cache.drain_unconsumed(now)
        self.cache.entries.clear()
        self.cache.prefetch_fifo.clear()
