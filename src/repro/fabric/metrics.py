"""Fabric metrics: per-tenant tails, fairness/slowdown, link utilization.

Multi-tenant quality is about *distributions*, not means: the paper's
Fig. 13 argument is that per-application isolation keeps one tenant's
prefetch storm out of another tenant's p99. So the per-tenant report
carries the full percentile ladder (p50/p90/p99/p99.9), and fabric-level
summaries add Jain's fairness index and per-tenant slowdown vs. a solo
(uncontended) run of the same tenant.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs.metrics import percentile_ladder


def percentile_summary(latencies) -> dict:
    """p50/p90/p99/p99.9 + avg/max + n of a latency sample (µs).

    Delegates to the unified ladder in :mod:`repro.obs.metrics`. Empty
    samples report ``NaN`` everywhere plus ``n=0`` — all-zeros would be
    indistinguishable from a genuinely zero-latency tenant downstream.
    """
    return percentile_ladder(latencies, qs=(50.0, 90.0, 99.0, 99.9))


def jain_index(values) -> float:
    """Jain's fairness index: 1.0 = perfectly fair, 1/n = one hog."""
    arr = np.asarray(list(values), dtype=np.float64)
    if arr.size == 0 or not np.any(arr):
        return 1.0
    return float(arr.sum() ** 2 / (arr.size * (arr ** 2).sum()))


def slowdowns(report: "FabricReport", solo: dict) -> dict:
    """Per-tenant slowdown = contended completion / solo completion.

    ``solo`` maps tenant name -> solo completion time (same spec run
    alone on the fabric). 1.0 = no interference; 2.0 = took twice as long.
    """
    out = {}
    for t in report.tenants:
        base = solo.get(t.name)
        if base:
            out[t.name] = t.completion_time / base
    return out


@dataclasses.dataclass
class TenantReport:
    name: str
    faults: int
    cache_hits: int
    misses: int
    prefetch_hits: int
    completion_time: float          # last access done (incl. trailing gap), µs
    latency: dict                   # percentile_summary of per-fault latency

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.faults if self.faults else 0.0

    @property
    def coverage(self) -> float:
        return self.prefetch_hits / self.faults if self.faults else 0.0

    @property
    def throughput(self) -> float:
        """Faults served per µs — the fairness-index input."""
        return self.faults / self.completion_time if self.completion_time else 0.0

    def summary(self) -> dict:
        return {
            "tenant": self.name, "faults": self.faults,
            "hit_rate": round(self.hit_rate, 4),
            "coverage": round(self.coverage, 4),
            "completion_us": round(self.completion_time, 1),
            "p50": round(self.latency["p50"], 2),
            "p99": round(self.latency["p99"], 2),
            "p99.9": round(self.latency["p99.9"], 2),
        }


@dataclasses.dataclass
class FabricReport:
    tenants: list[TenantReport]
    makespan: float                 # max tenant completion time (µs)
    link_stats: dict                # tier -> {busy_time, utilization, completed}
    seed: int
    # DESIGN.md §12: {"migrations", "dropped", "rehomed_pages"} when the
    # scenario ran with a MigrationCfg; None keeps two-tier reports exact.
    migration: dict | None = None

    def tenant(self, name: str) -> TenantReport:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(name)

    @property
    def fairness(self) -> float:
        """Jain index over per-tenant throughputs under contention."""
        return jain_index([t.throughput for t in self.tenants])

    def worst_p99(self) -> float:
        return max((t.latency["p99"] for t in self.tenants), default=0.0)

    def mean_p99(self) -> float:
        ps = [t.latency["p99"] for t in self.tenants]
        return float(np.mean(ps)) if ps else 0.0

    def summary(self) -> dict:
        return {
            "tenants": len(self.tenants),
            "makespan_us": round(self.makespan, 1),
            "worst_p99": round(self.worst_p99(), 2),
            "mean_p99": round(self.mean_p99(), 2),
            "fairness": round(self.fairness, 4),
            "link": {k: {kk: round(vv, 4) if isinstance(vv, float) else vv
                         for kk, vv in v.items()}
                     for k, v in self.link_stats.items()},
        }
