"""Multi-tenant remote-memory fabric: discrete-event simulation subsystem.

Models N concurrent tenant streams (each with an isolated prefetcher +
page cache + arrival process) contending for a shared remote-memory
fabric with configurable queue-pair counts and bandwidth-arbitration
policies — the shared data path of paper §4.1/§4.4 and Fig. 13.

Layout (see DESIGN.md §3):

* :mod:`chaos`   — fault-injection specs (stragglers, degradation, node
  loss, elastic grants) + the fixed-point deadline estimator (DESIGN.md §9).
* :mod:`engine`  — event heap + virtual clock, deterministic tie-breaking.
* :mod:`link`    — fabric links/tiers, queue pairs, arbitration policies.
* :mod:`tenants` — per-tenant specs + runtime (think time, bursts, churn).
* :mod:`metrics` — per-tenant tail latency, fairness, link utilization.
* :mod:`sim`     — scenario runner; also backs ``repro.core.simulate``.
* :mod:`linkstep` — lock-step width-B link twin of the budgeted jitted
  multi-stream path (DESIGN.md §5); the counts cross-validation bridge.
* :mod:`shardstep` — lock-step *sharded*-fabric twin (one NIC per home
  shard, near/far arrival, DESIGN.md §7) of the mesh-sharded cold pool;
  the event engine mirrors the same placement via per-tenant home nodes
  (``TenantSpec.home_node`` + ``FabricScenario.n_nodes``).
"""

from .chaos import ChaosSpec, compile_chaos, est_init, est_step, rehome_shard
from .engine import EventEngine
from .link import ARBITRATIONS, FabricLink, Request
from .linkstep import LinkStepReport, run_linkstep
from .metrics import (FabricReport, TenantReport, jain_index,
                      percentile_summary, slowdowns)
from .shardstep import run_shardstep
from .sim import FabricScenario, run_fabric, run_single_stream
from .tenants import ArrivalProcess, Tenant, TenantSpec

__all__ = [
    "ARBITRATIONS", "ArrivalProcess", "ChaosSpec", "EventEngine",
    "FabricLink", "FabricReport",
    "FabricScenario", "LinkStepReport", "Request", "Tenant", "TenantReport",
    "TenantSpec", "compile_chaos", "est_init", "est_step", "jain_index",
    "percentile_summary", "rehome_shard", "run_fabric", "run_linkstep",
    "run_shardstep", "run_single_stream", "slowdowns",
]
