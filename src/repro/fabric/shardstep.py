"""Step-synchronous sharded-fabric reference: one NIC per home shard.

The lock-step twin of the *mesh-sharded* jitted path
(``repro.paging.sharded_pool.sharded_multi_stream_consume``, DESIGN.md §7),
extending :mod:`repro.fabric.linkstep` from one global link to a fabric of
``n_shards`` NICs:

* every page has a **home shard** — the same ``block``/``interleave``
  placement rule as :func:`repro.core.pool.page_home` — and every transfer
  of that page (demand or prefetch) occupies its home shard's NIC;
* arbitration is the §5 demand-first discipline *per NIC*: shard g's
  prefetch landing capacity at step *t* is
  ``max(0, budget - demand_fetches_on_g[t-1])``, granted to queued
  prefetches homed on g whose nominal arrival has passed, in ascending
  global issue order;
* prefetch arrival is **distance-dependent**: a candidate homed on the
  issuing stream's own shard (stream s lives on shard ``s % n_shards``)
  is ready after ``near_delay`` steps, a cross-shard candidate after
  ``far_delay`` — mirroring the per-candidate deadline vector the jitted
  path feeds :func:`repro.core.pool.pool_issue`.

Same validity domain as linkstep (residency tracked as sets — size the
jitted ``n_slots`` so the free stack never runs dry) and the same
counters/report shape. ``tests/test_sharded_pool.py`` pins the jitted
per-stream hit / partial / deferred / drop counts to this model across
placements, budgets, shard counts and patterns; ``n_shards=1`` reduces to
``run_linkstep`` exactly (also pinned).
"""

from __future__ import annotations

import math

from ..core.history import DEFAULT_H_SIZE
from ..core.metrics import PrefetchStats
from ..core.prefetcher import LeapPrefetcher
from ..core.trend import DEFAULT_N_SPLIT
from ..core.window import DEFAULT_PW_MAX
from .linkstep import LinkStepReport, _Inflight, _Stream


def home_of(page: int, n_pages: int, n_shards: int, placement: str) -> int:
    """Python twin of :func:`repro.core.pool.page_home` (host-side ints)."""
    p = min(max(int(page), 0), n_pages - 1)
    if placement == "interleave":
        return p % n_shards
    return p // (n_pages // n_shards)


def run_shardstep(schedules, n_pages: int, n_shards: int, placement: str,
                  budget: int | None, ring_size: int,
                  near_delay: int = 1, far_delay: int = 2,
                  pw_max: int = DEFAULT_PW_MAX, h_size: int = DEFAULT_H_SIZE,
                  n_split: int = DEFAULT_N_SPLIT,
                  recorder=None, chaos=None, migration=None) -> LinkStepReport:
    """Run ``schedules`` (``[S][T]`` page ids) through the sharded fabric.

    ``budget`` is *per NIC* (``None`` = infinite NICs: every eligible
    prefetch lands at its nominal distance-dependent arrival). Returns a
    :class:`repro.fabric.linkstep.LinkStepReport`; the per-step link
    histograms aggregate over all NICs.

    ``recorder`` (:class:`repro.obs.trace.TraceRecorder`) receives every
    transition page-level with the page's home shard stamped — same hook
    contract as :func:`repro.fabric.linkstep.run_linkstep`.

    ``chaos`` (:class:`repro.fabric.chaos.ChaosSpec`) mirrors the fault
    semantics of the jitted chaos path step for step (DESIGN.md §9): the
    same :func:`repro.fabric.chaos.compile_chaos` tables drive per-step
    dilation/budget/grant, node death discards the dead shard's resident
    and in-flight prefetches as pollution and re-homes its pages for every
    scheduling decision, and the same Q8 integer EWMA tracks per-(stream,
    shard) delay — Python ints here, an int32 scan carry there, identical
    bit patterns. Event shard stamps always use the *physical* placement
    home (matching ``decode_stream_events``).

    ``migration`` (:class:`repro.paging.lifecycle.MigrationCfg`) mirrors
    the jitted three-tier lifecycle (DESIGN.md §12) with the same phase
    order and Python-int formulas: heat decay, migration grants out of the
    leftover per-NIC capacity after prefetch grants (lowest-``seq``-wins
    dedupe, cooldown re-check), promote-on-bytes-moved against the
    start-of-step compressed snapshot, demand heat touch, the decompress
    surcharge on cold issue candidates, capacity-driven coldest-first
    demotion, and trend-driven proposals carried one step. Composes with
    ``chaos``: node death re-homes the *dynamic* table and carried
    proposals into the dead shard are dropped and pollution-counted.
    """
    if placement not in ("block", "interleave"):
        raise ValueError(f"unknown placement {placement!r}")
    if n_pages % n_shards:
        raise ValueError(f"n_pages={n_pages} not divisible by "
                         f"n_shards={n_shards}")
    if migration is not None:
        from ..paging.lifecycle import resolve
        mig = resolve(migration)
        if mig is not None:
            near = max(near_delay, 1)
            return _run_shardstep_mig(
                schedules, n_pages, n_shards, placement, budget, ring_size,
                near, max(far_delay, near), pw_max, h_size, n_split,
                recorder, chaos, mig)
    schedules = [[int(p) for p in row] for row in schedules]
    S = len(schedules)
    T = len(schedules[0]) if S else 0
    near_delay = max(near_delay, 1)     # mirrors pool_issue's clamp
    far_delay = max(far_delay, near_delay)
    cap_inf = budget is None
    rec = recorder.emit if recorder is not None else (lambda *a, **k: None)
    home = lambda p: home_of(p, n_pages, n_shards, placement)
    streams = [_Stream(LeapPrefetcher(h_size=h_size, n_split=n_split,
                                      pw_max=pw_max),
                       PrefetchStats(), set(), []) for _ in range(S)]
    demand_hist, landed_hist, issued_hist = [], [], []
    d_prev = [0] * n_shards

    cz = est = None
    if chaos is not None:
        from .chaos import EST_ONE, compile_chaos, est_init, est_step
        cz = compile_chaos(chaos, n_steps=T, n_streams=S, n_shards=n_shards,
                           n_pages=n_pages, placement=placement,
                           base_budget=budget)
        est = [[int(v) for v in row]
               for row in est_init(S, n_shards, near_delay, far_delay)]
        home0 = [int(h) for h in cz["home"][0]]
        home1 = [int(h) for h in cz["home"][1]]

    def sched_home(p: int, t: int) -> int:
        """Scheduling home at step t: the re-homed map after node death."""
        if cz is None:
            return home(p)
        hv = home1 if (cz["t_fail"] is not None and t >= cz["t_fail"]) else home0
        return hv[min(max(int(p), 0), n_pages - 1)]

    for t in range(T):
        if cz is not None and cz["t_fail"] == t:
            # Node death: the dead shard's landed-but-unconsumed prefetches
            # and in-flight fetches are lost — pollution, exactly like the
            # jitted pool_invalidate sweep over the dead page list.
            dead_set = set(int(p) for p in cz["dead_pages"])
            for s, st in enumerate(streams):
                lost = st.resident & dead_set
                st.stats.pollution += len(lost)
                st.resident -= lost
                kept = [e for e in st.queue if e.page not in dead_set]
                dropped = [e for e in st.queue if e.page in dead_set]
                st.stats.pollution += len(dropped)
                st.queue[:] = kept
                # Pollution is a summary kind in the §8 trace contract
                # (folded per-stream run total) — emit one evict per lost
                # entry so the diff against the jitted decode stays zero.
                for p in sorted(lost) + [e.page for e in dropped]:
                    rec("evict", t, s, page=p, shard=home(p))

        # -- 1. per-NIC landing grants: leftover budget, global seq order ----
        if cz is None:
            caps = [math.inf if cap_inf else max(0, budget - d)
                    for d in d_prev]
        else:
            caps = [max(0, int(cz["budget"][t][g]) - d_prev[g])
                    for g in range(n_shards)]
        eligible = sorted((e.seq, s, e) for s, st in enumerate(streams)
                          for e in st.queue if e.ready <= t)
        landed = 0
        obs_sum = [[0] * n_shards for _ in range(S)]
        obs_cnt = [[0] * n_shards for _ in range(S)]
        for _, s, e in eligible:
            g = sched_home(e.page, t)
            if caps[g] <= 0:
                continue                 # this NIC is out of budget; others
            caps[g] -= 1                 # may still land later-seq entries
            st = streams[s]
            st.queue.remove(e)
            st.resident.add(e.page)
            rec("land", t, s, page=e.page, shard=home(e.page), seq=e.seq)
            if e.deadline < t:
                st.stats.deferred += 1
                rec("defer", t, s, page=e.page, shard=home(e.page), seq=e.seq)
            if cz is not None:
                obs_sum[s][g] += t - e.issued_at
                obs_cnt[s][g] += 1
            landed += 1
        landed_hist.append(landed)
        if cz is not None:
            # Estimator update: one order-independent batch fold per step
            # from this step's landings — same formula, same Q8 integers as
            # the jitted scan carry.
            for s in range(S):
                for g in range(n_shards):
                    if obs_cnt[s][g]:
                        est[s][g] = est_step(est[s][g], obs_sum[s][g],
                                             obs_cnt[s][g])

        # -- 2. serve each stream's demand (private residency) ---------------
        d_t = [0] * n_shards
        issued_t = 0
        for s, st in enumerate(streams):
            page = schedules[s][t]
            my_shard = s % n_shards
            st.stats.faults += 1
            inflight = next((e for e in st.queue if e.page == page), None)
            if page in st.resident:
                st.stats.cache_hits += 1
                st.stats.prefetch_hits += 1
                st.resident.discard(page)
                pf_hit = True
                rec("hit", t, s, page=page, shard=home(page), pref=True)
            elif inflight is not None:
                # partial hit: completes early on the page's home NIC
                st.queue.remove(inflight)
                st.stats.cache_hits += 1
                st.stats.prefetch_hits += 1
                st.stats.partial_hits += 1
                rec("partial", t, s, page=page, shard=home(page),
                    seq=inflight.seq, pref=True)
                if inflight.deadline < t:
                    st.stats.deferred += 1
                    rec("defer", t, s, page=page, shard=home(page),
                        seq=inflight.seq)
                d_t[sched_home(page, t)] += 1
                pf_hit = True
            else:
                st.stats.misses += 1
                d_t[sched_home(page, t)] += 1
                pf_hit = False
                rec("miss", t, s, page=page, shard=home(page))

            # -- 3. controller + distance-delayed, globally ordered issue ----
            grant_cap = None if cz is None else int(cz["grant"][t][s])
            for k, cand in enumerate(st.prefetcher.on_fault(page, pf_hit)):
                if cand < 0 or cand >= n_pages:
                    continue
                if cand in st.resident or any(e.page == cand
                                              for e in st.queue):
                    continue
                full = len(st.queue) >= ring_size
                over_grant = (grant_cap is not None and
                              len(st.resident) + len(st.queue) >= grant_cap)
                if full or over_grant:
                    st.drops += 1
                    rec("drop", t, s, page=cand, shard=home(cand))
                    continue
                g_c = sched_home(cand, t)
                base = near_delay if g_c == my_shard else far_delay
                seq = (t * S + s) * pw_max + k
                if cz is None:
                    e = _Inflight(cand, t + base, seq)
                else:
                    true_d = max(1, base * int(cz["dilation"][t][g_c]))
                    if chaos.adaptive_deadline:
                        expect_d = max(1, (est[s][g_c] + EST_ONE // 2)
                                       // EST_ONE)
                    else:
                        expect_d = base
                    e = _Inflight(cand, t + true_d, seq,
                                  expect=t + expect_d, issued_at=t)
                st.queue.append(e)
                st.stats.prefetch_issued += 1
                rec("issue", t, s, page=cand, shard=home(cand), seq=seq)
                issued_t += 1
        demand_hist.append(sum(d_t))
        issued_hist.append(issued_t)
        d_prev = d_t

    return LinkStepReport(
        per_stream=[st.stats for st in streams],
        drops=[st.drops for st in streams],
        resident_unused=[len(st.resident) for st in streams],
        inflight_at_end=[len(st.queue) for st in streams],
        demand_fetches=demand_hist, landed=landed_hist, issued=issued_hist)


def _run_shardstep_mig(schedules, n_pages, n_shards, placement, budget,
                       ring_size, near_delay, far_delay, pw_max, h_size,
                       n_split, recorder, chaos, mig) -> LinkStepReport:
    """The three-tier lifecycle twin loop (DESIGN.md §12).

    Kept as a separate body so the pinned two-tier path above stays
    byte-for-byte untouched. Phase order per step mirrors the jitted scan
    exactly: node death → heat decay (+ compressed snapshot) → prefetch
    landing grants ranked on *pre-grant* homes → migration grants out of
    the leftover capacity (everything downstream sees post-grant homes) →
    EWMA fold → serve every stream → promote on bytes moved + demand heat
    touch → controller + issue (decompress surcharge) → coldest-first
    demotion → next step's proposals from the updated trend.
    """
    schedules = [[int(p) for p in row] for row in schedules]
    S = len(schedules)
    T = len(schedules[0]) if S else 0
    cap_inf = budget is None
    rec = recorder.emit if recorder is not None else (lambda *a, **k: None)
    home = lambda p: home_of(p, n_pages, n_shards, placement)
    streams = [_Stream(LeapPrefetcher(h_size=h_size, n_split=n_split,
                                      pw_max=pw_max),
                       PrefetchStats(), set(), []) for _ in range(S)]
    demand_hist, landed_hist, issued_hist = [], [], []
    d_prev = [0] * n_shards

    # Lifecycle tables — Python ints, the same formulas as the jitted
    # ``tier_*`` transactions (``core.pool``) and ``paging.lifecycle``.
    homeT = [home(p) for p in range(n_pages)]
    compT = [False] * n_pages
    heatT = [0] * n_pages
    last_migT = [-(1 << 30)] * n_pages
    pend: list = []                  # [(seq, stream, page, dest)] proposals
    mig_counts = [0] * S
    prom_counts = [0] * S
    demoted_total = 0
    M = mig.mig_per_stream

    cz = est = None
    dead_g = rehome_vec = None
    if chaos is not None:
        from .chaos import (EST_ONE, compile_chaos, est_init, est_step,
                            rehome_shard)
        cz = compile_chaos(chaos, n_steps=T, n_streams=S, n_shards=n_shards,
                           n_pages=n_pages, placement=placement,
                           base_budget=budget)
        est = [[int(v) for v in row]
               for row in est_init(S, n_shards, near_delay, far_delay)]
        if cz["t_fail"] is not None:
            dead_g = int(chaos.node_loss[0])
            rehome_vec = [rehome_shard(p, dead_g, dead_g, n_shards)
                          for p in range(n_pages)]

    for t in range(T):
        if cz is not None and cz["t_fail"] == t:
            # Node death against the *dynamic* table: everything currently
            # homed on the dying shard (migrated-in pages included) is
            # invalidated as pollution and re-homed by the §9 rule.
            dead_set = {p for p in range(n_pages) if homeT[p] == dead_g}
            for s, st in enumerate(streams):
                lost = st.resident & dead_set
                st.stats.pollution += len(lost)
                st.resident -= lost
                kept = [e for e in st.queue if e.page not in dead_set]
                dropped = [e for e in st.queue if e.page in dead_set]
                st.stats.pollution += len(dropped)
                st.queue[:] = kept
                for p in sorted(lost) + [e.page for e in dropped]:
                    rec("evict", t, s, page=p, shard=home(p))
            for p in dead_set:
                homeT[p] = rehome_vec[p]

        heatT = [(h * 3) >> 2 for h in heatT]
        comp_pre = list(compT)

        # -- 1. prefetch landing grants: pre-grant homes rank the queue -----
        if cz is None:
            caps = [math.inf if cap_inf else max(0, budget - d)
                    for d in d_prev]
        else:
            caps = [max(0, int(cz["budget"][t][g]) - d_prev[g])
                    for g in range(n_shards)]
        eligible = sorted((e.seq, s, e) for s, st in enumerate(streams)
                          for e in st.queue if e.ready <= t)
        landed = 0
        landed_entries = []
        for _, s, e in eligible:
            g = homeT[e.page]
            if caps[g] <= 0:
                continue
            caps[g] -= 1
            st = streams[s]
            st.queue.remove(e)
            st.resident.add(e.page)
            rec("land", t, s, page=e.page, shard=home(e.page), seq=e.seq)
            if e.deadline < t:
                st.stats.deferred += 1
                rec("defer", t, s, page=e.page, shard=home(e.page), seq=e.seq)
            landed_entries.append((s, e))
            landed += 1
        landed_hist.append(landed)

        # -- 2. migration grants: leftover capacity, global seq order -------
        seen: set = set()
        for seq, s, page, dest in sorted(pend):
            src = homeT[page]
            if src == dest or t - last_migT[page] < mig.cooldown:
                continue                     # revalidation against current
            if page in seen:                 # lifecycle state, then lowest-
                continue                     # seq-wins same-page dedupe
            seen.add(page)
            if dead_g is not None and dest == dead_g and t >= cz["t_fail"]:
                # Carried proposal into a dead shard: wasted transfer.
                streams[s].stats.pollution += 1
                rec("evict", t, s, page=page, shard=home(page))
                continue
            if caps[src] <= 0:
                continue
            caps[src] -= 1
            homeT[page] = dest
            last_migT[page] = t
            mig_counts[s] += 1
            rec("migrate", t, s, page=page, shard=dest, seq=seq)
        pend = []

        if cz is not None:
            # EWMA fold buckets by the *post-grant* home, like the jitted
            # ``_home(landed_pages)`` read after the tier rebind.
            obs_sum = [[0] * n_shards for _ in range(S)]
            obs_cnt = [[0] * n_shards for _ in range(S)]
            for s, e in landed_entries:
                g = homeT[e.page]
                obs_sum[s][g] += t - e.issued_at
                obs_cnt[s][g] += 1
            for s in range(S):
                for g in range(n_shards):
                    if obs_cnt[s][g]:
                        est[s][g] = est_step(est[s][g], obs_sum[s][g],
                                             obs_cnt[s][g])

        # -- 3. serve every stream (post-grant homes account demand) --------
        d_t = [0] * n_shards
        served = []
        for s, st in enumerate(streams):
            page = schedules[s][t]
            st.stats.faults += 1
            inflight = next((e for e in st.queue if e.page == page), None)
            if page in st.resident:
                st.stats.cache_hits += 1
                st.stats.prefetch_hits += 1
                st.resident.discard(page)
                pf_hit, fetched = True, False
                rec("hit", t, s, page=page, shard=home(page), pref=True)
            elif inflight is not None:
                st.queue.remove(inflight)
                st.stats.cache_hits += 1
                st.stats.prefetch_hits += 1
                st.stats.partial_hits += 1
                rec("partial", t, s, page=page, shard=home(page),
                    seq=inflight.seq, pref=True)
                if inflight.deadline < t:
                    st.stats.deferred += 1
                    rec("defer", t, s, page=page, shard=home(page),
                        seq=inflight.seq)
                d_t[homeT[page]] += 1
                pf_hit, fetched = True, True
            else:
                st.stats.misses += 1
                d_t[homeT[page]] += 1
                pf_hit, fetched = False, True
                rec("miss", t, s, page=page, shard=home(page))
            served.append((page, pf_hit, fetched))

        # -- 4. promote on bytes moved (vs start-of-step snapshot) + heat ---
        if mig.compressed:
            for s, e in landed_entries:
                if comp_pre[e.page]:
                    prom_counts[s] += 1
                    rec("promote", t, s, page=e.page, shard=home(e.page))
                compT[e.page] = False
            for s, (page, _, fetched) in enumerate(served):
                if fetched and 0 <= page < n_pages:
                    if comp_pre[page]:
                        prom_counts[s] += 1
                        rec("promote", t, s, page=page, shard=home(page))
                    compT[page] = False
        for s, (page, _, _) in enumerate(served):
            if 0 <= page < n_pages:
                heatT[page] += mig.heat_access

        # -- 5. controller + issue (decompress surcharge on cold pages) -----
        issued_t = 0
        for s, st in enumerate(streams):
            page, pf_hit, _ = served[s]
            my_shard = s % n_shards
            grant_cap = None if cz is None else int(cz["grant"][t][s])
            for k, cand in enumerate(st.prefetcher.on_fault(page, pf_hit)):
                if cand < 0 or cand >= n_pages:
                    continue
                if cand in st.resident or any(e.page == cand
                                              for e in st.queue):
                    continue
                full = len(st.queue) >= ring_size
                over_grant = (grant_cap is not None and
                              len(st.resident) + len(st.queue) >= grant_cap)
                if full or over_grant:
                    st.drops += 1
                    rec("drop", t, s, page=cand, shard=home(cand))
                    continue
                g_c = homeT[cand]
                base = near_delay if g_c == my_shard else far_delay
                sur = (mig.decompress_delay
                       if mig.compressed and compT[cand] else 0)
                seq = (t * S + s) * pw_max + k
                if cz is None:
                    e = _Inflight(cand, t + base + sur, seq)
                else:
                    true_d = max(1, base * int(cz["dilation"][t][g_c])) + sur
                    if chaos.adaptive_deadline:
                        expect_d = max(1, (est[s][g_c] + EST_ONE // 2)
                                       // EST_ONE)
                    else:
                        expect_d = base + sur
                    e = _Inflight(cand, t + true_d, seq,
                                  expect=t + expect_d, issued_at=t)
                st.queue.append(e)
                st.stats.prefetch_issued += 1
                rec("issue", t, s, page=cand, shard=home(cand), seq=seq)
                issued_t += 1
        demand_hist.append(sum(d_t))
        issued_hist.append(issued_t)

        # -- 6. demote the coldest while over uncompressed capacity ---------
        if mig.compressed:
            n_uncomp = compT.count(False)
            need = min(mig.demote_per_step,
                       max(0, n_uncomp - mig.far_capacity))
            if need > 0:
                elig = [p for p in range(n_pages)
                        if not compT[p] and heatT[p] <= mig.heat_cold
                        and t - last_migT[p] >= mig.cooldown]
                elig.sort(key=lambda p: heatT[p] * n_pages + p)
                for p in elig[:need]:
                    compT[p] = True
                    last_migT[p] = t
                    demoted_total += 1
                    rec("demote", t, 0, page=p, shard=home(p))

        # -- 7. propose next step's migrations from the updated trend -------
        for s, st in enumerate(streams):
            trend = st.prefetcher.current_trend
            if trend is None or trend == 0:
                continue
            my_shard = s % n_shards
            if dead_g is not None and my_shard == dead_g \
                    and t >= cz["t_fail"]:
                continue
            page = schedules[s][t]
            for j in range(M):
                cand = page + trend * (pw_max + mig.lead + j)
                if not 0 <= cand < n_pages:
                    continue
                if homeT[cand] == my_shard:
                    continue
                if t - last_migT[cand] < mig.cooldown:
                    continue
                pend.append(((t * S + s) * M + j, s, cand, my_shard))
        d_prev = d_t

    return LinkStepReport(
        per_stream=[st.stats for st in streams],
        drops=[st.drops for st in streams],
        resident_unused=[len(st.resident) for st in streams],
        inflight_at_end=[len(st.queue) for st in streams],
        demand_fetches=demand_hist, landed=landed_hist, issued=issued_hist,
        migrations=mig_counts, promotions=prom_counts,
        demotions=demoted_total)
