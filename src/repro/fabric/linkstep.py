"""Step-synchronous shared-link reference: width-B fabric in lock-step.

This is the host-side twin of the *budgeted* jitted multi-stream path
(``repro.paging.prefetch_serving.multi_stream_consume(...,
link_budget=B)``, DESIGN.md §5): S streams advance in lock-step (one
slow-tier access per stream per step) over a shared fabric link that can
move ``budget`` pages per step. Arbitration is demand-first:

1. The link carried last step's demand fetches with strict priority, so
   prefetch *landing* capacity at step *t* is
   ``max(0, budget - demand_fetches[t-1])``.
2. Landing grants go to queued prefetches whose nominal arrival
   (``issue_step + arrival_delay``) has passed, across all streams in
   ascending global issue order (FIFO over the link). The surplus stays
   queued past its arrival time; when such an entry finally completes —
   by landing or by a demand finishing it early (partial hit) — it
   counts as **deferred**.
3. Per-stream controller, residency and in-flight queue stay private
   (paper §4.1): only bandwidth is shared, never detector state.

It is intentionally *not* the event-driven engine of ``repro.fabric.sim``
(whose continuous clock ties progress to latency draws): lock-step is
what makes its per-stream hit / partial / deferral counts *exactly*
comparable to the jitted scan, giving the first quantitative bridge
between the two subsystems. The controller is the NumPy
:class:`repro.core.prefetcher.LeapPrefetcher` (itself pinned
bit-equivalent to the jitted ``leap_step``), and the counters are
:class:`repro.core.metrics.PrefetchStats` — the same pieces the event
engine uses. ``tests/test_link_budget.py`` pins the jitted counts to this
model across budgets, stream counts and patterns.

Validity domain: the model tracks residency as plain sets, i.e. it
assumes the hot buffer never evicts (choose ``n_slots`` in the jitted
geometry large enough that the free stack cannot run dry — e.g.
``n_slots >= n_pages``). Under eviction pressure the jitted path's FIFO
pollution kicks in and the two intentionally diverge.
"""

from __future__ import annotations

import dataclasses
import math

from ..core.history import DEFAULT_H_SIZE
from ..core.metrics import PrefetchStats
from ..core.prefetcher import LeapPrefetcher
from ..core.trend import DEFAULT_N_SPLIT
from ..core.window import DEFAULT_PW_MAX


@dataclasses.dataclass
class _Inflight:
    """One queued prefetch transfer on the shared link."""

    page: int
    ready: int        # physical arrival step: issue_step + true delay
    seq: int          # global issue order (step-major, stream, candidate)
    expect: int = -1  # expected arrival (deadline) when it differs from
    #                   ready (chaos slowdown / adaptive deadlines,
    #                   DESIGN.md §9); -1 = same as ready (clean fabric)
    issued_at: int = -1  # issue step — the estimator's observation anchor

    @property
    def deadline(self) -> int:
        return self.ready if self.expect < 0 else self.expect


@dataclasses.dataclass
class _Stream:
    prefetcher: LeapPrefetcher
    stats: PrefetchStats
    resident: set          # landed, unconsumed prefetched pages (eager)
    queue: list            # list[_Inflight], bounded by ring_size
    drops: int = 0         # issues rejected on a full queue


@dataclasses.dataclass
class LinkStepReport:
    """Per-stream counters + per-step link totals of one lock-step run."""

    per_stream: list               # list[PrefetchStats]
    drops: list                    # list[int] per stream
    resident_unused: list          # list[int] per stream (end of run)
    inflight_at_end: list          # list[int] per stream (end of run)
    demand_fetches: list           # list[int] per step (all streams)
    landed: list                   # list[int] per step
    issued: list                   # list[int] per step
    # Tier-lifecycle totals (DESIGN.md §12); None unless the run had
    # ``migration`` enabled, so two-tier summaries keep their exact shape.
    migrations: list | None = None   # list[int] per stream (granted moves)
    promotions: list | None = None   # list[int] per stream
    demotions: int | None = None     # run total (pool-wide, not per stream)

    def stream_summary(self, i: int) -> dict:
        """Counter dict shaped like ``repro.core.pool.pool_stats``."""
        s = self.per_stream[i]
        out = {
            "faults": s.faults,
            "hits": s.cache_hits,
            "misses": s.misses,
            "prefetch_issued": s.prefetch_issued,
            "prefetch_hits": s.prefetch_hits,
            "partial_hits": s.partial_hits,
            "deferred": s.deferred,
            "pollution": s.pollution,
            "resident_unused": self.resident_unused[i],
            "inflight_at_end": self.inflight_at_end[i],
            "ring_drops": self.drops[i],
        }
        if self.migrations is not None:
            out["migrations"] = self.migrations[i]
            out["promotions"] = self.promotions[i]
        return out


def run_linkstep(schedules, n_pages: int, budget=None,
                 ring_size: int = 8, arrival_delay=1,
                 pw_max: int = DEFAULT_PW_MAX, h_size: int = DEFAULT_H_SIZE,
                 n_split: int = DEFAULT_N_SPLIT,
                 recorder=None, nominal_delay: int | None = None,
                 migration=None) -> LinkStepReport:
    """Run ``schedules`` (``[S][T]`` page ids) through the lock-step link.

    ``budget=None`` models private infinite links (every eligible prefetch
    lands at its nominal arrival — the unbudgeted jitted path).

    ``migration`` (:class:`repro.paging.lifecycle.MigrationCfg`) turns on
    the three-tier lifecycle (DESIGN.md §12). At one link there is one
    shard, so no page is ever cross-shard and migration proper never fires;
    what remains is the compressed cold tier — demotion, promotion, and the
    decompress surcharge on cold candidates. The single-link run is the
    ``n_shards == 1`` case of :func:`repro.fabric.shardstep.run_shardstep`
    (already pinned equal), so this delegates to it; per-step ``budget`` /
    ``arrival_delay`` sequences are not supported together with
    ``migration`` (use the shardstep chaos path for that).

    ``budget`` and ``arrival_delay`` also accept per-step sequences
    (length >= T) — the chaos fabric's transient link degradation and
    slowdown windows at ``n_shards == 1`` (DESIGN.md §9). A per-step
    ``arrival_delay`` dilates the *physical* arrival while the entry's
    deadline stays at the static ``nominal_delay`` (default: the scalar
    ``arrival_delay``, or 1): entries completing past it count deferred.

    ``recorder`` (an :class:`repro.obs.trace.TraceRecorder`) receives a
    page-level event at every transition — ``land``/``defer`` at grant
    time, ``hit``/``partial``/``miss`` at serve time, ``issue``/``drop``
    at issue time — the ground-truth side of the §8 trace diff against
    the jitted path's decoded info arrays.
    """
    if migration is not None:
        from ..paging.lifecycle import resolve
        if resolve(migration) is not None:
            if not isinstance(arrival_delay, int) or \
                    (budget is not None and not isinstance(budget, int)):
                raise ValueError("migration needs scalar budget/arrival_delay")
            from .shardstep import run_shardstep
            return run_shardstep(schedules, n_pages, 1, "interleave", budget,
                                 ring_size, near_delay=arrival_delay,
                                 far_delay=arrival_delay, pw_max=pw_max,
                                 h_size=h_size, n_split=n_split,
                                 recorder=recorder, migration=migration)
    schedules = [[int(p) for p in row] for row in schedules]
    S = len(schedules)
    T = len(schedules[0]) if S else 0
    delay_seq = not isinstance(arrival_delay, int)
    if not delay_seq:
        arrival_delay = max(arrival_delay, 1)   # mirrors pool_issue's clamp
    if nominal_delay is None:
        nominal_delay = 1 if delay_seq else arrival_delay
    nominal_delay = max(nominal_delay, 1)
    budget_seq = budget is not None and not isinstance(budget, int)
    cap_inf = budget is None
    rec = recorder.emit if recorder is not None else (lambda *a, **k: None)
    streams = [_Stream(LeapPrefetcher(h_size=h_size, n_split=n_split,
                                      pw_max=pw_max),
                       PrefetchStats(), set(), []) for _ in range(S)]
    demand_hist, landed_hist, issued_hist = [], [], []
    d_prev = 0

    for t in range(T):
        # -- 1. landing grants: leftover budget, global issue order ----------
        budget_t = budget[t] if budget_seq else budget
        cap = math.inf if cap_inf or budget_t is None \
            else max(0, budget_t - d_prev)
        eligible = sorted((e.seq, s, e) for s, st in enumerate(streams)
                          for e in st.queue if e.ready <= t)
        landed = 0
        for _, s, e in eligible:
            if landed >= cap:
                break
            st = streams[s]
            st.queue.remove(e)
            st.resident.add(e.page)
            rec("land", t, s, page=e.page, seq=e.seq)
            if e.deadline < t:
                st.stats.deferred += 1
                rec("defer", t, s, page=e.page, seq=e.seq)
            landed += 1
        landed_hist.append(landed)

        # -- 2. serve each stream's demand (private residency) ---------------
        d_t = 0
        issued_t = 0
        for s, st in enumerate(streams):
            page = schedules[s][t]
            st.stats.faults += 1
            inflight = next((e for e in st.queue if e.page == page), None)
            if page in st.resident:
                # full prefetched hit; eager eviction frees it on first use
                st.stats.cache_hits += 1
                st.stats.prefetch_hits += 1
                st.resident.discard(page)
                pf_hit = True
                rec("hit", t, s, page=page, pref=True)
            elif inflight is not None:
                # partial hit: the demand completes the transfer early and
                # blocks on the residual only; it consumes demand bandwidth
                st.queue.remove(inflight)
                st.stats.cache_hits += 1
                st.stats.prefetch_hits += 1
                st.stats.partial_hits += 1
                rec("partial", t, s, page=page, seq=inflight.seq, pref=True)
                if inflight.deadline < t:
                    st.stats.deferred += 1
                    rec("defer", t, s, page=page, seq=inflight.seq)
                d_t += 1
                pf_hit = True
            else:
                st.stats.misses += 1
                d_t += 1
                pf_hit = False
                rec("miss", t, s, page=page)

            # -- 3. controller + globally ordered issue ----------------------
            for k, cand in enumerate(st.prefetcher.on_fault(page, pf_hit)):
                if cand < 0 or cand >= n_pages:
                    continue
                if cand in st.resident or any(e.page == cand
                                              for e in st.queue):
                    continue
                if len(st.queue) >= ring_size:
                    st.drops += 1
                    rec("drop", t, s, page=cand)
                    continue
                seq = (t * S + s) * pw_max + k
                true_d = (max(int(arrival_delay[t]), 1) if delay_seq
                          else arrival_delay)
                st.queue.append(_Inflight(cand, t + true_d, seq,
                                          expect=t + nominal_delay,
                                          issued_at=t))
                st.stats.prefetch_issued += 1
                rec("issue", t, s, page=cand, seq=seq)
                issued_t += 1
        demand_hist.append(d_t)
        issued_hist.append(issued_t)
        d_prev = d_t

    return LinkStepReport(
        per_stream=[st.stats for st in streams],
        drops=[st.drops for st in streams],
        resident_unused=[len(st.resident) for st in streams],
        inflight_at_end=[len(st.queue) for st in streams],
        demand_fetches=demand_hist, landed=landed_hist, issued=issued_hist)
