"""Discrete-event engine: event heap, virtual clock, deterministic ties.

The engine is a plain binary heap of ``(time, rank, seq, callback)``
entries plus a virtual clock. Determinism has two layers:

* ``seq`` — a monotone insertion counter — breaks exact ``(time, rank)``
  ties, so a replay of the same scenario is bit-identical.
* ``rank`` orders *simultaneous events of different actors*. The fabric
  assigns each tenant a rank drawn from a seed-derived permutation
  (:meth:`EventEngine.actor_ranks`), so "who goes first when two tenants
  fault at the same instant" is a function of the scenario seed rather
  than of tenant construction order. Re-seeding reshuffles ties without
  touching anything else (DESIGN.md §3.1).

The engine knows nothing about tenants or links; it only runs callbacks.
"""

from __future__ import annotations

import heapq
import itertools

import numpy as np


class EventEngine:
    """Virtual-time event loop with seeded tie-breaking."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self.now = 0.0
        self.events_run = 0
        self.rng = np.random.default_rng(self.seed)
        self._heap: list[tuple[float, int, int, object]] = []
        self._seq = itertools.count()

    # -- scheduling ---------------------------------------------------------
    def schedule_at(self, t: float, fn, rank: int = 0) -> None:
        """Run ``fn()`` at virtual time ``t`` (must not be in the past)."""
        t = float(t)
        if t < self.now:
            raise ValueError(f"cannot schedule at {t} < now {self.now}")
        heapq.heappush(self._heap, (t, int(rank), next(self._seq), fn))

    def schedule(self, delay: float, fn, rank: int = 0) -> None:
        """Run ``fn()`` after ``delay`` time units."""
        self.schedule_at(self.now + float(delay), fn, rank)

    def actor_ranks(self, n: int) -> list[int]:
        """Seed-derived permutation of ``range(n)`` used as tie ranks."""
        return [int(r) for r in self.rng.permutation(int(n))]

    # -- execution ----------------------------------------------------------
    def run(self, until: float | None = None) -> float:
        """Drain the heap (optionally stopping at ``until``); returns now.

        When ``until`` is given, the clock advances to it afterwards —
        safe because every event left in the heap is later than it, so
        virtual time stays monotone across successive ``run`` calls.
        """
        while self._heap:
            t, rank, seq, fn = self._heap[0]
            if until is not None and t > until:
                break
            heapq.heappop(self._heap)
            self.now = t
            self.events_run += 1
            fn()
        if until is not None and until > self.now:
            self.now = until
        return self.now

    def __len__(self) -> int:
        return len(self._heap)
