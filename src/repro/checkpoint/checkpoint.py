"""Checkpointing: async, sharded layout, mesh-independent restore.

On-disk layout (one directory per step, atomic rename commit)::

    <dir>/step_000123.tmp/        # written here first
        manifest.json             # step, tree structure, leaf index, extras
        arr_00000.npy ...         # one .npy per pytree leaf (logical array)
    <dir>/step_000123/            # rename on completion = commit

Leaves are saved as *logical* (global) arrays, so a checkpoint written on a
(16,16) mesh restores onto (2,16,16), (8,)-way, or a single CPU — restore
just ``device_put``s each leaf with the target sharding (**elastic
scaling**). At real multi-host scale each host writes only the shards it
owns into per-shard chunk files; the layout keeps that extension local to
``_save_leaf`` (chunk index already lives in the manifest). Async: the
device->host copy happens at call time (cheap), serialization happens on a
background thread; ``wait()`` joins before the next save or exit.

Restart contract (used by ``runtime.fault_tolerance``): ``latest_step`` +
``restore_checkpoint`` resume training bit-exact — params, optimizer
moments, RNG key, and the data pipeline's step counter all live here.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree, extras: dict | None = None,
                    ) -> str:
    """Blocking save with atomic commit; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten_with_paths(tree)
    index = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        name = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, name), arr)
        index.append({"file": name, "shape": list(arr.shape),
                      "dtype": str(arr.dtype)})
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if hasattr(jax.tree_util.tree_structure(tree), "serialize_using_proto")
        else None,
        "n_leaves": len(leaves),
        "index": index,
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, tree_like,
                       shardings=None) -> tuple[object, dict]:
    """Restore into the structure of ``tree_like``; reshard if asked.

    ``shardings``: optional pytree (matching ``tree_like``) of
    ``jax.sharding.Sharding`` — this is the elastic-rescale path: the same
    logical arrays are laid out onto whatever mesh the new job built.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(tree_like)
    assert len(leaves_like) == manifest["n_leaves"], (
        f"checkpoint has {manifest['n_leaves']} leaves, "
        f"tree expects {len(leaves_like)}")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    out = []
    for i, (like, shd) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(os.path.join(path, manifest["index"][i]["file"]))
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=like.dtype)
                       if hasattr(like, "dtype") else arr)
    return jax.tree.unflatten(treedef, out), manifest["extras"]


class AsyncCheckpointer:
    """Background-thread saver: snapshot at call time, serialize off-thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree, extras: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extras)
                self._gc()
            except Exception as e:      # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
