"""Admission queue + slot scheduler: the serving engine's control plane.

Pure host-side Python over :class:`repro.serving.request.Request` and
:class:`repro.paging.kv_cache.PageAllocator` — no JAX anywhere, so the
whole admission/eviction discipline is exercisable (and hypothesis-
property-tested) without a model or a device pool.

Discipline (DESIGN.md §10):

* **Admission** is arrival-ordered and capacity-reserving: a WAITING
  request is admitted when (a) its arrival step has passed, (b) a serving
  slot is free, and (c) the allocator's free pages minus the pages already
  *reserved* by in-flight requests cover the request's full eventual need
  (``ceil((prompt+gen)/page_size)``). Reserving the whole need up front
  means an admitted request can never hit pool exhaustion mid-decode —
  admission is the only place a request can wait on memory.
* **Page growth** is incremental: prompt pages are allocated as prefill
  chunks reach them and decode extends one page at a time
  (``PageAllocator.extend_seq``), so occupancy tracks actual context
  length, not the reservation.
* **Eviction** recycles a finished request's pages through
  ``PageAllocator.recycle`` and frees its slot. Conservation — pages
  allocated == pages recycled, allocator occupancy back to baseline when
  the schedule drains — is the property test's core invariant.
"""

from __future__ import annotations

from repro.paging.kv_cache import PageAllocator

from .request import DECODE, FINISHED, PREFILL, WAITING, Request


class AdmissionQueue:
    """Arrival-ordered FIFO of WAITING requests."""

    def __init__(self, requests=()):
        self._pending: list[Request] = sorted(
            requests, key=lambda r: (r.arrival_step, r.req_id))
        for r in self._pending:
            if r.state != WAITING:
                raise ValueError(f"request {r.req_id} enqueued in state "
                                 f"{r.state}")

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, req: Request) -> None:
        self._pending.append(req)
        self._pending.sort(key=lambda r: (r.arrival_step, r.req_id))

    def head_ready(self, step: int) -> Request | None:
        """The next admissible request (arrived by ``step``), FIFO order."""
        if self._pending and self._pending[0].arrival_step <= step:
            return self._pending[0]
        return None

    def pop(self) -> Request:
        return self._pending.pop(0)


class SlotScheduler:
    """Fixed slot set + capacity-reserving admission + recycling eviction.

    Args:
      n_slots: concurrent serving slots (the tiered data path's stream
        count — fixed shapes; a slot with no request sweeps nothing).
      allocator: the shared :class:`PageAllocator` over the cold pool.
      page_size: tokens per KV page.
      gang: lock-step admission mode (the baseline the continuous engine
        is benchmarked against): requests are only admitted when *every*
        slot is free, then as many arrived requests as fit are ganged in
        together — the fixed-batch prefill→decode serving loop this
        refactor replaces.
    """

    def __init__(self, n_slots: int, allocator: PageAllocator,
                 page_size: int, gang: bool = False):
        self.n_slots = n_slots
        self.allocator = allocator
        self.page_size = page_size
        self.gang = gang
        self.slots: list[Request | None] = [None] * n_slots
        self.reserved = 0            # pages promised to admitted requests
        self.pages_allocated = 0     # conservation counters
        self.pages_recycled = 0

    # -- introspection -------------------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def active(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    def headroom(self) -> int:
        """Unreserved free pages available to new admissions."""
        return self.allocator.free_count - self.reserved

    # -- admission -----------------------------------------------------------
    def can_admit(self, req: Request) -> bool:
        return req.pages_needed(self.page_size) <= self.headroom()

    def admit_ready(self, queue: AdmissionQueue, step: int) -> list[Request]:
        """Admit arrived requests into free slots (FIFO, head-of-line).

        Returns the requests admitted this step, already transitioned to
        PREFILL and bound to their slots. Admission stops at the first
        request that does not fit (no reordering past the head — arrival
        order is the fairness contract).
        """
        if self.gang and any(r is not None for r in self.slots):
            return []
        admitted = []
        free = self.free_slots()
        while free:
            req = queue.head_ready(step)
            if req is None or not self.can_admit(req):
                break
            queue.pop()
            slot = free.pop(0)
            if self.slots[slot] is not None:
                raise RuntimeError(f"slot {slot} double-occupancy")
            req.slot = slot
            req.to(PREFILL, step)
            self.reserved += req.pages_needed(self.page_size)
            self.slots[slot] = req
            admitted.append(req)
        return admitted

    # -- page growth ---------------------------------------------------------
    def page_for_position(self, req: Request, position: int) -> int:
        """Physical page holding ``position``, extending the request's
        allocation when the position crosses into a new page. Draws down
        the admission reservation page by page."""
        idx = position // self.page_size
        if idx > len(req.pages):
            raise ValueError(f"request {req.req_id}: position {position} "
                             f"skips page {len(req.pages)}")
        if idx == len(req.pages):
            (page,) = self.allocator.extend_seq(req.req_id, 1)
            req.pages.append(page)
            self.reserved -= 1
            self.pages_allocated += 1
        return req.pages[idx]

    # -- eviction ------------------------------------------------------------
    def finish(self, req: Request, step: int) -> int:
        """Evict a DECODE-complete request: recycle pages, free the slot.

        Returns the number of pages recycled (asserted == pages owned).
        """
        if req.state != DECODE or req.decoded < req.gen:
            raise ValueError(f"request {req.req_id} not finishable "
                             f"(state={req.state}, {req.decoded}/{req.gen})")
        req.to(FINISHED, step)
        n_owned = len(req.pages)
        n = self.allocator.recycle(req.pages)
        if n != n_owned:
            raise RuntimeError(
                f"request {req.req_id}: recycled {n} of {n_owned} pages — "
                "a page was yanked by someone else mid-flight")
        # hand back the unused tail of the reservation (requests whose
        # final decode token never writes a page keep a page in reserve)
        self.reserved -= req.pages_needed(self.page_size) - n_owned
        self.pages_recycled += n
        self.slots[req.slot] = None
        return n
