"""Serving engine: request lifecycle, admission, scheduling, execution.

The continuous-batching serving stack (DESIGN.md §10), carved out of the
``launch/serve.py`` monolith so the control plane is testable on its own:

* :mod:`repro.serving.request`      — per-request state machine
  (WAITING → PREFILL → DECODE → FINISHED), token/page accounting.
* :mod:`repro.serving.scheduler`    — admission queue + slot scheduler
  (capacity-reserving admission, recycling eviction). Pure Python.
* :mod:`repro.serving.executor`     — model executors: chunked prefill +
  batch-1 decode per request (real model or synthetic K/V).
* :mod:`repro.serving.engine`       — the step executor composing
  scheduler + executor + the tiered paged-KV data path, with the §6.4
  flat/tiered pin enforced every step over dynamic batch composition.
* :mod:`repro.serving.batch_driver` — the legacy lock-step fixed-batch
  replay (gang admission), kept as the baseline and the
  ``--arrival batch`` path.

``launch/serve.py`` is the thin CLI front-end over all of it.
"""

from .engine import (PINNED_COUNTERS, ServeConfig, ServingEngine,
                     build_executor, serve_continuous)
from .executor import ModelExecutor, SyntheticExecutor
from .request import DECODE, FINISHED, PREFILL, WAITING, Request
from .scheduler import AdmissionQueue, SlotScheduler

__all__ = [
    "AdmissionQueue", "DECODE", "FINISHED", "ModelExecutor",
    "PINNED_COUNTERS", "PREFILL", "Request", "ServeConfig", "ServingEngine",
    "SlotScheduler", "SyntheticExecutor", "WAITING", "build_executor",
    "serve_continuous",
]
