"""Continuous-batching serving engine over the tiered paged-KV data path.

The step executor that turns every prior subsystem into a servable engine
(DESIGN.md §10). One engine step:

1. **Admit** — arrived requests enter free slots under the capacity-
   reserving policy (:class:`repro.serving.scheduler.SlotScheduler`);
   arrivals come from a seeded :class:`repro.fabric.tenants.ArrivalProcess`
   (constant / bursty / churn), quantized onto the step clock.
2. **Model work** — PREFILL slots consume up to ``prefill_chunk`` prompt
   tokens (chunked prefill: long prompts never stall in-flight decode);
   DECODE slots emit one token. Every produced K/V lands in the cold paged
   pool at its request's allocator-assigned page (incremental page growth).
3. **Data path** — written pages are invalidated in every stream's hot
   tier (write coherence, §6), then all decoding slots sweep their context
   pages through the Leap-managed hot pools in one
   :func:`repro.paging.tiered_kv.tiered_sweep` over the *dynamic* batch
   composition (idle slots sweep nothing — fixed shapes, ``-1`` rows), and
   hot-slot attention is pinned **bit-identical** to the flat-pool
   reference for every active row (§6.4 — the pin survives dynamic
   batches because both sides read the same page table rows and lengths).
4. **Evict** — finished requests recycle their pages through
   ``PageAllocator.recycle``, their slot's stream state cold-resets
   (:func:`tiered_reset_stream`), and their counters fold into the
   per-slot base so the §8 event-totals pin spans slot reuse.

Per-request TTFT and token-latency ladders ride
:class:`repro.obs.metrics.Registry`; the request lifecycle is exported as
its own Perfetto track keyed by request id (slot-reuse-proof), next to the
per-stream page-lifecycle tracks.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.fabric.tenants import ArrivalProcess
from repro.obs.metrics import Registry
from repro.obs.trace import (Event, RequestPhase, decode_sweep_events,
                             events_to_counts, summary_events)
from repro.paging.kv_cache import (PageAllocator, init_paged_kv,
                                   paged_decode_attention)
from repro.paging.sharded_pool import ShardedPoolCfg
from repro.paging.tiered_kv import (TieredKV, normalize_attn_kernel,
                                    tiered_attention, tiered_init,
                                    tiered_invalidate, tiered_min_slots,
                                    tiered_reset_stream, tiered_stats,
                                    tiered_sweep)

from .request import DECODE, PREFILL, Request
from .scheduler import AdmissionQueue, SlotScheduler

#: event-type totals pinned bit-exact against the pool counters whenever a
#: trace is decoded (DESIGN.md §8.2) — same contract as the batch driver
PINNED_COUNTERS = ("hits", "misses", "partial_hits", "prefetch_hits",
                   "prefetch_issued", "deferred", "ring_drops", "pollution")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static configuration of one continuous-batching serving run."""

    requests: int = 8
    slots: int = 4
    prompt_len: int = 32
    gen: int = 16
    #: per-request length heterogeneity: request i draws prompt/gen
    #: uniformly from [ceil(len*(1-jitter)), len] (seeded). 0 = uniform.
    length_jitter: float = 0.0
    page_size: int = 4
    prefill_chunk: int = 8        # prompt tokens per engine step per slot
    chunk: int = 4                # sweep demand pages per chunk step
    ring_size: int = 8
    async_datapath: bool = False
    link_budget: int | None = None
    shards: int = 1
    placement: str = "interleave"
    far_delay: int = 2
    use_kernel: bool = True
    #: decode-attention consumer: "ref" | "kernel" (unfused stacked hot
    #: pool) | "fused" | "fused_async" (in-place hot-slot kernel — no
    #: stacked-pool copy). The §6.4 flat pin runs against the matching
    #: flat-pool implementation (ref vs ref, kernel vs kernel) so the
    #: comparison stays bit-identical.
    attn_kernel: str = "ref"
    # arrival process (request-level, quantized to the step clock)
    arrival: str = "bursty"       # constant | bursty | churn
    think_time: float = 1000.0    # µs between arrivals
    burst_len: int = 4
    idle_time: float = 4000.0
    churn_every: int = 3
    churn_downtime: float = 6000.0
    step_us: float = 1000.0
    seed: int = 0
    # admission mode: False = continuous; True = lock-step gang admission
    # (the fixed-batch baseline benchmarks/serving.py compares against)
    gang: bool = False
    pool_pages: int | None = None
    trace: bool = False
    #: three-tier page lifecycle (DESIGN.md §12): a
    #: repro.paging.lifecycle.MigrationCfg, or None / enabled=False for the
    #: exact two-tier engine. The host-side PageLifecycle mirror runs
    #: between steps: trend-driven hot-ward migration re-homes pages toward
    #: their consumer's shard (scheduling only — budgets, deadlines, NIC
    #: accounting), and with cfg.compressed the coldest pages round-trip
    #: through the int8 page codec at demote time (stale hot copies
    #: invalidated, so the §6.4 flat/tiered bit-identity pin keeps holding
    #: — both sides read the same post-roundtrip cold bytes).
    migration: object = None

    def arrival_process(self) -> ArrivalProcess:
        return ArrivalProcess(kind=self.arrival, think_time=self.think_time,
                              burst_len=self.burst_len,
                              idle_time=self.idle_time,
                              churn_every=self.churn_every,
                              churn_downtime=self.churn_downtime)


class ServingEngine:
    """Request-lifecycle serving over the tiered paged-KV data path.

    ``executor`` is a :class:`repro.serving.executor.ModelExecutor` or
    :class:`repro.serving.executor.SyntheticExecutor`; the engine only
    assumes ``begin/end``, ``prefill_chunk``, ``decode`` and the
    ``n_kv_heads / head_dim / dtype`` payload attributes.
    """

    def __init__(self, config: ServeConfig, executor, mesh=None):
        self.cfg = config
        self.ex = executor
        c = config
        self.npps = -(-(c.prompt_len + c.gen) // c.page_size)
        hkv, dh = executor.n_kv_heads, executor.head_dim
        # the sweep's residency floor, uncapped (a pool smaller than this
        # cannot host a hot tier the lazy LRU won't cannibalize mid-batch)
        floor = tiered_min_slots(
            self.npps, TieredKV(1 << 30, 1, c.page_size, hkv, dh,
                                chunk=c.chunk, ring_size=c.ring_size))
        if c.pool_pages is not None and c.pool_pages < floor:
            raise ValueError(f"pool_pages={c.pool_pages} is below the "
                             f"tiered residency floor ({floor} pages)")
        n_pages = max(c.pool_pages or c.slots * self.npps, floor)
        n_pages = -(-n_pages // c.shards) * c.shards      # shardable pool
        self.n_pages = n_pages
        self.allocator = PageAllocator(n_pages)
        self.sched = SlotScheduler(c.slots, self.allocator, c.page_size,
                                   gang=c.gang)
        arrivals = c.arrival_process().arrival_steps(
            c.requests, seed=c.seed, step_us=c.step_us)
        lrng = np.random.default_rng(c.seed + 17)

        def draw(base: int) -> int:
            if c.length_jitter <= 0:
                return base
            lo = max(1, int(round(base * (1 - c.length_jitter))))
            return int(lrng.integers(lo, base + 1))

        self.queue = AdmissionQueue(
            Request(req_id=i, prompt_len=draw(c.prompt_len),
                    gen=draw(c.gen), arrival_step=int(arrivals[i]))
            for i in range(c.requests))
        self.dtype = jnp.dtype(executor.dtype)
        self.hq = getattr(executor, "n_q_heads", hkv)
        self.geom = TieredKV(n_pages, min(floor, n_pages), c.page_size,
                             hkv, dh, chunk=c.chunk, ring_size=c.ring_size,
                             use_kernel=c.use_kernel)
        self.tstate = tiered_init(self.geom, c.slots, self.dtype)
        self.pool = init_paged_kv(1, n_pages, c.page_size, hkv, dh,
                                  self.dtype)
        self.fabric = self.mesh = None
        if c.shards > 1:
            self.fabric = ShardedPoolCfg(
                n_shards=c.shards, placement=c.placement,
                link_budget=c.link_budget, near_delay=1,
                far_delay=c.far_delay)
            if mesh is None:
                from repro.launch.mesh import make_fabric_mesh
                mesh = make_fabric_mesh(c.shards)
            self.mesh = mesh
        self.reg = Registry()
        self.phases: list[RequestPhase] = []
        self.events: list[Event] | None = [] if c.trace else None
        self.link_hist: list[np.ndarray] = []
        self.shard_hist: list[np.ndarray] = []
        # per-slot counter base: stats of previous occupants folded in at
        # each stream reset, so the §8 totals pin spans slot reuse
        self.counter_base = [dict.fromkeys(PINNED_COUNTERS, 0)
                             for _ in range(c.slots)]
        from repro.paging.lifecycle import PageLifecycle, resolve
        mig = resolve(c.migration)
        self.lifecycle = None if mig is None else PageLifecycle(
            n_pages, max(c.shards, 1), c.placement, mig)
        self.equiv_ok = True
        self.first_bad_step: int | None = None
        self.occupancy_peak = 0.0
        self._chunk_clock = 0
        self._n_chunks = -(-self.npps // c.chunk)
        self._inv_width = c.slots * max(c.prefill_chunk, 1)
        self._finished: list[Request] = []

    # -- device helpers ------------------------------------------------------
    def _write_tokens(self, req: Request, k, v, start: int) -> list[int]:
        """Mirror ``[n, Hkv, dh]`` K/V into the cold pool at positions
        ``start..start+n-1``; returns the distinct pages written."""
        n = k.shape[0]
        pages = [self.sched.page_for_position(req, start + j)
                 for j in range(n)]
        ps = self.cfg.page_size
        pg = jnp.asarray(pages, jnp.int32)
        off = (start + jnp.arange(n, dtype=jnp.int32)) % ps
        self.pool = _scatter_tokens(self.pool, pg, off, k, v)
        return sorted(set(pages))

    def _sweep_and_pin(self, t: int, decoding: list[Request]) -> None:
        S, npps = self.cfg.slots, self.npps
        rows = np.full((S, npps), -1, np.int32)
        lengths = np.zeros((S,), np.int32)
        for req in decoding:
            rows[req.slot, :len(req.pages)] = req.pages
            lengths[req.slot] = req.prefilled + req.decoded - 1
        rows_j = jnp.asarray(rows)
        lengths_j = jnp.asarray(lengths)
        sweep_kw = {}
        lc = self.lifecycle
        if lc is not None:
            # drive the §12 lifecycle mirror between steps: decay + heat,
            # trend-driven hot-ward migration, capacity demotion. All of it
            # is scheduling metadata except demotion, which round-trips the
            # victim's cold bytes once (both the flat reference and the
            # tiered path then read the same post-roundtrip bytes, so the
            # §6.4 pin holds) and drops any stale hot copy.
            lc.begin_step()
            lc.touch(rows[rows >= 0])
            trend = np.asarray(self.tstate["leap"]["trend"])
            has = np.asarray(self.tstate["leap"]["has_trend"])
            G = max(self.cfg.shards, 1)
            for req in decoding:
                s = req.slot
                if G <= 1 or not has[s] or not trend[s]:
                    continue
                frontier = int(req.pages[-1])
                cands = [frontier + int(trend[s])
                         * (self.geom.pw_max + lc.cfg.lead + j)
                         for j in range(lc.cfg.mig_per_stream)]
                moved = lc.migrate_toward(cands, s % G)
                if moved and self.events is not None:
                    self.events.append(Event("migrate", self._chunk_clock,
                                             s, count=moved))
            victims = lc.demote_victims()
            if victims:
                vict = jnp.asarray(victims, jnp.int32)
                self.pool = _roundtrip_pages(self.pool, vict)
                inv = jnp.broadcast_to(vict[None], (S, len(victims)))
                self.tstate = tiered_invalidate(self.tstate, inv)
                if self.events is not None:
                    self.events.append(Event("demote", self._chunk_clock,
                                             0, count=len(victims)))
            sweep_kw["home_map"] = lc.home_map()
            if lc.cfg.compressed:
                sweep_kw["comp_map"] = lc.comp_map()
                sweep_kw["decompress_delay"] = lc.cfg.decompress_delay
        cold = {"k": self.pool["k"][0], "v": self.pool["v"][0]}
        q = jax.random.normal(jax.random.PRNGKey(1000 + t),
                              (S, 1, self.hq, self.ex.head_dim), self.dtype)
        with self.reg.span("tiered_sweep") as sp:
            self.tstate, info = tiered_sweep(
                self.tstate, cold, rows_j, self.geom,
                async_datapath=self.cfg.async_datapath,
                link_budget=self.cfg.link_budget,
                fabric=self.fabric, mesh=self.mesh, **sweep_kw)
            sp.sync = info
        mode = normalize_attn_kernel(self.cfg.attn_kernel)
        with self.reg.span("tiered_attention") as sp:
            tiered, resident = tiered_attention(q, self.tstate, rows_j,
                                                lengths_j, attn_kernel=mode)
            sp.sync = tiered
        flat = paged_decode_attention(q, self.pool, jnp.int32(0), rows_j,
                                      lengths_j,
                                      use_kernel=(mode != "ref"))
        act = [r.slot for r in decoding]
        step_ok = bool(resident) and bool(
            (np.asarray(tiered)[act] == np.asarray(flat)[act]).all())
        if not step_ok:
            self.equiv_ok = False
            if self.first_bad_step is None:
                self.first_bad_step = t
        if self.events is not None:
            self.events.extend(
                decode_sweep_events(info, step_offset=self._chunk_clock))
            self.link_hist.append(np.asarray(info["link_demand_fetches"]))
            self.shard_hist.append(np.asarray(info["shard_demand_fetches"]))
        self._chunk_clock += self._n_chunks

    # -- one engine step -----------------------------------------------------
    def _step(self, t: int) -> None:
        for req in self.sched.admit_ready(self.queue, t):
            self.ex.begin(req)
            self.phases.append(RequestPhase("admit", req.req_id,
                                            req.arrival_step, t, req.slot))
        written: list[tuple[int, int]] = []       # (slot, page)
        decoding: list[Request] = []
        finishers: list[Request] = []
        for req in sorted(self.sched.active(), key=lambda r: r.slot):
            if req.state == PREFILL:
                n = min(self.cfg.prefill_chunk,
                        req.prompt_len - req.prefilled)
                k, v, tok = self.ex.prefill_chunk(req, n)
                pages = self._write_tokens(req, k, v, req.prefilled)
                written.extend((req.slot, p) for p in pages)
                req.advance_prefill(n, t)
                self.phases.append(RequestPhase("prefill_chunk", req.req_id,
                                                t, t + 1, req.slot, n))
                if req.state == DECODE:           # prompt done: TTFT token
                    self.reg.histogram("ttft_steps").observe(req.ttft_steps)
                    if req.decoded >= req.gen:
                        finishers.append(req)
            elif req.state == DECODE:
                pos = req.prefilled + req.decoded - 1
                with self.reg.span("token_latency") as sp:
                    k, v, tok = self.ex.decode(req)
                    sp.sync = k
                pages = self._write_tokens(req, k[None], v[None], pos)
                written.extend((req.slot, p) for p in pages)
                done = req.advance_decode(t)
                decoding.append(req)
                if done:
                    finishers.append(req)
        if written and self.lifecycle is not None:
            # freshly written bytes are uncompressed by construction: clear
            # the comp bit (else a recycled page would charge a decompress
            # surcharge — and dodge its roundtrip — on stale state)
            n_prom = self.lifecycle.promote([p for _, p in written])
            if n_prom and self.events is not None:
                self.events.append(Event("promote", self._chunk_clock, 0,
                                         count=n_prom))
        if written:
            inv = np.full((self._inv_width,), -1, np.int32)
            inv[:len(written)] = [p for _, p in written]
            inv_j = jnp.broadcast_to(jnp.asarray(inv)[None],
                                     (self.cfg.slots, self._inv_width))
            self.tstate = tiered_invalidate(self.tstate, inv_j)
            if self.events is not None:
                self.events.extend(
                    Event("invalidate", self._chunk_clock, s, page=p,
                          seq=self.allocator.stamp_of(p))
                    for s, p in written)
        if decoding:
            self._sweep_and_pin(t, decoding)
        self.occupancy_peak = max(self.occupancy_peak,
                                  self.allocator.occupancy())
        for req in finishers:
            self._evict(req, t)

    def _evict(self, req: Request, t: int) -> None:
        self.phases.append(RequestPhase("decode", req.req_id,
                                        req.first_token_step, t, req.slot,
                                        req.decoded))
        slot = req.slot
        stats = tiered_stats(self.tstate, slot)
        base = self.counter_base[slot]
        for key in PINNED_COUNTERS:
            base[key] += int(stats[key])
        self.tstate = tiered_reset_stream(self.tstate, slot, self.geom,
                                          self.dtype)
        self.sched.finish(req, t)
        self.ex.end(req)
        self._finished.append(req)
        self.phases.append(RequestPhase("evict", req.req_id, t, t, slot))

    # -- run -----------------------------------------------------------------
    def run(self) -> dict:
        c = self.cfg
        last_arrival = max((r.arrival_step for r in self.queue._pending),
                           default=0)
        per_req = -(-c.prompt_len // c.prefill_chunk) + c.gen + 2
        max_steps = last_arrival + (c.requests + 1) * per_req + 10
        t = 0
        t0 = time.perf_counter()
        while len(self.queue) or self.sched.active():
            if t > max_steps:
                raise RuntimeError(
                    f"engine livelock: {len(self.queue)} queued / "
                    f"{len(self.sched.active())} active after {t} steps")
            with self.reg.span("engine_step"):
                self._step(t)
            t += 1
        wall = time.perf_counter() - t0
        return self._report(t, wall)

    def _report(self, steps: int, wall: float) -> dict:
        c = self.cfg
        totals = []
        for s in range(c.slots):
            cur = tiered_stats(self.tstate, s)
            totals.append({k: self.counter_base[s][k] + int(cur[k])
                           for k in PINNED_COUNTERS})
        trace_totals_ok = True
        if self.events is not None:
            self.events.extend(summary_events(totals))
            cnts = events_to_counts(self.events, c.slots)
            trace_totals_ok = all(
                cnts[s][k] == totals[s][k]
                for s in range(c.slots) for k in PINNED_COUNTERS)
        rnd = lambda d: {k: round(v, 5) if isinstance(v, float) else v
                         for k, v in d.items()}
        ttfts = self.reg.histogram("ttft_steps")
        out = {
            "requests": c.requests,
            "slots": c.slots,
            "arrival": c.arrival,
            "admission": "gang" if c.gang else "continuous",
            "steps": steps,
            "wall_s": round(wall, 3),
            "tiered_equiv_ok": self.equiv_ok,
            "requests_finished": len(self._finished),
            "tokens_decoded": sum(r.decoded for r in self._finished),
            "ttft_steps": rnd(ttfts.ladder()),
            "mean_ttft_steps": round(float(np.mean(ttfts.samples)), 3)
            if ttfts.samples else float("nan"),
            "token_latency": rnd(self.reg.histogram("token_latency").ladder()),
            "pages_allocated": self.sched.pages_allocated,
            "pages_recycled": self.sched.pages_recycled,
            "alloc_in_use_end": self.allocator.in_use,
            "alloc_occupancy_peak": round(self.occupancy_peak, 3),
            "prefetch_hits_total": sum(tt["prefetch_hits"] for tt in totals),
            "deferred_total": sum(tt["deferred"] for tt in totals),
        }
        if self.first_bad_step is not None:
            out["tiered_first_bad_step"] = self.first_bad_step
        if self.events is not None:
            out["trace_totals_ok"] = trace_totals_ok
            out["trace_events"] = len(self.events)
        if c.shards > 1:
            out["shards"] = c.shards
            out["placement"] = c.placement
        if self.lifecycle is not None:
            out["residency"] = self.lifecycle.report()
        return out


@jax.jit
def _roundtrip_pages(pool: dict, pages) -> dict:
    """Apply the lossy int8 page round trip to layer 0's ``pages`` in
    place — one scale per page (demotion to the compressed tier)."""
    from repro.runtime.compression import page_roundtrip

    def rt(buf):
        return buf.at[0, pages].set(jax.vmap(page_roundtrip)(buf[0, pages]))

    return {"k": rt(pool["k"]), "v": rt(pool["v"])}


@jax.jit
def _scatter_tokens(pool: dict, pages, offs, k_new, v_new) -> dict:
    """Write ``n`` tokens' K/V at ``(pages[j], offs[j])`` of layer 0."""
    def wr(buf, new):
        return buf.at[0, pages, offs].set(new.astype(buf.dtype))

    return {"k": wr(pool["k"], k_new), "v": wr(pool["v"], v_new)}


def serve_continuous(config: ServeConfig, executor=None, arch: str = None,
                     smoke: bool = True) -> dict:
    """Build an executor (real model or synthetic), run the engine once.

    ``arch=None`` (or an encdec/unsupported family) uses the synthetic
    executor — real scheduling, paging and pins over PRNG K/V bytes.
    """
    if executor is None:
        executor = build_executor(arch, smoke=smoke, seed=config.seed)
    return ServingEngine(config, executor).run()


def build_executor(arch: str | None, smoke: bool = True, seed: int = 0):
    """The real :class:`ModelExecutor` for ``arch``, falling back to
    :class:`SyntheticExecutor` for cache-incompatible families."""
    from .executor import ModelExecutor, SyntheticExecutor

    if arch is None:
        return SyntheticExecutor(n_kv_heads=2, head_dim=8, seed=seed)
    from repro import configs as cfglib
    cfg = cfglib.get_smoke_config(arch) if smoke else cfglib.get_config(arch)
    if cfg.family == "encdec":
        return SyntheticExecutor(cfg.n_kv_heads, cfg.head_dim, cfg.dtype,
                                 seed=seed)
    return ModelExecutor(cfg, seed=seed)
