"""Model executors: per-request token production for the serving engine.

The engine's step loop is model-agnostic — it asks an executor for the
next chunk of tokens' K/V bytes (to mirror into the cold paged pool) and
the next output token, per request. Two implementations:

* :class:`ModelExecutor` — the real thing. Each request owns a batch-1
  decode state; **chunked prefill** feeds prompt tokens through the same
  jitted ``decode_step`` the decode path uses (one compile serves every
  request and both phases), so a long prompt costs
  ``ceil(prompt/prefill_chunk)`` engine steps instead of stalling
  in-flight decodes for a monolithic prefill. The chunk that consumes the
  last prompt token emits the first output token (greedy argmax) — token
  positions, cache slots and logits line up exactly with the one-shot
  ``model.prefill`` (pinned at the 5e-3 model tolerance in
  ``tests/test_serving.py``).
* :class:`SyntheticExecutor` — no model: deterministic PRNG K/V keyed by
  ``(request id, position)`` and counter tokens. The tiered data path,
  paging and the §6.4 pin are all still real; scheduling benchmarks use
  this to sweep arrival × load without paying model compute.

Both produce K/V bytes deterministic per (request, position) so the
flat/tiered equivalence pin is meaningful under any chunking or slot
assignment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.model import build_model

from .request import Request


@functools.partial(jax.jit, static_argnames=("n", "hkv", "dh", "dtype"))
def _synth_kv(key, req_id, start, n: int, hkv: int, dh: int, dtype: str):
    """Deterministic per-(request, position) K/V page bytes, ``[n,Hkv,dh]``."""
    def one(pos):
        kk = jax.random.fold_in(jax.random.fold_in(key, req_id), pos)
        kv = jax.random.normal(kk, (2, hkv, dh), jnp.dtype(dtype))
        return kv[0], kv[1]

    return jax.vmap(one)(start + jnp.arange(n, dtype=jnp.int32))


class SyntheticExecutor:
    """PRNG K/V + counter tokens; the data path without the model."""

    def __init__(self, n_kv_heads: int, head_dim: int, dtype="float32",
                 seed: int = 0):
        self.n_kv_heads = n_kv_heads
        self.head_dim = head_dim
        self.dtype = jnp.dtype(dtype).name
        self._key = jax.random.PRNGKey(seed)

    def begin(self, req: Request) -> None:
        pass

    def end(self, req: Request) -> None:
        pass

    def _kv(self, req: Request, start: int, n: int):
        return _synth_kv(self._key, req.req_id, start, n,
                         self.n_kv_heads, self.head_dim, self.dtype)

    def prefill_chunk(self, req: Request, n: int):
        """K/V for prompt positions ``[prefilled, prefilled+n)`` and, when
        the chunk finishes the prompt, the first output token."""
        k, v = self._kv(req, req.prefilled, n)
        done = req.prefilled + n >= req.prompt_len
        tok = req.req_id % 251 if done else None
        return k, v, tok

    def decode(self, req: Request):
        """K/V of the token being consumed (position ``length - 1``) and
        the next output token."""
        pos = req.prefilled + req.decoded - 1
        k, v = self._kv(req, pos, 1)
        return k[0], v[0], (req.req_id + req.decoded) % 251


class ModelExecutor:
    """Real model, batch-1 per-request decode states, chunked prefill."""

    def __init__(self, cfg, seed: int = 0):
        if cfg.family == "encdec":
            raise ValueError("continuous-batching engine drives decoder-only "
                             "families; encdec serving stays on the batch "
                             "driver")
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params, _ = self.model.init_params(jax.random.PRNGKey(seed))
        self._decode = jax.jit(self.model.decode_step)
        self._key = jax.random.PRNGKey(seed + 1)
        self._states: dict[int, dict] = {}
        self._prompts: dict[int, jax.Array] = {}
        self._last_tok: dict[int, int] = {}
        self.last_logits: dict[int, jax.Array] = {}
        self.n_kv_heads = cfg.n_kv_heads
        self.head_dim = cfg.head_dim
        self.dtype = jnp.dtype(cfg.dtype).name
        # a rolling SWA cache would overwrite mirrored positions; the paged
        # mirror needs the full context resident (checked per request in
        # begin())
        self._cache_cap = cfg.sliding_window or None
        self._synth = SyntheticExecutor(cfg.n_kv_heads, cfg.head_dim,
                                        cfg.dtype, seed=seed + 2)
        self._attn_period = next(
            (i for i, kind in enumerate(cfg.layer_kinds()[:cfg.scan_period()])
             if kind["mix"] == "attn"), None)

    def prompt_tokens(self, req: Request) -> jax.Array:
        if req.req_id not in self._prompts:
            key = jax.random.fold_in(self._key, req.req_id)
            self._prompts[req.req_id] = jax.random.randint(
                key, (req.prompt_len,), 0, self.cfg.vocab_size, jnp.int32)
        return self._prompts[req.req_id]

    def begin(self, req: Request) -> None:
        if self._cache_cap is not None and req.max_len > self._cache_cap:
            raise ValueError(
                f"request {req.req_id}: max_len {req.max_len} exceeds the "
                f"sliding-window cache ({self._cache_cap}) — the paged "
                "mirror would lose overwritten positions")
        self.prompt_tokens(req)
        self._states[req.req_id] = self.model.init_decode_state(
            1, req.max_len)

    def end(self, req: Request) -> None:
        self._states.pop(req.req_id, None)
        self._prompts.pop(req.req_id, None)
        self._last_tok.pop(req.req_id, None)
        self.last_logits.pop(req.req_id, None)

    def _kv_written(self, req: Request, state, pos: int):
        """The K/V bytes ``decode_step`` just wrote at cache position
        ``pos`` — ``[Hkv, dh]`` each — from the first attention stack of
        the scan period. Cache-free families (pure mamba/xlstm) mirror
        synthetic bytes so the data path stays end-to-end real."""
        if self._attn_period is None:
            k, v = self._synth._kv(req, pos, 1)
            return k[0], v[0]
        blk = state["blocks"][self._attn_period]
        return blk["k"][0, 0, pos], blk["v"][0, 0, pos]

    def _feed(self, req: Request, token: int | jax.Array):
        """One ``decode_step``: returns ``(logits [V], k, v)`` where k/v
        are the bytes written for the *input* token at its position."""
        state = self._states[req.req_id]
        pos = int(state["pos"])
        tok = jnp.asarray([token], jnp.int32)
        logits, state = self._decode(self.params, tok, state)
        self._states[req.req_id] = state
        k, v = self._kv_written(req, state, pos)
        return logits[0], k, v

    def prefill_chunk(self, req: Request, n: int):
        """Consume ``n`` prompt tokens; K/V ``[n, Hkv, dh]``; the first
        output token when the prompt is exhausted."""
        prompt = self.prompt_tokens(req)
        ks, vs = [], []
        logits = None
        for j in range(req.prefilled, req.prefilled + n):
            logits, k, v = self._feed(req, prompt[j])
            ks.append(k)
            vs.append(v)
        tok = None
        if req.prefilled + n >= req.prompt_len:
            tok = int(jnp.argmax(logits))
            self._last_tok[req.req_id] = tok
            self.last_logits[req.req_id] = logits
        return jnp.stack(ks), jnp.stack(vs), tok

    def decode(self, req: Request):
        """Consume the last emitted token, emit the next one."""
        logits, k, v = self._feed(req, self._last_tok[req.req_id])
        tok = int(jnp.argmax(logits))
        self._last_tok[req.req_id] = tok
        self.last_logits[req.req_id] = logits
        return k, v, tok

    def oneshot_prefill_logits(self, req: Request) -> jax.Array:
        """Reference: ``model.prefill`` over the same prompt in one shot
        (the chunked-prefill equivalence oracle; [V] float32)."""
        batch = {"tokens": self.prompt_tokens(req)[None]}
        logits, _ = self.model.prefill(self.params, batch, req.max_len)
        return logits[0]
