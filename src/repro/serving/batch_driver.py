"""Lock-step batch serving: the fixed-batch tiered replay + chaos sidecar.

The original serving loop (pre-continuous-batching): every request in the
batch prefills together, decodes together, finishes together. The tiered
replay here is still the reference data-path driver — it mirrors the
model's *real* decoded K/V into the cold pool and pins tiered/flat
bit-identity every step — and the continuous engine
(:mod:`repro.serving.engine`) is benchmarked against its gang-admission
discipline. ``launch/serve.py`` dispatches here for ``--arrival batch``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.export import write_chrome_trace, write_jsonl
from repro.obs.metrics import Registry
from repro.obs.trace import (Event, decode_sweep_events, events_to_counts,
                             summary_events)
from repro.paging.kv_cache import (append_kv, init_paged_kv,
                                   linear_page_table, paged_decode_attention)
from repro.paging.sharded_pool import ShardedPoolCfg
from repro.paging.tiered_kv import (TieredKV, normalize_attn_kernel,
                                    tiered_attention, tiered_init,
                                    tiered_invalidate, tiered_min_slots,
                                    tiered_stats, tiered_sweep)

#: event-type totals that must reproduce the pool counters bit-exactly
#: whenever a trace is written (DESIGN.md §8.2)
PINNED_COUNTERS = ("hits", "misses", "partial_hits", "prefetch_hits",
                   "prefetch_issued", "deferred", "ring_drops", "pollution")


def find_dense_kv(state) -> tuple[jax.Array, jax.Array] | tuple[None, None]:
    """Pull one attention block's dense KV cache out of a decode state.

    Returns ``(k, v)`` each ``[B, T, Hkv, dh]`` (first attention layer of
    the scan period / the self-attention stack), or ``(None, None)`` for
    cache-free families (pure mamba/xlstm) — the caller then mirrors
    synthetic KV so the tiered data path is still exercised end to end.
    """
    cands = []
    if isinstance(state, dict):
        cands.extend(b for b in state.get("blocks", ()) if isinstance(b, dict))
        if isinstance(state.get("self_kv"), dict):
            cands.append(state["self_kv"])
    for b in cands:
        if "k" in b and "v" in b and getattr(b["k"], "ndim", 0) == 5:
            return b["k"][0], b["v"][0]
    return None, None


def serve_batch_tiered(cfg, state, args, B: int, prompt_len: int,
                       max_len: int, reg: Registry | None = None,
                       trace_path: str | None = None) -> dict:
    """Replay the decode window through the tiered paged-KV data path.

    Mirrors the model's real decoded K/V into the cold paged pool, then per
    decode step: append the step's KV (``append_kv``), invalidate the
    written page in every stream's hot tier, demand-sweep each request's
    context pages through its hot pool, and serve attention from hot slots
    — asserting bit-identity against the flat pool every step.

    With ``trace_path`` the per-sweep info arrays are decoded host-side
    (after the timed window — the jitted path is untouched) into the
    page-lifecycle event log on the global chunk-step clock, written as a
    Chrome trace + JSONL, and the event-type totals are pinned bit-exact
    against the final pool counters.
    """
    ps = args.page_size
    npps = -(-max_len // ps)
    n_pages = B * npps
    hkv, hq, dh = cfg.n_kv_heads, cfg.n_heads, cfg.head_dim
    n_streams = args.streams if args.streams > 1 else B

    kd, vd = find_dense_kv(state)
    if kd is None:
        # cache-free family: synthetic KV, the data path is still real
        kd = jax.random.normal(jax.random.PRNGKey(7),
                               (B, max_len, hkv, dh), jnp.dtype(cfg.dtype))
        vd = jax.random.normal(jax.random.PRNGKey(8),
                               (B, max_len, hkv, dh), jnp.dtype(cfg.dtype))

    def pad_to(x, T):
        if x.shape[1] >= T:
            return x[:, :T]
        return jnp.concatenate(
            [x, jnp.zeros((B, T - x.shape[1]) + x.shape[2:], x.dtype)], 1)

    kd, vd = pad_to(kd, npps * ps), pad_to(vd, npps * ps)
    pt_full = linear_page_table(B, npps)

    # Cold tier: mirror the prompt prefix now; decode positions are appended
    # step by step inside the replay loop (the real write path).
    pool = init_paged_kv(1, n_pages, ps, hkv, dh, kd.dtype)
    pos_ids = jnp.arange(npps * ps)
    prefix = lambda x: jnp.where((pos_ids < prompt_len)[None, :, None, None],
                                 x, 0)
    to_pages = lambda x: x.reshape(B * npps, ps, hkv, dh)
    pool = {"k": pool["k"].at[0, pt_full.reshape(-1)].set(
                to_pages(prefix(kd))),
            "v": pool["v"].at[0, pt_full.reshape(-1)].set(
                to_pages(prefix(vd)))}

    # n_slots derived from the sweep geometry (the documented residency
    # floor), not a hardcoded constant that ignores pw_max/ring.
    proto = TieredKV(n_pages, 1, ps, hkv, dh, chunk=args.chunk,
                     ring_size=args.ring_size)
    geom = TieredKV(n_pages, tiered_min_slots(npps, proto), ps, hkv, dh,
                    chunk=args.chunk, ring_size=args.ring_size)
    tstate = tiered_init(geom, n_streams, kd.dtype)
    rows = jnp.stack([pt_full[s % B] for s in range(n_streams)])

    fabric = mesh = None
    if args.shards > 1:
        from repro.launch.mesh import make_fabric_mesh
        if n_pages % args.shards:
            raise SystemExit(f"--shards {args.shards} must divide the "
                             f"{n_pages}-page cold pool")
        fabric = ShardedPoolCfg(n_shards=args.shards,
                                placement=args.placement,
                                link_budget=args.link_budget,
                                near_delay=1, far_delay=args.far_delay)
        mesh = make_fabric_mesh(args.shards)
        # append_kv mutates the cold pool every step, so tiered_sweep
        # re-places the whole pool home-major per call — fine for this
        # pin-every-step smoke driver (which also recomputes the flat
        # reference each step); a production loop would keep the pool
        # permanently placed and route append_kv writes through place_perm

    reg = reg if reg is not None else Registry()
    attn_mode = normalize_attn_kernel(getattr(args, "attn_kernel", "ref"))
    n_chunks = -(-npps // geom.chunk)      # global clock: chunk steps
    events = [] if trace_path else None
    link_hist, shard_hist = [], []
    equiv_ok = True
    first_bad_step = None
    deferred = partials = 0
    shard_demand = np.zeros(args.shards, np.int64)
    for t in range(args.gen - 1):
        pos = prompt_len + t
        pool = append_kv(pool, jnp.int32(0), kd[:, pos], vd[:, pos],
                         pt_full, jnp.int32(pos))
        written = pt_full[:, pos // ps]                      # [B]
        inv_pages = jnp.stack([written[s % B] for s in range(n_streams)])
        tstate = tiered_invalidate(tstate, inv_pages[:, None])
        cold = {"k": pool["k"][0], "v": pool["v"][0]}
        lengths = jnp.full((n_streams,), pos + 1, jnp.int32)
        q = jax.random.normal(jax.random.PRNGKey(100 + t),
                              (n_streams, 1, hq, dh), jnp.dtype(cfg.dtype))
        # timed window covers only the serving path (sweep + attention);
        # the flat-pool reference, the bitwise pin check and the host-side
        # event decode all run outside it
        with reg.span("tiered_sweep") as sp:
            tstate, info = tiered_sweep(tstate, cold, rows, geom,
                                        async_datapath=args.async_datapath,
                                        link_budget=args.link_budget,
                                        fabric=fabric, mesh=mesh)
            sp.sync = info
        with reg.span("tiered_attention") as sp:
            tiered, resident = tiered_attention(q, tstate, rows, lengths,
                                                attn_kernel=attn_mode)
            sp.sync = tiered
        flat = paged_decode_attention(
            q, pool, jnp.int32(0), rows, lengths,
            use_kernel=(attn_mode != "ref"))
        step_ok = bool(resident) and bool(
            (np.asarray(tiered) == np.asarray(flat)).all())
        if not step_ok and first_bad_step is None:
            first_bad_step = t
        equiv_ok &= step_ok
        deferred += int(np.asarray(info["deferred"]).sum())
        partials += int(np.asarray(info["partial_hit"]).sum())
        if fabric is not None:
            shard_demand += np.asarray(info["shard_demand_fetches"]).sum(0)
        if events is not None:
            step0 = t * n_chunks           # each sweep advances the stream
            inv_np = np.asarray(inv_pages)  # clock by n_chunks steps
            events.extend(Event("invalidate", step0, s, page=int(inv_np[s]))
                          for s in range(n_streams))
            events.extend(decode_sweep_events(info, step_offset=step0))
            link_hist.append(np.asarray(info["link_demand_fetches"]))
            shard_hist.append(np.asarray(info["shard_demand_fetches"]))

    per = [tiered_stats(tstate, s) for s in range(n_streams)]
    t_tiered = (reg.histogram("tiered_sweep").total
                + reg.histogram("tiered_attention").total)
    out = {
        "tiered_equiv_ok": equiv_ok,
        "tiered_attn_kernel": attn_mode,
        "tiered_streams": n_streams,
        "tiered_n_slots": geom.n_slots,
        "tiered_hot_frac": round(n_streams * geom.n_slots / n_pages, 3),
        "tiered_decode_s": round(t_tiered, 3),
        "paged_prefetch_hit_rate": round(
            float(np.mean([p["coverage"] for p in per])), 3),
        "paged_pollution": sum(p["pollution"] for p in per),
        "paged_ring_drops": sum(p["ring_drops"] for p in per),
    }
    if args.async_datapath:
        out["paged_partial_hits"] = partials
        out["paged_latency_hidden_frac"] = round(
            float(np.mean([p["latency_hidden_frac"] for p in per])), 3)
    if args.link_budget is not None:
        out["paged_link_budget"] = args.link_budget
        out["paged_deferred"] = deferred
    if args.shards > 1:
        out["paged_shards"] = args.shards
        out["paged_placement"] = args.placement
        out["paged_shard_demand"] = shard_demand.tolist()
    if first_bad_step is not None:
        out["tiered_first_bad_step"] = first_bad_step
    spans = reg.summary()["histograms"]
    out["span_sweep_ms"] = round(spans["tiered_sweep"]["avg"] * 1e3, 3)
    out["span_attention_ms"] = round(spans["tiered_attention"]["avg"] * 1e3, 3)
    if events is not None:
        events.extend(summary_events(per))
        cnts = events_to_counts(events, n_streams)
        totals_ok = all(cnts[s][k] == per[s][k] for s in range(n_streams)
                        for k in PINNED_COUNTERS)
        counters = {"link_demand_fetches": np.concatenate(link_hist)}
        if args.shards > 1:
            counters["shard_demand_fetches"] = np.concatenate(shard_hist)
        write_chrome_trace(trace_path, events, counters)
        write_jsonl(trace_path + ".jsonl", events)
        out["trace_path"] = trace_path
        out["trace_events"] = len(events)
        out["trace_totals_ok"] = totals_ok
    if args.chaos:
        out.update(chaos_sidecar(args, rows, n_pages, n_streams))
    return out


def chaos_sidecar(args, rows, n_pages: int, n_streams: int) -> dict:
    """Replay the requests' context-page schedules under a ChaosSpec.

    The sidecar drives the chaos-enabled sharded consume path
    (DESIGN.md §9) over the same physical pages the tiered path serves:
    each stream walks its context pages cyclically, the spec's faults
    (stragglers / budget cuts / node loss / grant churn) hit the fabric
    model, and the report compares the adaptive-deadline EWMA's per-shard
    delay estimate against the true (dilated) delay at the end of the run
    — the operator-facing "is my deadline model tracking the fabric"
    signal.
    """
    from repro.fabric.chaos import EST_ONE, ChaosSpec, compile_chaos
    from repro.paging.prefetch_serving import (PrefetchedStream,
                                               stream_stats_at)
    from repro.paging.sharded_pool import sharded_multi_stream_consume

    with open(args.chaos) as f:
        spec = ChaosSpec.from_json(f.read())
    G = max(args.shards, 1)
    if n_pages % G:
        raise SystemExit(f"--chaos sidecar: {n_pages}-page pool not "
                         f"divisible by {G} shards")
    npps = rows.shape[1]
    T = min(max(4 * npps, 48), 256)
    rows_np = np.asarray(rows)
    scheds = np.stack([rows_np[s][np.arange(T) % npps]
                       for s in range(n_streams)]).astype(np.int32)
    geom = PrefetchedStream(n_pages=n_pages, n_slots=n_pages, page_elems=4,
                            ring_size=args.ring_size)
    fab = ShardedPoolCfg(n_shards=G, placement=args.placement,
                         link_budget=args.link_budget,
                         near_delay=1, far_delay=args.far_delay)
    cold = jnp.arange(n_pages * 4, dtype=jnp.float32).reshape(n_pages, 4)
    st, _, info = sharded_multi_stream_consume(
        cold, jnp.asarray(scheds), geom, fab, chaos=spec)
    per = [stream_stats_at(st, s) for s in range(n_streams)]
    faults = sum(p["faults"] for p in per)
    hits = sum(p["prefetch_hits"] for p in per)
    deferred = sum(p["deferred"] for p in per)
    cz = compile_chaos(spec, n_steps=T, n_streams=n_streams, n_shards=G,
                       n_pages=n_pages, placement=args.placement,
                       base_budget=args.link_budget)
    # final per-shard delay: estimate (stream-averaged EWMA, steps) vs the
    # true dilated delay at the last step (stream-averaged near/far base)
    est = np.asarray(info["est_q"], dtype=np.float64) / EST_ONE
    home = np.arange(n_streams) % G
    base = np.where(np.arange(G)[None, :] == home[:, None],
                    1, args.far_delay)
    true = base * np.asarray(cz["dilation"][-1], dtype=np.float64)[None, :]
    return {
        "chaos_spec": args.chaos,
        "chaos_steps": T,
        "chaos_shards": G,
        "chaos_faults": faults,
        "chaos_prefetch_hits": hits,
        "chaos_deferred": deferred,
        "chaos_timely_rate": round((hits - deferred) / max(1, faults), 3),
        "chaos_pollution": sum(p["pollution"] for p in per),
        "chaos_est_delay": [round(float(v), 2) for v in est.mean(0)],
        "chaos_true_delay": [round(float(v), 2) for v in true.mean(0)],
        "chaos_adaptive_deadline": spec.adaptive_deadline,
    }
