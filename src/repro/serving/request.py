"""Per-request lifecycle state machine: WAITING → PREFILL → DECODE → FINISHED.

The control-plane record of one serving request (DESIGN.md §10). Everything
here is host-side Python with no JAX dependency, so the scheduler invariants
(legal transitions, token accounting, page-demand bookkeeping) are property-
testable without building a model or a device pool: the hypothesis harness
in ``tests/test_serving.py`` drives thousands of random arrival/finish
schedules through :class:`Request` + :class:`repro.serving.scheduler`.

State semantics:

* ``WAITING``  — arrived, sitting in the admission queue; owns nothing.
* ``PREFILL`` — admitted to a slot; the prompt is being consumed in chunks
  of at most ``prefill_chunk`` tokens per engine step (chunked prefill:
  long prompts never monopolize a step, in-flight decodes keep going).
  The first output token is emitted by the chunk that consumes the last
  prompt token — that step stamps TTFT.
* ``DECODE``  — one output token per engine step until ``gen`` tokens.
* ``FINISHED``— evicted: pages recycled, slot freed, stream state reset.
"""

from __future__ import annotations

import dataclasses

WAITING = "WAITING"
PREFILL = "PREFILL"
DECODE = "DECODE"
FINISHED = "FINISHED"

#: legal transitions of the request state machine
_EDGES = {
    WAITING: (PREFILL,),
    PREFILL: (DECODE,),
    DECODE: (FINISHED,),
    FINISHED: (),
}


@dataclasses.dataclass
class Request:
    """One serving request's control-plane record.

    Attributes:
      req_id:      global request id (the trace/track key that survives
                   slot recycling).
      prompt_len:  prompt tokens to prefill.
      gen:         output tokens to decode (including the TTFT token).
      arrival_step: engine step the request becomes admissible.
    """

    req_id: int
    prompt_len: int
    gen: int
    arrival_step: int = 0

    # -- runtime (managed by the scheduler/engine) ---------------------------
    state: str = WAITING
    slot: int = -1
    prefilled: int = 0          # prompt tokens consumed so far
    decoded: int = 0            # output tokens emitted so far
    pages: list[int] = dataclasses.field(default_factory=list)
    admit_step: int = -1
    first_token_step: int = -1
    finish_step: int = -1

    def __post_init__(self):
        if self.prompt_len < 1 or self.gen < 1:
            raise ValueError("prompt_len and gen must both be >= 1")

    # -- derived -------------------------------------------------------------
    @property
    def max_len(self) -> int:
        """Total context tokens at finish (prompt + generated)."""
        return self.prompt_len + self.gen

    @property
    def length(self) -> int:
        """Valid context tokens right now (prompt consumed + decoded)."""
        return self.prefilled + self.decoded

    def pages_needed(self, page_size: int) -> int:
        """Total pages this request will ever own (admission reservation)."""
        return -(-self.max_len // page_size)

    @property
    def ttft_steps(self) -> int:
        """Steps from arrival to the first output token (-1 until emitted)."""
        if self.first_token_step < 0:
            return -1
        return self.first_token_step - self.arrival_step

    # -- transitions ---------------------------------------------------------
    def to(self, state: str, step: int) -> None:
        """Move to ``state``, enforcing the lifecycle edges."""
        if state not in _EDGES[self.state]:
            raise ValueError(f"illegal transition {self.state} -> {state} "
                             f"for request {self.req_id}")
        self.state = state
        if state == PREFILL:
            self.admit_step = step
        elif state == FINISHED:
            self.finish_step = step

    def advance_prefill(self, n: int, step: int) -> int:
        """Consume up to ``n`` prompt tokens; returns tokens consumed.

        When the chunk reaches the end of the prompt the request emits its
        first output token in the same step (TTFT) and moves to DECODE.
        """
        if self.state != PREFILL:
            raise ValueError(f"request {self.req_id} not in PREFILL "
                             f"(state={self.state})")
        take = min(n, self.prompt_len - self.prefilled)
        if take <= 0:
            raise ValueError(f"request {self.req_id}: no prompt left to "
                             "prefill")
        self.prefilled += take
        if self.prefilled == self.prompt_len:
            self.decoded = 1                       # prefill emits token 0
            self.first_token_step = step
            self.to(DECODE, step)
        return take

    def advance_decode(self, step: int) -> bool:
        """Emit one output token; returns True when the quota is reached."""
        if self.state != DECODE:
            raise ValueError(f"request {self.req_id} not in DECODE "
                             f"(state={self.state})")
        if self.decoded >= self.gen:
            raise ValueError(f"request {self.req_id} decoded past its quota")
        self.decoded += 1
        return self.decoded >= self.gen
