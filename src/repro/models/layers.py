"""Shared layers: norms, embeddings, RoPE / M-RoPE, SwiGLU MLP, chunked CE.

Every ``*_init`` returns ``(params, specs)`` — two pytrees of identical
structure; spec leaves are tuples of *logical* axis names that
``repro.distributed.sharding`` later maps onto mesh axes (TP/FSDP/EP rules).
Logical vocabulary: ``embed`` (d_model), ``vocab``, ``heads`` (flattened
n_heads*d_head — kept flat so TP divides even when the head count doesn't),
``kv_heads``, ``ff``, ``experts``, ``inner`` (mamba/xlstm inner width),
``layers`` (the stacked period-scan axis, always unsharded).

Compute dtype discipline: matmuls run in the config dtype (bf16 on TPU);
norms, softmax, rotary, and losses compute in float32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# param init helpers
# --------------------------------------------------------------------------
def dense_init(rng, d_in: int, d_out: int, axes: tuple, dtype,
               scale: float | None = None):
    """Truncated-normal 2D weight with fan-in scaling."""
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    w = (jax.random.truncated_normal(rng, -2.0, 2.0, (d_in, d_out), jnp.float32)
         * scale).astype(dtype)
    return w, axes


def embed_init(rng, vocab: int, d: int, dtype):
    w = (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02).astype(dtype)
    return w, ("vocab", "embed")


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def norm_init(d: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}
    return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)})


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y + p["bias"].astype(jnp.float32)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings (RoPE + qwen2-vl M-RoPE)
# --------------------------------------------------------------------------
def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions [...,] -> angles [..., head_dim//2] (float32)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rotary(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x [..., h, d]; angles broadcastable to [..., 1, d//2]. Pairs (i, i+d/2)."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    c, s = jnp.cos(angles), jnp.sin(angles)
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], -1).astype(x.dtype)


def mrope_angles(positions3: jax.Array, head_dim: int, theta: float,
                 sections: tuple[int, int, int]) -> jax.Array:
    """M-RoPE (qwen2-vl): positions3 [3, ...] (t,h,w ids) -> angles [..., d//2].

    The d//2 frequency slots are split into ``sections`` (t, h, w); each slice
    rotates by its own coordinate. Text tokens carry t==h==w, which makes
    M-RoPE coincide with 1-D RoPE there — the property tests pin this.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    sel = np.repeat(np.arange(3), np.asarray(sections))          # [half] -> which coord
    # gather per-slot coordinate: positions3 [3, ...] -> [..., half]
    coord = jnp.moveaxis(positions3, 0, -1).astype(jnp.float32)   # [..., 3]
    per_slot = coord[..., sel]                                    # [..., half]
    return per_slot * freqs


# --------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------------
def mlp_init(rng, d: int, ff: int, dtype):
    kg, ku, kd = jax.random.split(rng, 3)
    wg, ag = dense_init(kg, d, ff, ("embed", "ff"), dtype)
    wu, au = dense_init(ku, d, ff, ("embed", "ff"), dtype)
    wd, ad = dense_init(kd, ff, d, ("ff", "embed"), dtype)
    return ({"wg": wg, "wu": wu, "wd": wd},
            {"wg": ag, "wu": au, "wd": ad})


def apply_mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    f = jax.nn.silu if act == "silu" else jax.nn.gelu
    g = f(x @ p["wg"]) * (x @ p["wu"])
    return g @ p["wd"]


# --------------------------------------------------------------------------
# chunked cross-entropy (vocab-sharded LM head, bounded logits footprint)
# --------------------------------------------------------------------------
def chunked_ce_loss(hidden: jax.Array, w_out: jax.Array, targets: jax.Array,
                    mask: jax.Array, n_chunks: int = 0) -> jax.Array:
    """Mean CE over [B,S] targets without materializing [B,S,V] logits.

    Scans over S in ``n_chunks`` chunks; each chunk's [B,C,V] logits live only
    inside one scan step (remat recomputes them in backward). V can be
    mesh-sharded ("vocab" -> model); the log-sum-exp reduces over it with the
    collectives GSPMD inserts. ``n_chunks=0`` auto-sizes so a chunk's fp32
    logits stay ~<= 2^28 elements globally (~64 MB/chip when V shards 16-way).
    """
    B, S, D = hidden.shape
    V = w_out.shape[1]
    if n_chunks <= 0:
        # More chunks shrink live logits, but the scan accumulates (and
        # under GSPMD all-reduces) the w_out gradient EVERY chunk — 512
        # chunks cost 512 weight-grad reductions (H4 finding). 32 caps that
        # while keeping per-chunk logits ~B*S*V/32 elements.
        n_chunks = max(8, min(32, (B * S * V + (1 << 28) - 1) >> 28))
    n_chunks = min(n_chunks, S)
    while S % n_chunks:
        n_chunks -= 1
    hs = hidden.reshape(B, n_chunks, S // n_chunks, D).swapaxes(0, 1)
    ts = targets.reshape(B, n_chunks, S // n_chunks).swapaxes(0, 1)
    ms = mask.reshape(B, n_chunks, S // n_chunks).swapaxes(0, 1)

    def chunk(carry, xs):
        h, t, m = xs
        logits = (h @ w_out).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, t[..., None], -1)[..., 0]
        nll = (lse - gold) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(chunk, (jnp.float32(0), jnp.float32(0)),
                                 (hs, ts, ms))
    return tot / jnp.maximum(cnt, 1.0)
