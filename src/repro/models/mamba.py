"""Mamba (S6) selective state-space block — train scan + O(1) decode step.

Faithful S6 structure (Gu & Dao 2023): in_proj -> (x, z); causal depthwise
conv; data-dependent (Δ, B, C) projections; selective scan
``h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t``, ``y_t = C_t h_t + D x_t``; gated
output ``y·silu(z)``; out_proj. The training path is a ``lax.scan`` over the
sequence (single compact HLO loop; the chunked associative-scan variant is a
§Perf candidate). Decode carries ``(conv_state, h)`` — O(1) per token, which
is what makes the hybrid archs long_500k-capable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


def mamba_dims(d_model: int, expand: int, d_state: int):
    di = expand * d_model
    dt_rank = -(-d_model // 16)
    return di, dt_rank, d_state


def _pick_chunk(S: int, target: int = 128) -> int:
    """Largest divisor of S that is <= target (chunked-scan granularity)."""
    c = min(S, target)
    while S % c:
        c -= 1
    return c


def mamba_init(rng, d_model: int, expand: int, d_state: int, d_conv: int, dtype):
    di, dt_rank, N = mamba_dims(d_model, expand, d_state)
    ks = jax.random.split(rng, 6)
    w_in, a_in = dense_init(ks[0], d_model, 2 * di, ("embed", "inner"), dtype)
    w_xdbc, a_xdbc = dense_init(ks[1], di, dt_rank + 2 * N, ("inner", None), dtype)
    w_dt, a_dt = dense_init(ks[2], dt_rank, di, (None, "inner"), dtype)
    w_out, a_out = dense_init(ks[3], di, d_model, ("inner", "embed"), dtype)
    conv = (jax.random.normal(ks[4], (d_conv, di), jnp.float32)
            / jnp.sqrt(jnp.float32(d_conv))).astype(dtype)
    # S4D-real init for A; dt bias init so softplus(dt) spans (1e-3, 1e-1)
    a_log = jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :],
                             (di, 1)))
    dt_bias = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[5], (di,), jnp.float32,
                                   jnp.log(1e-3), jnp.log(1e-1)))))
    p = {"w_in": w_in, "w_xdbc": w_xdbc, "w_dt": w_dt, "w_out": w_out,
         "conv": conv, "a_log": a_log.astype(jnp.float32),
         "dt_bias": dt_bias.astype(jnp.float32),
         "d_skip": jnp.ones((di,), jnp.float32)}
    s = {"w_in": a_in, "w_xdbc": a_xdbc, "w_dt": a_dt, "w_out": a_out,
         "conv": (None, "inner"), "a_log": ("inner", None),
         "dt_bias": ("inner",), "d_skip": ("inner",)}
    return p, s


def _dbc(p, xc, dt_rank, N):
    """conv'd activations -> (Δ [.. di], B [.. N], C [.. N]) in fp32."""
    dbc = (xc @ p["w_xdbc"]).astype(jnp.float32)
    dt_lowrank, b, c = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_lowrank @ p["w_dt"].astype(jnp.float32)
                         + p["dt_bias"])
    return dt, b, c


def apply_mamba(p: dict, x: jax.Array, d_state: int,
                return_state: bool = False):
    """Train/prefill path: x [B,S,D] -> y [B,S,D] (scan over S).

    With ``return_state`` also returns the decode carry {conv, h} at step S.
    """
    B, S, D = x.shape
    di = p["w_in"].shape[1] // 2
    dt_rank = p["w_dt"].shape[0]
    N = d_state

    xz = x @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                     # [B,S,di]
    # causal depthwise conv over S
    K = p["conv"].shape[0]
    xpad = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))
    xc = sum(xpad[:, k:k + S] * p["conv"][k] for k in range(K))
    xc = jax.nn.silu(xc)

    from repro.perf_flags import enabled
    if enabled("sscan_kernel"):
        # Fused Pallas selective scan (forward-only: prefill/serving). The
        # per-step h carry stays in VMEM — see kernels/selective_scan.
        from repro.kernels.selective_scan import selective_scan
        dt, bb, cc = _dbc(p, xc, dt_rank, N)
        a = -jnp.exp(p["a_log"])
        out = selective_scan(dt, bb, cc, xc.astype(jnp.float32), a,
                             return_state=return_state)
        y_s, h_fin = out if return_state else (out, None)
        y = y_s + xc.astype(jnp.float32) * p["d_skip"]
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
        res = y @ p["w_out"]
        if not return_state:
            return res
        conv_tail = xpad[:, S:S + K - 1] if K > 1 else xi[:, :0]
        return res, {"conv": conv_tail.astype(p["conv"].dtype), "h": h_fin}

    a = -jnp.exp(p["a_log"])                              # [di,N]

    # Chunked selective scan: outer scan over chunks saves only boundary
    # states; the rematted inner scan's per-step residuals ([B,di,N] each)
    # materialize one chunk at a time during backward. Without this, scan-AD
    # stores S per-step carries (TB-scale at 32K seq).
    C = _pick_chunk(S)
    ch = lambda t: jnp.moveaxis(t.reshape(B, S // C, C, *t.shape[2:]), 1, 0)
    from repro.perf_flags import enabled
    dbc_in_chunk = enabled("mamba_dbc")
    if dbc_in_chunk:
        # H3: derive (Δ,B,C) per chunk inside the rematted body — avoids
        # materializing [B,S,di] fp32 projections for the whole sequence.
        xs_c = (ch(xc),)
    else:
        dt, b, c = _dbc(p, xc, dt_rank, N)                # [B,S,di],[B,S,N]x2
        xs_c = (ch(dt), ch(b), ch(c), ch(xc.astype(jnp.float32)))

    @jax.checkpoint
    def chunk(h, xs):
        if dbc_in_chunk:
            (xc_k,) = xs                                  # [B,C,di]
            dt_k, b_k, c_k = _dbc(p, xc_k, dt_rank, N)
            x_k = xc_k.astype(jnp.float32)
        else:
            dt_k, b_k, c_k, x_k = xs                      # [B,C,...]

        def step(h, t):
            da = jnp.exp(dt_k[:, t][..., None] * a)       # [B,di,N]
            dbx = dt_k[:, t][..., None] * b_k[:, t][:, None, :] * x_k[:, t][..., None]
            h = da * h + dbx
            y = jnp.einsum("bdn,bn->bd", h, c_k[:, t])
            return h, y

        h, ys = jax.lax.scan(step, h, jnp.arange(C))
        return h, ys.swapaxes(0, 1)                       # [B,C,di]

    h0 = jnp.zeros((B, di, N), jnp.float32)
    h_fin, ys = jax.lax.scan(chunk, h0, xs_c)
    y = (jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
         + xc.astype(jnp.float32) * p["d_skip"])
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["w_out"]
    if not return_state:
        return out
    conv_tail = xpad[:, S:S + K - 1] if K > 1 else xi[:, :0]
    return out, {"conv": conv_tail.astype(p["conv"].dtype), "h": h_fin}


def mamba_state_init(batch: int, p: dict, d_state: int) -> dict:
    di = p["w_in"].shape[1] // 2
    K = p["conv"].shape[0]
    return {"conv": jnp.zeros((batch, K - 1, di), p["conv"].dtype),
            "h": jnp.zeros((batch, di, d_state), jnp.float32)}


def mamba_decode_step(p: dict, x: jax.Array, state: dict, d_state: int
                      ) -> tuple[jax.Array, dict]:
    """x [B,1,D] one token; state from :func:`mamba_state_init`."""
    B = x.shape[0]
    dt_rank = p["w_dt"].shape[0]
    N = d_state
    xz = x[:, 0] @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                     # [B,di]
    hist = jnp.concatenate([state["conv"], xi[:, None]], 1)   # [B,K,di]
    xc = jnp.einsum("bkd,kd->bd", hist, p["conv"])
    xc = jax.nn.silu(xc)
    dt, b, c = _dbc(p, xc, dt_rank, N)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a)
    h = da * state["h"] + dt[..., None] * b[:, None, :] * xc.astype(jnp.float32)[..., None]
    y = jnp.einsum("bdn,bn->bd", h, c) + xc.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return (y @ p["w_out"])[:, None], {"conv": hist[:, 1:], "h": h}
