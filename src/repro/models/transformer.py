"""Decoder-only LM assembly: period-scan over heterogeneous layer stacks.

The layer pattern of every assigned arch repeats with a small period P
(qwen2: 1; llama4: 2 dense/MoE; jamba: 8 = 1 attn + 7 mamba with MoE every
2nd; xlstm: 8 = 1 sLSTM + 7 mLSTM). Parameters are stacked per
period-position — each leaf [n_periods, ...] — and the trunk is one
``lax.scan`` over periods with the P positions unrolled inside. This keeps
the HLO compact (one loop regardless of depth: 80-layer qwen2 lowers the
same graph as an 8-layer one), which matters for the 512-device dry-run
compiles, and gives remat a natural per-period boundary.

Three entry points per model (built by :func:`repro.models.model.build_model`):
``train_forward`` (loss), ``prefill`` (tokens -> last logits + decode state),
``decode_step`` (one token + state -> logits + state). Decode state mirrors
the parameter stacking: per-position leaves [n_periods, ...]; attention
positions carry KV caches (full or SWA rolling buffer), mamba/xlstm carry
their O(1) recurrent states.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .attention import blocked_attention, decode_attention
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, apply_rotary, chunked_ce_loss,
                     dense_init, embed_init, mlp_init, mrope_angles,
                     norm_init, rope_angles)
from .mamba import apply_mamba, mamba_decode_step, mamba_init
from .moe import apply_moe, moe_init
from .xlstm import (apply_mlstm, apply_slstm, mlstm_decode_step, mlstm_init,
                    mlstm_state_init, slstm_decode_step, slstm_init)


# --------------------------------------------------------------------------
# per-kind block init
# --------------------------------------------------------------------------
def _attn_init(rng, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.head_dim
    ks = jax.random.split(rng, 4)
    wq, aq = dense_init(ks[0], d, cfg.n_heads * h, ("embed", "heads"), dtype)
    wk, ak = dense_init(ks[1], d, cfg.n_kv_heads * h, ("embed", "kv_heads"), dtype)
    wv, av = dense_init(ks[2], d, cfg.n_kv_heads * h, ("embed", "kv_heads"), dtype)
    wo, ao = dense_init(ks[3], cfg.n_heads * h, d, ("heads", "embed"), dtype)
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    s = {"wq": aq, "wk": ak, "wv": av, "wo": ao}
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((cfg.n_heads * h,), dtype),
                 bk=jnp.zeros((cfg.n_kv_heads * h,), dtype),
                 bv=jnp.zeros((cfg.n_kv_heads * h,), dtype))
        s.update(bq=("heads",), bk=("kv_heads",), bv=("kv_heads",))
    return p, s


def _block_init(rng, cfg: ModelConfig, kind: dict, dtype):
    kn, km, kf = jax.random.split(rng, 3)
    p: dict = {}
    s: dict = {}
    p["norm1"], s["norm1"] = norm_init(cfg.d_model, cfg.norm, dtype)
    mix = kind["mix"]
    if mix == "attn":
        p["mix"], s["mix"] = _attn_init(km, cfg, dtype)
    elif mix == "mamba":
        p["mix"], s["mix"] = mamba_init(km, cfg.d_model, cfg.mamba_expand,
                                        cfg.mamba_d_state, cfg.mamba_d_conv, dtype)
    elif mix == "mlstm":
        p["mix"], s["mix"] = mlstm_init(km, cfg.d_model, cfg.n_heads,
                                        cfg.xlstm_proj_factor, cfg.xlstm_conv, dtype)
    elif mix == "slstm":
        p["mix"], s["mix"] = slstm_init(km, cfg.d_model, cfg.n_heads, dtype)
    if kind["ff"] == "mlp":
        p["norm2"], s["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["ff"], s["ff"] = mlp_init(kf, cfg.d_model, cfg.d_ff, dtype)
    elif kind["ff"] == "moe":
        p["norm2"], s["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["ff"], s["ff"] = moe_init(kf, cfg.d_model, cfg.ff_expert,
                                    cfg.n_experts, cfg.n_shared_experts, dtype)
    return p, s


def init_params(rng, cfg: ModelConfig) -> tuple[dict, dict]:
    """Build (params, logical-axis specs); period leaves stacked [n_periods,...]."""
    dtype = jnp.dtype(cfg.dtype)
    P = cfg.scan_period()
    n_periods = cfg.n_layers // P
    kinds = cfg.layer_kinds()[:P]
    k_emb, k_blocks, k_head = jax.random.split(rng, 3)

    params: dict = {}
    specs: dict = {}
    params["embed"], specs["embed"] = embed_init(k_emb, cfg.padded_vocab,
                                                 cfg.d_model, dtype)
    blocks_p, blocks_s = [], []
    for pos in range(P):
        keys = jax.random.split(jax.random.fold_in(k_blocks, pos), n_periods)
        stacked = jax.vmap(lambda k: _block_init(k, cfg, kinds[pos], dtype)[0])(keys)
        _, spec = _block_init(keys[0], cfg, kinds[pos], dtype)
        spec = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                            spec, is_leaf=lambda x: isinstance(x, tuple))
        blocks_p.append(stacked)
        blocks_s.append(spec)
    params["period"] = tuple(blocks_p)
    specs["period"] = tuple(blocks_s)
    params["final_norm"], specs["final_norm"] = norm_init(cfg.d_model, cfg.norm,
                                                          dtype)
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = dense_init(
            k_head, cfg.d_model, cfg.padded_vocab, ("embed", "vocab"), dtype)
    return params, specs


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------
def _angles_for(cfg: ModelConfig, positions, batch=None):
    if cfg.rope_type == "none":
        return None
    if cfg.rope_type == "mrope":
        p3 = None if batch is None else batch.get("positions3")
        if p3 is None:
            p3 = jnp.broadcast_to(positions, (3,) + positions.shape)
        return mrope_angles(p3, cfg.head_dim, cfg.rope_theta,
                            cfg.mrope_sections)
    return rope_angles(positions, cfg.head_dim, cfg.rope_theta)


def _qkv(p, cfg: ModelConfig, y, angles):
    B, S, _ = y.shape
    h = cfg.head_dim
    q = y @ p["wq"] + (p["bq"] if "bq" in p else 0)
    k = y @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = y @ p["wv"] + (p["bv"] if "bv" in p else 0)
    q = q.reshape(B, S, cfg.n_heads, h)
    k = k.reshape(B, S, cfg.n_kv_heads, h)
    v = v.reshape(B, S, cfg.n_kv_heads, h)
    if angles is not None:
        a = angles if angles.ndim == 3 else angles[None]     # [B,S,half]
        q = apply_rotary(q, a[:, :, None, :])
        k = apply_rotary(k, a[:, :, None, :])
    return q, k, v


def _block_apply(p, kind, cfg: ModelConfig, x, angles, collect_state: bool,
                 dropless: bool = False):
    """One block, train/prefill. Returns (x, aux_loss, state_or_None).

    ``collect_state`` (prefill) captures what decode needs: roped K/V for
    attention positions, the final recurrent carry for mamba/xlstm positions.
    ``dropless`` routes MoE blocks without capacity drops (inference paths;
    see :func:`repro.models.moe.apply_moe`).
    """
    from repro.perf_flags import enabled as _perf
    from repro.distributed.activations import matmul_input_constraint
    y = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
    if _perf("mm_gather"):
        y = matmul_input_constraint(y)
    aux = jnp.float32(0)
    st = None
    mix = kind["mix"]
    if mix == "attn":
        q, k, v = _qkv(p["mix"], cfg, y, angles)
        from repro.perf_flags import enabled
        if enabled("attn_reshard"):
            from repro.distributed.activations import attn_constraint
            q, k, v = attn_constraint(q, k, v)
        o = blocked_attention(q, k, v, causal=True,
                              window=cfg.sliding_window,
                              softcap=cfg.attn_logit_softcap,
                              block_k=2048 if enabled("blockk") else 512)
        B, S = x.shape[:2]
        x = x + o.reshape(B, S, -1) @ p["mix"]["wo"]
        if collect_state:
            st = {"k": k, "v": v}
    elif mix == "mamba":
        r = apply_mamba(p["mix"], y, cfg.mamba_d_state, collect_state)
        x, st = (x + r[0], r[1]) if collect_state else (x + r, None)
    elif mix == "mlstm":
        r = apply_mlstm(p["mix"], y, cfg.n_heads, cfg.xlstm_conv, collect_state)
        x, st = (x + r[0], r[1]) if collect_state else (x + r, None)
    elif mix == "slstm":
        r = apply_slstm(p["mix"], y, cfg.n_heads, collect_state)
        x, st = (x + r[0], r[1]) if collect_state else (x + r, None)
    if kind["ff"] == "mlp":
        y2 = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        if _perf("mm_gather"):
            y2 = matmul_input_constraint(y2)
        x = x + apply_mlp(p["ff"], y2, cfg.act)
    elif kind["ff"] == "moe":
        y2 = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        if _perf("mm_gather"):
            y2 = matmul_input_constraint(y2)
        o, a = apply_moe(p["ff"], y2, cfg.top_k, cfg.capacity_factor, cfg.act,
                         dropless=dropless)
        x, aux = x + o, aux + a
    return x, aux, st


def forward_hidden(params, cfg: ModelConfig, x, positions, batch=None,
                   collect_state: bool = False, dropless: bool = False):
    """Trunk: embedded input [B,S,D] -> (hidden, aux, per-position states)."""
    P = cfg.scan_period()
    kinds = cfg.layer_kinds()[:P]
    angles = _angles_for(cfg, positions, batch)

    from repro.distributed.activations import activation_constraint

    def period(carry, pp):
        x, aux = carry
        sts = []
        for pos in range(P):
            x, a, st = _block_apply(pp[pos], kinds[pos], cfg, x,
                                    angles, collect_state, dropless)
            aux = aux + a
            sts.append(st)
        return (activation_constraint(x), aux), tuple(sts)

    body = jax.checkpoint(period) if cfg.remat else period
    (x, aux), state_stacks = jax.lax.scan(body, (x, jnp.float32(0)),
                                          params["period"])
    return x, aux, state_stacks


def embed_tokens(params, cfg: ModelConfig, tokens):
    return params["embed"][tokens].astype(jnp.dtype(cfg.dtype))


def lm_head(params, cfg: ModelConfig):
    return (params["lm_head"] if not cfg.tie_embeddings
            else params["embed"].T)


def train_forward(params, cfg: ModelConfig, batch) -> jax.Array:
    """batch: tokens/targets/mask [B,S] (+ 'embeds' for stub frontends)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = (batch["embeds"].astype(jnp.dtype(cfg.dtype)) if "embeds" in batch
         else embed_tokens(params, cfg, tokens))
    positions = jnp.arange(S)
    h, aux, _ = forward_hidden(params, cfg, x, positions, batch)
    h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
    loss = chunked_ce_loss(h, lm_head(params, cfg), batch["targets"],
                           batch["mask"])
    return loss + 0.01 * aux


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def _cache_len(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def init_decode_state(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    """Zeroed decode state; prefill fills it, dry-run lowers its specs."""
    dtype = jnp.dtype(cfg.dtype)
    P = cfg.scan_period()
    n_periods = cfg.n_layers // P
    kinds = cfg.layer_kinds()[:P]
    T = _cache_len(cfg, max_len)

    def one(kind):
        mix = kind["mix"]
        if mix == "attn":
            sh = (batch_size, T, cfg.n_kv_heads, cfg.head_dim)
            return {"k": jnp.zeros(sh, dtype), "v": jnp.zeros(sh, dtype)}
        if mix == "mamba":
            di = cfg.mamba_expand * cfg.d_model
            return {"conv": jnp.zeros((batch_size, cfg.mamba_d_conv - 1, di), dtype),
                    "h": jnp.zeros((batch_size, di, cfg.mamba_d_state), jnp.float32)}
        if mix == "mlstm":
            di = int(cfg.xlstm_proj_factor * cfg.d_model)
            dh = di // cfg.n_heads
            return {"conv": jnp.zeros((batch_size, cfg.xlstm_conv - 1, di), dtype),
                    "C": jnp.zeros((batch_size, cfg.n_heads, dh, dh), jnp.float32),
                    "n": jnp.zeros((batch_size, cfg.n_heads, dh), jnp.float32),
                    "m": jnp.zeros((batch_size, cfg.n_heads), jnp.float32)}
        if mix == "slstm":
            return {k: jnp.zeros((batch_size, cfg.d_model), jnp.float32)
                    for k in ("h", "c", "n", "m")}
        return {}

    blocks = tuple(jax.tree.map(
        lambda t: jnp.broadcast_to(t, (n_periods,) + t.shape).copy(), one(k))
        for k in kinds)
    return {"blocks": blocks, "pos": jnp.zeros((), jnp.int32)}


def decode_state_specs(cfg: ModelConfig) -> dict:
    """Logical axes for the decode state (mirrors init_decode_state)."""
    P = cfg.scan_period()
    kinds = cfg.layer_kinds()[:P]

    def one(kind):
        mix = kind["mix"]
        if mix == "attn":
            kv = ("layers", "batch", "kv_seq", "kv_heads_s", None)
            return {"k": kv, "v": kv}
        if mix == "mamba":
            return {"conv": ("layers", "batch", None, "inner"),
                    "h": ("layers", "batch", "inner", None)}
        if mix == "mlstm":
            return {"conv": ("layers", "batch", None, "inner"),
                    "C": ("layers", "batch", None, None, None),
                    "n": ("layers", "batch", None, None),
                    "m": ("layers", "batch", None)}
        if mix == "slstm":
            return {k: ("layers", "batch", "embed") for k in ("h", "c", "n", "m")}
        return {}

    return {"blocks": tuple(one(k) for k in kinds), "pos": ()}


def _attn_decode(p, cfg: ModelConfig, y, st, pos, angles):
    B = y.shape[0]
    q, k, v = _qkv(p, cfg, y, angles)                    # S=1
    T = st["k"].shape[1]
    slot = jnp.mod(pos, T) if cfg.sliding_window else pos
    k_cache = jax.lax.dynamic_update_slice(st["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(st["v"], v, (0, slot, 0, 0))
    length = jnp.minimum(pos + 1, T)
    o = decode_attention(q, k_cache, v_cache, length,
                         softcap=cfg.attn_logit_softcap)
    return o.reshape(B, 1, -1) @ p["wo"], {"k": k_cache, "v": v_cache}


def decode_step(params, cfg: ModelConfig, token, state, embeds=None):
    """One token for every stream: token [B] int32 -> (logits [B,V], state)."""
    P = cfg.scan_period()
    kinds = cfg.layer_kinds()[:P]
    pos = state["pos"]
    x = (embeds if embeds is not None
         else embed_tokens(params, cfg, token[:, None]))   # [B,1,D]
    positions = pos[None]                                  # [1]
    angles = _angles_for(cfg, positions, None)

    def period(x, xs):
        pp, ps = xs
        new_states = []
        for i, kind in enumerate(kinds):
            p, st = pp[i], ps[i]
            y = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
            mix = kind["mix"]
            if mix == "attn":
                o, st = _attn_decode(p["mix"], cfg, y, st, pos, angles)
                x = x + o
            elif mix == "mamba":
                o, st = mamba_decode_step(p["mix"], y, st, cfg.mamba_d_state)
                x = x + o
            elif mix == "mlstm":
                o, st = mlstm_decode_step(p["mix"], y, st, cfg.n_heads)
                x = x + o
            elif mix == "slstm":
                o, st = slstm_decode_step(p["mix"], y, st, cfg.n_heads)
                x = x + o
            if kind["ff"] == "mlp":
                y2 = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
                x = x + apply_mlp(p["ff"], y2, cfg.act)
            elif kind["ff"] == "moe":
                y2 = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
                o, _ = apply_moe(p["ff"], y2, cfg.top_k, cfg.capacity_factor,
                                 cfg.act, dropless=True)
                x = x + o
            new_states.append(st)
        return x, tuple(new_states)

    x, new_blocks = jax.lax.scan(period, x,
                                 (params["period"], state["blocks"]))
    h = apply_norm(params["final_norm"], x[:, 0], cfg.norm, cfg.norm_eps)
    logits = (h @ lm_head(params, cfg)).astype(jnp.float32)
    return logits, {"blocks": new_blocks, "pos": pos + 1}


def prefill(params, cfg: ModelConfig, tokens, max_len: int, batch=None):
    """tokens [B,S] -> (last-token logits [B,V], decode state at pos=S)."""
    B, S = tokens.shape
    x = (batch["embeds"].astype(jnp.dtype(cfg.dtype))
         if batch and "embeds" in batch else embed_tokens(params, cfg, tokens))
    positions = jnp.arange(S)
    h, _, state_stacks = forward_hidden(params, cfg, x, positions, batch,
                                        collect_state=True, dropless=True)
    state = init_decode_state(cfg, B, max_len)
    T = _cache_len(cfg, max_len)
    P = cfg.scan_period()
    kinds = cfg.layer_kinds()[:P]
    new_blocks = []
    for i, st0 in enumerate(state["blocks"]):
        st = state_stacks[i]
        if kinds[i]["mix"] == "attn":
            k, v = st["k"], st["v"]                      # [n_periods,B,S,Hkv,dh]
            if S >= T:
                # rolling buffer: token j lives at slot j % T
                k = jnp.roll(k[:, :, S - T:], (S - T) % T, axis=2)
                v = jnp.roll(v[:, :, S - T:], (S - T) % T, axis=2)
                st = {"k": k, "v": v}
            else:
                st = {"k": st0["k"].at[:, :, :S].set(k),
                      "v": st0["v"].at[:, :, :S].set(v)}
        new_blocks.append(st)
    h_last = apply_norm(params["final_norm"], h[:, -1], cfg.norm, cfg.norm_eps)
    logits = (h_last @ lm_head(params, cfg)).astype(jnp.float32)
    return logits, {"blocks": tuple(new_blocks), "pos": jnp.int32(S)}
