"""Mixture-of-Experts: top-k router + sort-based capacity dispatch (EP-ready).

Dispatch is *sort-based* rather than GShard one-hot-einsum: tokens are
argsorted by assigned expert and scattered into an ``[E, C, D]`` buffer, so
the dispatch cost is data movement + an O(T·k·E) int cumsum instead of a
T·E·C·D matmul — keeping HLO FLOPs ≈ useful FLOPs (the roofline's
MODEL_FLOPS/HLO_FLOPS ratio stays honest). Under EP the buffer's expert axis
is mesh-sharded ("experts" -> model), and GSPMD lowers the token->expert
scatter to an all-to-all, which is exactly the paper-faithful "remote page"
traffic that Leap's expert-prefetch stream models (see repro.paging).

Tokens beyond an expert's capacity C = ceil(T·k/E · capacity_factor) are
dropped (standard Switch behavior); the combine step renormalizes so dropped
slots contribute zero, and the router aux loss pushes load toward balance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


def moe_init(rng, d: int, ff: int, n_experts: int, n_shared: int, dtype):
    ks = jax.random.split(rng, 5)
    wr, ar = dense_init(ks[0], d, n_experts, ("embed", "experts"), dtype)
    shape = (n_experts, d, ff)
    mk = lambda k, sh, ax: (
        (jax.random.truncated_normal(k, -2., 2., sh, jnp.float32)
         / jnp.sqrt(jnp.float32(sh[1]))).astype(dtype), ax)
    # EP x FSDP 2-D sharding: experts -> model, expert_ff -> data. The ff dim
    # (not d_model) takes the second axis so the [*,C,F] expert activations
    # shard over data instead of replicating (per-chip memory, see DESIGN §5).
    wg, ag = mk(ks[1], shape, ("experts", None, "expert_ff"))
    wu, au = mk(ks[2], shape, ("experts", None, "expert_ff"))
    wd, ad = mk(ks[3], (n_experts, ff, d), ("experts", "expert_ff", None))
    p = {"wr": wr, "wg": wg, "wu": wu, "wd": wd}
    s = {"wr": ar, "wg": ag, "wu": au, "wd": ad}
    if n_shared:
        from .layers import mlp_init
        ps, ss = mlp_init(ks[4], d, ff * n_shared, dtype)
        p["shared"], s["shared"] = ps, ss
    return p, s


def _router(x, wr, top_k: int):
    """x [T,D] -> (weights [T,k] fp32 softmaxed over k, ids [T,k], aux loss)."""
    logits = (x @ wr).astype(jnp.float32)              # [T,E]
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e (fraction of tokens to e) * (mean prob of e)
    E = wr.shape[1]
    hot = jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32)
    aux = E * jnp.sum(hot.mean(0) * probs.mean(0))
    return w, ids, aux


def _dispatch_group(xg, wg_, idsg, p, top_k, C, act):
    """Sort-based dispatch for one token group. xg [T,D]; returns y [T,D].

    Groups are the unit of sharding: the caller vmaps this over the batch
    dim, so the [E,C,D] buffers carry the batch ('data') sharding while the
    expert weights stay E-sharded ('model') — the group->expert scatter is
    the all-to-all (EP dispatch) under GSPMD, never a replicated T·E·C
    tensor (that replication is what blows per-chip memory with a global
    dispatch).
    """
    T, D = xg.shape
    E = p["wr"].shape[1]
    k = top_k
    N = T * k
    e_flat = idsg.reshape(N)                           # expert of each (tok,k)
    tok_flat = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(e_flat, stable=True)
    es, toks = e_flat[order], tok_flat[order]
    # rank of each sorted slot within its expert run
    oh = (es[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    rank = (jnp.cumsum(oh, 0) - oh)[jnp.arange(N), es]
    keep = rank < C
    dest = jnp.where(keep, es * C + rank, E * C)       # overflow -> dustbin row

    buf = jnp.zeros((E * C + 1, D), xg.dtype).at[dest].set(xg[toks])
    eb = buf[: E * C].reshape(E, C, D)

    f = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = f(jnp.einsum("ecd,edf->ecf", eb, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", eb, p["wu"])
    y_e = jnp.einsum("ecf,efd->ecd", h, p["wd"])

    y_flat = jnp.concatenate([y_e.reshape(E * C, D),
                              jnp.zeros((1, D), y_e.dtype)])[dest]  # [N,D] sorted
    inv = jnp.argsort(order, stable=True)
    y_tok = y_flat[inv].reshape(T, k, D)
    return jnp.sum(y_tok * wg_[..., None].astype(y_tok.dtype), axis=1)


def apply_moe(p: dict, x: jax.Array, top_k: int, capacity_factor: float = 1.25,
              act: str = "silu", dropless: bool = False
              ) -> tuple[jax.Array, jax.Array]:
    """x [B,S,D] -> (y [B,S,D], aux_loss scalar). Grouped dispatch (group=row).

    ``dropless=True`` sizes the expert capacity to the group's worst case
    (``S`` — top-k experts are distinct per token) so no token is ever
    dropped. Capacity dropping is a
    *training-time* load-balancing behavior: whether a token survives
    depends on which other tokens share its group, so a prefill group of S
    tokens and a decode group of 1 token can route the same token
    differently. Inference paths (prefill / decode_step) therefore route
    droplessly — that is what makes prefill and decode_step produce
    identical logits for the same token (the per-arch smoke consistency
    pin; the llama4 interleaved dense/MoE config is where grouped drops
    first bit).
    """
    B, S, D = x.shape
    E = p["wr"].shape[1]
    xf = x.reshape(B * S, D)
    w, ids, aux = _router(xf, p["wr"], top_k)
    # dropless sizes C to the static worst case: top_k picks *distinct*
    # experts per token, so one expert can receive at most S of a row's
    # assignments — C = S guarantees rank < C for every token. The
    # [E, C, D] buffers and expert einsums still carry padding vs the
    # actual (usually balanced) load — the jit-shape price of the
    # consistency pin; a segment-based dispatch over occupied rows would
    # remove it without changing the routing
    C = (S if dropless
         else max(1, int(-(-S * top_k // E) * capacity_factor)))
    y = jax.vmap(
        lambda xg, wg_, idsg: _dispatch_group(xg, wg_, idsg, p, top_k, C, act)
    )(x, w.reshape(B, S, top_k), ids.reshape(B, S, top_k))
    if "shared" in p:
        from .layers import apply_mlp
        y = y + apply_mlp(p["shared"], x, act)
    return y.reshape(B, S, D), aux


def apply_moe_dense_ref(p: dict, x: jax.Array, top_k: int,
                        act: str = "silu") -> jax.Array:
    """Oracle: per-token gather of expert weights, no capacity drops.

    O(T·k·D·F) like the real path but with per-token weight gathers — only
    viable for tiny test configs; used to pin apply_moe correctness when no
    token exceeds capacity.
    """
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    w, ids, _ = _router(xf, p["wr"], top_k)
    f = jax.nn.silu if act == "silu" else jax.nn.gelu

    def per_k(j):
        wg, wu, wd = p["wg"][ids[:, j]], p["wu"][ids[:, j]], p["wd"][ids[:, j]]
        h = f(jnp.einsum("td,tdf->tf", xf, wg)) * jnp.einsum("td,tdf->tf", xf, wu)
        return jnp.einsum("tf,tfd->td", h, wd) * w[:, j, None].astype(x.dtype)

    y = sum(per_k(j) for j in range(top_k))
    if "shared" in p:
        from .layers import apply_mlp
        y = y + apply_mlp(p["shared"], xf, act)
    return y.reshape(B, S, D)
