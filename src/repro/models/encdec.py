"""Encoder-decoder (seamless-m4t backbone): bidirectional encoder + causal
decoder with cross-attention.

The audio frontend is a stub per the brief: the encoder consumes precomputed
frame embeddings ``frames [B,Se,D]`` (plus a small input projection). The
decoder is the standard causal LM with per-layer cross-attention against the
encoder output; cross-K/V are computed once at prefill and stay static
through decode. Layers are stacked and scanned like ``transformer.py``
(period is always 1 for this family).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import blocked_attention, decode_attention, full_attention
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, chunked_ce_loss,
                     dense_init, embed_init, mlp_init, norm_init, rope_angles)
from .transformer import _attn_init, _qkv, lm_head


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _xattn_init(rng, cfg: ModelConfig, dtype):
    """Cross-attention projections (q from decoder, k/v from encoder)."""
    return _attn_init(rng, cfg, dtype)


def _enc_block_init(rng, cfg, dtype):
    ka, kf = jax.random.split(rng)
    p, s = {}, {}
    p["norm1"], s["norm1"] = norm_init(cfg.d_model, cfg.norm, dtype)
    p["attn"], s["attn"] = _attn_init(ka, cfg, dtype)
    p["norm2"], s["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
    p["ff"], s["ff"] = mlp_init(kf, cfg.d_model, cfg.d_ff, dtype)
    return p, s


def _dec_block_init(rng, cfg, dtype):
    ka, kx, kf = jax.random.split(rng, 3)
    p, s = {}, {}
    p["norm1"], s["norm1"] = norm_init(cfg.d_model, cfg.norm, dtype)
    p["self"], s["self"] = _attn_init(ka, cfg, dtype)
    p["normx"], s["normx"] = norm_init(cfg.d_model, cfg.norm, dtype)
    p["cross"], s["cross"] = _xattn_init(kx, cfg, dtype)
    p["norm2"], s["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
    p["ff"], s["ff"] = mlp_init(kf, cfg.d_model, cfg.d_ff, dtype)
    return p, s


def _stack_init(key, n, one_init):
    keys = jax.random.split(key, n)
    stacked = jax.vmap(lambda k: one_init(k)[0])(keys)
    spec = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                        one_init(keys[0])[1],
                        is_leaf=lambda x: isinstance(x, tuple))
    return stacked, spec


def init_params(rng, cfg: ModelConfig) -> tuple[dict, dict]:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 6)
    params: dict = {}
    specs: dict = {}
    params["in_proj"], specs["in_proj"] = dense_init(
        ks[0], cfg.d_model, cfg.d_model, ("embed", "embed"), dtype)
    params["enc"], specs["enc"] = _stack_init(
        ks[1], cfg.n_enc_layers, lambda k: _enc_block_init(k, cfg, dtype))
    params["enc_norm"], specs["enc_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
    params["embed"], specs["embed"] = embed_init(ks[2], cfg.padded_vocab,
                                                 cfg.d_model, dtype)
    params["dec"], specs["dec"] = _stack_init(
        ks[3], cfg.n_layers, lambda k: _dec_block_init(k, cfg, dtype))
    params["final_norm"], specs["final_norm"] = norm_init(cfg.d_model, cfg.norm,
                                                          dtype)
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = dense_init(
            ks[4], cfg.d_model, cfg.padded_vocab, ("embed", "vocab"), dtype)
    return params, specs


# --------------------------------------------------------------------------
# encoder
# --------------------------------------------------------------------------
def encode(params, cfg: ModelConfig, frames) -> jax.Array:
    """frames [B,Se,D] (stub frontend output) -> encoder hidden [B,Se,D]."""
    x = frames.astype(jnp.dtype(cfg.dtype)) @ params["in_proj"]
    Se = x.shape[1]
    angles = rope_angles(jnp.arange(Se), cfg.head_dim, cfg.rope_theta)

    from repro.distributed.activations import activation_constraint

    def block(x, p):
        y = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
        q, k, v = _qkv(p["attn"], cfg, y, angles)
        o = blocked_attention(q, k, v, causal=False)
        x = x + o.reshape(*x.shape[:2], -1) @ p["attn"]["wo"]
        y2 = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        return activation_constraint(x + apply_mlp(p["ff"], y2, cfg.act)), None

    body = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(body, x, params["enc"])
    return apply_norm(params["enc_norm"], x, cfg.norm, cfg.norm_eps)


# --------------------------------------------------------------------------
# decoder
# --------------------------------------------------------------------------
def _cross_kv(p, cfg: ModelConfig, enc_out):
    B, Se, _ = enc_out.shape
    h = cfg.head_dim
    k = (enc_out @ p["wk"] + (p["bk"] if "bk" in p else 0)).reshape(
        B, Se, cfg.n_kv_heads, h)
    v = (enc_out @ p["wv"] + (p["bv"] if "bv" in p else 0)).reshape(
        B, Se, cfg.n_kv_heads, h)
    return k, v


def _dec_block(p, cfg, x, enc_out, angles, collect):
    y = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
    q, k, v = _qkv(p["self"], cfg, y, angles)
    o = blocked_attention(q, k, v, causal=True)
    x = x + o.reshape(*x.shape[:2], -1) @ p["self"]["wo"]
    yx = apply_norm(p["normx"], x, cfg.norm, cfg.norm_eps)
    B, Sd, _ = x.shape
    h = cfg.head_dim
    qx = (yx @ p["cross"]["wq"] + (p["cross"].get("bq", 0))).reshape(
        B, Sd, cfg.n_heads, h)
    kx, vx = _cross_kv(p["cross"], cfg, enc_out)
    ox = full_attention(qx, kx, vx, causal=False)
    x = x + ox.reshape(B, Sd, -1) @ p["cross"]["wo"]
    y2 = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
    x = x + apply_mlp(p["ff"], y2, cfg.act)
    st = {"k": k, "v": v} if collect else None
    return x, st


def decode_hidden(params, cfg: ModelConfig, tokens, enc_out, collect=False):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    Sd = tokens.shape[1]
    angles = rope_angles(jnp.arange(Sd), cfg.head_dim, cfg.rope_theta)

    from repro.distributed.activations import activation_constraint

    def block(x, p):
        x, st = _dec_block(p, cfg, x, enc_out, angles, collect)
        return activation_constraint(x), st

    body = jax.checkpoint(block) if cfg.remat else block
    x, kvs = jax.lax.scan(body, x, params["dec"])
    return apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps), kvs


def train_forward(params, cfg: ModelConfig, batch) -> jax.Array:
    enc_out = encode(params, cfg, batch["frames"])
    h, _ = decode_hidden(params, cfg, batch["tokens"], enc_out)
    return chunked_ce_loss(h, lm_head(params, cfg), batch["targets"],
                           batch["mask"])


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def decode_state_specs(cfg: ModelConfig) -> dict:
    kv = {"k": ("layers", "batch", "kv_seq", "kv_heads_s", None),
          "v": ("layers", "batch", "kv_seq", "kv_heads_s", None)}
    return {"self_kv": dict(kv), "cross_kv": dict(kv), "pos": ()}


def init_decode_state(cfg: ModelConfig, batch_size: int, max_len: int,
                      enc_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    kv = lambda T: {"k": jnp.zeros((L, batch_size, T, cfg.n_kv_heads,
                                    cfg.head_dim), dtype),
                    "v": jnp.zeros((L, batch_size, T, cfg.n_kv_heads,
                                    cfg.head_dim), dtype)}
    return {"self_kv": kv(max_len), "cross_kv": kv(enc_len),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, cfg: ModelConfig, frames, tokens, max_len: int):
    """Encode + decoder prefill -> (last logits, decode state)."""
    B, Sd = tokens.shape
    enc_out = encode(params, cfg, frames)
    h, kvs = decode_hidden(params, cfg, tokens, enc_out, collect=True)
    state = init_decode_state(cfg, B, max_len, frames.shape[1])
    state["self_kv"] = {
        "k": state["self_kv"]["k"].at[:, :, :Sd].set(kvs["k"]),
        "v": state["self_kv"]["v"].at[:, :, :Sd].set(kvs["v"])}
    cross = jax.vmap(lambda p: _cross_kv(p["cross"], cfg, enc_out))(
        params["dec"])
    state["cross_kv"] = {"k": cross[0], "v": cross[1]}
    state["pos"] = jnp.int32(Sd)
    logits = (h[:, -1] @ lm_head(params, cfg)).astype(jnp.float32)
    return logits, state


def decode_step(params, cfg: ModelConfig, token, state):
    """token [B] -> (logits [B,V], state). Cross-KV static, self-KV appended."""
    pos = state["pos"]
    x = params["embed"][token[:, None]].astype(jnp.dtype(cfg.dtype))
    angles = rope_angles(pos[None], cfg.head_dim, cfg.rope_theta)

    def block(x, xs):
        p, skv, xkv = xs
        y = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
        q, k, v = _qkv(p["self"], cfg, y, angles)
        kc = jax.lax.dynamic_update_slice(skv["k"], k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(skv["v"], v, (0, pos, 0, 0))
        o = decode_attention(q, kc, vc, pos + 1)
        x = x + o.reshape(*x.shape[:2], -1) @ p["self"]["wo"]
        yx = apply_norm(p["normx"], x, cfg.norm, cfg.norm_eps)
        B = x.shape[0]
        qx = (yx @ p["cross"]["wq"] + (p["cross"].get("bq", 0))).reshape(
            B, 1, cfg.n_heads, cfg.head_dim)
        ox = decode_attention(qx, xkv["k"], xkv["v"], xkv["k"].shape[1])
        x = x + ox.reshape(B, 1, -1) @ p["cross"]["wo"]
        y2 = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        x = x + apply_mlp(p["ff"], y2, cfg.act)
        return x, {"k": kc, "v": vc}

    x, new_skv = jax.lax.scan(block, x, (params["dec"], state["self_kv"],
                                         state["cross_kv"]))
    h = apply_norm(params["final_norm"], x[:, 0], cfg.norm, cfg.norm_eps)
    logits = (h @ lm_head(params, cfg)).astype(jnp.float32)
    return logits, {"self_kv": new_skv, "cross_kv": state["cross_kv"],
                    "pos": pos + 1}
