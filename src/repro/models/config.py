"""ModelConfig — one schema covering every assigned architecture family.

The config is deliberately flat: family-specific knobs default to "off" so a
dense transformer is the zero case. ``layer_kinds()`` expands the interleave
knobs into the explicit per-layer pattern that the period-scan executor
(``transformer.py``) consumes; ``param_count()`` gives the N used by the
roofline's MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) sanity ratio.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "encdec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family = "dense"

    # -- trunk dimensions ---------------------------------------------------
    n_layers: int = 12
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    d_head: int = 0                   # 0 -> d_model // n_heads
    d_ff: int = 2048
    vocab_size: int = 32000

    # -- attention ----------------------------------------------------------
    qkv_bias: bool = False            # qwen2 family
    rope_theta: float = 10_000.0
    rope_type: Literal["rope", "mrope", "none"] = "rope"
    mrope_sections: tuple[int, int, int] = (16, 24, 24)   # t/h/w (qwen2-vl)
    sliding_window: int = 0           # 0 = full attention (h2o-danube: SWA)
    attn_logit_softcap: float = 0.0

    # -- interleave patterns (hybrid / MoE / xLSTM) ---------------------------
    attn_every: int = 1               # jamba: 8 (1 attn : 7 mamba)
    attn_offset: int = 0              # jamba: 4
    moe_every: int = 0                # 0 = no MoE; llama4: 2; jamba: 2; phi: 1
    moe_offset: int = 0
    slstm_every: int = 0              # xlstm: 8 (1 sLSTM : 7 mLSTM)
    slstm_offset: int = 0

    # -- MoE ------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0              # 0 -> d_ff
    n_shared_experts: int = 0         # llama4: 1 shared expert
    capacity_factor: float = 1.25

    # -- Mamba (jamba) --------------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # -- xLSTM ----------------------------------------------------------------
    xlstm_proj_factor: float = 2.0    # mLSTM up-projection
    xlstm_conv: int = 4

    # -- encoder-decoder ------------------------------------------------------
    n_enc_layers: int = 0             # encdec: encoder depth (n_layers = decoder)

    # -- misc -----------------------------------------------------------------
    vocab_pad: int = 0                # pad embedding rows for TP divisibility
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["silu", "gelu"] = "silu"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"           # param/compute dtype (tests use float32)
    remat: bool = True                # activation checkpointing in the scan

    # ------------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return self.vocab_size + self.vocab_pad

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def ff_expert(self) -> int:
        return self.d_ff_expert or self.d_ff

    def layer_kinds(self) -> list[dict]:
        """Expand interleave knobs -> per-layer {'mix': .., 'ff': ..} kinds.

        mix in {'attn','mamba','mlstm','slstm'}; ff in {'mlp','moe','none'}.
        """
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                mix = ("slstm" if self.slstm_every
                       and i % self.slstm_every == self.slstm_offset else "mlstm")
                ff = "mlp" if self.d_ff else "none"
            elif self.family == "hybrid":
                mix = ("attn" if i % self.attn_every == self.attn_offset
                       else "mamba")
                ff = ("moe" if self.moe_every
                      and i % self.moe_every == self.moe_offset else "mlp")
            else:
                mix = "attn"
                ff = ("moe" if self.moe_every
                      and i % self.moe_every == self.moe_offset else "mlp")
            kinds.append({"mix": mix, "ff": ff})
        return kinds

    def scan_period(self) -> int:
        """Smallest period the layer pattern repeats with (for period-scan)."""
        period = 1
        for knob in (self.attn_every if self.family == "hybrid" else 1,
                     self.moe_every or 1, self.slstm_every or 1):
            period = math.lcm(period, knob)
        # the pattern must tile n_layers exactly
        while self.n_layers % period:
            period += 1
        return period

    # ------------------------------------------------------------------------
    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter counts — embeddings included in total.

        Active = params touched per token (MoE: top_k + shared experts only).
        """
        d, h = self.d_model, self.head_dim
        total = active = 0

        def add(n, is_active=True):
            nonlocal total, active
            total += n
            if is_active:
                active += n

        # embeddings (+ untied LM head)
        add(self.vocab_size * d)
        if not self.tie_embeddings:
            add(self.vocab_size * d)

        def attn_params():
            n = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d
            if self.qkv_bias:
                n += self.n_heads * h + 2 * self.n_kv_heads * h
            return n

        def mlp_params(ff):
            return 3 * d * ff        # gate/up/down (SwiGLU)

        def mamba_params():
            di = self.mamba_expand * d
            dt_rank = -(-d // 16)                # ceil(d/16), mamba default
            n = d * 2 * di                       # in_proj (x, z)
            n += di * self.mamba_d_conv          # depthwise conv
            n += di * (dt_rank + 2 * self.mamba_d_state)  # x -> (dt, B, C)
            n += dt_rank * di + di               # dt_proj + bias
            n += di * self.mamba_d_state         # A (log)
            n += di                              # D
            n += di * d                          # out_proj
            return n

        def mlstm_params():
            di = int(self.xlstm_proj_factor * d)
            dh = di // self.n_heads
            # up/gate proj; block-diag q/k/v; i/f gates; o proj; down proj
            return (d * 2 * di + 3 * self.n_heads * dh * dh
                    + 2 * self.n_heads + di * di + di * d)

        def slstm_params():
            # 4 gates x (recurrent + input) at model width, heads block-diagonal
            return 4 * d * d + 4 * d * (d // max(1, self.n_heads)) + d * d

        for kind in self.layer_kinds():
            if kind["mix"] == "attn":
                add(attn_params())
            elif kind["mix"] == "mamba":
                add(mamba_params())
            elif kind["mix"] == "mlstm":
                add(mlstm_params())
            elif kind["mix"] == "slstm":
                add(slstm_params())
            if kind["ff"] == "mlp":
                add(mlp_params(self.d_ff))
            elif kind["ff"] == "moe":
                e = mlp_params(self.ff_expert)
                total += self.n_experts * e
                active += min(self.top_k, self.n_experts) * e
                if self.n_shared_experts:
                    add(self.n_shared_experts * e)
                add(d * self.n_experts)          # router
        # encoder stack (encdec): mirror of decoder without cross-attn scaling
        if self.family == "encdec" and self.n_enc_layers:
            per = attn_params() + mlp_params(self.d_ff)
            add(self.n_enc_layers * per)
            add(self.n_layers * attn_params())   # decoder cross-attention
        return total, active

    def validate(self) -> None:
        assert self.d_model % self.n_heads == 0 or self.d_head, self.name
        assert self.n_heads % self.n_kv_heads == 0, self.name
        if self.moe_every:
            assert self.n_experts >= self.top_k > 0, self.name
        assert self.n_layers % self.scan_period() == 0, self.name
