"""build_model — one step-function surface per architecture family."""

from __future__ import annotations

import dataclasses
from typing import Callable

from .config import ModelConfig
from . import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    """Bound functional surface: everything launch/serve/tests consume."""
    cfg: ModelConfig
    init_params: Callable
    train_forward: Callable
    prefill: Callable
    decode_step: Callable
    init_decode_state: Callable
    decode_state_specs: Callable | None = None


def build_model(cfg: ModelConfig) -> Model:
    cfg.validate()
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init_params=lambda rng: encdec.init_params(rng, cfg),
            train_forward=lambda p, b: encdec.train_forward(p, cfg, b),
            prefill=lambda p, b, max_len: encdec.prefill(
                p, cfg, b["frames"], b["tokens"], max_len),
            decode_step=lambda p, tok, st: encdec.decode_step(p, cfg, tok, st),
            init_decode_state=lambda bs, max_len, enc_len=0: (
                encdec.init_decode_state(cfg, bs, max_len, enc_len or max_len)),
            decode_state_specs=lambda: encdec.decode_state_specs(cfg),
        )
    return Model(
        cfg=cfg,
        init_params=lambda rng: transformer.init_params(rng, cfg),
        train_forward=lambda p, b: transformer.train_forward(p, cfg, b),
        prefill=lambda p, b, max_len: transformer.prefill(
            p, cfg, b["tokens"], max_len, b),
        decode_step=lambda p, tok, st: transformer.decode_step(p, cfg, tok, st),
        init_decode_state=lambda bs, max_len, enc_len=0: (
            transformer.init_decode_state(cfg, bs, max_len)),
        decode_state_specs=lambda: transformer.decode_state_specs(cfg),
    )
