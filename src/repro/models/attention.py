"""GQA attention: reference, blocked-flash (pure jnp), sliding-window, decode.

All paths share one contract: ``q [B,Sq,Hq,dh]``, ``k/v [B,Sk,Hkv,dh]`` with
``Hq = G*Hkv`` (GQA); softmax statistics in float32; outputs in input dtype.

* :func:`full_attention` — materializes [B,Hkv,G,Sq,Sk] scores. Reference
  oracle for tests and small smoke configs.
* :func:`blocked_attention` — flash-style online-softmax ``lax.scan`` over KV
  blocks (memory O(block) instead of O(S²)); the lowering used by train/
  prefill paths so 32K-seq activations stay bounded. Causal and
  sliding-window masks are applied per block pair. (The Pallas TPU kernel in
  ``repro.kernels.flash_attention`` implements the same math with explicit
  VMEM tiling; this is its lowering-visible twin.)
* :func:`decode_attention` — one-token query against a [B,T,...] cache with a
  length mask; T may be mesh-sharded (GSPMD partitions the reductions).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_gqa(q: jax.Array, n_kv: int) -> jax.Array:
    """[B,S,Hq,dh] -> [B,S,Hkv,G,dh]."""
    B, S, Hq, dh = q.shape
    return q.reshape(B, S, n_kv, Hq // n_kv, dh)


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int = 0,
                   softcap: float = 0.0, q_offset: int = 0) -> jax.Array:
    """Reference GQA attention. ``q_offset`` places q rows inside the kv seq."""
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    qg = _split_gqa(q, Hkv).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(dh))
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, dh).astype(q.dtype)


def _attention_q_chunk(qg, k, v, q0, *, causal, window, softcap,
                       block_k, q_offset):
    """Online-softmax sweep of all KV blocks for one q chunk.

    qg [B,Hkv,G,Cq,dh] (pre-scaled); k/v [B,Sk,Hkv,dh]; q0 = chunk's global
    start row. Returns [B,Hkv,G,Cq,dh] fp32.
    """
    B, Hkv, G, Cq, dh = qg.shape
    Sk = k.shape[1]
    n_blocks = Sk // block_k
    kb = k.reshape(B, n_blocks, block_k, Hkv, dh).swapaxes(0, 1)
    vb = v.reshape(B, n_blocks, block_k, Hkv, dh).swapaxes(0, 1)
    qpos = jnp.arange(Cq) + q0 + q_offset

    from repro.perf_flags import enabled
    mxu = enabled("attn_bf16") and k.dtype != jnp.float32

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, j = xs
        if mxu:
            s = jnp.einsum("bkgqd,bskd->bkgqs", qg.astype(kblk.dtype), kblk,
                           preferred_element_type=jnp.float32)
        else:
            s = jnp.einsum("bkgqd,bskd->bkgqs", qg, kblk.astype(jnp.float32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = j * block_k + jnp.arange(block_k)
        msk = jnp.ones((Cq, block_k), bool)
        if causal:
            msk &= kpos[None, :] <= qpos[:, None]
        if window:
            msk &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(msk[None, None, None], p, 0.0)
        corr = jnp.exp(jnp.where(m <= NEG_INF / 2, NEG_INF, m) - m_safe)
        corr = jnp.where(m <= NEG_INF / 2, 0.0, corr)
        l_new = l * corr + p.sum(-1)
        if mxu:
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vblk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, Hkv, G, Cq), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, Cq), jnp.float32),
            jnp.zeros((B, Hkv, G, Cq, dh), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init,
                                  (kb, vb, jnp.arange(n_blocks)))
    return acc / jnp.maximum(l, 1e-30)[..., None]


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      softcap: float = 0.0, block_q: int = 1024,
                      block_k: int = 512, q_offset: int = 0) -> jax.Array:
    """Flash-style attention, memory-safe in *both* directions.

    Structure: outer scan over q chunks whose body (a KV-block online-softmax
    sweep) is ``jax.checkpoint``-ed. Forward never materializes [Sq,Sk];
    backward recomputes one q chunk's sweep at a time, so residuals peak at
    O(Cq·block_k) instead of O(n_kv_blocks · Sq) — without this, scan-AD
    saves every per-block carry and a 32K-seq layer needs ~100+ GB.

    All KV blocks are visited (masked where inactive); the triangular-pair
    schedule that skips fully-masked causal blocks is a §Perf iteration.
    """
    B, Sq, Hq, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    while Sk % block_k:
        block_k //= 2
    while Sq % block_q:
        block_q //= 2
    nq = Sq // block_q
    G = Hq // Hkv
    qg = _split_gqa(q, Hkv).astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))
    qg = qg.transpose(0, 2, 3, 1, 4)                 # [B,Hkv,G,Sq,dh]
    qc = jnp.moveaxis(qg.reshape(B, Hkv, G, nq, block_q, dh), 3, 0)

    @jax.checkpoint
    def chunk_body(carry, xs):
        qchunk, i = xs                               # [B,Hkv,G,bq,dh]
        o = _attention_q_chunk(qchunk, k, v, i * block_q, causal=causal,
                               window=window, softcap=softcap,
                               block_k=block_k, q_offset=q_offset)
        return carry, o

    _, oc = jax.lax.scan(chunk_body, (), (qc, jnp.arange(nq)))
    out = jnp.moveaxis(oc, 0, 3).reshape(B, Hkv, G, Sq, dh)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, dh)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length, *, window: int = 0,
                     softcap: float = 0.0) -> jax.Array:
    """Single-position attention: q [B,1,Hq,dh] vs cache k/v [B,T,Hkv,dh].

    ``length`` (int or [B] array) masks the valid cache prefix; with
    ``window``, only the trailing ``window`` positions stay active (the
    rolling-buffer SWA cache passes its own geometry instead).
    """
    B, _, Hq, dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    from repro.perf_flags import enabled
    if enabled("attn_bf16"):
        # H5b: MXU semantics — bf16 operands, fp32 accumulation. Without
        # this, `.astype(f32)` materializes the whole KV cache in fp32
        # (2x reads + a full write-back every step).
        qg = _split_gqa(q, Hkv)[:, 0]
        s = jnp.einsum("bkgd,btkd->bkgt", qg, k,
                       preferred_element_type=jnp.float32)
    else:
        qg = _split_gqa(q, Hkv)[:, 0].astype(jnp.float32)  # [B,Hkv,G,dh]
        s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32))
    if enabled("decode_tsh"):
        from repro.distributed.activations import decode_logits_constraint
        s = decode_logits_constraint(s)
    s = s / jnp.sqrt(jnp.float32(dh))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    tpos = jnp.arange(T)
    ln = jnp.asarray(length)
    ln = ln[:, None] if ln.ndim else ln[None, None] * jnp.ones((B, 1), ln.dtype)
    msk = tpos[None, :] < ln
    if window:
        msk &= tpos[None, :] >= ln - window
    s = jnp.where(msk[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if enabled("attn_bf16"):
        out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, 1, Hq, dh).astype(q.dtype)
