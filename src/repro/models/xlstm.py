"""xLSTM blocks — mLSTM (matrix memory) and sLSTM (scalar memory, block-diag R).

Follows Beck et al. 2024 (arXiv:2405.04517). Both cells use exponential
gating with the max-stabilizer state ``m`` so the recurrences stay finite:

  m_t = max(f̃_t + m_{t-1}, ĩ_t);  i = exp(ĩ - m_t);  f = exp(f̃ + m_{t-1} - m_t)

* mLSTM: per-head matrix memory ``C [dk,dv]``; q/k from a causal-conv path,
  v from the residual path; retrieval ``h = C·q / max(|n·q|, 1)``. Fully
  parallelizable in theory (chunkwise form is the §Perf candidate); the
  training path here is a compact ``lax.scan``.
* sLSTM: per-channel scalar memory with block-diagonal (per-head) recurrent
  weights — the part of xLSTM that is *inherently* sequential.

Both expose O(1)-state decode steps, which is why the `ssm` family runs the
``long_500k`` shape that full-attention archs skip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init
from .mamba import _pick_chunk


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------
def mlstm_init(rng, d: int, n_heads: int, proj_factor: float, d_conv: int, dtype):
    di = int(proj_factor * d)
    dh = di // n_heads
    ks = jax.random.split(rng, 7)
    w_up, a_up = dense_init(ks[0], d, 2 * di, ("embed", "inner"), dtype)
    # q/k/v are block-diagonal per head (xLSTM paper's BlockDiagonal linear)
    bd = lambda k: ((jax.random.normal(k, (n_heads, dh, dh), jnp.float32)
                     / jnp.sqrt(jnp.float32(dh))).astype(dtype),
                    ("heads", None, None))
    (w_q, a_q), (w_k, a_k), (w_v, a_v) = bd(ks[1]), bd(ks[2]), bd(ks[3])
    w_if, a_if = dense_init(ks[4], di, 2 * n_heads, ("inner", None), dtype)
    w_o, a_o = dense_init(ks[5], di, di, ("inner", "inner"), dtype)
    w_dn, a_dn = dense_init(ks[6], di, d, ("inner", "embed"), dtype)
    conv = (jnp.zeros((d_conv, di), jnp.float32)
            .at[-1].set(1.0)).astype(dtype)               # identity-ish init
    p = {"w_up": w_up, "w_q": w_q, "w_k": w_k, "w_v": w_v, "w_if": w_if,
         "w_o": w_o, "w_dn": w_dn, "conv": conv,
         "f_bias": jnp.full((n_heads,), 3.0, jnp.float32),
         "i_bias": jnp.zeros((n_heads,), jnp.float32),
         "skip": jnp.ones((di,), jnp.float32)}
    s = {"w_up": a_up, "w_q": a_q, "w_k": a_k, "w_v": a_v, "w_if": a_if,
         "w_o": a_o, "w_dn": a_dn, "conv": (None, "inner"),
         "f_bias": (None,), "i_bias": (None,), "skip": ("inner",)}
    return p, s


def _mlstm_gates(p, xc, H):
    raw = (xc @ p["w_if"]).astype(jnp.float32)            # [..., 2H]
    i_raw, f_raw = jnp.split(raw, 2, -1)
    return i_raw + p["i_bias"], f_raw + p["f_bias"]


def _mlstm_qkv(p, xc, xv, H):
    dh = p["w_q"].shape[-1]
    sh = xc.shape[:-1]
    xch = xc.reshape(*sh, H, dh)
    xvh = xv.reshape(*sh, H, dh)
    q = jnp.einsum("...hk,hkv->...hv", xch, p["w_q"])
    k = jnp.einsum("...hk,hkv->...hv", xch, p["w_k"]) / jnp.sqrt(jnp.float32(dh))
    v = jnp.einsum("...hk,hkv->...hv", xvh, p["w_v"])
    return q, k, v


def apply_mlstm(p: dict, x: jax.Array, n_heads: int, d_conv: int,
                return_state: bool = False):
    """Train/prefill: x [B,S,D] -> [B,S,D], scan over S.

    With ``return_state`` also returns the decode carry {conv, C, n, m}.
    """
    B, S, D = x.shape
    H = n_heads
    up = x @ p["w_up"]
    a, gate = jnp.split(up, 2, -1)                        # [B,S,di] each
    K = p["conv"].shape[0]
    apad = jnp.pad(a, ((0, 0), (K - 1, 0), (0, 0)))
    xc = jax.nn.silu(sum(apad[:, k:k + S] * p["conv"][k] for k in range(K)))
    q, k, v = _mlstm_qkv(p, xc, a, H)
    i_raw, f_raw = _mlstm_gates(p, xc, H)                 # [B,S,H]
    o = jax.nn.sigmoid((xc @ p["w_o"]).astype(jnp.float32))
    di = q.shape[-2] * q.shape[-1]
    dh = di // H
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))

    # Chunked scan (see mamba.py): boundary-only saves + rematted chunks so
    # backward materializes the [B,H,dh,dh] matrix-memory residuals per
    # chunk, not per step.
    Ck = _pick_chunk(S)
    nch = S // Ck
    cast = lambda t: jnp.moveaxis(t.reshape(B, nch, Ck, *t.shape[2:]), 1, 0)
    q_c, k_c, v_c = cast(qf), cast(kf), cast(vf)
    i_c, f_c = cast(i_raw), cast(f_raw)

    @jax.checkpoint
    def chunk(carry, xs):
        qk, kk, vk, ik, fk = xs

        def step(carry, t):
            C, n, m = carry
            it, ft = ik[:, t], fk[:, t]
            m_new = jnp.maximum(ft + m, it)
            i_g = jnp.exp(it - m_new)
            f_g = jnp.exp(ft + m - m_new)
            kv = jnp.einsum("bhk,bhv->bhkv", kk[:, t], vk[:, t])
            C = f_g[..., None, None] * C + i_g[..., None, None] * kv
            n = f_g[..., None] * n + i_g[..., None] * kk[:, t]
            num = jnp.einsum("bhkv,bhk->bhv", C, qk[:, t])
            den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qk[:, t])), 1.0)
            return (C, n, m_new), num / den[..., None]

        carry, ys = jax.lax.scan(step, carry, jnp.arange(Ck))
        return carry, ys.swapaxes(0, 1)                   # [B,Ck,H,dh]

    init = (jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.zeros((B, H), jnp.float32))
    (C_f, n_f, m_f), hs = jax.lax.scan(chunk, init, (q_c, k_c, v_c, i_c, f_c))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, di)          # [B,S,di]
    h = o * h + xc.astype(jnp.float32) * p["skip"]
    y = (h * jax.nn.silu(gate.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["w_dn"]
    if not return_state:
        return out
    conv_tail = apad[:, S:S + K - 1] if K > 1 else a[:, :0]
    return out, {"conv": conv_tail.astype(p["conv"].dtype),
                 "C": C_f, "n": n_f, "m": m_f}


def mlstm_state_init(batch: int, p: dict, n_heads: int) -> dict:
    dh = p["w_q"].shape[-1]
    di = dh * n_heads
    K = p["conv"].shape[0]
    return {"conv": jnp.zeros((batch, K - 1, di), p["conv"].dtype),
            "C": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
            "m": jnp.zeros((batch, n_heads), jnp.float32)}


def mlstm_decode_step(p: dict, x: jax.Array, state: dict, n_heads: int
                      ) -> tuple[jax.Array, dict]:
    B = x.shape[0]
    H = n_heads
    up = x[:, 0] @ p["w_up"]
    a, gate = jnp.split(up, 2, -1)
    hist = jnp.concatenate([state["conv"], a[:, None]], 1)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", hist, p["conv"]))
    q, k, v = _mlstm_qkv(p, xc, a, H)
    i_raw, f_raw = _mlstm_gates(p, xc, H)
    o = jax.nn.sigmoid((xc @ p["w_o"]).astype(jnp.float32))
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    m_new = jnp.maximum(f_raw + state["m"], i_raw)
    i_g, f_g = jnp.exp(i_raw - m_new), jnp.exp(f_raw + state["m"] - m_new)
    C = (f_g[..., None, None] * state["C"]
         + i_g[..., None, None] * jnp.einsum("bhk,bhv->bhkv", kf, vf))
    n = f_g[..., None] * state["n"] + i_g[..., None] * kf
    num = jnp.einsum("bhkv,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)
    h = (num / den[..., None]).reshape(B, -1)
    h = o * h + xc.astype(jnp.float32) * p["skip"]
    y = (h * jax.nn.silu(gate.astype(jnp.float32))).astype(x.dtype)
    return (y @ p["w_dn"])[:, None], {"conv": hist[:, 1:], "C": C, "n": n,
                                      "m": m_new}


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------
def slstm_init(rng, d: int, n_heads: int, dtype):
    dh = d // n_heads
    ks = jax.random.split(rng, 3)
    w, aw = dense_init(ks[0], d, 4 * d, ("embed", None), dtype)
    r = (jax.random.normal(ks[1], (4, n_heads, dh, dh), jnp.float32)
         / jnp.sqrt(jnp.float32(dh))).astype(dtype)
    w_dn, a_dn = dense_init(ks[2], d, d, ("embed", "embed"), dtype)
    p = {"w": w, "r": r, "w_dn": w_dn,
         "bias": jnp.concatenate([jnp.zeros((2 * d,)),
                                  jnp.full((d,), 3.0),      # forget bias
                                  jnp.zeros((d,))]).astype(jnp.float32)}
    s = {"w": aw, "r": (None, None, None, None), "w_dn": a_dn, "bias": (None,)}
    return p, s


def _slstm_step(p, xw_t, carry, H):
    """One recurrence step. xw_t [B,4D] precomputed input contribution."""
    h, c, n, m = carry                                    # [B,D] x3, [B,D]
    B, Dm = h.shape
    dh = Dm // H
    hh = h.reshape(B, H, dh)
    rec = jnp.einsum("bhk,ghkv->gbhv", hh.astype(p["r"].dtype), p["r"])
    rec = rec.reshape(4, B, Dm).transpose(1, 0, 2).reshape(B, 4 * Dm)
    raw = (xw_t + rec).astype(jnp.float32) + p["bias"]
    z_r, i_r, f_r, o_r = jnp.split(raw, 4, -1)
    m_new = jnp.maximum(f_r + m, i_r)
    i_g, f_g = jnp.exp(i_r - m_new), jnp.exp(f_r + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_r)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_r) * c_new / jnp.maximum(n_new, 1.0)
    return h_new, c_new, n_new, m_new


def apply_slstm(p: dict, x: jax.Array, n_heads: int,
                return_state: bool = False):
    """Train/prefill: x [B,S,D] -> [B,S,D] (inherently sequential scan)."""
    B, S, D = x.shape
    xw = x @ p["w"]                                       # [B,S,4D]
    Ck = _pick_chunk(S)
    xw_c = jnp.moveaxis(xw.reshape(B, S // Ck, Ck, 4 * D), 1, 0)

    @jax.checkpoint
    def chunk(carry, xw_k):
        def step(carry, t):
            carry = _slstm_step(p, xw_k[:, t], carry, n_heads)
            return carry, carry[0]
        carry, hs = jax.lax.scan(step, carry, jnp.arange(Ck))
        return carry, hs.swapaxes(0, 1)

    init = tuple(jnp.zeros((B, D), jnp.float32) for _ in range(4))
    fin, hs = jax.lax.scan(chunk, init, xw_c)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, D).astype(x.dtype)
    out = h @ p["w_dn"]
    if not return_state:
        return out
    return out, dict(zip(("h", "c", "n", "m"), fin))


def slstm_state_init(batch: int, d: int) -> dict:
    return {k: jnp.zeros((batch, d), jnp.float32) for k in ("h", "c", "n", "m")}


def slstm_decode_step(p: dict, x: jax.Array, state: dict, n_heads: int
                      ) -> tuple[jax.Array, dict]:
    xw = x[:, 0] @ p["w"]
    carry = (state["h"], state["c"], state["n"], state["m"])
    h, c, n, m = _slstm_step(p, xw, carry, n_heads)
    y = h.astype(x.dtype) @ p["w_dn"]
    return y[:, None], {"h": h, "c": c, "n": n, "m": m}
