"""Functional JAX model zoo for the assigned architectures.

Pure-functional models (pytree params, no NN library): dense / MoE / hybrid
Mamba / xLSTM decoder LMs, plus one encoder-decoder. Every model exposes the
same step surface consumed by ``repro.launch``:

* ``init_params(rng, cfg)``  -> (params, param_specs)
* ``train_forward(params, cfg, batch)`` -> scalar loss
* ``prefill(params, cfg, tokens, ...)`` -> (logits_last, kv_state)
* ``decode_step(params, cfg, token, kv_state, pos)`` -> (logits, kv_state)
"""

from .config import ModelConfig
# build_model imported lazily in model.py (late in the build)

__all__ = ["ModelConfig"]
