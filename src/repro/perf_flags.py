"""Perf-iteration toggles (EXPERIMENTS.md §Perf).

The hillclimb compares lowerings with and without each optimization; flags
are read at *trace time* from ``REPRO_OPT`` (comma list or ``all``):

* ``attn_reshard`` — pin q/k/v to a head-sharded, sequence-gathered layout
  before blocked attention (one reshard per layer) instead of letting GSPMD
  re-gather K/V inside every kv-block scan step (hypothesis H1).
* ``blockk``       — larger attention KV blocks (512 -> 2048): 4x fewer
  online-softmax steps => 4x less HBM carry traffic (hypothesis H2).
* ``mamba_dbc``    — compute the (Δ,B,C) projections inside each rematted
  scan chunk instead of materializing [B,S,·] fp32 tensors up front
  (hypothesis H3).
"""

from __future__ import annotations

import os


def enabled(name: str) -> bool:
    v = os.environ.get("REPRO_OPT", "")
    if not v:
        return False
    parts = {p.strip() for p in v.split(",")}
    return "all" in parts or name in parts
