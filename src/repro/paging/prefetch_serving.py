"""Leap-prefetched page streaming: controller + hot buffer + gather, jitted.

This is the end-to-end in-model integration of the paper: a compute stream
that consumes remote pages (KV pages during chunked long-context processing,
expert blocks, offloaded layer weights) runs against a small hot buffer;
every slow-tier access feeds the per-stream Leap controller
(:mod:`repro.core.leap_jax`), whose candidates are fetched *alongside* the
demand page in one batched :func:`pool_access` — the prefetch DMA overlaps
the next compute step exactly like the paper's async RDMA queues overlap the
faulting process' progress.

Everything is fixed-shape and lives in one ``lax.scan`` per stream, so the
whole serving path jits; per-stream isolation (paper §4.1) is ``vmap`` over
the controller+buffer state.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.leap_jax import leap_init, leap_step
from repro.core.pool import pool_access, pool_init, pool_stats
from repro.core.window import DEFAULT_PW_MAX


@dataclasses.dataclass(frozen=True)
class PrefetchedStream:
    """Static geometry of one prefetched page stream."""
    n_pages: int
    n_slots: int
    page_elems: int
    pw_max: int = DEFAULT_PW_MAX
    h_size: int = 32
    n_split: int = 8


def stream_init(geom: PrefetchedStream, dtype=jnp.float32) -> dict:
    return {
        "leap": leap_init(geom.h_size),
        "pool_meta": pool_init(geom.n_pages, geom.n_slots),
        "hot": jnp.zeros((geom.n_slots, geom.page_elems), dtype),
    }


def stream_step(state: dict, pool_data: jax.Array, page: jax.Array,
                geom: PrefetchedStream) -> tuple[dict, jax.Array, dict]:
    """Service one page access; returns (state, page_data, info).

    Order per fault (paper Fig. 6): look up / demand-fetch the page, notify
    the tracker (with whether it hit a prefetched entry), then issue the
    controller's candidates — they ride the same batched fetch and land
    before the next step consumes them.
    """
    # Probe residency first so the controller sees prefetched_hit correctly.
    slot0 = state["pool_meta"]["page_slot"][jnp.clip(page, 0, geom.n_pages - 1)]
    meta = state["pool_meta"]
    s_safe = jnp.maximum(slot0, 0)
    was_pref = ((slot0 >= 0) & meta["slot_prefetched"][s_safe]
                & ~meta["slot_consumed"][s_safe])

    new_leap, cands, valid = leap_step(state["leap"], page, was_pref,
                                       n_split=geom.n_split,
                                       pw_max=geom.pw_max)
    pages = jnp.concatenate([page[None], cands])
    is_pf = jnp.concatenate([jnp.zeros((1,), bool), jnp.ones_like(valid)])
    val = jnp.concatenate([jnp.ones((1,), bool),
                           valid & (cands >= 0) & (cands < geom.n_pages)])
    meta, hot, slots, info = pool_access(meta, state["hot"], pool_data,
                                         pages, is_pf, val)
    data = hot[jnp.maximum(slots[0], 0)]
    return ({"leap": new_leap, "pool_meta": meta, "hot": hot},
            data, {"hit": info["hit"][0], "pref_hit": info["prefetched_hit"][0]})


@functools.partial(jax.jit, static_argnames=("geom",))
def stream_consume(pool_data: jax.Array, schedule: jax.Array,
                   geom: PrefetchedStream, state: dict | None = None):
    """Run a whole access schedule [T] through the stream; scan-jitted.

    Returns (state, data_sums [T] checksum of each served page, hits [T]).
    """
    if state is None:
        state = stream_init(geom, pool_data.dtype)

    def body(st, page):
        st, data, info = stream_step(st, pool_data, page, geom)
        return st, (data.sum(), info["hit"], info["pref_hit"])

    state, (sums, hits, pref_hits) = jax.lax.scan(body, state, schedule)
    return state, sums, {"hit": hits, "pref_hit": pref_hits}


def multi_stream_consume(pool_data: jax.Array, schedules: jax.Array,
                         geom: PrefetchedStream):
    """Isolated per-stream state over a shared pool: vmap(streams).

    schedules [n_streams, T]. The paper's Fig. 13 scenario: concurrent
    streams with different patterns do not pollute each other's detectors.
    """
    def one(schedule):
        return stream_consume(pool_data, schedule, geom)

    return jax.vmap(one)(schedules)


def stream_stats(state: dict) -> dict:
    return pool_stats(state["pool_meta"])
