"""Leap-prefetched page streaming: controller + hot buffer + gather, jitted.

This is the end-to-end in-model integration of the paper: a compute stream
that consumes remote pages (KV pages during chunked long-context processing,
expert blocks, offloaded layer weights) runs against a small hot buffer;
every slow-tier access feeds the per-stream Leap controller
(:mod:`repro.core.leap_jax`), whose candidates are fetched ahead of use.

Two data paths realize the fetches (paper §4.2–4.4, DESIGN.md §4):

* **Sync** (:func:`stream_step`): the demand page and the controller's
  candidates ride one blocking batched :func:`repro.core.pool.pool_access` —
  every prefetch byte sits on the critical path of the step that issued it
  (the read-ahead-style baseline).
* **Async issue/wait** (:func:`stream_step_async`): candidates are *issued*
  into a fixed-shape in-flight ring (:func:`repro.core.pool.pool_issue`)
  with an arrival deadline one step out; the next step's
  :func:`repro.core.pool.pool_wait` *lands* them before serving its demand —
  the prefetch DMA overlaps the consumer's compute, exactly like the paper's
  async RDMA queues overlap the faulting process' progress. A demand access
  to a page still in flight completes it early as a *partial hit* (swap-cache
  semantics) and only the residual transfer blocks.

Everything is fixed-shape and lives in one ``lax.scan`` per stream, so the
whole serving path jits; per-stream isolation (paper §4.1) is ``vmap`` over
the controller+buffer(+ring) state.

Multi-stream serving can additionally model the *shared* RDMA link the
paper's §4.4/Fig. 13 contention results are about:
:func:`multi_stream_consume` with a finite ``link_budget`` runs a single
``lax.scan`` over time with stacked per-stream states and arbitrates a
per-step fetch budget across every stream — demand fetches strictly first,
leftover budget granted to in-flight prefetches in global issue order, the
surplus deferred in the ring with pushed-out arrivals (DESIGN.md §5).
Controller, hot buffer and ring stay private per stream (§4.1); only the
link budget and the issue order are shared. ``link_budget=None`` keeps the
independent ``vmap`` path (every stream gets a private, infinite link).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.leap_jax import leap_init, leap_step
from repro.core.pool import (pool_access, pool_init, pool_issue, pool_stats,
                             pool_wait, ring_init)
from repro.core.window import DEFAULT_PW_MAX


def _payload_checksum(data):
    """Scalar checksum of a served payload (array or pytree of arrays)."""
    return sum(jax.tree.leaves(jax.tree.map(lambda d: d.sum(), data)))


@dataclasses.dataclass(frozen=True)
class PrefetchedStream:
    """Static geometry of one prefetched page stream.

    Attributes:
      n_pages:    slow-tier size (page ids are ``int32`` in ``[0, n_pages)``).
      n_slots:    hot-buffer capacity; size ``>= 2 * (1 + pw_max)`` so a
                  batch's evictions never race its allocations.
      page_elems: flattened payload elements per page.
      pw_max:     controller prefetch-window cap (candidates per fault).
      h_size:     controller access-history length.
      n_split:    FINDTREND ladder start (``h_size // n_split`` window).
      ring_size:  async in-flight ring capacity. ``0`` makes the async path
                  degenerate to the sync one (bit-equivalent, tested).
      arrival_delay: steps between issue and arrival; ``1`` = issued at *t*,
                  consumable at *t+1* (double-buffered overlap).
    """
    n_pages: int
    n_slots: int
    page_elems: int
    pw_max: int = DEFAULT_PW_MAX
    h_size: int = 32
    n_split: int = 8
    ring_size: int = 8
    arrival_delay: int = 1


def stream_init(geom: PrefetchedStream, dtype=jnp.float32,
                payload_like=None) -> dict:
    """Fresh stream state: controller + pool metadata + hot buffer + ring.

    Returns a pytree dict with keys ``leap`` (controller state),
    ``pool_meta`` (:func:`repro.core.pool.pool_init`), ``hot``
    (``[n_slots, page_elems]`` of ``dtype``) and ``ring``
    (:func:`repro.core.pool.ring_init`, inert on the sync path).

    ``payload_like`` switches the hot buffer to a structured payload: pass
    the slow-tier pytree (leaves ``[n_pages, ...]``, e.g. a ``{"k","v"}``
    KV-page pair) and each hot leaf becomes ``[n_slots, ...]`` of the
    matching trailing shape/dtype — the pool layer moves all leaves of a
    slot together (DESIGN.md §6). ``geom.page_elems``/``dtype`` are ignored
    in that mode.
    """
    hot = (jnp.zeros((geom.n_slots, geom.page_elems), dtype)
           if payload_like is None else
           jax.tree.map(lambda c: jnp.zeros((geom.n_slots,) + c.shape[1:],
                                            c.dtype), payload_like))
    return {
        "leap": leap_init(geom.h_size),
        "pool_meta": pool_init(geom.n_pages, geom.n_slots),
        "hot": hot,
        "ring": ring_init(geom.ring_size),
    }


def stream_step(state: dict, pool_data: jax.Array, page: jax.Array,
                geom: PrefetchedStream) -> tuple[dict, jax.Array, dict]:
    """Synchronous step: service one page access, fetch candidates inline.

    Args:
      state: stream state from :func:`stream_init`.
      pool_data: ``[n_pages, page_elems]`` slow tier.
      page: ``int32`` demand page id.

    Returns ``(state, data, info)`` with ``data = [page_elems]`` payload and
    scalar ``info`` keys: bools ``hit`` / ``pref_hit`` / ``partial_hit`` /
    ``fetched`` (``partial_hit`` is always False here: the sync batch blocks
    until every requested byte has landed, so nothing is ever left in
    flight; ``fetched`` means the demand page moved over the link) and
    int32 ``issued`` (candidates fetched this step — on this path they all
    ride the blocking batch) / ``deferred`` (always 0 here).

    Order per fault (paper Fig. 6): look up / demand-fetch the page, notify
    the tracker (with whether it hit a prefetched entry), then issue the
    controller's candidates — they ride the same batched fetch, fully on
    this step's critical path.
    """
    # Probe residency first so the controller sees prefetched_hit correctly.
    slot0 = state["pool_meta"]["page_slot"][jnp.clip(page, 0, geom.n_pages - 1)]
    meta = state["pool_meta"]
    s_safe = jnp.maximum(slot0, 0)
    was_pref = ((slot0 >= 0) & meta["slot_prefetched"][s_safe]
                & ~meta["slot_consumed"][s_safe])

    new_leap, cands, valid = leap_step(state["leap"], page, was_pref,
                                       n_split=geom.n_split,
                                       pw_max=geom.pw_max)
    pages = jnp.concatenate([page[None], cands])
    is_pf = jnp.concatenate([jnp.zeros((1,), bool), jnp.ones_like(valid)])
    val = jnp.concatenate([jnp.ones((1,), bool),
                           valid & (cands >= 0) & (cands < geom.n_pages)])
    meta, hot, slots, info = pool_access(meta, state["hot"], pool_data,
                                         pages, is_pf, val)
    data = jax.tree.map(lambda h: h[jnp.maximum(slots[0], 0)], hot)
    issued = jnp.sum(info["fetched"][1:].astype(jnp.int32))
    return ({**state, "leap": new_leap, "pool_meta": meta, "hot": hot},
            data, {"hit": info["hit"][0], "pref_hit": info["prefetched_hit"][0],
                   "partial_hit": jnp.zeros((), bool),
                   "fetched": info["fetched"][0],
                   "issued": issued,
                   # sync path: every candidate rides the blocking batch, so
                   # each issue lands within its own step
                   "landed": issued,
                   "deferred": jnp.zeros((), jnp.int32)})


def stream_step_async(state: dict, pool_data: jax.Array, page: jax.Array,
                      geom: PrefetchedStream) -> tuple[dict, jax.Array, dict]:
    """Asynchronous step: wait (land + serve demand), then issue candidates.

    Same signature and return contract as :func:`stream_step`; the
    difference is *when* prefetch data moves. Per step at clock *t*:

    1. :func:`repro.core.pool.pool_wait` lands every ring entry whose
       deadline has passed (DMA that completed during step *t-1*'s compute)
       and serves the demand — resident hit, partial hit (demand completes a
       still-in-flight entry and blocks only on the residual), or miss.
    2. The controller consumes the fault (a partial hit counts as a
       prefetched hit, as in the kernel swap cache) and emits candidates.
    3. :func:`repro.core.pool.pool_issue` enqueues them with deadline
       ``t + geom.arrival_delay`` — off the critical path of this step.

    With ``geom.ring_size == 0`` there is nowhere to park an in-flight fetch,
    so the step delegates to :func:`stream_step` and is bit-equivalent to the
    sync path (pinned in ``tests/test_paging.py``).
    """
    if geom.ring_size == 0:
        new_state, data, info = stream_step(state, pool_data, page, geom)
        ring = dict(new_state["ring"])
        ring["now"] = ring["now"] + 1
        return {**new_state, "ring": ring}, data, info

    meta, ring, hot = state["pool_meta"], state["ring"], state["hot"]
    now = ring["now"]
    deferred0 = meta["n_deferred"]
    meta, ring, hot, slot, data, winfo = pool_wait(meta, ring, hot, pool_data,
                                                   page, now)
    pref_feedback = winfo["prefetched_hit"] | winfo["partial_hit"]
    new_leap, cands, valid = leap_step(state["leap"], page, pref_feedback,
                                       n_split=geom.n_split,
                                       pw_max=geom.pw_max)
    val = valid & (cands >= 0) & (cands < geom.n_pages)
    issued0 = meta["n_prefetch_issued"]
    meta, ring = pool_issue(meta, ring, cands, val, now,
                            jnp.int32(geom.arrival_delay))
    ring = dict(ring)
    ring["now"] = now + 1
    return ({**state, "leap": new_leap, "pool_meta": meta, "hot": hot,
             "ring": ring},
            data, {"hit": winfo["hit"], "pref_hit": winfo["prefetched_hit"],
                   "partial_hit": winfo["partial_hit"],
                   "fetched": winfo["fetched"],
                   "issued": meta["n_prefetch_issued"] - issued0,
                   "landed": jnp.sum(winfo["landed"].astype(jnp.int32)),
                   "deferred": meta["n_deferred"] - deferred0})


@functools.partial(jax.jit, static_argnames=("geom", "async_datapath"))
def stream_consume(pool_data: jax.Array, schedule: jax.Array,
                   geom: PrefetchedStream, state: dict | None = None,
                   async_datapath: bool = False):
    """Run a whole access schedule through the stream; scan-jitted.

    Args:
      pool_data: ``[n_pages, page_elems]`` slow tier — or a payload pytree
        whose leaves share the leading page axis (``{"k","v"}`` KV pages);
        the hot buffer mirrors its structure (:func:`stream_init`
        ``payload_like``) and all leaves of a page move together.
      schedule: ``int32[T]`` demand page ids.
      state: optional stream state to continue from (default: fresh).
      async_datapath: static switch — False replays the sync batched path
        (:func:`stream_step`), True the issue/wait overlap path
        (:func:`stream_step_async`).

    Returns ``(state, data_sums, info)``: ``data_sums`` is a ``[T]`` checksum
    of each served page's payload (summed across leaves for structured
    payloads), ``info`` has bool ``[T]`` arrays ``hit``,
    ``pref_hit``, ``partial_hit`` (all-False on the sync path) and
    ``fetched`` (demand moved a page over the link), plus int32 ``[T]``
    arrays ``issued`` (candidates fetched/enqueued per step), ``landed``
    (in-flight prefetches copied into the hot buffer this step; equals
    ``issued`` on the sync path where the batch blocks) and ``deferred``
    (prefetches completing past their deadline — only ever non-zero under
    the budgeted multi-stream path).

    The per-step info arrays are the wire format of the page-lifecycle
    event log: :func:`repro.obs.trace.decode_stream_events` expands them
    (plus the schedule and final counters) into ``issue``/``land``/``hit``/
    ``partial``/``miss``/… events host-side, with no change to this jitted
    path (DESIGN.md §8).
    """
    if state is None:
        state = (stream_init(geom, pool_data.dtype)
                 if isinstance(pool_data, jax.Array)
                 else stream_init(geom, payload_like=pool_data))
    step_fn = stream_step_async if async_datapath else stream_step

    def body(st, page):
        st, data, info = step_fn(st, pool_data, page, geom)
        return st, (_payload_checksum(data), info["hit"], info["pref_hit"],
                    info["partial_hit"], info["fetched"], info["issued"],
                    info["landed"], info["deferred"])

    state, (sums, hits, pref_hits, partials, fetched, issued, landed,
            deferred) = jax.lax.scan(body, state, schedule)
    return state, sums, {"hit": hits, "pref_hit": pref_hits,
                         "partial_hit": partials, "fetched": fetched,
                         "issued": issued, "landed": landed,
                         "deferred": deferred}


def multi_stream_consume(pool_data: jax.Array, schedules: jax.Array,
                         geom: PrefetchedStream,
                         async_datapath: bool = False,
                         link_budget: int | None = None):
    """Concurrent streams over a shared pool, optionally on a shared link.

    Args:
      schedules: ``int32[n_streams, T]`` demand page ids per stream.
      async_datapath: static sync/async selector, as in
        :func:`stream_consume` (one value for all streams).
      link_budget: static pages/step the shared fabric link can move across
        *all* streams (DESIGN.md §5). ``None`` models private infinite
        links: every stream runs independently (``vmap``), exactly the
        paper's Fig. 13 isolated setup. A finite budget switches to a
        single ``lax.scan`` over time with stacked per-stream states:
        demand fetches are served first every step, leftover budget lands
        in-flight prefetches in global issue order, and the surplus stays
        in the ring with pushed-out arrivals (counted ``deferred``). A
        large-enough budget is bit-equivalent to ``link_budget=None``
        (pinned in ``tests/test_link_budget.py``).

    Per-stream state (controller + hot buffer + ring) stays private either
    way (§4.1): the budget arbitrates *bandwidth*, never detector state, so
    different patterns still cannot pollute each other's detectors.

    Returns ``(state, data_sums, info)`` shaped like a stacked
    :func:`stream_consume` (leading ``[n_streams]`` axis). With a budget,
    ``info`` gains shared per-step int32 ``[T]`` link totals:
    ``link_demand_fetches``, ``link_prefetch_issued`` and ``link_deferred``
    (on the sync path the budget cannot change behavior — every fetch
    already blocks its issuing step — so the totals just price the link).
    """
    if link_budget is not None and async_datapath and geom.ring_size > 0:
        return _multi_stream_consume_budgeted(pool_data, schedules, geom,
                                              int(link_budget))

    def one(schedule):
        return stream_consume(pool_data, schedule, geom,
                              async_datapath=async_datapath)

    state, sums, info = jax.vmap(one)(schedules)
    if link_budget is not None:
        # Sync (or ring-less) fetches all block their issuing step: a budget
        # changes the price of a step, not what happens in it. Report the
        # per-step link totals so callers can price contention.
        info = dict(info)
        info["link_demand_fetches"] = jnp.sum(
            info["fetched"].astype(jnp.int32), axis=0)
        info["link_prefetch_issued"] = jnp.sum(info["issued"], axis=0)
        info["link_deferred"] = jnp.sum(info["deferred"], axis=0)
    return state, sums, info


def _multi_stream_consume_budgeted(pool_data: jax.Array,
                                   schedules: jax.Array,
                                   geom: PrefetchedStream,
                                   link_budget: int):
    """Budgeted async multi-stream path: one scan over time, shared link.

    Per step *t* (DESIGN.md §5):

    1. **Grant** — the link moved last step's demand fetches first
       (strict demand priority), so prefetch landing capacity is
       ``max(0, link_budget - demand_fetches[t-1])``. Grants go to due ring
       entries (``deadline <= t``) across all streams in ascending global
       issue order (``seq``, FIFO over the link); the rest stay in the ring
       past their deadline (deferred).
    2. **Wait/serve** — per-stream :func:`repro.core.pool.pool_wait` with
       the grant mask: land granted entries, serve this step's demand
       (hit / partial / miss).
    3. **Issue** — per-stream controllers emit candidates;
       :func:`repro.core.pool.pool_issue` stamps them with globally ordered
       ``seq`` (step-major, then stream, then candidate).

    Streams advance in lock-step (one access per step each), which is what
    makes the per-stream hit/partial/deferral counts directly comparable to
    a step-synchronous width-``link_budget`` fabric run on the same
    schedules (``repro.fabric.linkstep``, cross-validated in
    ``tests/test_link_budget.py``).

    Since the mesh-sharded cold pool landed (DESIGN.md §7) this is the
    degenerate one-shard case of
    :func:`repro.paging.sharded_pool.sharded_multi_stream_consume` — a
    single "fabric" of one NIC carrying the whole budget, every page near
    — and it delegates there. The §5 pins in ``tests/test_link_budget.py``
    (vmap bit-equivalence at infinite budget, exact linkstep counts at
    finite budgets) gate that reduction.
    """
    from repro.paging.sharded_pool import (ShardedPoolCfg,
                                           sharded_multi_stream_consume)
    delay = max(geom.arrival_delay, 1)    # pool_issue clamps to >= 1 anyway
    fabric = ShardedPoolCfg(n_shards=1, placement="interleave",
                            link_budget=int(link_budget),
                            near_delay=delay, far_delay=delay)
    return sharded_multi_stream_consume(pool_data, schedules, geom, fabric)


def stream_stats(state: dict) -> dict:
    """Counter summary of a stream state; not jittable (host-side ints).

    Extends :func:`repro.core.pool.pool_stats` with the async-path
    decomposition (DESIGN.md §4): every issued prefetch is exactly one of
    ``prefetch_hits`` (consumed; ``partial_hits`` = the subset consumed
    while still in flight), ``pollution`` (landed, evicted unused),
    ``inflight_at_end`` (still in the ring) or ``resident_unused`` (landed,
    never consumed, still resident). ``latency_hidden_frac`` is the fraction
    of consumed prefetches that had fully arrived before first use — 1.0
    means the ring hid every transfer behind compute; the sync path reports
    1.0 vacuously (its fetches all block the issuing step instead).
    """
    return pool_stats(state["pool_meta"], state.get("ring"))


def stream_stats_at(state: dict, i: int) -> dict:
    """:func:`stream_stats` of stream ``i`` in a stacked multi-stream state.

    ``state`` is the leading-``[n_streams]``-axis pytree returned by
    :func:`multi_stream_consume`; this slices out one stream's counters
    without callers having to know the stacked layout. The pool-wide
    ``tier`` table a migration-enabled run returns (DESIGN.md §12) has no
    stream axis and is excluded from the slice.
    """
    state = {k: v for k, v in state.items() if k != "tier"}
    return stream_stats(jax.tree.map(lambda x: x[i], state))
