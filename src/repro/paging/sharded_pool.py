"""Mesh-sharded cold pool: per-shard NICs, placement, near/far asymmetry.

Until now the serving path pretended the cold tier is one flat local array
behind one link. Rack-scale disaggregation has real topology: the cold pool
is *sharded* over a device mesh's ``fabric`` axis — each device owns a
``[n_pages / n_shards, ...]`` slice of every payload leaf behind its own
NIC — and a page's cost depends on *where it lives* (DESIGN.md §7):

* **Placement** maps each page id to a home shard
  (:func:`repro.core.pool.page_home`): ``"block"`` keeps contiguous id
  ranges together, ``"interleave"`` round-robins consecutive ids across
  shards. Placement is a policy knob precisely because it changes contention:
  strided multi-stream traffic hammers one block shard while interleave
  spreads the same accesses over every NIC (``benchmarks/sharded_pool.py``).
* **Per-shard link budgets** replace the single global link of §5: each
  shard's NIC moves ``link_budget`` pages/step, arbitrated demand-first by
  :func:`repro.core.pool.link_grants_sharded` — the same discipline as
  :func:`repro.core.pool.link_grants`, ranked and capped per home shard.
* **Near/far delay asymmetry**: a prefetch of a page homed on the
  consuming stream's own shard arrives after ``near_delay`` steps; a
  cross-shard prefetch rides the fabric and arrives after ``far_delay``.
  The per-candidate delay vector threads straight into
  :func:`repro.core.pool.pool_issue` deadlines.

Two data planes move the same bytes (pinned bit-equal in
``tests/test_sharded_pool.py``):

* **Flat** (no mesh): the cold pool is a local array, pages are gathered by
  plain indexing — placement/budgets/delays still shape the *metadata*
  (what lands when), so the scheduling model runs anywhere, single-device
  CPU included.
* **Sharded** (mesh with a ``fabric`` axis): the whole consume scan runs
  under ``shard_map``; each device holds its home slice
  (:func:`place_cold` permutes pages home-major so ``P('fabric')`` on the
  page axis lands every page on its home shard) and cross-shard pages move
  via a ring of ``lax.ppermute`` collective permutes — shard slices rotate
  around the fabric and every consumer picks up the pages homed on the
  currently-visiting shard.

``n_shards=1`` reduces bit-exactly to the §5 single-link path:
``repro.paging.prefetch_serving.multi_stream_consume(..., link_budget=B)``
now *delegates* here with the degenerate config, so the existing
``tests/test_link_budget.py`` pins (vmap equivalence, linkstep
cross-validation) gate this module too. The lock-step fabric mirror for
``n_shards > 1`` is :func:`repro.fabric.shardstep.run_shardstep`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.leap_jax import leap_step_batched
from repro.core.pool import (NO_PAGE, PLACEMENTS, link_grants_sharded,
                             page_home, page_local, pool_invalidate,
                             pool_issue, pool_wait, tier_demote,
                             tier_heat_decay, tier_init, tier_migrate,
                             tier_promote, tier_touch)
from repro.paging.lifecycle import (MigrationCfg, propose_migrations,
                                    resolve, revalidate_proposals,
                                    select_demotions)


@dataclasses.dataclass(frozen=True)
class ShardedPoolCfg:
    """Static fabric topology of the sharded cold pool.

    Attributes:
      n_shards:    devices the cold pool's page axis is sharded over (one
                   NIC each). ``1`` is the degenerate single-link fabric.
      placement:   page -> home shard policy, ``"block"`` or
                   ``"interleave"`` (:func:`repro.core.pool.page_home`).
      link_budget: pages/step *each shard's NIC* can move (demand-first,
                   DESIGN.md §5 per shard). ``None`` = infinite NICs —
                   only the delay asymmetry is modeled.
      near_delay:  prefetch arrival delay (steps) from the consumer's own
                   shard.
      far_delay:   arrival delay for cross-shard prefetches (>= near).
    """
    n_shards: int = 1
    placement: str = "interleave"
    link_budget: int | None = None
    near_delay: int = 1
    far_delay: int = 2

    def __post_init__(self):
        if self.placement not in PLACEMENTS:
            raise ValueError(f"placement must be one of {PLACEMENTS}, "
                             f"got {self.placement!r}")
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if not 1 <= self.near_delay <= self.far_delay:
            raise ValueError("need 1 <= near_delay <= far_delay "
                             f"(got {self.near_delay}/{self.far_delay})")


def stream_homes(n_streams: int, n_shards: int) -> jax.Array:
    """Home shard of each stream: ``s % n_shards`` (fixed round-robin —
    the lock-step mirror uses the same mapping)."""
    return jnp.mod(jnp.arange(n_streams, dtype=jnp.int32), n_shards)


def place_perm(n_pages: int, fabric: ShardedPoolCfg) -> np.ndarray:
    """Permutation putting pages in home-major order.

    ``placed[i] = cold[perm[i]]``: shard g's slice ``[g*pps, (g+1)*pps)``
    of the placed array holds exactly the pages homed on g, each at its
    :func:`repro.core.pool.page_local` index — so sharding the placed
    array's page axis over the ``fabric`` mesh axis gives every page to
    its home shard.
    """
    if n_pages % fabric.n_shards:
        raise ValueError(f"n_pages={n_pages} not divisible by "
                         f"n_shards={fabric.n_shards}")
    pages = np.arange(n_pages)
    pps = n_pages // fabric.n_shards
    if fabric.placement == "interleave":
        home, local = pages % fabric.n_shards, pages // fabric.n_shards
    else:
        home, local = pages // pps, pages % pps
    perm = np.empty(n_pages, np.int64)
    perm[home * pps + local] = pages
    return perm


def place_cold(cold, n_pages: int, fabric: ShardedPoolCfg):
    """Permute every payload leaf's page axis into home-major order."""
    perm = jnp.asarray(place_perm(n_pages, fabric))
    return jax.tree.map(lambda c: c[perm], cold)


def check_fabric_topology(n_pages: int, fabric: ShardedPoolCfg,
                          mesh=None) -> None:
    """Shared entry-point validation: the pool must split evenly over the
    shards, and a mesh (if given) must carry a matching ``fabric`` axis.
    One implementation so every §7 entry point rejects with the same
    message."""
    if n_pages % fabric.n_shards:
        raise ValueError(f"n_pages={n_pages} not divisible by "
                         f"n_shards={fabric.n_shards}")
    if mesh is not None and fabric.n_shards > 1 \
            and mesh.shape.get("fabric") != fabric.n_shards:
        raise ValueError(f"mesh fabric axis {mesh.shape.get('fabric')} != "
                         f"n_shards {fabric.n_shards}")


# --------------------------------------------------------------------------
# data planes
# --------------------------------------------------------------------------
def _gather_flat(cold, pages: jax.Array):
    """Plain local gather (single-device cold pool, original page order)."""
    safe = jnp.maximum(pages, 0)
    return jax.tree.map(lambda c: c[safe], cold)


def fabric_ring_gather(buf: jax.Array, local: jax.Array, homes: jax.Array,
                       n_shards: int, pick) -> jax.Array:
    """One-leaf collective gather over the ``fabric`` axis (inside shard_map).

    Ring algorithm: the home slice ``buf`` rotates one hop per round via
    ``lax.ppermute``; at round r every device is visited by shard
    ``(me - r) % n_shards``'s slice and keeps the entries homed there
    (``homes``), read at their within-shard ``local`` indices by
    ``pick(buf, local)`` — a plain ``buf[local]`` for jnp gathers, or one
    of the :mod:`repro.kernels.gather_pages` kernels so the bytes still
    move through the DMA-pipelined gather within each round. After
    ``n_shards`` rounds every device holds all requested entries — the
    replicated result the (replicated) metadata scan consumes, bit-
    identical to the flat gather on the unplaced pool. This is the single
    implementation of the §7 ring discipline — the stream consume and the
    tiered sweep both ride it, so their bit-equivalence pins share one
    rotation order.
    """
    me = jax.lax.axis_index("fabric")
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    out = None
    for r in range(n_shards):
        take = homes == jnp.mod(me - r, n_shards)
        picked = pick(buf, local)
        mask = take.reshape(take.shape + (1,) * (picked.ndim - take.ndim))
        out = jnp.where(mask, picked, 0 if out is None else out)
        if r < n_shards - 1:
            buf = jax.lax.ppermute(buf, "fabric", perm)
    return out


def _gather_fabric(cold_local, pages: jax.Array, n_pages: int,
                   fabric: ShardedPoolCfg):
    """Collective gather of ``pages`` from the sharded cold pool: the
    :func:`fabric_ring_gather` ring with plain indexing per leaf."""
    G = fabric.n_shards
    pps = n_pages // G
    home = page_home(pages, n_pages, G, fabric.placement)
    local = jnp.clip(page_local(pages, n_pages, G, fabric.placement),
                     0, pps - 1)
    return jax.tree.map(
        lambda c: fabric_ring_gather(c, local, home, G,
                                     lambda b, ix: b[ix]), cold_local)


def scatter_hot(hot, data, dst: jax.Array, mask: jax.Array):
    """Scatter gathered page payloads (leaves ``[S, K, ...page]``) into the
    stacked ``[S, n_slots, ...]`` hot pool at per-stream slots ``dst
    [S, K]``; masked-out entries scatter out of bounds and drop. The single
    OOB-drop scatter discipline — the stream consume and the tiered sweep
    both apply their copy plans through it."""
    S, n_slots = jax.tree.leaves(hot)[0].shape[:2]
    gdst = (jnp.arange(S, dtype=jnp.int32)[:, None] * n_slots
            + jnp.maximum(dst, 0)).reshape(-1)
    gdst = jnp.where(mask.reshape(-1), gdst, S * n_slots)

    def one(h, d):
        flat = h.reshape((S * n_slots,) + h.shape[2:])
        d = d.reshape((-1,) + d.shape[2:])
        return flat.at[gdst].set(d.astype(h.dtype),
                                 mode="drop").reshape(h.shape)

    return jax.tree.map(one, hot, data)


# --------------------------------------------------------------------------
# the sharded consume scan
# --------------------------------------------------------------------------
def _consume_impl(cold, schedules: jax.Array, geom, fabric: ShardedPoolCfg,
                  sharded: bool, chaos=None, migration=None):
    """Lock-step multi-stream consume over the (possibly sharded) cold pool.

    Generalizes the §5 budgeted scan (DESIGN.md §5 -> §7): per-step,

    1. **Grant** — shard g's NIC moved last step's demand fetches homed on
       g first, so its prefetch landing capacity is
       ``max(0, link_budget - demand_on_g[t-1])``; grants go to due ring
       entries homed on g in ascending global ``seq``
       (:func:`repro.core.pool.link_grants_sharded`).
    2. **Wait/serve** — per-stream metadata-only
       :func:`repro.core.pool.pool_wait` with the grant mask; the copy
       plan (landings + demand fetch) is applied by the data plane (flat
       gather, or ring-``ppermute`` collective gather when ``sharded``).
    3. **Issue** — controllers emit candidates; each is stamped with the
       global ``seq`` and a *distance-dependent* deadline: ``near_delay``
       if its home shard is the stream's own, else ``far_delay``.

    ``fabric.n_shards == 1`` with ``near_delay == geom.arrival_delay``
    reduces bit-exactly to the single-link §5 scan.

    ``chaos`` (a static :class:`repro.fabric.chaos.ChaosSpec`, DESIGN.md §9)
    injects faults without touching the clean path (``None`` compiles the
    exact scan above). With a spec, the step order becomes: node-death
    invalidation -> per-shard grants against the *per-step* budget table ->
    wait -> EWMA estimator update from this step's landings -> demand
    accounting and issue against the re-homed page->shard map, with
    physical delays dilated by the slowdown table, deadlines either static
    or estimator-driven, and issues capped by the elastic grant table. The
    estimator state ``est_q int32[S, G]`` rides the scan carry and is
    returned as ``info["est_q"]``.

    ``migration`` (a static :class:`repro.paging.lifecycle.MigrationCfg`,
    DESIGN.md §12) turns on the three-tier lifecycle: the page->home map
    becomes the time-varying ``tier["home"]`` table riding the scan carry,
    and each step grows the phases

    * **heat decay** then, at the grant phase, **migration grants**: last
      step's trend-driven proposals are re-validated (cooldown, still
      cross-shard, lowest-seq-wins dedupe) and granted out of each source
      NIC's capacity *left after every prefetch grant* — the third, lowest
      §5 class (:func:`repro.core.pool.link_grants_sharded`). A grant
      re-homes the page immediately, so this step's issues already see it
      near. Like chaos re-homing, migration moves *scheduling metadata
      only* — the data plane keeps gathering from the static physical
      placement.
    * **promote** after the wait: any landing or demand fetch of a
      compressed page clears its compressed bit (counted against the
      start-of-step snapshot, per stream); **heat touch** on the demand
      pages.
    * **issue** charges ``decompress_delay`` extra steps on candidates
      whose cold bytes are compressed; after the issue, capacity-driven
      **demotion** compresses the coldest eligible pages while the
      uncompressed population exceeds ``far_capacity``, and the updated
      trend proposes next step's migrations.

    With chaos node loss, death re-homes the *dynamic* table (every page
    currently homed on the dead shard, migrated-in pages included, is
    invalidated and re-homed by the §9 rule) and carried proposals
    targeting the dead shard are dropped and pollution-counted.
    ``migration=None`` compiles the exact two-tier scan above.
    """
    from repro.paging.prefetch_serving import stream_init

    S, T = schedules.shape
    K = geom.pw_max
    G = fabric.n_shards
    n_pages = geom.n_pages
    budget = fabric.link_budget
    homes_s = stream_homes(S, G)
    stream_ids = jnp.arange(S, dtype=jnp.int32)
    gather = (functools.partial(_gather_fabric, n_pages=n_pages,
                                fabric=fabric) if sharded else _gather_flat)

    mig = resolve(migration)

    cz = None
    if chaos is not None:
        from repro.fabric.chaos import (EST_ONE, compile_chaos, est_init,
                                        est_step)
        cz = compile_chaos(chaos, n_steps=T, n_streams=S, n_shards=G,
                           n_pages=n_pages, placement=fabric.placement,
                           base_budget=budget)
        dil_t = jnp.asarray(cz["dilation"])        # [T, G]
        bud_t = jnp.asarray(cz["budget"])          # [T, G]
        grant_t = jnp.asarray(cz["grant"])         # [T, S]
        home_tab = jnp.asarray(cz["home"])         # [2, n_pages]
        t_fail = cz["t_fail"]
        dead = (jnp.asarray(cz["dead_pages"]) if t_fail is not None else None)
        est0 = jnp.asarray(est_init(S, G, fabric.near_delay,
                                    fabric.far_delay))

    if mig is not None:
        tier0 = tier_init(n_pages, G, fabric.placement)
        M = mig.mig_per_stream
        pend0 = (jnp.zeros((S, M), jnp.int32), jnp.zeros((S, M), jnp.int32),
                 jnp.zeros((S, M), jnp.bool_), jnp.zeros((S, M), jnp.int32))
        dead_g = rehome_vec = None
        if cz is not None and cz["t_fail"] is not None:
            from repro.fabric.chaos import rehome_shard
            dead_g = int(chaos.node_loss[0])
            rehome_vec = jnp.asarray(np.array(
                [rehome_shard(p, dead_g, dead_g, G) for p in range(n_pages)],
                np.int32))

    # payload_like trailing shapes are per-page, hence shard-invariant —
    # the local [pps, ...] slice seeds the same hot-buffer layout the full
    # [n_pages, ...] pool would.
    one = (stream_init(geom, cold.dtype) if isinstance(cold, jax.Array)
           else stream_init(geom, payload_like=cold))
    state0 = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (S,) + x.shape), one)

    def _wait(meta, ring, page, now, ok):
        return pool_wait(meta, ring, None, None, page, now, land_ok=ok)

    def _issue(meta, ring, cands, val, now, seq, delay):
        return pool_issue(meta, ring, cands, val, now, delay, seq=seq)

    def _issue_chaos(meta, ring, cands, val, now, seq, delay, true_delay,
                     quota):
        return pool_issue(meta, ring, cands, val, now, delay, seq=seq,
                          true_delay=true_delay, quota=quota)

    def body(carry, xs):
        if mig is not None:
            carry, tier, pend = carry[:-2], carry[-2], carry[-1]
        if cz is None:
            state, d_prev = carry                  # d_prev: int32[G]
        else:
            state, d_prev, est_q = carry           # est_q: int32[S, G]
        t, pages = xs
        meta, ring, hot = state["pool_meta"], state["ring"], state["hot"]
        now = ring["now"]                          # int32[S], == t

        if mig is not None:
            # Dynamic scheduling home map. Chaos node death re-homes the
            # *current* table (migrated-in pages included) by the §9 rule
            # and invalidates everything homed on the dying shard; the data
            # plane still gathers from the static physical placement.
            if cz is not None and cz["t_fail"] is not None:
                on_dead = tier["home"] == dead_g
                kill = jnp.broadcast_to(t == cz["t_fail"],
                                        (n_pages,)) & on_dead
                all_pages = jnp.arange(n_pages, dtype=jnp.int32)
                meta, ring = jax.vmap(
                    lambda m, r: pool_invalidate(m, r, all_pages, kill))(
                        meta, ring)
                tier = dict(tier)
                tier["home"] = jnp.where(kill, rehome_vec, tier["home"])
            tier = tier_heat_decay(tier)
            comp_pre = tier["comp"]                # start-of-step snapshot

            def _home(x):
                # Reads the *current* binding of ``tier``: the grant phase
                # below rebinds it, so homes seen after the migration grant
                # (demand accounting, issue delays) already reflect this
                # step's grants — the twin mirrors this order.
                return tier["home"][jnp.clip(x, 0, n_pages - 1)]
        elif cz is None:
            def _home(x):
                return page_home(x, n_pages, G, fabric.placement)
        else:
            # Scheduling home map, re-homed from the death step on. The
            # data plane below keeps gathering from the physical placement
            # (the survivor serves a replica): re-homing is metadata only.
            if cz["t_fail"] is None:
                hv = home_tab[0]
            else:
                hv = jnp.where(t >= cz["t_fail"], home_tab[1], home_tab[0])

            def _home(x):
                return hv[jnp.clip(x, 0, n_pages - 1)]

            if cz["t_fail"] is not None:
                # Node death at the top of the step: the dead shard's
                # resident prefetches and in-flight fetches are lost
                # (pollution); freed slots recycle through the free stack.
                kill = jnp.broadcast_to(t == cz["t_fail"], dead.shape)
                meta, ring = jax.vmap(
                    lambda m, r: pool_invalidate(m, r, dead, kill))(meta, ring)

        # --- per-shard landing grants (leftover NIC budget, global seq) -----
        if mig is not None:
            # Prefetch grants rank against the pre-grant home map; granted
            # migrations re-home immediately, so everything downstream
            # (demand accounting, issue delays) sees the post-grant map.
            mp, md, mv0, msq = pend
            mv, msrc = revalidate_proposals(mp, md, mv0, msq, tier, t, mig)
            if cz is not None and cz["t_fail"] is not None:
                # Carried proposals that crossed the death step targeting
                # the dead shard: dropped and pollution-counted (per
                # proposing stream), like any other wasted transfer.
                dead_hit = mv & (md == dead_g) & (t >= cz["t_fail"])
                meta = dict(meta)
                meta["n_pollution"] = meta["n_pollution"] + jnp.sum(
                    dead_hit.astype(jnp.int32), axis=1)
                mv = mv & ~dead_hit
            if cz is not None:
                caps = jnp.maximum(bud_t[t] - d_prev, 0)
            elif budget is not None:
                caps = jnp.maximum(jnp.int32(budget) - d_prev, 0)
            else:
                caps = None
            homes_ring = _home(ring["page"])
            if caps is None:
                allowed = jnp.ones(ring["page"].shape, bool)
                mig_ok = mv
                pf_on_g = jnp.zeros((G,), jnp.int32)
            else:
                allowed, mig_ok = link_grants_sharded(
                    ring, now, caps, homes_ring, msrc, mv, msq)
                pf_on_g = jnp.zeros((G,), jnp.int32).at[
                    jnp.clip(homes_ring.reshape(-1), 0, G - 1)].add(
                        allowed.reshape(-1).astype(jnp.int32))
            tier = tier_migrate(tier, mp.reshape(-1), md.reshape(-1),
                                mig_ok.reshape(-1), t)
            migrated_s = jnp.sum(mig_ok.astype(jnp.int32), axis=1)
            mig_on_g = jnp.zeros((G,), jnp.int32).at[
                jnp.clip(msrc.reshape(-1), 0, G - 1)].add(
                    mig_ok.reshape(-1).astype(jnp.int32))
        elif cz is not None:
            caps = jnp.maximum(bud_t[t] - d_prev, 0)
            allowed = link_grants_sharded(ring, now, caps, _home(ring["page"]))
        elif budget is None:
            allowed = jnp.ones(ring["page"].shape, bool)
        else:
            caps = jnp.maximum(jnp.int32(budget) - d_prev, 0)
            homes_ring = page_home(ring["page"], n_pages, G, fabric.placement)
            allowed = link_grants_sharded(ring, now, caps, homes_ring)
        # --- wait/serve (metadata-only; copy plan applied below) ------------
        deferred0 = meta["n_deferred"]
        meta, ring, _, slot, _, winfo = jax.vmap(_wait)(
            meta, ring, pages, now, allowed)
        if cz is not None:
            # EWMA update from this step's landings: obs = realized delay,
            # bucketed per (stream, home shard), order-independent batch
            # form (DESIGN.md §9) so the Python twin folds identically.
            lp, li = winfo["landed_pages"], winfo["landed_issued"]
            lmask = lp >= 0
            homes_l = jnp.where(lmask, _home(lp), G)     # G = drop row
            rows = jnp.broadcast_to(stream_ids[:, None], lp.shape)
            obs = jnp.where(lmask, now[:, None] - li, 0).astype(jnp.int32)
            obs_sum = jnp.zeros((S, G), jnp.int32).at[rows, homes_l].add(
                obs, mode="drop")
            cnt = jnp.zeros((S, G), jnp.int32).at[rows, homes_l].add(
                lmask.astype(jnp.int32), mode="drop")
            est_q = jnp.where(cnt > 0,
                              est_step(est_q, obs_sum, jnp.maximum(cnt, 1)),
                              est_q)
        homes_d = _home(pages)
        d_t = jnp.zeros((G,), jnp.int32).at[homes_d].add(
            winfo["fetched"].astype(jnp.int32), mode="drop")
        # --- promote on bytes moved + demand heat (DESIGN.md §12) -----------
        if mig is not None:
            if mig.compressed:
                # Any landing or demand fetch of a compressed page promotes
                # it; counted against the start-of-step snapshot so the
                # per-stream attribution is order-independent (clearing the
                # bit is idempotent).
                lp = winfo["landed_pages"]
                prom_land = (winfo["landed"]
                             & comp_pre[jnp.clip(lp, 0, n_pages - 1)])
                prom_dem = (winfo["fetched"]
                            & comp_pre[jnp.clip(pages, 0, n_pages - 1)])
                promoted_s = (jnp.sum(prom_land.astype(jnp.int32), axis=1)
                              + prom_dem.astype(jnp.int32))
                moved = jnp.concatenate([lp.reshape(-1), pages])
                moved_ok = jnp.concatenate(
                    [winfo["landed"].reshape(-1), winfo["fetched"]])
                tier, _ = tier_promote(tier, moved, moved_ok, comp_pre)
            else:
                promoted_s = jnp.zeros((S,), jnp.int32)
            tier = tier_touch(tier, pages, (pages >= 0) & (pages < n_pages),
                              mig.heat_access)
        # --- controllers + globally ordered, distance-delayed issue ---------
        pref_feedback = winfo["prefetched_hit"] | winfo["partial_hit"]
        new_leap, cands, valid = leap_step_batched(
            state["leap"], pages, pref_feedback,
            n_split=geom.n_split, pw_max=geom.pw_max)
        val = valid & (cands >= 0) & (cands < n_pages)
        seq = ((t * S + stream_ids)[:, None] * K
               + jnp.arange(K, dtype=jnp.int32)[None, :])
        homes_c = _home(cands)
        base = jnp.where(homes_c == homes_s[:, None],
                         jnp.int32(fabric.near_delay),
                         jnp.int32(fabric.far_delay))
        if mig is not None and mig.compressed:
            # Promote-from-compressed pays the codec: extra steps on top of
            # the wire delay (dilation multiplies the wire only).
            sur = (tier["comp"][jnp.clip(cands, 0, n_pages - 1)]
                   .astype(jnp.int32) * jnp.int32(mig.decompress_delay))
        else:
            sur = None
        issued0 = meta["n_prefetch_issued"]
        if cz is None:
            delay_v = base if sur is None else base + sur
            meta, ring = jax.vmap(_issue)(meta, ring, cands, val, now, seq,
                                          delay_v)
        else:
            true_delay = base * dil_t[t][homes_c]
            if sur is not None:
                true_delay = true_delay + sur
            if chaos.adaptive_deadline:
                rows_c = jnp.broadcast_to(stream_ids[:, None], homes_c.shape)
                eg = est_q[rows_c, homes_c]
                deadline = jnp.maximum(1, (eg + EST_ONE // 2) // EST_ONE)
            else:
                deadline = base if sur is None else base + sur
            # Elastic grant: cap the stream's unconsumed-resident +
            # in-flight footprint; issues beyond the cap are drops.
            res_unused = jnp.sum((meta["slot_page"] >= 0)
                                 & meta["slot_prefetched"]
                                 & ~meta["slot_consumed"], axis=1)
            occ = jnp.sum(ring["page"] >= 0, axis=1)
            quota = jnp.maximum(grant_t[t] - res_unused - occ, 0)
            meta, ring = jax.vmap(_issue_chaos)(
                meta, ring, cands, val, now, seq, deadline, true_delay, quota)
        ring = dict(ring)
        ring["now"] = now + 1
        issued_s = meta["n_prefetch_issued"] - issued0
        deferred_s = meta["n_deferred"] - deferred0
        # --- demote the coldest + propose next step's migrations ------------
        if mig is not None:
            if mig.compressed:
                dpages, dok = select_demotions(tier, t, mig)
                tier = tier_demote(tier, dpages, dok, t)
                demoted_t = jnp.sum(dok.astype(jnp.int32))
            else:
                demoted_t = jnp.int32(0)
            mp2, md2, mv2, msq2 = propose_migrations(
                new_leap, pages, homes_s, tier, t, n_pages, K, mig)
            if cz is not None and cz["t_fail"] is not None:
                mv2 = mv2 & ~((md2 == dead_g) & (t >= cz["t_fail"]))
            pend = (mp2, md2, mv2, msq2)
        landed_s = jnp.sum(winfo["landed"].astype(jnp.int32), axis=1)
        # --- data plane: replay the copy plan (landings, then demand) -------
        src = jnp.concatenate(
            [winfo["landed_pages"],
             jnp.where(winfo["fetched"], pages, NO_PAGE)[:, None]], axis=1)
        dst = jnp.concatenate([winfo["landed_slots"], slot[:, None]], axis=1)
        msk = jnp.concatenate([winfo["landed"],
                               winfo["fetched"][:, None]], axis=1)
        data = gather(cold, src)                   # [S, R+1, ...page]
        hot = scatter_hot(hot, data, dst, msk)
        served = jax.tree.map(
            lambda h: h[stream_ids, jnp.maximum(slot, 0)], hot)
        sums = sum(jax.tree.leaves(jax.tree.map(
            lambda d: d.reshape(S, -1).sum(-1), served)))
        state = {"leap": new_leap, "pool_meta": meta, "hot": hot,
                 "ring": ring}
        outs = (sums, winfo["hit"], winfo["prefetched_hit"],
                winfo["partial_hit"], winfo["fetched"], issued_s, landed_s,
                deferred_s, d_t, jnp.sum(issued_s), jnp.sum(deferred_s))
        carry = ((state, d_t) if cz is None else (state, d_t, est_q))
        if mig is not None:
            carry = carry + (tier, pend)
            outs = outs + (migrated_s, promoted_s, demoted_t, mig_on_g,
                           pf_on_g)
        return carry, outs

    xs = (jnp.arange(T, dtype=jnp.int32), schedules.T)
    carry0 = ((state0, jnp.zeros((G,), jnp.int32)) if cz is None
              else (state0, jnp.zeros((G,), jnp.int32), est0))
    if mig is not None:
        carry0 = carry0 + (tier0, pend0)
    final, outs = jax.lax.scan(body, carry0, xs)
    (sums, hit, pref, part, fetched, issued, landed, deferred,
     shard_d, link_i, link_def) = outs[:11]
    state = final[0]
    info = {"hit": hit.T, "pref_hit": pref.T, "partial_hit": part.T,
            "fetched": fetched.T, "issued": issued.T, "landed": landed.T,
            "deferred": deferred.T,
            "shard_demand_fetches": shard_d,           # [T, G]
            "link_demand_fetches": shard_d.sum(axis=1),
            "link_prefetch_issued": link_i, "link_deferred": link_def}
    if cz is not None:
        info["est_q"] = final[2]                       # int32[S, G]
    if mig is not None:
        migd, promd, demd, mig_g, pf_g = outs[11:]
        info["migrated"] = migd.T                      # [S, T]
        info["promoted"] = promd.T                     # [S, T]
        info["demoted"] = demd                         # [T]
        info["mig_on_shard"] = mig_g                   # [T, G]
        info["pf_on_shard"] = pf_g                     # [T, G]
        state = dict(state, tier=final[len(final) - 2])
    return state, sums.T, info


@functools.partial(jax.jit,
                   static_argnames=("geom", "fabric", "chaos", "migration"))
def _consume_flat(cold, schedules, geom, fabric, chaos=None, migration=None):
    return _consume_impl(cold, schedules, geom, fabric, sharded=False,
                         chaos=chaos, migration=migration)


_SHARD_MAP_CACHE: dict = {}


def cached_shard_map(key: tuple, make_fn, in_specs):
    """Memoized ``jax.jit(shard_map(...))`` wrapper for one static topology.

    The single implementation of the §7 wrap idiom (cold sharded over the
    ``fabric`` axis, every other input and all outputs replicated,
    ``check_rep=False`` because the replication of the metadata scan is by
    construction, not provable) — the stream consume and the tiered sweep
    both build their mesh runners through it. ``key`` must start with the
    mesh and include a caller tag plus every static config the wrapped
    ``make_fn()`` closes over; entries live for the process, like jit's
    own executable cache.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if key not in _SHARD_MAP_CACHE:
        _SHARD_MAP_CACHE[key] = jax.jit(shard_map(
            make_fn(), mesh=key[0], in_specs=in_specs, out_specs=P(),
            check_rep=False))
    return _SHARD_MAP_CACHE[key]


def _consume_sharded_fn(mesh, geom, fabric: ShardedPoolCfg, chaos=None,
                        migration=None):
    """The jitted shard_map consume for one topology (memoized)."""
    from jax.sharding import PartitionSpec as P

    return cached_shard_map(
        (mesh, "consume", geom, fabric, chaos, migration),
        lambda: functools.partial(_consume_impl, geom=geom, fabric=fabric,
                                  sharded=True, chaos=chaos,
                                  migration=migration),
        (P("fabric"), P()))


def sharded_multi_stream_consume(cold, schedules: jax.Array, geom,
                                 fabric: ShardedPoolCfg, mesh=None,
                                 chaos=None, migration=None):
    """Concurrent streams over a mesh-sharded cold pool.

    Args:
      cold: ``[n_pages, page_elems]`` payload array or pytree of
        ``[n_pages, ...]`` leaves, in *original page-id order* (placement
        permutation is internal).
      schedules: ``int32[n_streams, T]`` demand page ids per stream.
      geom: :class:`repro.paging.prefetch_serving.PrefetchedStream`; the
        async issue/wait path is implied (``ring_size`` must be > 0) —
        per-NIC budgets arbitrate *landings*, which only exist with a ring.
      fabric: :class:`ShardedPoolCfg` topology.
      mesh: optional ``jax.sharding.Mesh`` with a ``"fabric"`` axis of size
        ``fabric.n_shards``; when given (and ``n_shards > 1``) the scan
        runs under ``shard_map`` — each device owns its home slice of
        ``cold`` and cross-shard pages move by ``lax.ppermute`` ring
        rotations. Without a mesh the same scheduling model runs against a
        local cold pool (bit-identical results, pinned).
      chaos: optional static :class:`repro.fabric.chaos.ChaosSpec` fault
        schedule (DESIGN.md §9). Adds ``info["est_q"] int32[S, n_shards]``
        (final Q8 deadline estimates). ``None`` = the clean fabric.
      migration: optional static
        :class:`repro.paging.lifecycle.MigrationCfg` (DESIGN.md §12) —
        turns on the three-tier lifecycle (online migration under the
        third §5 grant class, optionally a compressed cold tier). Adds
        ``info`` keys ``migrated``/``promoted`` ``int32[S, T]``,
        ``demoted int32[T]``, ``mig_on_shard``/``pf_on_shard``
        ``int32[T, n_shards]`` (per-NIC migration / prefetch grants — the
        demand-never-displaced witness), and the final lifecycle tables as
        ``state["tier"]``. ``None`` (or ``enabled=False``) compiles the
        exact two-tier path.

    Returns ``(state, data_sums, info)`` exactly like the §5 budgeted
    ``multi_stream_consume`` with additionally ``info["shard_demand_fetches"]
    int32[T, n_shards]`` (per-NIC demand traffic). Stream s is homed on
    shard ``s % n_shards`` (:func:`stream_homes`).
    """
    if geom.ring_size <= 0:
        raise ValueError("sharded consume needs the async issue/wait ring "
                         "(geom.ring_size > 0)")
    check_fabric_topology(geom.n_pages, fabric, mesh)
    migration = resolve(migration)
    if mesh is not None and fabric.n_shards > 1:
        placed = place_cold(cold, geom.n_pages, fabric)
        return _consume_sharded_fn(mesh, geom, fabric, chaos,
                                   migration)(placed, schedules)
    return _consume_flat(cold, schedules, geom, fabric, chaos, migration)
