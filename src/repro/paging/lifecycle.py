"""Three-tier page lifecycle policy: hot/cold classification + migration.

DESIGN.md §12. The pool layer (:mod:`repro.core.pool`) owns the lifecycle
*state* and transactions (``tier_init`` / ``tier_migrate`` / ``tier_demote``
/ ``tier_promote``); this module owns the *policy* that drives them:

* **Classification** rides the Leap trend detector (DESIGN.md §2): a page is
  *hot-ward* when a stream's detected trend will reach it just beyond the
  prefetch window (``page + trend * (pw_max + lead + j)``) — those are the
  migration proposals. A page is *cold* when its decayed access heat
  (``tier_touch`` / ``tier_heat_decay``) has drained to ``heat_cold`` —
  those are the demotion victims when the uncompressed tier is over
  capacity.
* **Hysteresis** is a per-page cooldown: any tier transition stamps
  ``last_mig``, and a page is neither proposed nor demoted again until
  ``cooldown`` steps later — a page oscillating at the hot/cold boundary
  migrates at most once per cooldown window (pinned in
  ``tests/test_migration.py``).
* **Arbitration** is the third, lowest class of the §5 demand-first per-NIC
  budget (:func:`repro.core.pool.link_grants_sharded`): a granted proposal
  re-homes the page toward its consumer out of capacity left after demand
  and prefetch. Like chaos re-homing (§9), migration is *scheduling
  metadata only* — the physical byte layout never moves, which is what
  keeps the flat and shard_map data planes bit-equal across migration.

Everything here is fixed-shape and order-independent so the jitted scan
(:mod:`repro.paging.sharded_pool`) and the Python lock-step twins
(:mod:`repro.fabric.shardstep` / ``linkstep``) can evaluate the same policy
and land on bit-identical decisions. :class:`PageLifecycle` is the
host-side NumPy mirror the continuous-batching serving engine drives
between decode steps.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pool import page_home, tier_init

_INT32_MAX = np.iinfo(np.int32).max


@dataclasses.dataclass(frozen=True)
class MigrationCfg:
    """Static policy knobs of the three-tier lifecycle (jit-static).

    Attributes:
      enabled:         master switch; ``False`` (or passing ``None`` for the
                       whole config) compiles the exact two-tier path.
      mig_per_stream:  migration proposals per stream per step (``M``).
      lead:            proposals target ``page + trend * (pw_max + lead + j)``
                       for ``j < M`` — just beyond the prefetch window, so a
                       migration granted next step re-homes the page before
                       the window reaches it.
      cooldown:        hysteresis window (steps): a page is neither proposed
                       nor demoted until ``cooldown`` steps after its last
                       tier transition.
      compressed:      enable the compressed cold tier (demotions).
      far_capacity:    max pages the *uncompressed* far tier holds; demotion
                       triggers while the uncompressed population exceeds
                       it. Required when ``compressed``.
      demote_per_step: max demotions per step (``D``).
      decompress_delay: extra arrival-delay steps charged on a prefetch of a
                       compressed page (the promote-from-compressed cost,
                       threaded into :func:`repro.core.pool.pool_issue`
                       deadlines).
      heat_access:     heat added per demand access of a page.
      heat_cold:       demotion eligibility threshold (``heat <= heat_cold``).
    """
    enabled: bool = True
    mig_per_stream: int = 2
    lead: int = 1
    cooldown: int = 16
    compressed: bool = False
    far_capacity: int | None = None
    demote_per_step: int = 4
    decompress_delay: int = 2
    heat_access: int = 8
    heat_cold: int = 0

    def __post_init__(self):
        if self.mig_per_stream < 1:
            raise ValueError("mig_per_stream must be >= 1")
        if self.lead < 1:
            raise ValueError("lead must be >= 1")
        if self.cooldown < 1:
            raise ValueError("cooldown must be >= 1")
        if self.compressed and self.far_capacity is None:
            raise ValueError("compressed tier needs far_capacity")
        if self.demote_per_step < 1:
            raise ValueError("demote_per_step must be >= 1")
        if self.decompress_delay < 0:
            raise ValueError("decompress_delay must be >= 0")


def resolve(migration: MigrationCfg | None) -> MigrationCfg | None:
    """Normalize the config: a disabled config is the same as ``None`` —
    both must compile the exact two-tier path (the off-flag reduction pin)."""
    if migration is not None and not migration.enabled:
        return None
    return migration


def propose_migrations(leap: dict, pages: jax.Array, homes_s: jax.Array,
                       tier: dict, t: jax.Array, n_pages: int, pw_max: int,
                       cfg: MigrationCfg):
    """Per-stream migration proposals from the post-step Leap trend.

    Proposals made at step ``t`` are granted at step ``t+1``'s grant phase
    (they ride the scan carry), and within a step grants precede issues —
    so ``lead >= 1`` guarantees the re-homed page is still outside the
    prefetch window when the stream first issues for it near.

    Args:
      leap:    the *updated* batched controller state of this step
               (``trend`` / ``has_trend`` per stream).
      pages:   ``int32[S]`` this step's demand pages.
      homes_s: ``int32[S]`` each stream's own shard (the migration dest).
      tier:    lifecycle state (:func:`repro.core.pool.tier_init`).
      t:       step clock.

    Returns ``(mpages, mdest, mvalid, mseq)``, each ``[S, M]``. Validity:
    the stream has a nonzero trend, the target is in range, not already
    homed on the stream's shard, and outside its cooldown window. ``mseq``
    is the global proposal order ``(t*S + s)*M + j`` (all distinct).
    """
    S = pages.shape[0]
    M = cfg.mig_per_stream
    js = jnp.arange(M, dtype=jnp.int32)
    step = leap["trend"]
    cand = (pages.astype(jnp.int32)[:, None]
            + step[:, None] * (jnp.int32(pw_max + cfg.lead) + js)[None, :])
    in_range = (cand >= 0) & (cand < n_pages)
    p_safe = jnp.clip(cand, 0, n_pages - 1)
    cool = (t - tier["last_mig"][p_safe]) >= cfg.cooldown
    valid = (leap["has_trend"][:, None] & (step[:, None] != 0) & in_range
             & (tier["home"][p_safe] != homes_s[:, None]) & cool)
    sid = jnp.arange(S, dtype=jnp.int32)
    seq = ((t * S + sid)[:, None] * M + js[None, :]).astype(jnp.int32)
    dest = jnp.broadcast_to(homes_s[:, None], (S, M))
    return p_safe, dest, valid, seq


def revalidate_proposals(mpages: jax.Array, mdest: jax.Array,
                         mvalid: jax.Array, mseq: jax.Array, tier: dict,
                         t: jax.Array, cfg: MigrationCfg):
    """Grant-phase re-validation + same-page dedupe of carried proposals.

    Re-reads the *current* lifecycle state (a demotion or another grant may
    have touched the page since propose time): still cross-shard, still
    outside cooldown. Then the arbiter's lowest-``seq``-wins rule: of
    several valid proposals for one page this step, only the lowest ``mseq``
    survives (order-independent — the twins apply the same rule by sorted
    order). Returns ``(mvalid', msrc)`` where ``msrc`` is each page's
    current home (the NIC its move occupies).
    """
    msrc = tier["home"][mpages]
    cool = (t - tier["last_mig"][mpages]) >= cfg.cooldown
    valid = mvalid & (msrc != mdest) & cool
    p = mpages.reshape(-1)
    v = valid.reshape(-1)
    s = mseq.reshape(-1)
    loses = jnp.any((p[None, :] == p[:, None]) & v[None, :]
                    & (s[None, :] < s[:, None]), axis=1)
    return (v & ~loses).reshape(valid.shape), msrc


def select_demotions(tier: dict, t: jax.Array, cfg: MigrationCfg):
    """Capacity-driven demotion victims: the coldest eligible pages.

    While the uncompressed population exceeds ``far_capacity``, up to
    ``demote_per_step`` pages are demoted per step, coldest first —
    eligible = uncompressed, ``heat <= heat_cold``, outside cooldown;
    ordered by ``(heat asc, page asc)`` (the composite key
    ``heat * n_pages + page`` is unique per page, so any argsort
    tie-breaking yields the same order — the twins sort the same key).
    Returns ``(pages int32[D], ok bool[D])`` with distinct pages where
    ``ok``.
    """
    n_pages = tier["home"].shape[0]
    D = cfg.demote_per_step
    comp, heat = tier["comp"], tier["heat"]
    n_uncomp = jnp.sum((~comp).astype(jnp.int32))
    cool = (t - tier["last_mig"]) >= cfg.cooldown
    eligible = ~comp & (heat <= cfg.heat_cold) & cool
    key = jnp.where(eligible,
                    heat * n_pages + jnp.arange(n_pages, dtype=jnp.int32),
                    jnp.int32(_INT32_MAX))
    order = jnp.argsort(key)[:D].astype(jnp.int32)
    need = jnp.clip(n_uncomp - jnp.int32(cfg.far_capacity), 0, D)
    ok = (jnp.arange(D, dtype=jnp.int32) < need) & eligible[order]
    return order, ok


# --------------------------------------------------------------------------
# host-side mirror for the serving engine
# --------------------------------------------------------------------------
class PageLifecycle:
    """NumPy mirror of the lifecycle the serving engine drives per step.

    The continuous-batching engine runs decode steps on device but makes
    admission/eviction decisions on host between steps; this class keeps the
    lifecycle tables host-side with the *same* formulas as the jitted scan
    (decay ``(h*3) >> 2``, cooldown hysteresis, coldest-first demotion) so
    the residency report and the device-threaded ``home_map``/``comp_map``
    stay one source of truth.

    The serving path only demotes pages the caller reports as safe
    (not hot-resident, not in flight), so no invalidation traffic is
    needed: the lossy :func:`repro.runtime.compression.page_roundtrip` is
    applied by the caller to the cold bytes of each returned victim, once,
    at demote time.
    """

    def __init__(self, n_pages: int, n_shards: int, placement: str,
                 cfg: MigrationCfg):
        self.n_pages, self.n_shards, self.cfg = n_pages, n_shards, cfg
        t0 = tier_init(n_pages, n_shards, placement)
        self.home = np.asarray(t0["home"]).copy()
        self.comp = np.zeros(n_pages, bool)
        self.heat = np.zeros(n_pages, np.int64)
        self.last_mig = np.full(n_pages, -(1 << 30), np.int64)
        self.migrations = self.demotions = self.promotions = 0
        self.t = 0

    def begin_step(self) -> None:
        self.heat = (self.heat * 3) >> 2
        self.t += 1

    def touch(self, pages) -> None:
        for p in np.asarray(pages, np.int64).ravel():
            if 0 <= p < self.n_pages:
                self.heat[p] += self.cfg.heat_access

    def migrate_toward(self, pages, dest: int) -> int:
        """Re-home ``pages`` to shard ``dest`` (cooldown-gated). Returns the
        number actually moved."""
        n = 0
        for p in np.asarray(pages, np.int64).ravel():
            if not 0 <= p < self.n_pages or self.home[p] == dest:
                continue
            if self.t - self.last_mig[p] < self.cfg.cooldown:
                continue
            self.home[p] = dest
            self.last_mig[p] = self.t
            n += 1
        self.migrations += n
        return n

    def promote(self, pages) -> int:
        """Clear the compressed bit on pages whose bytes just moved
        hot-ward. Returns the number that were compressed."""
        n = 0
        for p in np.asarray(pages, np.int64).ravel():
            if 0 <= p < self.n_pages and self.comp[p]:
                self.comp[p] = False
                n += 1
        self.promotions += n
        return n

    def demote_victims(self, safe_mask: np.ndarray | None = None) -> list[int]:
        """Pick + demote this step's victims; returns their page ids so the
        caller can round-trip the cold bytes. ``safe_mask`` (bool[n_pages])
        additionally restricts eligibility (e.g. not hot-resident)."""
        cfg = self.cfg
        if not cfg.compressed:
            return []
        n_uncomp = int(np.sum(~self.comp))
        need = min(cfg.demote_per_step, max(0, n_uncomp - cfg.far_capacity))
        if need <= 0:
            return []
        eligible = (~self.comp & (self.heat <= cfg.heat_cold)
                    & (self.t - self.last_mig >= cfg.cooldown))
        if safe_mask is not None:
            eligible &= safe_mask
        cand = np.nonzero(eligible)[0]
        cand = cand[np.argsort(self.heat[cand] * self.n_pages + cand)][:need]
        for p in cand:
            self.comp[p] = True
            self.last_mig[p] = self.t
        self.demotions += len(cand)
        return [int(p) for p in cand]

    def home_map(self) -> jax.Array:
        return jnp.asarray(self.home, jnp.int32)

    def comp_map(self) -> jax.Array:
        return jnp.asarray(self.comp)

    def report(self) -> dict:
        """Per-tier residency + lifecycle counters (the serve.py report)."""
        per_shard = [int(np.sum(self.home == g)) for g in range(self.n_shards)]
        return {
            "n_pages": self.n_pages,
            "uncompressed": int(np.sum(~self.comp)),
            "compressed": int(np.sum(self.comp)),
            "per_shard": per_shard,
            "migrations": self.migrations,
            "demotions": self.demotions,
            "promotions": self.promotions,
        }


def static_home_map(n_pages: int, n_shards: int, placement: str) -> jax.Array:
    """The t=0 home table (the static placement formula, materialized)."""
    return page_home(jnp.arange(n_pages, dtype=jnp.int32), n_pages, n_shards,
                     placement)
