"""MoE expert paging: Leap over the router's expert-id access stream.

For MoE archs the "page" is an expert's weight block living in the
disaggregated tier (EP-sharded or host-offloaded); the access stream is the
sequence of expert ids the router emits. Skewed/correlated routing (common
in practice) gives the stream structure Leap can exploit; uniform-random
routing is the Memcached case where Leap's contribution is *throttling* —
it stops prefetching instead of thrashing the buffer (paper §5.3.4).

``ExpertPrefetcher`` tracks one stream per (layer, slot) — the per-process
isolation of §4.1 — and exposes hit/pollution counters per stream. With
``async_datapath=True`` the expert-block fetches go through the issue/wait
in-flight ring (DESIGN.md §4): blocks speculated at routing step *t* arrive
during step *t+1*'s expert compute instead of stalling step *t*.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.paging.prefetch_serving import (PrefetchedStream,
                                           multi_stream_consume, stream_init,
                                           stream_step, stream_step_async)


@dataclasses.dataclass(frozen=True)
class ExpertPrefetcher:
    """Leap-managed hot buffer of expert weight blocks.

    Attributes:
      n_experts:   slow-tier size (router ids are ``int32`` in
                   ``[0, n_experts)``).
      n_hot:       experts resident at once (hot-buffer slots).
      block_elems: flattened expert weight block size (payload elements).
      pw_max:      prefetch-window cap — experts are big; keep it tight.
      async_datapath: fetch blocks via the issue/wait ring instead of the
                   blocking batched path (sync-vs-async contract of
                   :mod:`repro.paging.prefetch_serving`).
      ring_size:   in-flight ring capacity for the async path.
      link_budget: expert blocks/step the shared host link can move across
                   *all* concurrently consumed streams (DESIGN.md §5);
                   applies to :meth:`consume_route_traces`. ``None`` =
                   private infinite links per stream.
    """
    n_experts: int
    n_hot: int                   # experts resident at once
    block_elems: int             # flattened expert weight block size
    pw_max: int = 2              # experts are big; keep the window tight
    async_datapath: bool = False
    ring_size: int = 4
    link_budget: int | None = None

    def geom(self) -> PrefetchedStream:
        return PrefetchedStream(n_pages=self.n_experts, n_slots=self.n_hot,
                                page_elems=self.block_elems,
                                pw_max=self.pw_max, ring_size=self.ring_size)

    def init(self, dtype=jnp.float32) -> dict:
        """Fresh per-stream state (controller + hot buffer + ring)."""
        return stream_init(self.geom(), dtype)

    def _step(self):
        return stream_step_async if self.async_datapath else stream_step

    def fetch(self, state: dict, expert_weights: jax.Array,
              expert_id: jax.Array):
        """Serve one routed expert id; returns ``(state, block, info)``.

        ``expert_weights`` is ``[n_experts, block_elems]``; ``block`` is the
        ``[block_elems]`` payload, ``info`` the scalar-bool hit masks of
        :func:`repro.paging.prefetch_serving.stream_step`.
        """
        return self._step()(state, expert_weights, expert_id, self.geom())

    def consume_route_trace(self, state: dict, expert_weights: jax.Array,
                            ids: jax.Array):
        """Scan a ``int32[T]`` expert-id trace (one layer's routing).

        Returns ``(state, info)`` with ``[T]`` bool arrays ``hit`` /
        ``pref_hit`` / ``partial_hit`` (the last all-False on the sync path).
        """
        geom = self.geom()
        step_fn = self._step()

        def body(st, e):
            st, _, info = step_fn(st, expert_weights, e, geom)
            return st, (info["hit"], info["pref_hit"], info["partial_hit"])

        state, (hits, pref, partial) = jax.lax.scan(body, state, ids)
        return state, {"hit": hits, "pref_hit": pref, "partial_hit": partial}

    def consume_route_traces(self, expert_weights: jax.Array,
                             ids: jax.Array):
        """Consume ``int32[S, T]`` routing traces of S concurrent streams.

        One stream per (layer, slot) — §4.1 isolation — but all expert-block
        fetches share the host↔accelerator link: with ``link_budget`` set,
        demand block fetches are arbitrated first each routing step and
        surplus speculated blocks arrive late (``deferred``) — see
        :func:`repro.paging.prefetch_serving.multi_stream_consume`. Returns
        its ``(state, data_sums, info)`` (leading ``[S]`` axis).
        """
        return multi_stream_consume(expert_weights, ids, self.geom(),
                                    async_datapath=self.async_datapath,
                                    link_budget=self.link_budget)
