"""MoE expert paging: Leap over the router's expert-id access stream.

For MoE archs the "page" is an expert's weight block living in the
disaggregated tier (EP-sharded or host-offloaded); the access stream is the
sequence of expert ids the router emits. Skewed/correlated routing (common
in practice) gives the stream structure Leap can exploit; uniform-random
routing is the Memcached case where Leap's contribution is *throttling* —
it stops prefetching instead of thrashing the buffer (paper §5.3.4).

``ExpertPrefetcher`` tracks one stream per (layer, slot) — the per-process
isolation of §4.1 — and exposes hit/pollution counters per stream.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.leap_jax import leap_init, leap_step_batched
from repro.paging.prefetch_serving import PrefetchedStream, stream_init, stream_step


@dataclasses.dataclass(frozen=True)
class ExpertPrefetcher:
    """Leap-managed hot buffer of expert weight blocks."""
    n_experts: int
    n_hot: int                   # experts resident at once
    block_elems: int             # flattened expert weight block size
    pw_max: int = 2              # experts are big; keep the window tight

    def geom(self) -> PrefetchedStream:
        return PrefetchedStream(n_pages=self.n_experts, n_slots=self.n_hot,
                                page_elems=self.block_elems,
                                pw_max=self.pw_max)

    def init(self, dtype=jnp.float32) -> dict:
        return stream_init(self.geom(), dtype)

    def fetch(self, state: dict, expert_weights: jax.Array,
              expert_id: jax.Array):
        """Serve one routed expert id; returns (state, block, info)."""
        return stream_step(state, expert_weights, expert_id, self.geom())

    def consume_route_trace(self, state: dict, expert_weights: jax.Array,
                            ids: jax.Array):
        """Scan a [T] expert-id trace (one layer's routing over steps)."""
        geom = self.geom()

        def body(st, e):
            st, _, info = stream_step(st, expert_weights, e, geom)
            return st, (info["hit"], info["pref_hit"])

        state, (hits, pref) = jax.lax.scan(body, state, ids)
        return state, {"hit": hits, "pref_hit": pref}
