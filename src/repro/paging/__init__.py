"""Paging: paged KV cache, Leap-prefetched page streaming, expert paging.

The disaggregated-memory layer of the framework. ``kv_cache`` is the
vLLM-style paged KV pool (page dim mesh-shardable = the remote tier);
``prefetch_serving`` wires the jittable Leap controller + hot-buffer pool +
gather_pages kernel into a page-stream consumer, with a sync (blocking
batched fetch) and an async (issue/wait in-flight ring, DESIGN.md §4) data
path; ``expert_stream`` applies the same controller to MoE expert-id
streams (weight paging).
"""

from .kv_cache import (PageAllocator, append_kv, init_paged_kv,
                       linear_page_table, paged_decode_attention)
from .prefetch_serving import (PrefetchedStream, multi_stream_consume,
                               stream_consume, stream_init, stream_step,
                               stream_step_async, stream_stats)
from .expert_stream import ExpertPrefetcher

__all__ = ["PageAllocator", "append_kv", "init_paged_kv",
           "linear_page_table", "paged_decode_attention",
           "PrefetchedStream", "multi_stream_consume", "stream_consume",
           "stream_init", "stream_step", "stream_step_async", "stream_stats",
           "ExpertPrefetcher"]
