"""Paging: paged KV cache, Leap-prefetched page streaming, expert paging.

The disaggregated-memory layer of the framework. ``kv_cache`` is the
vLLM-style paged KV pool (page dim mesh-shardable = the remote tier);
``prefetch_serving`` wires the jittable Leap controller + hot-buffer pool +
gather_pages kernel into a page-stream consumer, with a sync (blocking
batched fetch) and an async (issue/wait in-flight ring, DESIGN.md §4) data
path; ``tiered_kv`` puts a Leap-managed HBM hot pool in front of the cold
KV pool and serves real decode attention from it (chunked demand sweep +
remapped page table, DESIGN.md §6); ``expert_stream`` applies the same
controller to MoE expert-id streams (weight paging).
"""

from .kv_cache import (PageAllocator, append_kv, init_paged_kv,
                       linear_page_table, paged_decode_attention)
from .prefetch_serving import (PrefetchedStream, multi_stream_consume,
                               stream_consume, stream_init, stream_step,
                               stream_step_async, stream_stats)
from .tiered_kv import (ATTN_KERNEL_MODES, TieredKV, normalize_attn_kernel,
                        tiered_attention, tiered_decode_step,
                        tiered_init, tiered_invalidate, tiered_min_slots,
                        tiered_reset_stream, tiered_slot_table,
                        tiered_slot_table_local, tiered_stats, tiered_sweep)
from .expert_stream import ExpertPrefetcher

__all__ = ["PageAllocator", "append_kv", "init_paged_kv",
           "linear_page_table", "paged_decode_attention",
           "PrefetchedStream", "multi_stream_consume", "stream_consume",
           "stream_init", "stream_step", "stream_step_async", "stream_stats",
           "ATTN_KERNEL_MODES", "TieredKV", "normalize_attn_kernel",
           "tiered_attention", "tiered_decode_step",
           "tiered_init", "tiered_invalidate", "tiered_min_slots",
           "tiered_reset_stream", "tiered_slot_table",
           "tiered_slot_table_local", "tiered_stats",
           "tiered_sweep", "ExpertPrefetcher"]
