"""Paged KV cache: pool + page table + append + attention.

Layout per layer stack: ``k_pool/v_pool [n_pages, page_size, Hkv, dh]`` with
the page dim shardable over the mesh — pages of a sequence's context live
round-robin across chips, which *is* the disaggregated memory pool of the
paper (each chip contributes "remote memory" for everyone else's sequences).
``page_table [B, n_pages_per_seq]`` maps logical to physical pages.

Two allocators:
* :func:`linear_page_table` — static round-robin layout for fixed-shape
  serving (dry-run / benchmarks): physical page = b * npps + j, interleaved
  so consecutive logical pages land on different shards.
* :class:`PageAllocator` — host-side free-list for the dynamic serving loop
  (continuous batching): O(1) alloc/free per page, no device sync.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import paged_attention


def init_paged_kv(n_layers: int, n_pages: int, page_size: int, n_kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16) -> dict:
    """Zeroed KV pool: ``{"k","v"}`` each ``[L, n_pages, page, Hkv, dh]``
    of ``dtype`` (default bf16). The page dim is the mesh-shardable
    disaggregated tier (see :func:`kv_pool_specs`)."""
    sh = (n_layers, n_pages, page_size, n_kv_heads, head_dim)
    return {"k": jnp.zeros(sh, dtype), "v": jnp.zeros(sh, dtype)}


def kv_pool_specs(n_layers: int) -> dict:
    """Logical axes: page dim sharded (the disaggregated tier)."""
    ax = ("layers", "pages", None, "kv_heads_s", None)
    return {"k": ax, "v": ax}


def linear_page_table(batch: int, n_pages_per_seq: int,
                      stride: int = 1) -> jax.Array:
    """Static allocation: seq b's logical page j -> b*npps + (j*stride % npps).

    ``stride`` spreads a sequence's logical pages over its physical range
    (consecutive logical pages land ``stride`` physical pages apart, e.g. on
    different shards). ``j -> j*stride % npps`` is a permutation of
    ``[0, npps)`` only when ``gcd(stride, npps) == 1``; any other stride
    collides physical pages within the sequence (stride=2, npps=4 maps
    logical pages to 0,2,0,2 — two logical pages silently sharing storage),
    so non-coprime strides are rejected.

    Returns ``int32[batch, n_pages_per_seq]`` of physical page ids.
    """
    if math.gcd(stride, n_pages_per_seq) != 1:
        raise ValueError(
            f"stride={stride} is not coprime with n_pages_per_seq="
            f"{n_pages_per_seq}: j*stride % npps would collide physical "
            "pages within a sequence")
    base = jnp.arange(batch)[:, None] * n_pages_per_seq
    return (base + (jnp.arange(n_pages_per_seq)[None, :] * stride)
            % n_pages_per_seq).astype(jnp.int32)


def append_kv(pool: dict, layer: jax.Array, k_new: jax.Array, v_new: jax.Array,
              page_table: jax.Array, pos: jax.Array) -> dict:
    """Write one token's K/V for every sequence at position ``pos``.

    ``k_new``/``v_new`` are ``[B, Hkv, dh]`` (cast to the pool dtype); pool
    leaves are ``[L, n_pages, page, Hkv, dh]``; ``layer``/``pos`` are scalar
    int32. Returns the updated pool dict (functional, jit/scan-safe).
    """
    page_size = pool["k"].shape[2]
    B = k_new.shape[0]
    logical = pos // page_size
    offset = pos % page_size
    phys = page_table[jnp.arange(B), logical]            # [B]

    def write(buf, new):
        return buf.at[layer, phys, offset].set(new.astype(buf.dtype))

    return {"k": write(pool["k"], k_new), "v": write(pool["v"], v_new)}


def paged_decode_attention(q: jax.Array, pool: dict, layer: jax.Array,
                           page_table: jax.Array, lengths: jax.Array, *,
                           use_kernel: bool = False) -> jax.Array:
    """Decode attention: ``q [B,1,Hq,dh]`` against layer ``layer``.

    ``page_table`` is ``int32[B, npps]``, ``lengths`` ``int32[B]`` valid
    context tokens per sequence. Returns ``[B, 1, Hq, dh]`` in q's dtype.
    """
    k_pool = pool["k"][layer]
    v_pool = pool["v"][layer]
    return paged_attention(q, k_pool, v_pool, page_table, lengths,
                           use_kernel=use_kernel)


@dataclasses.dataclass
class PageAllocator:
    """Host-side page free-list (control plane for continuous batching).

    Besides the free list it keeps two pieces of bookkeeping the serving
    engine's admission/eviction discipline leans on:

    * **occupancy introspection** — :meth:`alive` (live sequence ids),
      :attr:`free_count` and :meth:`occupancy`, so an admission policy can
      reserve capacity without poking at internals.
    * **reuse seq-stamps** — every allocation event bumps a monotone
      generation counter and stamps the handed-out pages with it
      (:meth:`stamp_of`). A physical page recycled from a finished request
      and re-allocated to a new one therefore carries a *different* stamp;
      trace events keyed by ``(page, stamp)`` can never alias the previous
      owner's lifecycle (the slot-reuse aliasing guard of DESIGN.md §10).
    """

    n_pages: int

    def __post_init__(self):
        self.free = list(range(self.n_pages - 1, -1, -1))
        self.owned: dict[int, list[int]] = {}
        self._stamp = [0] * self.n_pages
        self._next_stamp = 1

    def alloc_seq(self, seq_id: int, n: int) -> list[int]:
        if len(self.free) < n:
            raise MemoryError(f"pool exhausted: need {n}, have {len(self.free)}")
        pages = [self.free.pop() for _ in range(n)]
        self.owned.setdefault(seq_id, []).extend(pages)
        for p in pages:
            self._stamp[p] = self._next_stamp
        self._next_stamp += 1
        return pages

    def extend_seq(self, seq_id: int, n: int = 1) -> list[int]:
        return self.alloc_seq(seq_id, n)

    def free_seq(self, seq_id: int) -> int:
        pages = self.owned.pop(seq_id, [])
        self.free.extend(reversed(pages))
        return len(pages)

    def recycle(self, pages) -> int:
        """Forcibly reclaim ``pages`` from whichever sequences own them.

        The node-death path (DESIGN.md §9): when a shard dies, the pages it
        physically held are yanked out from under their sequences and
        returned to the free list so re-homed replacements can be allocated.
        Pages that are already free (or unknown) are skipped. Returns the
        number of pages actually reclaimed; the free list is extended in
        descending page order so subsequent allocs stay deterministic.
        """
        want = set(int(p) for p in pages) - set(self.free)
        reclaimed = []
        for seq_id, owned in self.owned.items():
            keep = [p for p in owned if p not in want]
            reclaimed.extend(p for p in owned if p in want)
            owned[:] = keep
        self.owned = {s: o for s, o in self.owned.items() if o}
        self.free.extend(sorted(reclaimed, reverse=True))
        return len(reclaimed)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self.free)

    @property
    def free_count(self) -> int:
        return len(self.free)

    def occupancy(self) -> float:
        """Fraction of the pool currently allocated (0.0 at baseline)."""
        return self.in_use / self.n_pages

    def alive(self) -> tuple[int, ...]:
        """Sequence ids that currently own at least one page, sorted."""
        return tuple(sorted(self.owned))

    def owner_of(self, page: int) -> int | None:
        """Sequence id owning ``page``, or None if free/unknown."""
        for seq_id, pages in self.owned.items():
            if page in pages:
                return seq_id
        return None

    def stamp_of(self, page: int) -> int:
        """Allocation-generation stamp of ``page`` (0 = never allocated).

        Strictly increases every time the page is handed out again, so a
        recycled page re-allocated to a new request never shares a stamp
        with its previous life.
        """
        return self._stamp[page]
