"""Tiered paged-KV serving: a Leap-managed HBM hot pool feeding decode attention.

This is the application-integrated data path the paper argues for (§4.2-4.4):
instead of a stand-alone page-stream simulator running beside the model, the
KV pages that decode attention actually reads live in a two-tier hierarchy —

* **cold tier**: the existing paged KV pool layer slice
  (``{"k","v"}: [n_pages, page_size, Hkv, dh]``, the mesh-shardable
  disaggregated side, :mod:`repro.paging.kv_cache`);
* **hot tier**: a small HBM-resident pool of slots *per request stream*
  (``{"k","v"}: [n_streams, n_slots, page_size, Hkv, dh]`` — the k and v
  leaves of a slot always move together), managed by the per-stream Leap
  controller exactly like the kernel-space page cache of the paper.

Access model (DESIGN.md §6): each decode step, every request *sweeps* its
context pages through the hot pool in chunks of ``geom.chunk`` pages — the
multi-page demand batch of :func:`repro.core.pool.pool_wait_batch` /
:func:`repro.core.pool.pool_access`. The sweep feeds the Leap controller,
whose candidates run ahead of the sweep frontier; on the async path they ride
the issue/wait in-flight ring and their DMA overlaps the next chunk's
compute. The hot tier retains pages under the *lazy* (LRU) eviction policy —
the residency window a consumer that reads pages **after** the sweep needs —
and once the sweep completes, attention runs directly over hot slots through
a remapped page table (:func:`tiered_slot_table`) into
:func:`repro.kernels.paged_attention.paged_attention`. Because the remapped
gather reads bit-identical bytes in the same logical order, tiered decode
logits are **bit-identical** to the flat-pool
:func:`repro.paging.kv_cache.paged_decode_attention` (pinned in
``tests/test_tiered_kv.py``).

The metadata transactions are metadata-only pool calls (``hot=None``); the
actual bytes move through the :mod:`repro.kernels.gather_pages` kernels —
the pipelined gather on the sync path, the explicit
``make_async_copy`` issue/wait double-buffer (:func:`gather_pages_async`) on
the async path — one batched kernel call per chunk step over all streams.

Write coherence: the serving loop appends new K/V into cold pages
(``append_kv``) every decode step; :func:`tiered_invalidate` must drop the
written page from each stream's hot tier (and in-flight ring) so a stale hot
copy never serves attention.

Streams advance in lock-step over chunk steps, so a finite ``link_budget``
composes with the DESIGN.md §5 arbitration unchanged: demand chunk fetches
complete in-step, leftover budget lands in-flight prefetches across all
streams in global issue order, the surplus defers in the ring.

The sweep also composes with the **mesh-sharded cold pool** (DESIGN.md §7,
:mod:`repro.paging.sharded_pool`): hot pools stay local per stream, the
cold ``{"k","v"}`` pool shards over the mesh's ``fabric`` axis. Pass a
:class:`repro.paging.sharded_pool.ShardedPoolCfg` (and a mesh) to
:func:`tiered_sweep` — the per-chunk budget becomes *per NIC* (one §5
arbiter per home shard), prefetch deadlines gain the near/far asymmetry,
and the chunk copy plans gather cross-shard pages with ``lax.ppermute``
ring rotations under ``shard_map``. ``shards=1`` (or no fabric) reduces
bit-exactly to the single-link path above.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.leap_jax import leap_init, leap_step
from repro.core.pool import (NO_PAGE, link_grants_sharded, page_home,
                             page_local, pool_access, pool_init,
                             pool_invalidate, pool_issue, pool_wait_batch,
                             ring_init)
from repro.core.window import DEFAULT_PW_MAX
from repro.kernels.gather_pages import gather_pages, gather_pages_async
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_hot_slots)
from repro.paging.prefetch_serving import stream_stats_at
from repro.paging.sharded_pool import (ShardedPoolCfg, cached_shard_map,
                                       check_fabric_topology,
                                       fabric_ring_gather, place_cold,
                                       scatter_hot, stream_homes)


@dataclasses.dataclass(frozen=True)
class TieredKV:
    """Static geometry of the tiered paged-KV cache.

    Attributes:
      n_pages:    cold-tier pages (shared by all streams; page ids are the
                  *physical* page-table values).
      n_slots:    hot slots per stream; must be at least
                  :func:`tiered_min_slots` of the sweep length so every
                  swept page is still resident when attention reads it.
      page_size:  tokens per KV page.
      n_kv_heads / head_dim: KV page payload shape.
      chunk:      demand pages per sweep step (the multi-page demand batch).
      pw_max / h_size / n_split: Leap controller knobs (see
                  :class:`repro.paging.prefetch_serving.PrefetchedStream`).
      ring_size:  async in-flight ring capacity; ``0`` degenerates the async
                  path to the sync one (same convention as the stream layer).
      arrival_delay: chunk steps between prefetch issue and arrival.
      use_kernel: move bytes through the Pallas gather kernels (True) or the
                  jnp reference gather (False — identical bytes, no kernel).
    """
    n_pages: int
    n_slots: int
    page_size: int
    n_kv_heads: int
    head_dim: int
    chunk: int = 4
    pw_max: int = DEFAULT_PW_MAX
    h_size: int = 32
    n_split: int = 8
    ring_size: int = 8
    arrival_delay: int = 1
    use_kernel: bool = True

    @property
    def page_shape(self) -> tuple[int, int, int]:
        return (self.page_size, self.n_kv_heads, self.head_dim)


def tiered_min_slots(npps: int, geom: TieredKV) -> int:
    """Hot-slot floor for a sweep of ``npps`` pages per decode step.

    The whole swept row must stay resident until attention reads it, plus
    headroom for one chunk's demand staging, the prefetch frontier running
    past the row, and in-flight landings — below this floor the lazy LRU
    can cannibalize the sweep and break the equivalence pin. Capped at
    ``n_pages``: a fully hot tier can never evict at all.
    """
    return min(npps + geom.chunk + max(geom.pw_max, geom.ring_size) + 2,
               geom.n_pages)


def tiered_init(geom: TieredKV, n_streams: int, dtype=jnp.bfloat16) -> dict:
    """Stacked per-stream tiered state (leading ``[n_streams]`` axis).

    Keys per stream: ``leap`` (controller), ``pool_meta``
    (:func:`repro.core.pool.pool_init`), ``ring``
    (:func:`repro.core.pool.ring_init`) and the hot payload
    ``hot = {"k","v"}: [n_slots, page_size, Hkv, dh]`` of ``dtype``.
    """
    kv = jnp.zeros((geom.n_slots,) + geom.page_shape, dtype)
    one = {
        "leap": leap_init(geom.h_size),
        "pool_meta": pool_init(geom.n_pages, geom.n_slots),
        "ring": ring_init(geom.ring_size),
        "hot": {"k": kv, "v": kv},
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_streams,) + x.shape).copy(), one)


def _apply_copies(hot: dict, cold: dict, src: jax.Array, dst: jax.Array,
                  mask: jax.Array, *, asynchronous: bool, use_kernel: bool,
                  fabric: ShardedPoolCfg | None = None,
                  sharded: bool = False, n_pages: int = 0) -> dict:
    """Data plane: move ``cold[src] -> hot[dst]`` where ``mask``, k+v together.

    ``src``/``dst``/``mask`` are ``[S, K]`` (per-stream copy plans from the
    metadata transactions); the cold tier is shared, so all streams' copies
    ride **one** gather kernel call per leaf — ``gather_pages`` (pipelined
    double-buffered DMA) on the sync path, ``gather_pages_async`` (explicit
    issue/wait pairs) on the async path — scattered into the stacked hot
    pool. Masked-out entries scatter out of bounds and are dropped.

    ``sharded=True`` (inside ``shard_map``, cold leaves ``[pps, ...]``
    home-major): the gather becomes a ring of ``lax.ppermute`` rotations
    over the ``fabric`` axis — each rotation runs the same gather kernel
    against the visiting shard's slice at :func:`repro.core.pool.page_local`
    indices and keeps the pages homed there (DESIGN.md §7). Bytes are
    bit-identical to the flat gather.
    """
    S = src.shape[0]
    gfn = gather_pages_async if asynchronous else gather_pages
    if not sharded:
        flat_src = jnp.maximum(src, 0).reshape(-1)
        gather = lambda c: gfn(c, flat_src, use_kernel=use_kernel)
    else:
        G = fabric.n_shards
        pps = n_pages // G
        homes = page_home(src, n_pages, G, fabric.placement).reshape(-1)
        local = jnp.clip(page_local(src, n_pages, G, fabric.placement),
                         0, pps - 1).reshape(-1)
        gather = lambda c: fabric_ring_gather(
            c, local, homes, G,
            lambda b, ix: gfn(b, ix, use_kernel=use_kernel))

    data = jax.tree.map(
        lambda c: gather(c).reshape((S, -1) + c.shape[1:]), cold)
    return scatter_hot(hot, data, dst, mask)


def _leap_chunk(leap: dict, pages: jax.Array, feedback: jax.Array,
                valid: jax.Array, geom: TieredKV):
    """Feed one chunk of demand accesses through the controller.

    Every valid page updates the tracker (history + FINDTREND + window);
    the emitted candidates are the *frontier's* — the last valid page of
    the chunk — so prefetching runs ahead of the sweep, not inside it.
    Returns ``(leap, candidates[pw_max], cand_valid[pw_max])``.
    """
    C = pages.shape[0]

    def body(lp, inp):
        page, fb, v = inp
        lp2, cands, cvalid = leap_step(lp, jnp.maximum(page, 0), fb,
                                       n_split=geom.n_split,
                                       pw_max=geom.pw_max)
        lp = jax.tree.map(lambda a, b: jnp.where(v, b, a), lp, lp2)
        return lp, (cands, cvalid & v)

    leap, (cands_all, cvalid_all) = jax.lax.scan(
        body, leap, (pages, feedback, valid))
    last = jnp.maximum(
        jnp.argmax(jnp.where(valid, jnp.arange(C, dtype=jnp.int32), -1)), 0)
    return leap, cands_all[last], cvalid_all[last] & jnp.any(valid)


def _chunk_sync(leap: dict, meta: dict, pages: jax.Array, geom: TieredKV):
    """One sync chunk step for one stream: controller first, then one
    blocking batched transaction carrying the chunk's demands *and* the
    frontier candidates (mirrors :func:`stream_step`, metadata-only)."""
    C = pages.shape[0]
    valid_d = pages >= 0
    p_safe = jnp.clip(pages, 0, geom.n_pages - 1)
    slot0 = meta["page_slot"][p_safe]
    s_safe = jnp.maximum(slot0, 0)
    was_pref = (valid_d & (slot0 >= 0) & meta["slot_prefetched"][s_safe]
                & ~meta["slot_consumed"][s_safe])
    leap, cands, cvalid = _leap_chunk(leap, pages, was_pref, valid_d, geom)

    req = jnp.concatenate([pages, cands])
    is_pf = jnp.concatenate([jnp.zeros((C,), bool),
                             jnp.ones((geom.pw_max,), bool)])
    val = jnp.concatenate(
        [valid_d, cvalid & (cands >= 0) & (cands < geom.n_pages)])
    meta, _, slots, info = pool_access(meta, None, None, req, is_pf, val,
                                       lazy=True)
    issued = jnp.sum(info["fetched"][C:].astype(jnp.int32))
    return leap, meta, slots, info, req, issued


def _chunk_async(leap: dict, meta: dict, ring: dict, pages: jax.Array,
                 land_ok: jax.Array, seq: jax.Array, home_s: jax.Array,
                 geom: TieredKV, fabric: ShardedPoolCfg, home_tab=None,
                 comp_tab=None, mig_delay: int = 0):
    """One async chunk step for one stream: wait (land + serve the chunk's
    demands), controller, issue (mirrors :func:`stream_step_async`,
    metadata-only). ``home_s`` is the stream's home shard — candidates
    homed there get ``fabric.near_delay`` deadlines, cross-shard ones
    ``fabric.far_delay`` (DESIGN.md §7; degenerate at one shard).

    ``home_tab`` (``int32[n_pages]``, the §12 lifecycle's time-varying home
    map) replaces the static placement formula for deadline routing;
    ``comp_tab`` (``bool[n_pages]``) adds the ``mig_delay`` decompress
    surcharge to candidates sitting in the compressed cold tier (the
    promote-from-compressed cost). Both ``None`` is the exact two-tier
    path."""
    now = ring["now"]
    valid_d = pages >= 0
    deferred0 = meta["n_deferred"]
    issued0 = meta["n_prefetch_issued"]
    meta, ring, _, slots, winfo = pool_wait_batch(
        meta, ring, None, None, pages, valid_d, now, lazy=True,
        land_ok=land_ok)
    fb = winfo["prefetched_hit"] | winfo["partial_hit"]
    leap, cands, cvalid = _leap_chunk(leap, pages, fb, valid_d, geom)
    cval = cvalid & (cands >= 0) & (cands < geom.n_pages)
    if home_tab is None:
        homes_c = page_home(cands, geom.n_pages, fabric.n_shards,
                            fabric.placement)
    else:
        homes_c = home_tab[jnp.clip(cands, 0, geom.n_pages - 1)]
    delay = jnp.where(homes_c == home_s, jnp.int32(fabric.near_delay),
                      jnp.int32(fabric.far_delay))
    if comp_tab is not None:
        delay = delay + jnp.where(
            comp_tab[jnp.clip(cands, 0, geom.n_pages - 1)],
            jnp.int32(mig_delay), jnp.int32(0))
    meta, ring = pool_issue(meta, ring, cands, cval, now, delay, seq=seq)
    ring = dict(ring)
    ring["now"] = now + 1
    issued = meta["n_prefetch_issued"] - issued0
    deferred = meta["n_deferred"] - deferred0
    return leap, meta, ring, slots, winfo, issued, deferred


def _sweep_fn(state: dict, cold: dict, sched: jax.Array, geom: TieredKV,
              async_datapath: bool, fabric: ShardedPoolCfg, sharded: bool,
              lifecycle: dict | None = None, mig_delay: int = 0):
    """Lock-step sweep over ``sched [n_chunks, S, chunk]``.

    ``fabric`` is always present: the single-link path is the degenerate
    one-shard fabric (whole budget on one NIC, every page near — reduces
    bit-exactly to the pre-§7 behavior). ``sharded=True`` means the
    function runs inside ``shard_map`` with ``cold`` leaves holding the
    local ``[pps, ...]`` home slice.

    ``lifecycle`` (``{"home": int32[n_pages], "comp": bool[n_pages]}``, the
    §12 tier maps the serving engine's :class:`PageLifecycle` maintains
    between steps) reroutes *scheduling* — budget arbitration, near/far
    deadlines (+``mig_delay`` on compressed pages), per-NIC demand
    accounting — while the data plane keeps gathering from the static
    placement (migration is scheduling metadata only, which is what keeps
    the flat and shard_map planes bit-equal).
    """
    n_chunks, S, C = sched.shape
    G = fabric.n_shards
    stream_ids = jnp.arange(S, dtype=jnp.int32)
    homes_s = stream_homes(S, G)
    home_tab = None if lifecycle is None else lifecycle["home"]
    comp_tab = None if lifecycle is None else lifecycle.get("comp")
    _homes = (lambda p: page_home(p, geom.n_pages, G, fabric.placement)) \
        if home_tab is None else \
        (lambda p: home_tab[jnp.clip(p, 0, geom.n_pages - 1)])

    def body(carry, pages):
        state, d_prev = carry                # pages: [S, C]; d_prev int32[G]
        leap, meta = state["leap"], state["pool_meta"]
        ring, hot = state["ring"], state["hot"]
        if async_datapath:
            now = ring["now"]                                # int32[S]
            if fabric.link_budget is not None:
                # per-NIC leftover budget: shard g's demand traffic last
                # chunk step comes off shard g's landing capacity
                caps = jnp.maximum(jnp.int32(fabric.link_budget) - d_prev, 0)
                homes_ring = _homes(ring["page"])
                ok = link_grants_sharded(ring, now, caps, homes_ring)
            else:
                ok = jnp.ones(ring["page"].shape, bool)
            # seq rides the persistent per-stream clock (not the per-call
            # chunk index) so entries surviving across tiered_sweep calls —
            # deferred or issued on the last chunk step — keep their global
            # FIFO rank and no two live entries ever share a stamp.
            seq = ((now * S + stream_ids)[:, None] * geom.pw_max
                   + jnp.arange(geom.pw_max, dtype=jnp.int32)[None, :])
            leap, meta, ring, slots, info, issued, deferred = jax.vmap(
                functools.partial(_chunk_async, geom=geom, fabric=fabric,
                                  home_tab=home_tab, comp_tab=comp_tab,
                                  mig_delay=mig_delay))(
                leap, meta, ring, pages, ok, seq, homes_s)
            # copy plan: landings first, then demand fetches (internal order)
            src = jnp.concatenate(
                [info["landed_pages"],
                 jnp.where(info["fetched"], pages, NO_PAGE)], axis=1)
            dst = jnp.concatenate([info["landed_slots"], slots], axis=1)
            mask = jnp.concatenate([info["landed"], info["fetched"]], axis=1)
            landed = jnp.sum(info["landed"].astype(jnp.int32), axis=1)
        else:
            leap, meta, slots, info, req, issued = jax.vmap(
                functools.partial(_chunk_sync, geom=geom))(leap, meta, pages)
            src, dst, mask = req, slots, info["fetched"]
            info = {"hit": info["hit"][:, :C],
                    "prefetched_hit": info["prefetched_hit"][:, :C],
                    "partial_hit": jnp.zeros((S, C), bool),
                    "fetched": info["fetched"][:, :C]}
            deferred = jnp.zeros((S,), jnp.int32)
            landed = issued      # sync: candidates land in their own chunk step
        hot = _apply_copies(hot, cold, src, dst, mask,
                            asynchronous=async_datapath,
                            use_kernel=geom.use_kernel,
                            fabric=fabric, sharded=sharded,
                            n_pages=geom.n_pages)
        state = {"leap": leap, "pool_meta": meta, "ring": ring, "hot": hot}
        cnt = lambda m: jnp.sum(m.astype(jnp.int32), axis=1)  # [S]
        d_t = cnt(info["fetched"])
        homes_d = _homes(pages)
        d_t_shard = jnp.zeros((G,), jnp.int32).at[homes_d.reshape(-1)].add(
            info["fetched"].reshape(-1).astype(jnp.int32), mode="drop")
        outs = (cnt(info["hit"]), cnt(info["prefetched_hit"]),
                cnt(info["partial_hit"]), d_t, issued, landed, deferred,
                jnp.sum(d_t), d_t_shard)
        return (state, d_t_shard), outs

    (state, _), (hit, pref, part, fetched, issued, landed, deferred, link_d,
                 shard_d) = jax.lax.scan(
        body, (state, jnp.zeros((G,), jnp.int32)), sched)
    info = {"hit": hit.T, "pref_hit": pref.T, "partial_hit": part.T,
            "fetched": fetched.T, "issued": issued.T, "landed": landed.T,
            "deferred": deferred.T,
            "link_demand_fetches": link_d,
            "shard_demand_fetches": shard_d}                  # [n_chunks, G]
    return state, info


_sweep_impl = jax.jit(_sweep_fn, static_argnames=("geom", "async_datapath",
                                                  "fabric", "sharded",
                                                  "mig_delay"))

def _sweep_sharded(mesh, geom: TieredKV, async_datapath: bool,
                   fabric: ShardedPoolCfg, with_lifecycle: bool = False,
                   mig_delay: int = 0):
    """The jitted shard_map sweep for one topology (memoized through
    :func:`repro.paging.sharded_pool.cached_shard_map`: cold sharded over
    the mesh's ``fabric`` axis, everything else replicated — including the
    §12 lifecycle maps, which only steer scheduling)."""
    from jax.sharding import PartitionSpec as P

    if with_lifecycle:
        return cached_shard_map(
            (mesh, "tiered_sweep_mig", geom, async_datapath, fabric,
             mig_delay),
            lambda: lambda state, cold, sched, lifecycle: _sweep_fn(
                state, cold, sched, geom, async_datapath, fabric, True,
                lifecycle, mig_delay),
            (P(), P("fabric"), P(), P()))
    return cached_shard_map(
        (mesh, "tiered_sweep", geom, async_datapath, fabric),
        lambda: functools.partial(_sweep_fn, geom=geom,
                                  async_datapath=async_datapath,
                                  fabric=fabric, sharded=True),
        (P(), P("fabric"), P()))


def tiered_sweep(state: dict, cold: dict, page_rows: jax.Array,
                 geom: TieredKV, *, async_datapath: bool = False,
                 link_budget: int | None = None,
                 fabric: ShardedPoolCfg | None = None,
                 mesh=None, home_map: jax.Array | None = None,
                 comp_map: jax.Array | None = None,
                 decompress_delay: int = 0) -> tuple[dict, dict]:
    """Sweep every stream's context pages through its hot pool, chunked.

    Args:
      state: stacked tiered state from :func:`tiered_init`.
      cold:  ``{"k","v"}: [n_pages, page_size, Hkv, dh]`` cold tier (one
             layer slice of the paged KV pool), in original page-id order.
      page_rows: ``int32[S, npps]`` physical page ids per stream (the
             page-table rows of the requests each stream serves; ``-1``
             entries are skipped).
      async_datapath: sync batched vs issue/wait chunk steps.
             ``geom.ring_size == 0`` degenerates async to sync (same
             convention as the stream layer).
      link_budget: optional pages/step the shared link moves across all
             streams' prefetches (DESIGN.md §5); demand chunks always
             complete in-step. Ignored when ``fabric`` is given (its
             ``link_budget`` — *per NIC* — takes over).
      fabric: optional :class:`repro.paging.sharded_pool.ShardedPoolCfg` —
             the cold pool is sharded over ``fabric.n_shards`` home shards
             (DESIGN.md §7): per-NIC §5 budgets, near/far prefetch
             deadlines (stream s homed on shard ``s % n_shards``).
      mesh:  optional ``jax.sharding.Mesh`` with a ``"fabric"`` axis of
             size ``fabric.n_shards``; the sweep then runs under
             ``shard_map`` with each device owning its home slice of
             ``cold`` and cross-shard chunk copies riding ``lax.ppermute``
             ring rotations. Without a mesh the same fabric scheduling
             model runs against the local cold pool (bit-identical).
      home_map: optional ``int32[n_pages]`` time-varying page→shard map
             (DESIGN.md §12, e.g. :meth:`PageLifecycle.home_map`): budget
             arbitration, near/far prefetch deadlines and per-NIC demand
             accounting read it instead of the static placement formula.
             The data plane still gathers from the static placement —
             migration is scheduling metadata only. ``None`` (default) is
             the exact pre-§12 path.
      comp_map: optional ``bool[n_pages]`` compressed-tier membership;
             prefetch candidates sitting compressed pay ``decompress_delay``
             extra chunk steps on their arrival deadline (the
             promote-from-compressed cost).

    Returns ``(state, info)`` with per-stream ``int32[S, n_chunks]`` counts
    ``hit`` / ``pref_hit`` / ``partial_hit`` / ``fetched`` / ``issued`` /
    ``landed`` / ``deferred`` plus the shared ``link_demand_fetches
    [n_chunks]`` and per-NIC ``shard_demand_fetches [n_chunks, n_shards]``
    (the count-granularity wire format
    :func:`repro.obs.trace.decode_sweep_events` expands into the
    page-lifecycle event log, DESIGN.md §8). After
    the sweep every valid page of ``page_rows`` is hot-resident, so
    :func:`tiered_attention` can serve decode attention from hot slots.
    """
    S, npps = page_rows.shape
    if geom.n_slots < tiered_min_slots(npps, geom):
        raise ValueError(
            f"n_slots={geom.n_slots} below tiered_min_slots("
            f"{npps} pages) = {tiered_min_slots(npps, geom)}: the swept row "
            "would not stay resident for attention")
    if async_datapath and geom.ring_size == 0:
        async_datapath = False
    if fabric is None:
        # degenerate one-shard fabric: whole budget on one NIC, every page
        # near — bit-exact reduction to the pre-§7 single-link sweep
        delay = max(geom.arrival_delay, 1)
        fabric = ShardedPoolCfg(
            n_shards=1, placement="interleave",
            link_budget=None if link_budget is None else int(link_budget),
            near_delay=delay, far_delay=delay)
    check_fabric_topology(geom.n_pages, fabric, mesh)
    C = geom.chunk
    n_chunks = -(-npps // C)
    pad = n_chunks * C - npps
    sched = jnp.concatenate(
        [page_rows.astype(jnp.int32),
         jnp.full((S, pad), NO_PAGE, jnp.int32)], axis=1)
    sched = sched.reshape(S, n_chunks, C).transpose(1, 0, 2)
    lifecycle = None
    if home_map is not None or comp_map is not None:
        if home_map is None:
            home_map = page_home(jnp.arange(geom.n_pages, dtype=jnp.int32),
                                 geom.n_pages, fabric.n_shards,
                                 fabric.placement)
        lifecycle = {"home": jnp.asarray(home_map, jnp.int32)}
        if comp_map is not None:
            lifecycle["comp"] = jnp.asarray(comp_map, bool)
    if mesh is not None and fabric.n_shards > 1:
        placed = place_cold(cold, geom.n_pages, fabric)
        if lifecycle is not None:
            return _sweep_sharded(mesh, geom, async_datapath, fabric,
                                  with_lifecycle=True,
                                  mig_delay=int(decompress_delay))(
                state, placed, sched, lifecycle)
        return _sweep_sharded(mesh, geom, async_datapath, fabric)(
            state, placed, sched)
    return _sweep_impl(state, cold, sched, geom, async_datapath, fabric,
                       False, lifecycle, int(decompress_delay))


def tiered_slot_table_local(state: dict, page_rows: jax.Array
                            ) -> tuple[jax.Array, jax.Array]:
    """Remap physical page ids to *per-stream* hot-slot ids.

    Returns ``(slot_table int32[S, npps], all_resident bool)``:
    ``slot_table[s, j]`` indexes stream s's own hot pool
    ``[n_slots, page, Hkv, dh]``, with ``-1`` for invalid page-table
    entries **and** non-resident pages — the form the fused
    :func:`repro.kernels.paged_attention.paged_attention_hot_slots` kernel
    consumes directly (its residency mask folds the ``all_resident`` guard
    into the softmax: a ``-1`` entry is masked, never silently read).
    ``all_resident`` is True iff every valid page of ``page_rows`` is
    hot-resident (a properly sized sweep guarantees it).
    """
    meta = state["pool_meta"]
    n_pages = meta["page_slot"].shape[-1]
    safe = jnp.clip(page_rows, 0, n_pages - 1)
    slots = jnp.take_along_axis(meta["page_slot"], safe, axis=1)
    valid = page_rows >= 0
    all_resident = jnp.all((slots >= 0) | ~valid)
    return jnp.where(valid, slots, -1).astype(jnp.int32), all_resident


def tiered_slot_table(state: dict, page_rows: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Remap physical page ids to stacked-hot-pool slot ids.

    Returns ``(slot_table int32[S, npps], all_resident bool)``:
    ``slot_table[s, j]`` indexes the flattened ``[S * n_slots]`` hot pool
    (stream s's slots live at ``s * n_slots + slot``) — the unfused
    stacked-pool form. ``all_resident`` is the equivalence guard — True
    iff every valid page of ``page_rows`` is hot-resident (a properly
    sized sweep guarantees it; attention output for non-resident pages
    would read unrelated slot bytes).
    """
    slots, all_resident = tiered_slot_table_local(state, page_rows)
    n_slots = jax.tree.leaves(state["hot"])[0].shape[1]
    S = page_rows.shape[0]
    gslots = (jnp.arange(S, dtype=jnp.int32)[:, None] * n_slots
              + jnp.maximum(slots, 0))
    return gslots.astype(jnp.int32), all_resident


ATTN_KERNEL_MODES = ("ref", "kernel", "fused", "fused_async")


def normalize_attn_kernel(mode) -> str:
    """Normalize an ``attn_kernel`` selector to one of
    :data:`ATTN_KERNEL_MODES`. Accepts the legacy bools (``False`` →
    ``"ref"``, ``True`` → ``"kernel"``) and CLI spellings
    (``"fused-async"`` → ``"fused_async"``)."""
    if mode is True:
        return "kernel"
    if mode is False or mode is None:
        return "ref"
    m = str(mode).replace("-", "_")
    if m not in ATTN_KERNEL_MODES:
        raise ValueError(
            f"attn_kernel={mode!r} not in {ATTN_KERNEL_MODES}")
    return m


def tiered_attention(q: jax.Array, state: dict, page_rows: jax.Array,
                     lengths: jax.Array, *,
                     attn_kernel: str | bool = "ref",
                     use_kernel: bool | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Decode attention served from the hot tier.

    ``q [S, 1, Hq, dh]``, ``lengths int32[S]``; ``attn_kernel`` selects the
    consumer (``use_kernel`` is the legacy bool alias):

    * ``"ref"`` / ``"kernel"`` — the **unfused** stacked path: the
      per-stream hot pools are copied into one flattened
      ``[S * n_slots, page, Hkv, dh]`` pool every call (a full hot-pool
      materialization) and attention runs through the remapped global
      table — identical shapes and identical bytes as the flat-pool
      :func:`repro.paging.kv_cache.paged_decode_attention`.
    * ``"fused"`` / ``"fused_async"`` — the **fused** path: attention
      reads the stacked per-stream hot pools *in place* through the local
      slot table (the ``[S, npps] → slot`` indirection composed inside the
      kernel's BlockSpec index maps), so no ``[S * n_slots, ...]`` pool is
      ever materialized; ``fused_async`` double-buffers K/V page tiles
      with explicit ``make_async_copy`` issue/wait pairs. Non-resident
      pages are masked in-kernel.

    All kernel modes execute the same per-page online-softmax op sequence,
    so on resident bytes their outputs are **bit-identical** to each other
    and to the flat-pool kernel (the tentpole equivalence pin). Returns
    ``(out [S, 1, Hq, dh], all_resident)``.
    """
    mode = normalize_attn_kernel(use_kernel if use_kernel is not None
                                 else attn_kernel)
    hot = state["hot"]
    if mode in ("fused", "fused_async"):
        table, ok = tiered_slot_table_local(state, page_rows)
        return paged_attention_hot_slots(
            q, hot["k"], hot["v"], table, lengths,
            async_copy=(mode == "fused_async")), ok
    table, ok = tiered_slot_table(state, page_rows)
    S, n_slots = hot["k"].shape[:2]
    hk = hot["k"].reshape((S * n_slots,) + hot["k"].shape[2:])
    hv = hot["v"].reshape((S * n_slots,) + hot["v"].shape[2:])
    return paged_attention(q, hk, hv, table, lengths,
                           use_kernel=(mode == "kernel")), ok


def tiered_decode_step(state: dict, cold: dict, q: jax.Array,
                       page_rows: jax.Array, lengths: jax.Array,
                       geom: TieredKV, *, async_datapath: bool = False,
                       link_budget: int | None = None,
                       fabric: ShardedPoolCfg | None = None, mesh=None,
                       attn_kernel: str | bool = False,
                       home_map: jax.Array | None = None,
                       comp_map: jax.Array | None = None,
                       decompress_delay: int = 0):
    """One tiered decode step: demand-sweep the context, attend over hot.

    ``attn_kernel`` is any :data:`ATTN_KERNEL_MODES` selector (or the
    legacy bool). Returns ``(state, out, info, all_resident)`` — see
    :func:`tiered_sweep` and :func:`tiered_attention`.
    """
    state, info = tiered_sweep(state, cold, page_rows, geom,
                               async_datapath=async_datapath,
                               link_budget=link_budget, fabric=fabric,
                               mesh=mesh, home_map=home_map,
                               comp_map=comp_map,
                               decompress_delay=decompress_delay)
    out, ok = tiered_attention(q, state, page_rows, lengths,
                               attn_kernel=attn_kernel)
    return state, out, info, ok


def tiered_invalidate(state: dict, pages: jax.Array) -> dict:
    """Drop ``pages int32[S, P]`` from each stream's hot tier + ring.

    Call after writing a cold page (``append_kv`` into the active tail
    page) so no stale hot copy or in-flight fetch of the old bytes serves
    a later attention read (write coherence, DESIGN.md §6). ``-1`` entries
    are ignored.
    """
    meta, ring = jax.vmap(lambda m, r, p: pool_invalidate(m, r, p, p >= 0))(
        state["pool_meta"], state["ring"], pages)
    return {**state, "pool_meta": meta, "ring": ring}


def tiered_reset_stream(state: dict, i: int, geom: TieredKV,
                        dtype=jnp.bfloat16) -> dict:
    """Return ``state`` with stream ``i`` cold-reset to a fresh init.

    The continuous-batching slot scheduler calls this when a finished
    sequence's slot is handed to a new request (DESIGN.md §10): the slot's
    Leap controller, pool metadata, in-flight ring and hot payload all
    restart from :func:`tiered_init` state so no stale page residency,
    in-flight fetch or trend history from the previous occupant can leak
    into the new request's stream. Other streams are untouched.
    """
    fresh = tiered_init(geom, 1, dtype)
    return jax.tree.map(lambda cur, f: cur.at[i].set(f[0]), state, fresh)


def tiered_stats(state: dict, i: int) -> dict:
    """Host-side :func:`repro.core.pool.pool_stats` of stream ``i``.

    The tiered state stacks the same ``pool_meta``/``ring`` keys as the
    multi-stream layer, so this is just
    :func:`repro.paging.prefetch_serving.stream_stats_at`.
    """
    return stream_stats_at(state, i)
