"""Int8 codecs: error-feedback gradient compression + the stateless page codec.

Two codecs share the int8-with-scale quantization scheme but serve different
subsystems, and the split matters (DESIGN.md §12.3):

* **Gradient path** (:func:`compress_int8` / :func:`compressed_psum`) —
  multi-pod training pays the pod-axis all-reduce over DCN (~25 GB/s/host vs
  ~50 GB/s/link ICI). Quantizing grads to int8 with per-leaf scales cuts that
  term 4x (fp32) / 2x (bf16); the quantization residual is carried into the
  next step (**error feedback**), which keeps SGD-style convergence —
  validated in tests on a quadratic + the tiny-LM integration run. Off by
  default; the launcher enables it with ``--grad-compression int8`` when the
  roofline says the collective term dominates (see DESIGN.md §12.3 and the
  README benchmark table).
* **Page codec** (:func:`compress_page` / :func:`decompress_page`) — the
  *stateless* backing store of the compressed cold tier (DESIGN.md §12): one
  int8 payload + one f32 scale per page, **no error feedback**. Pages are
  read back many times and out of order, so there is no "next step" to carry
  a residual into — the codec must be a pure function of the page bytes.
  Reconstruction error is bounded by ``scale/2`` per element and a
  compress→decompress→compress round trip is idempotent (pinned in
  ``tests/test_page_codec.py``); what the lifecycle pays instead of accuracy
  is *latency* — promoting a compressed page charges ``decompress_delay``
  extra steps on its ``pool_issue`` deadline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compress_int8(g: jax.Array, err: jax.Array):
    """-> (q int8, scale f32, new_err). Error feedback: q*scale + new_err ≈ g+err."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---- stateless page codec (compressed cold tier, DESIGN.md §12) -------------
def compress_page(page: jax.Array):
    """Quantize one page's payload to ``(q int8, scale f32 scalar)``.

    Stateless by design (no error feedback — see module docstring): the
    same page bytes always produce the same ``(q, scale)``, whatever was
    compressed before. ``scale = max|page|/127 + 1e-12``, so no element
    clips and every element reconstructs within ``scale/2``. Works on any
    float or integer payload dtype (bf16/f32 pinned in
    ``tests/test_page_codec.py``).
    """
    pf = page.astype(jnp.float32)
    scale = jnp.max(jnp.abs(pf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(pf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_page(q: jax.Array, scale: jax.Array, dtype=jnp.float32
                    ) -> jax.Array:
    """Inverse of :func:`compress_page` up to the ``scale/2`` bound."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def page_roundtrip(page: jax.Array) -> jax.Array:
    """Compress + decompress one page in place (same shape and dtype).

    This is what demotion to the compressed tier does to the cold bytes
    (DESIGN.md §12.3): the lossy round trip is applied *once, at demote
    time*, so every later reader — flat reference and tiered path alike —
    sees the same post-roundtrip bytes and the §6.4 bit-identity pin keeps
    holding with the compressed tier enabled.
    """
    q, scale = compress_page(page)
    return decompress_page(q, scale, dtype=page.dtype)


def compressed_psum(grads, err_state, axis_name: str):
    """Quantize -> psum(int32) -> dequantize, with error feedback state.

    The int8 payload is summed in int32 (no overflow for <= 2^23 workers);
    scales are averaged. Returns (mean grads, new err_state).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, scale, new_e = compress_int8(g, e)
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_mean = jax.lax.pmean(scale, axis_name)
        return (tot.astype(jnp.float32) * scale_mean / n).astype(g.dtype), new_e

    gl, treedef = jax.tree.flatten(grads)
    el = treedef.flatten_up_to(err_state)
    outs = [one(g, e) for g, e in zip(gl, el)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
