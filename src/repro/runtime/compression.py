"""Int8 gradient compression with error feedback (for DCN-bound all-reduce).

Multi-pod training pays the pod-axis all-reduce over DCN (~25 GB/s/host vs
~50 GB/s/link ICI). Quantizing grads to int8 with per-leaf scales cuts that
term 4x (fp32) / 2x (bf16); the quantization residual is carried into the
next step (error feedback), which keeps SGD-style convergence — validated in
tests on a quadratic + the tiny-LM integration run. Off by default; the
launcher enables it with ``--grad-compression int8`` when the roofline says
the collective term dominates (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compress_int8(g: jax.Array, err: jax.Array):
    """-> (q int8, scale f32, new_err). Error feedback: q*scale + new_err ≈ g+err."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, err_state, axis_name: str):
    """Quantize -> psum(int32) -> dequantize, with error feedback state.

    The int8 payload is summed in int32 (no overflow for <= 2^23 workers);
    scales are averaged. Returns (mean grads, new err_state).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, scale, new_e = compress_int8(g, e)
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_mean = jax.lax.pmean(scale, axis_name)
        return (tot.astype(jnp.float32) * scale_mean / n).astype(g.dtype), new_e

    gl, treedef = jax.tree.flatten(grads)
    el = treedef.flatten_up_to(err_state)
    outs = [one(g, e) for g, e in zip(gl, el)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
