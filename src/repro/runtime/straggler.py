"""Straggler detection: per-step EWMA timing with outlier flagging.

On a real pod every host runs this on its own step times; flagged hosts
are reported to the launcher which can demote them (drop from the data
mesh at the next elastic rescale) or pre-emptively reschedule. The data
pipeline's bounded PrefetchQueue handles the input-side stragglers.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StepTimeMonitor:
    alpha: float = 0.1           # EWMA smoothing
    threshold: float = 2.0       # flag if step > threshold * ewma
    warmup: int = 5

    def __post_init__(self):
        self.ewma = None
        self.count = 0
        self.flags = 0
        self.history: list[float] = []

    def record(self, dt: float) -> bool:
        """Record one step time; returns True if it's a straggler step."""
        self.history.append(dt)
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_outlier = (self.count > self.warmup
                      and dt > self.threshold * self.ewma)
        if is_outlier:
            self.flags += 1
        else:
            # outliers don't contaminate the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_outlier

    def summary(self) -> dict:
        return {"steps": self.count, "ewma": self.ewma,
                "straggler_steps": self.flags}
