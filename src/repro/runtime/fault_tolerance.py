"""Fault tolerance: step watchdog + bounded-retry restart-from-checkpoint.

The contract at 1000+ nodes: any worker can die at any step; the job must
resume from the last committed checkpoint with a bit-exact loss trajectory
(checkpoint carries params/opt/rng/data-state; data batches are pure
functions of step). ``run_with_restarts`` is the single-process harness of
that contract and is what the integration test kills mid-run; the multi-host
launcher wraps the same loop per host with its cluster manager.
"""

from __future__ import annotations

import threading
import time


class Watchdog:
    """Fires ``on_stall`` if ``beat()`` isn't called within ``timeout`` s.

    At scale: one watchdog per host; on_stall escalates to the cluster
    manager (kill + reschedule). Here it surfaces hangs in tests.
    """

    def __init__(self, timeout: float, on_stall=None):
        self.timeout = timeout
        self.on_stall = on_stall or (lambda: None)
        self._last = time.monotonic()
        self._stop = threading.Event()
        self.stalled = False
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def beat(self):
        self._last = time.monotonic()

    def _run(self):
        while not self._stop.is_set():
            if time.monotonic() - self._last > self.timeout:
                self.stalled = True
                self.on_stall()
                self._last = time.monotonic()
            time.sleep(min(0.05, self.timeout / 4))

    def stop(self):
        self._stop.set()
        # Join so no stale on_stall can fire after stop() returns (the old
        # daemon-thread leak made teardown racy under rapid test cycles).
        if self._thread.is_alive():
            self._thread.join(timeout=self.timeout + 1.0)


def run_with_restarts(make_state, train_one_step, save_state, restore_state,
                      n_steps: int, save_every: int, max_restarts: int = 3,
                      on_restart=None):
    """Drive training with checkpoint/restart semantics.

    make_state() -> state (fresh); restore_state() -> (state, step) or None;
    train_one_step(state, step) -> state  (may raise = node failure);
    save_state(state, step) -> None (atomic commit expected).

    Returns (state, restarts_used). Raises after ``max_restarts`` failures.
    """
    restarts = 0
    while True:
        restored = restore_state()
        if restored is None:
            state, step = make_state(), 0
        else:
            state, step = restored
        try:
            while step < n_steps:
                state = train_one_step(state, step)
                step += 1
                if step % save_every == 0 or step == n_steps:
                    save_state(state, step)
            return state, restarts
        except Exception:
            restarts += 1
            if on_restart:
                on_restart(restarts)
            if restarts > max_restarts:
                raise
