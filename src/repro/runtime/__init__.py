"""Runtime: fault tolerance, straggler mitigation, gradient compression."""

from .fault_tolerance import Watchdog, run_with_restarts
from .straggler import StepTimeMonitor
from .compression import (compress_int8, decompress_int8,
                          compressed_psum, init_error_feedback)

__all__ = ["Watchdog", "run_with_restarts", "StepTimeMonitor",
           "compress_int8", "decompress_int8", "compressed_psum",
           "init_error_feedback"]
