"""Jittable Leap controller — Alg. 1 + Alg. 2 fused, per-stream, batched.

This is the form of the paper's prefetcher that lives *inside* the jitted
``serve_step``/``train_step``: a fixed-shape state machine over int32 arrays
that consumes one slow-tier page access per step and emits up to ``PW_max``
prefetch candidates. Semantics are bit-exact to the NumPy
:class:`repro.core.prefetcher.LeapPrefetcher` (property-tested in
``tests/test_leap_jax.py``): history push -> FINDTREND (every fault; the
tracker maintains the current trend) -> GetPrefetchWindowSize -> DoPrefetch
with speculative fallback to the last-known trend.

State is a flat dict of arrays so it threads through ``lax.scan`` / pytree
checkpointing untouched; ``leap_step_batched`` vmaps over a leading stream
axis (per-request isolation = the paper's per-process isolation, §4.1).

Cost: O(H_size) int32 work per step (H=32 default) — noise next to a model
step; this is what makes "prefetcher in the hot loop" viable on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .history import DEFAULT_H_SIZE, init_history, push_history
from .trend import DEFAULT_N_SPLIT, trend_ladder
from .window import DEFAULT_PW_MAX, _round_up_pow2_jax


def leap_init(h_size: int = DEFAULT_H_SIZE, batch: tuple[int, ...] = ()) -> dict:
    """Fresh controller state (optionally batched over leading stream dims)."""
    z = lambda shape, dt: jnp.zeros(batch + shape, dt)
    state = init_history(h_size, batch)
    state.update(
        pw_prev=z((), jnp.int32),
        c_hit=z((), jnp.int32),
        trend=z((), jnp.int32),       # last Δ_maj found by FINDTREND
        has_trend=z((), jnp.bool_),
    )
    return state


def _find_trend_from(state: dict, n_split: int) -> tuple[jax.Array, jax.Array]:
    """FINDTREND ladder over the (already updated) history state.

    Delegates to :func:`repro.core.trend.trend_ladder` so the fused
    controller stays bit-equivalent to :func:`repro.core.trend.find_trend_jax`
    (including the final-rung clamp to the full history).
    """
    h_size = state["deltas"].shape[-1]
    idx = jnp.mod(state["head"] - jnp.arange(h_size), h_size)
    vals = state["deltas"][idx]                      # newest-first
    valid = jnp.arange(h_size) < state["count"]
    return trend_ladder(vals, valid, n_split)


@functools.partial(jax.jit, static_argnames=("n_split", "pw_max"))
def leap_step(state: dict, page: jax.Array, prefetched_hit: jax.Array,
              n_split: int = DEFAULT_N_SPLIT, pw_max: int = DEFAULT_PW_MAX,
              ) -> tuple[dict, jax.Array, jax.Array]:
    """One fault through the controller.

    Args:
      state: from :func:`leap_init` (unbatched here; vmap for streams).
      page: int32 page id of this slow-tier access.
      prefetched_hit: bool — did this access hit a *prefetched* cache entry.

    Returns ``(new_state, candidates[pw_max], valid[pw_max])`` where
    ``candidates[k] = page + step*(k+1)`` and ``valid`` masks the first
    ``PW_size`` of them (all False when prefetching is suspended).
    """
    state = dict(state)
    state["c_hit"] = state["c_hit"] + prefetched_hit.astype(jnp.int32)

    hist = {k: state[k] for k in ("deltas", "head", "count", "last_page", "has_last")}
    hist, delta = push_history(hist, page)
    state.update(hist)

    # FINDTREND every fault (tracker maintains the current trend).
    trend, found = _find_trend_from(state, n_split)
    cur_trend = jnp.where(found, trend, state["trend"])
    has_trend = state["has_trend"] | found

    # GetPrefetchWindowSize (Alg. 2 lines 5-16).
    follows = has_trend & (delta == cur_trend)
    c_hit, pw_prev = state["c_hit"], state["pw_prev"]
    cold = jnp.where(follows, 1, 0)
    grown = jnp.minimum(_round_up_pow2_jax(c_hit + 1), pw_max)
    grown = jnp.where(grown < pw_prev // 2, pw_prev // 2, grown)
    pw = jnp.where(c_hit == 0, cold, grown).astype(jnp.int32)

    state["pw_prev"] = pw
    state["c_hit"] = jnp.zeros_like(c_hit)
    state["trend"] = cur_trend
    state["has_trend"] = has_trend

    # DoPrefetch (Alg. 2 lines 19-27): along Δ_maj, else speculative.
    step = jnp.where(found, trend, cur_trend)
    can = (pw > 0) & has_trend & (step != 0)
    ks = jnp.arange(1, pw_max + 1, dtype=jnp.int32)
    candidates = page.astype(jnp.int32) + step * ks
    valid = can & (ks <= pw)
    return state, candidates, valid


def leap_step_batched(state: dict, pages: jax.Array, prefetched_hits: jax.Array,
                      n_split: int = DEFAULT_N_SPLIT, pw_max: int = DEFAULT_PW_MAX,
                      ) -> tuple[dict, jax.Array, jax.Array]:
    """Vmapped :func:`leap_step` over a leading [streams] axis."""
    fn = functools.partial(leap_step, n_split=n_split, pw_max=pw_max)
    return jax.vmap(fn)(state, pages, prefetched_hits)
