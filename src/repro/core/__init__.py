"""Leap's core: majority-trend prefetching, eager-eviction cache, two-tier pool.

NumPy references + trace simulator (paper figures) and jittable JAX twins
(in-step controller + pool) live side by side; property tests pin them equal.
"""

from .history import AccessHistory, DEFAULT_H_SIZE, init_history, push_history
from .trend import (DEFAULT_N_SPLIT, boyer_moore, find_trend, find_trend_jax)
from .window import DEFAULT_PW_MAX, PrefetchWindow, init_window_state
from .prefetcher import (LeapPrefetcher, NextNLinePrefetcher, NoPrefetcher,
                         PREFETCHERS, Prefetcher, ReadAheadPrefetcher,
                         StridePrefetcher, make_prefetcher)
from .cache import PageCache
from .metrics import PrefetchStats
from .simulator import (LATENCY_MODELS, LatencyModel, SimResult,
                        run_policy_matrix, simulate)
from .leap_jax import leap_init, leap_step, leap_step_batched
from .pool import pool_access, pool_init, pool_stats
from . import traces

__all__ = [
    "AccessHistory", "DEFAULT_H_SIZE", "DEFAULT_N_SPLIT", "DEFAULT_PW_MAX",
    "LATENCY_MODELS", "LatencyModel", "LeapPrefetcher", "NextNLinePrefetcher",
    "NoPrefetcher", "PageCache", "PREFETCHERS", "Prefetcher", "PrefetchStats",
    "PrefetchWindow", "ReadAheadPrefetcher", "SimResult", "StridePrefetcher",
    "boyer_moore", "find_trend", "find_trend_jax", "init_history",
    "init_window_state", "leap_init", "leap_step", "leap_step_batched",
    "make_prefetcher", "pool_access", "pool_init", "pool_stats",
    "push_history", "run_policy_matrix", "simulate", "traces",
]
