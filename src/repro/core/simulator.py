"""Trace-driven two-tier memory simulator with a calibrated latency model.

Replays a slow-tier access trace (``repro.core.traces``) against a
prefetch policy + page cache and reports the paper's metrics. The latency
constants are taken from the paper's own measurements (Fig. 1/2):

* 4 KB RDMA op            ≈ 4.3 µs   (fabric term, remote memory)
* 4 KB disk access        ≈ 91.5 µs  (fabric term, HDD)
* default block-layer path ≈ 34 µs extra, high variance (lognormal here)
* lean (Leap) data path   ≈ 1.2 µs extra, low variance
* cache hit               ≈ 0.8 µs  ("almost memory-speed")

plus TPU-flavored presets where the "fabric" is ICI/DCN and a page is a KV
block (see DESIGN.md §2). Prefetches are asynchronous but serialize on the
fabric link, so over-aggressive policies delay demand fetches — the paper's
"wasted I/O bandwidth" effect. An access to a still-in-flight page blocks
only for the residual transfer (partial hit, counted in
``stats.partial_hits``), like Linux's swap cache; prefetches whose transfer
never completed before the run ended are ``inflight_at_end``, not
pollution. These mirror the jitted async data path's issue/wait ring
(``repro.core.pool``, DESIGN.md §4), so the trace sim and the in-model
stream report comparable swap-cache partial-hit numbers.

``simulate`` runs one stream over the multi-tenant fabric engine
(``repro.fabric``, DESIGN.md §3) on a width-1 FIFO link; the original
sequential loop is retained as ``simulate_legacy``, the semantic reference
the engine is tested against. Multi-stream contention scenarios build a
``FabricScenario`` and call ``repro.fabric.run_fabric`` instead.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .cache import PageCache
from .metrics import PrefetchStats
from .prefetcher import Prefetcher


@dataclasses.dataclass
class LatencyModel:
    name: str = "rdma_lean"
    t_hit: float = 0.8              # cache-hit service time (µs)
    t_fabric: float = 4.3           # slow-tier fetch: launch + transfer (µs)
    t_xfer: float = 1.0             # bandwidth (serializing) share of t_fabric
    t_datapath: float = 1.2         # host data-path overhead mean (µs)
    datapath_sigma: float = 0.1     # lognormal sigma of the data-path overhead
    t_scan_unit: float = 0.01       # alloc-stall per scanned cache entry (µs)

    def datapath_cost(self, rng: np.random.Generator) -> float:
        if self.datapath_sigma <= 0:
            return self.t_datapath
        mu = np.log(self.t_datapath) - self.datapath_sigma ** 2 / 2
        return float(rng.lognormal(mu, self.datapath_sigma))


# Paper-calibrated presets (µs, 4KB pages) and TPU-flavored presets
# (µs, 32KB KV pages: 16 tok × 8 kv-heads × 128 dim × 2B ≈ 32 KB).
LATENCY_MODELS = {
    # default Linux block-layer path (Fig. 1: ~34µs overhead, high variance)
    "disk_block": LatencyModel("disk_block", 0.8, 91.5, 60.0, 34.0, 0.9, 0.01),
    "rdma_block": LatencyModel("rdma_block", 0.8, 4.3, 1.0, 34.0, 0.9, 0.01),
    # Leap's lean path (§4.4: block layer bypassed, per-core async queues)
    "disk_lean": LatencyModel("disk_lean", 0.8, 91.5, 60.0, 1.2, 0.1, 0.01),
    "rdma_lean": LatencyModel("rdma_lean", 0.8, 4.3, 1.0, 1.2, 0.1, 0.01),
    # TPU tiers: local HBM hit vs pool page over ICI (~50 GB/s/link) or DCN.
    "tpu_ici": LatencyModel("tpu_ici", 0.1, 1.64, 0.64, 0.3, 0.1, 0.002),
    "tpu_dcn": LatencyModel("tpu_dcn", 0.1, 13.1, 10.1, 0.3, 0.1, 0.002),
}


@dataclasses.dataclass
class SimResult:
    policy: str
    model: str
    stats: PrefetchStats
    total_time: float              # sim completion time (µs)
    link_busy: float               # fabric busy time (bandwidth consumed)
    scanned_entries: int           # kswapd-style scan work (LRU baseline)

    def summary(self) -> dict:
        s = self.stats.summary()
        s.update(policy=self.policy, model=self.model,
                 total_time=round(self.total_time, 1),
                 link_busy=round(self.link_busy, 1),
                 scanned_entries=self.scanned_entries)
        return s


def simulate(trace, prefetcher: Prefetcher, cache: PageCache,
             model: LatencyModel | str = "rdma_lean",
             think_time: float = 0.0, seed: int = 0) -> SimResult:
    """Replay ``trace`` through ``prefetcher`` + ``cache`` under ``model``.

    Thin wrapper over the multi-tenant fabric engine (``repro.fabric``):
    one tenant on a width-1 FIFO link, which reproduces the legacy loop
    (kept below as :func:`simulate_legacy`) operation-for-operation —
    pinned by ``tests/test_fabric.py``. Multi-stream contention scenarios
    should build a ``FabricScenario`` and call ``repro.fabric.run_fabric``.
    """
    from ..fabric.sim import run_single_stream
    return run_single_stream(trace, prefetcher, cache, model=model,
                             think_time=think_time, seed=seed)


def simulate_legacy(trace, prefetcher: Prefetcher, cache: PageCache,
                    model: LatencyModel | str = "rdma_lean",
                    think_time: float = 0.0, seed: int = 0) -> SimResult:
    """Reference implementation: the original strictly sequential loop.

    Retained as the semantic spec the fabric engine's single-tenant path
    is tested against (hit rate / coverage / completion-time equivalence).
    """
    if isinstance(model, str):
        model = LATENCY_MODELS[model]
    rng = np.random.default_rng(seed)
    stats = cache.stats
    now = 0.0
    link_free = 0.0                # busy-until time of the fabric link
    link_busy_total = 0.0

    for page in np.asarray(trace, dtype=np.int64):
        page = int(page)
        stats.faults += 1
        hit, pf_hit, wait = cache.lookup(page, now)
        if hit:
            stats.cache_hits += 1
            latency = model.t_hit + wait
        else:
            stats.misses += 1
            # demand fetch: data path + queue behind in-flight transfers
            start = max(now, link_free)
            done = start + model.t_xfer
            link_free = done
            link_busy_total += model.t_xfer
            stall_units = cache.insert_demand(page, now, done)
            latency = (model.datapath_cost(rng)
                       + (model.t_fabric - model.t_xfer)      # launch/latency part
                       + (done - now)                          # queue + transfer
                       + stall_units * model.t_scan_unit)
        # policy reacts to every fault (§4.1 page-access tracker semantics)
        for cand in prefetcher.on_fault(page, pf_hit):
            if cand < 0 or cand in cache:
                continue
            start = max(now, link_free)
            done = start + model.t_xfer
            if cache.insert_prefetch(cand, now, done):
                link_free = done                  # async, but consumes the link
                link_busy_total += model.t_xfer
        stats.latencies.append(latency)
        now += latency + think_time

    cache.drain_unconsumed(now)
    return SimResult(prefetcher.name, model.name, stats, now, link_busy_total,
                     cache.scanned_entries)


def run_policy_matrix(trace, policies: list[str], cache_capacity: int,
                      eviction_for: dict | None = None,
                      model: str = "rdma_lean", **policy_kwargs) -> dict:
    """Run several policies over one trace; returns {policy: SimResult}."""
    from .prefetcher import make_prefetcher

    eviction_for = eviction_for or {}
    out = {}
    for name in policies:
        pf = make_prefetcher(name, **policy_kwargs.get(name, {}))
        ev = eviction_for.get(name, "eager" if name == "leap" else "lru")
        cache = PageCache(cache_capacity, eviction=ev)
        out[name] = simulate(trace, pf, cache, model=model)
    return out
