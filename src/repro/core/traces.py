"""Synthetic slow-tier access-trace generators mirroring the paper's workloads.

The paper characterizes four applications' remote-page access streams (Fig. 3)
by the fraction of sequential / stride / other patterns inside fault windows
of length X ∈ {2,4,8}. We generate parameterized traces that reproduce those
mixes, plus the microbenchmark patterns of §2.2/§5.1:

* :func:`sequential` / :func:`stride` — the Fig. 2/7 microbenchmarks.
* :func:`phase_shift` — the worked example of Fig. 5 (trend flips mid-stream).
* :func:`interleaved` — multiple threads with different strides interleaved
  (the paper's motivating failure case for strict-pattern detectors, and the
  reason per-stream isolation matters for Fig. 13).
* :func:`powergraph_like` — mixed seq/stride/irregular segments (graph
  processing: long sequential edge scans + strided vertex gathers + random).
* :func:`numpy_matmul_like` — blocked two-operand matmul paging: mostly
  sequential with a periodic long back-jump at row boundaries.
* :func:`voltdb_like` — ~69% irregular, short sequential bursts (OLTP).
* :func:`memcached_like` — ~96% irregular (the Facebook-workload KV cache).

Every generator returns an ``np.int64`` array of page ids. ``classify_windows``
reproduces Fig. 3's categorization for validation.
"""

from __future__ import annotations

import numpy as np


def _rng(seed):
    return np.random.default_rng(seed)


# -- microbenchmarks ---------------------------------------------------------
def sequential(n: int, start: int = 0) -> np.ndarray:
    return np.arange(start, start + n, dtype=np.int64)


def stride(n: int, step: int = 10, start: int = 0) -> np.ndarray:
    return start + step * np.arange(n, dtype=np.int64)


def random_pages(n: int, space: int = 1 << 22, seed: int = 0) -> np.ndarray:
    return _rng(seed).integers(0, space, size=n, dtype=np.int64)


def phase_shift(n: int, deltas=(-3, 2), noise_every: int = 12, seed: int = 0,
                start: int = 1 << 16) -> np.ndarray:
    """Trend flips between ``deltas`` phases with sparse one-off noise (Fig. 5)."""
    rng = _rng(seed)
    out, page = [], start
    per_phase = max(4, n // len(deltas))
    i = 0
    for d in deltas:
        for _ in range(per_phase):
            if i >= n:
                break
            out.append(page)
            page += d
            if noise_every and i % noise_every == noise_every - 1:
                out[-1] += int(rng.integers(5, 50))  # transient irregularity
            i += 1
    while len(out) < n:
        out.append(page)
        page += deltas[-1]
    return np.asarray(out[:n], dtype=np.int64)


def interleaved(n: int, streams: int = 4, step: int = 7, seed: int = 0) -> np.ndarray:
    """Round-robin interleave of ``streams`` independent strided walkers."""
    bases = [(k + 1) << 20 for k in range(streams)]
    pages = []
    pos = list(bases)
    for i in range(n):
        s = i % streams
        pages.append(pos[s])
        pos[s] += step
    return np.asarray(pages, dtype=np.int64)


# -- application-like mixes ---------------------------------------------------
def _segmented(n: int, seed: int, seg_choices, seg_len_range,
               space: int = 1 << 22, noise: float = 0.0):
    """Concatenate segments drawn from (kind, param) choices with given probs.

    ``noise`` injects one-off transient irregularities *inside* regular
    segments (a random page, then the stream resumes) — the multi-threading
    interruptions of real applications that strict 2-fault detectors trip
    over and majority voting rides out (paper §2.3/§3.2).
    """
    rng = _rng(seed)
    kinds, probs = zip(*[(c[:2], c[2]) for c in seg_choices])
    probs = np.asarray(probs) / sum(probs)
    out = []
    page = int(rng.integers(0, space))

    def emit(p):
        if noise and rng.random() < noise:
            out.append(int(rng.integers(0, space)))   # transient interloper
        out.append(p)

    while len(out) < n:
        (kind, param) = kinds[int(rng.choice(len(kinds), p=probs))]
        seg = int(rng.integers(*seg_len_range))
        if kind == "seq":
            for _ in range(seg):
                emit(page)
                page += 1
        elif kind == "stride":
            st = param if param else int(rng.integers(2, 16))
            for _ in range(seg):
                emit(page)
                page += st
        else:  # random
            for _ in range(seg):
                page = int(rng.integers(0, space))
                out.append(page)
        if rng.random() < 0.3:  # occasional working-set jump
            page = int(rng.integers(0, space))
    return np.asarray(out[:n], dtype=np.int64)


def powergraph_like(n: int = 20000, seed: int = 1) -> np.ndarray:
    """Graph processing: ~60% sequential, ~20% stride, ~20% irregular at X=2,
    with multi-threaded one-off interruptions inside regular segments
    (paper Fig. 3: PowerGraph is mostly sequential at X=2, decaying by X=8)."""
    return _segmented(n, seed, [("seq", 0, 0.58), ("stride", 0, 0.20),
                                ("rand", 0, 0.22)], (6, 40), noise=0.08)


def numpy_matmul_like(n: int = 20000, rows: int = 64, seed: int = 2) -> np.ndarray:
    """Blocked matmul paging: sequential row sweeps + back-jumps per row."""
    rng = _rng(seed)
    out, page = [], 0
    b_base = 1 << 21
    while len(out) < n:
        for _ in range(rows):          # operand A row (sequential)
            if rng.random() < 0.03:    # GC / allocator interruption
                out.append(int(rng.integers(0, 1 << 22)))
            out.append(page)
            page += 1
        bcol = b_base + (len(out) // rows) % 97 * rows
        for k in range(rows // 4):     # operand B column (strided)
            out.append(bcol + k * rows)
    return np.asarray(out[:n], dtype=np.int64)


def voltdb_like(n: int = 20000, seed: int = 3) -> np.ndarray:
    """OLTP: ~69% irregular short transactions + small sequential bursts."""
    return _segmented(n, seed, [("rand", 0, 0.66), ("seq", 0, 0.26),
                                ("stride", 0, 0.08)], (2, 12), noise=0.05)


def memcached_like(n: int = 20000, seed: int = 4) -> np.ndarray:
    """KV cache: ~96% random single-page accesses, rare short runs."""
    return _segmented(n, seed, [("rand", 0, 0.95), ("seq", 0, 0.05)], (1, 6))


TRACES = {
    "sequential": lambda n=20000, **kw: sequential(n),
    "stride10": lambda n=20000, **kw: stride(n, 10),
    "phase_shift": phase_shift,
    "interleaved": interleaved,
    "powergraph": powergraph_like,
    "numpy": numpy_matmul_like,
    "voltdb": voltdb_like,
    "memcached": memcached_like,
}


# -- Fig. 3 classification -----------------------------------------------------
def classify_windows(pages: np.ndarray, x: int) -> dict:
    """Fraction of length-``x`` fault windows that are sequential / stride / other.

    sequential: all x pages consecutive (+1 deltas); stride: all x pages share
    one non-unit delta from the first page; other: anything else. Matches the
    paper's Fig. 3 definition.
    """
    pages = np.asarray(pages)
    n = len(pages) - x + 1
    if n <= 0:
        return {"sequential": 0.0, "stride": 0.0, "other": 0.0}
    seq = strd = 0
    for i in range(n):
        d = np.diff(pages[i:i + x])
        if np.all(d == 1):
            seq += 1
        elif d.size and np.all(d == d[0]) and d[0] != 0:
            strd += 1
    return {"sequential": seq / n, "stride": strd / n,
            "other": (n - seq - strd) / n}
