"""Two-tier paged pool: slow-tier page array + hot-buffer slot cache (jittable).

The accelerator-side analogue of the kernel page cache that Leap manages
(paper §2.2/§4.3), with the pool playing "remote memory" and the hot buffer
playing local DRAM:

* ``pool``: ``[n_pages, ...]`` array holding every page — in distributed use
  this is sharded across the mesh (the disaggregated tier); here it is the
  slow side of the two-tier hierarchy.
* ``hot``:  ``[n_slots, ...]`` small resident buffer the compute step reads.
* Metadata maps pages<->slots plus Leap's *eager eviction* bookkeeping: a
  free-slot stack and a FIFO ring of unconsumed prefetched slots
  (``PrefetchFifoLruList``). On the first hit of a prefetched slot the slot is
  freed in O(1) (metadata only — the data stays readable until reuse), so
  allocation never has to scan (paper: -36% page-allocation wait). Under
  pressure, unconsumed prefetches evict FIFO-first (§4.3).
* ``eviction='lazy'`` keeps consumed slots resident until pressure forces an
  LRU argmin scan — the kswapd baseline; benchmarks compare alloc-scan work.

All ops are fixed-shape and jit/scan-safe. The batch of page requests per call
is a fixed-size vector with a validity mask (misses = demand fetch, plus up to
``PW_max`` prefetch candidates from :mod:`repro.core.leap_jax`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NO_PAGE = jnp.int32(-1)
NO_SLOT = jnp.int32(-1)


def pool_init(n_pages: int, n_slots: int) -> dict:
    """Metadata state for an ``n_pages`` pool cached by ``n_slots`` hot slots."""
    return {
        "page_slot": jnp.full((n_pages,), NO_SLOT, jnp.int32),
        "slot_page": jnp.full((n_slots,), NO_PAGE, jnp.int32),
        "slot_prefetched": jnp.zeros((n_slots,), jnp.bool_),
        "slot_consumed": jnp.zeros((n_slots,), jnp.bool_),
        "slot_last_use": jnp.zeros((n_slots,), jnp.int32),
        # Free stack: free_stack[:free_top] are free slot ids (LIFO).
        "free_stack": jnp.arange(n_slots, dtype=jnp.int32)[::-1].copy(),
        "free_top": jnp.int32(n_slots),
        # FIFO ring of prefetched-not-yet-consumed slots (eviction order).
        "fifo": jnp.full((n_slots,), NO_SLOT, jnp.int32),
        "fifo_head": jnp.int32(0),   # oldest entry index
        "fifo_count": jnp.int32(0),
        "clock": jnp.int32(0),
        # Counters (paper §3.1 metrics, accumulated on-device).
        "n_hits": jnp.int32(0),
        "n_misses": jnp.int32(0),
        "n_prefetch_issued": jnp.int32(0),
        "n_prefetch_hits": jnp.int32(0),
        "n_pollution": jnp.int32(0),
        "n_alloc_scans": jnp.int32(0),
    }


def _free_push(st: dict, slot: jax.Array) -> dict:
    st = dict(st)
    st["free_stack"] = st["free_stack"].at[st["free_top"]].set(slot)
    st["free_top"] = st["free_top"] + 1
    return st


def _fifo_pop_oldest_valid(st: dict) -> tuple[dict, jax.Array]:
    """Pop the oldest FIFO entry that is still an unconsumed prefetch.

    Entries become stale when their slot was consumed (eager-freed) earlier;
    staleness is detected via slot_page/slot_prefetched. Bounded scan over the
    ring (n_slots is small: the hot buffer).
    """
    n = st["fifo"].shape[0]
    # Masked first-live search over ring order: compute each fifo entry's
    # liveness, take the first live one (stale entries skipped for free).
    order = jnp.mod(st["fifo_head"] + jnp.arange(n, dtype=jnp.int32), n)
    slots = st["fifo"][order]
    safe = jnp.maximum(slots, 0)
    live = ((slots >= 0)
            & (st["slot_page"][safe] >= 0)
            & st["slot_prefetched"][safe]
            & ~st["slot_consumed"][safe]
            & (jnp.arange(n) < st["fifo_count"]))
    any_live = jnp.any(live)
    first = jnp.argmax(live)                       # first True in ring order
    victim = jnp.where(any_live, slots[first], NO_SLOT)
    # Advance head past everything up to and including the victim (stale
    # entries are discarded for free).
    advance = jnp.where(any_live, first + 1, st["fifo_count"])
    st = dict(st)
    st["fifo_head"] = jnp.mod(st["fifo_head"] + advance, n)
    st["fifo_count"] = st["fifo_count"] - advance
    return st, victim


def _evict_for_alloc(st: dict, lazy: bool) -> tuple[dict, jax.Array]:
    """Produce one free slot when the free stack is empty."""
    if not lazy:
        st, victim = _fifo_pop_oldest_valid(st)
        # victim == -1 cannot happen if n_slots >= max in-flight prefetches + 1;
        # guard anyway by falling back to slot 0.
        victim = jnp.where(victim >= 0, victim, 0)
        st = dict(st)
        st["n_pollution"] = st["n_pollution"] + 1   # evicted before any hit
        return st, victim
    # Lazy/kswapd baseline: LRU argmin scan over all occupied slots.
    st = dict(st)
    occupied = st["slot_page"] >= 0
    key = jnp.where(occupied, st["slot_last_use"], jnp.iinfo(jnp.int32).max)
    victim = jnp.argmin(key).astype(jnp.int32)
    was_unconsumed_prefetch = (st["slot_prefetched"][victim]
                               & ~st["slot_consumed"][victim])
    st["n_pollution"] = st["n_pollution"] + was_unconsumed_prefetch.astype(jnp.int32)
    st["n_alloc_scans"] = st["n_alloc_scans"] + st["slot_page"].shape[0]
    return st, victim


def _unmap(st: dict, slot: jax.Array) -> dict:
    st = dict(st)
    old_page = st["slot_page"][slot]
    st["page_slot"] = jnp.where(
        old_page >= 0, st["page_slot"].at[jnp.maximum(old_page, 0)].set(NO_SLOT),
        st["page_slot"])
    st["slot_page"] = st["slot_page"].at[slot].set(NO_PAGE)
    st["slot_prefetched"] = st["slot_prefetched"].at[slot].set(False)
    st["slot_consumed"] = st["slot_consumed"].at[slot].set(False)
    return st


@functools.partial(jax.jit, static_argnames=("lazy",), donate_argnums=(0, 1))
def pool_access(st: dict, hot: jax.Array, pool: jax.Array,
                pages: jax.Array, is_prefetch: jax.Array, valid: jax.Array,
                lazy: bool = False) -> tuple[dict, jax.Array, jax.Array, dict]:
    """Service a fixed-size batch of page requests against the hot buffer.

    Args:
      st:   metadata from :func:`pool_init`.
      hot:  ``[n_slots, ...]`` hot buffer (donated, updated in place).
      pool: ``[n_pages, ...]`` slow tier.
      pages: ``int32[K]`` requested page ids (demand first, then candidates).
      is_prefetch: ``bool[K]`` — True for prefetch candidates.
      valid: ``bool[K]`` request mask.

    Returns ``(st, hot, slots, info)``: ``slots[K]`` is where each valid
    request's data now resides in ``hot``; ``info`` has per-request ``hit``
    and ``prefetched_hit`` masks.

    Slots eager-freed during this batch (consumed prefetches, demand staging)
    are *unmapped immediately* but only returned to the free stack at the end
    of the batch, so their data stays readable until the next call — the
    caller reads ``hot[slots]`` between calls. Callers should size
    ``n_slots >= 2*K`` so eviction never races a same-batch allocation.
    """
    K = pages.shape[0]

    def step(carry, k):
        st, hot = carry
        page = pages[k]
        req_valid = valid[k]
        pref = is_prefetch[k]
        st = dict(st)
        st["clock"] = st["clock"] + req_valid.astype(jnp.int32)

        slot0 = st["page_slot"][jnp.maximum(page, 0)]
        in_range = (page >= 0) & (page < st["page_slot"].shape[0])
        resident = req_valid & in_range & (slot0 >= 0)
        s_safe = jnp.maximum(slot0, 0)
        was_pref_hit = (resident & ~pref
                        & st["slot_prefetched"][s_safe] & ~st["slot_consumed"][s_safe])

        # ---- hit path (demand access only; prefetch of a resident page is a
        # no-op duplicate) ---------------------------------------------------
        demand_hit = resident & ~pref
        st["n_hits"] = st["n_hits"] + demand_hit.astype(jnp.int32)
        st["n_prefetch_hits"] = st["n_prefetch_hits"] + was_pref_hit.astype(jnp.int32)
        st["slot_consumed"] = jnp.where(
            demand_hit, st["slot_consumed"].at[s_safe].set(True), st["slot_consumed"])
        st["slot_last_use"] = jnp.where(
            demand_hit, st["slot_last_use"].at[s_safe].set(st["clock"]),
            st["slot_last_use"])
        if not lazy:
            # Eager eviction (§4.3): first hit of a prefetched slot frees it.
            # Unmap now; the slot id is emitted for a deferred free-stack push.
            un = _unmap(dict(st), s_safe)
            st = jax.tree.map(lambda a, b: jnp.where(was_pref_hit, b, a), st, un)

        # ---- miss path: allocate + copy --------------------------------------
        need_fetch = req_valid & in_range & ~resident
        have_free = st["free_top"] > 0
        # (a) from free stack
        top_slot = st["free_stack"][jnp.maximum(st["free_top"] - 1, 0)]
        # (b) else evict
        st_ev, victim = _evict_for_alloc(st, lazy)
        st_ev = _unmap(st_ev, victim)
        take_ev = need_fetch & ~have_free
        st = jax.tree.map(lambda a, b: jnp.where(take_ev, b, a), st, st_ev)
        slot_new = jnp.where(have_free, top_slot, victim)
        st["free_top"] = jnp.where(need_fetch & have_free,
                                   st["free_top"] - 1, st["free_top"])

        # map + copy
        def mapped(st):
            st = dict(st)
            st["page_slot"] = st["page_slot"].at[page].set(slot_new)
            st["slot_page"] = st["slot_page"].at[slot_new].set(page)
            st["slot_prefetched"] = st["slot_prefetched"].at[slot_new].set(pref)
            st["slot_consumed"] = st["slot_consumed"].at[slot_new].set(~pref)
            st["slot_last_use"] = st["slot_last_use"].at[slot_new].set(st["clock"])
            # prefetches enter the FIFO eviction ring
            tail = jnp.mod(st["fifo_head"] + st["fifo_count"], st["fifo"].shape[0])
            st["fifo"] = jnp.where(pref, st["fifo"].at[tail].set(slot_new), st["fifo"])
            st["fifo_count"] = st["fifo_count"] + pref.astype(jnp.int32)
            st["n_prefetch_issued"] = st["n_prefetch_issued"] + pref.astype(jnp.int32)
            st["n_misses"] = st["n_misses"] + (~pref).astype(jnp.int32)
            return st
        st_m = mapped(st)
        st = jax.tree.map(lambda a, b: jnp.where(need_fetch, b, a), st, st_m)
        hot = jnp.where(need_fetch,
                        hot.at[slot_new].set(pool[jnp.maximum(page, 0)]), hot)

        # Demand fetch under eager policy: consumed-on-arrival -> unmap now
        # (demand pages are never tracked by the cache, §4.3) and return the
        # staging slot to the free stack at end-of-batch.
        give_back = need_fetch & ~pref & (not lazy)
        if not lazy:
            st_back = _unmap(st, slot_new)
            st = jax.tree.map(lambda a, b: jnp.where(give_back, b, a), st, st_back)

        freed_slot = jnp.where(was_pref_hit, s_safe,
                               jnp.where(give_back, slot_new, NO_SLOT))
        out_slot = jnp.where(resident, slot0, jnp.where(need_fetch, slot_new, NO_SLOT))
        return (st, hot), (out_slot, resident, was_pref_hit, freed_slot)

    (st, hot), (slots, hits, pref_hits, freed) = jax.lax.scan(
        step, (st, hot), jnp.arange(K))

    # Deferred free-stack pushes (see docstring).
    def push_body(i, st):
        s = freed[i]
        stp = _free_push(st, jnp.maximum(s, 0))
        return jax.tree.map(lambda a, b: jnp.where(s >= 0, b, a), st, stp)

    st = jax.lax.fori_loop(0, K, push_body, st)
    return st, hot, slots, {"hit": hits, "prefetched_hit": pref_hits}


def pool_stats(st: dict) -> dict:
    """Python-side counter summary (paper §3.1)."""
    g = lambda k: int(st[k])
    issued, phits = g("n_prefetch_issued"), g("n_prefetch_hits")
    faults = g("n_hits") + g("n_misses")
    return {
        "faults": faults,
        "hits": g("n_hits"),
        "misses": g("n_misses"),
        "prefetch_issued": issued,
        "prefetch_hits": phits,
        "pollution": g("n_pollution"),
        "alloc_scans": g("n_alloc_scans"),
        "accuracy": phits / issued if issued else 0.0,
        "coverage": phits / faults if faults else 0.0,
    }
