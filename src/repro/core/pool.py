"""Two-tier paged pool: slow-tier page array + hot-buffer slot cache (jittable).

The accelerator-side analogue of the kernel page cache that Leap manages
(paper §2.2/§4.3), with the pool playing "remote memory" and the hot buffer
playing local DRAM:

* ``pool``: ``[n_pages, ...]`` array holding every page — in distributed use
  this is sharded across the mesh (the disaggregated tier); here it is the
  slow side of the two-tier hierarchy.
* ``hot``:  ``[n_slots, ...]`` small resident buffer the compute step reads.
* Metadata maps pages<->slots plus Leap's *eager eviction* bookkeeping: a
  free-slot stack and a FIFO ring of unconsumed prefetched slots
  (``PrefetchFifoLruList``). On the first hit of a prefetched slot the slot is
  freed in O(1) (metadata only — the data stays readable until reuse), so
  allocation never has to scan (paper: -36% page-allocation wait). Under
  pressure, unconsumed prefetches evict FIFO-first (§4.3).
* ``eviction='lazy'`` keeps consumed slots resident until pressure forces an
  LRU argmin scan — the kswapd baseline; benchmarks compare alloc-scan work.

All ops are fixed-shape and jit/scan-safe. The batch of page requests per call
is a fixed-size vector with a validity mask (misses = demand fetch, plus up to
``PW_max`` prefetch candidates from :mod:`repro.core.leap_jax`).

Two data paths share this metadata (DESIGN.md §4):

* **Synchronous** — :func:`pool_access`: demand page and prefetch candidates
  are fetched in one blocking batch; every byte lands on the critical path of
  the step that requested it. This is the legacy read-ahead-style path.
* **Asynchronous (issue/wait)** — :func:`pool_issue` enqueues candidates into
  a fixed-shape in-flight ring (:func:`ring_init`) with an *arrival deadline*
  (a step-clock value); :func:`pool_wait` lands everything whose deadline has
  passed and services one demand access. A demand access to a page still in
  the ring is a **partial hit**: it completes the transfer early and is
  charged only the residual (paper's swap-cache semantics, §4.2). Candidates
  issued at step *t* with ``delay=1`` land at the top of step *t+1* — the
  prefetch DMA overlaps the consumer's compute instead of blocking it.

The async pair also carries the hooks for the *shared-link budget
arbitration* layer (DESIGN.md §5): :func:`pool_issue` stamps each entry with
a global issue-order ``seq``, :func:`pool_wait` accepts a per-entry landing
grant (``land_ok``) computed by the arbiter (:func:`link_grants`) from the
per-step link budget, and entries that complete past their nominal deadline
count ``n_deferred``. Per-stream callers that never budget-gate can ignore
all three.

**Payloads are pytrees** (DESIGN.md §6): ``hot`` and ``pool`` may be single
arrays (the original contract), structured pytrees whose leaves share a
leading slot/page axis (e.g. ``{"k": ..., "v": ...}`` KV pages — the leaves
of one slot always move together), or ``None`` for *metadata-only*
transactions where the caller moves the bytes itself from the returned
copy plan (``slots`` + ``fetched``/``landed`` masks) — the tiered-KV layer
does exactly that through the :mod:`repro.kernels.gather_pages` kernels.
The wait path additionally supports a multi-page demand batch
(:func:`pool_wait_batch`) for chunked context sweeps, and
:func:`pool_invalidate` drops pages whose cold-tier bytes were mutated
(write coherence for the tiered KV cache).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NO_PAGE = jnp.int32(-1)
NO_SLOT = jnp.int32(-1)


# ---- home-shard metadata (DESIGN.md §7) -------------------------------------
# The cold pool may be sharded over a device mesh's ``fabric`` axis: every
# page has a *home shard* (the device whose HBM slice physically holds it,
# behind that device's NIC). Two placement policies:
#
# * ``"block"``      — shard g holds the contiguous id range
#                      ``[g*pps, (g+1)*pps)`` (pps = n_pages // n_shards).
# * ``"interleave"`` — page p lives on shard ``p % n_shards`` (consecutive
#                      ids round-robin across NICs).
#
# ``page_home``/``page_local`` are the single source of the mapping — the
# jitted sharded data path, the per-shard link arbiter and the lock-step
# fabric mirror (``repro.fabric.shardstep``) all call them.
#
# The mapping is *time-varying* under the three-tier lifecycle
# (DESIGN.md §12): online migration re-homes pages while a run is in
# flight. Callers that carry a dynamic home table (:func:`tier_init`) pass
# it as ``home_map`` and every scheduling decision reads the current
# assignment; ``home_map=None`` is the static placement formula. The
# *physical* byte layout never moves (``page_local`` + the home-major
# placement permutation stay placement-formula-only), which is what keeps
# the flat and shard_map data planes bit-equal across migration — re-homing
# is scheduling metadata, exactly like chaos node-loss re-homing (§9).

PLACEMENTS = ("block", "interleave")


def page_home(pages: jax.Array, n_pages: int, n_shards: int,
              placement: str, home_map: jax.Array | None = None) -> jax.Array:
    """Home shard of each page id (same shape; invalid ids map to shard of
    their clipped value — callers mask with their own validity).

    ``home_map`` (``int32[n_pages]``) is the time-varying assignment under
    the migration lifecycle; ``None`` evaluates the static placement
    formula."""
    if placement not in PLACEMENTS:
        raise ValueError(f"placement must be one of {PLACEMENTS}, "
                         f"got {placement!r}")
    p = jnp.clip(pages, 0, n_pages - 1)
    if home_map is not None:
        return home_map[p].astype(jnp.int32)
    if placement == "interleave":
        return jnp.mod(p, n_shards).astype(jnp.int32)
    return (p // (n_pages // n_shards)).astype(jnp.int32)


def page_local(pages: jax.Array, n_pages: int, n_shards: int,
               placement: str) -> jax.Array:
    """Index of each page within its home shard's ``[pps, ...]`` slice."""
    if placement not in PLACEMENTS:
        raise ValueError(f"placement must be one of {PLACEMENTS}, "
                         f"got {placement!r}")
    p = jnp.clip(pages, 0, n_pages - 1)
    if placement == "interleave":
        return (p // n_shards).astype(jnp.int32)
    return jnp.mod(p, n_pages // n_shards).astype(jnp.int32)


# ---- three-tier residency lifecycle (DESIGN.md §12) -------------------------
# Beyond hot-resident vs remote, every *cold* page now carries lifecycle
# metadata: its current (time-varying) home shard and whether its bytes sit
# in the uncompressed far tier or the compressed cold tier. The state is a
# flat dict of fixed-shape arrays (jit/scan-safe) with the same ownership
# rule as ``pool_init``: the pool layer owns the transactions, the stream /
# migration layers own the policy (``repro.paging.lifecycle``).
#
# Transactions are scatter-based and order-independent: callers pass a
# validity mask and invalid entries scatter out of range (``mode="drop"``),
# so the jitted scan and the Python lock-step twins can apply them in any
# equivalent order and land on bit-identical state.

# ``last_mig`` init: far enough in the past that the cooldown gate is open
# at t=0 but ``t - last_mig`` never overflows int32.
_TIER_NEVER = -(1 << 30)


def tier_init(n_pages: int, n_shards: int, placement: str) -> dict:
    """Lifecycle metadata for the three-tier residency model (DESIGN.md §12).

    * ``home int32[n_pages]`` — current home shard, seeded from the static
      ``placement`` formula and re-written by granted migrations. This is
      the table callers thread into :func:`page_home` as ``home_map``.
    * ``comp bool[n_pages]`` — True = the page's cold bytes live in the
      compressed tier (promote-from-compressed pays ``decompress_delay``).
    * ``heat int32[n_pages]`` — decayed access heat driving the hot/cold
      classifier (:func:`tier_touch` / :func:`tier_heat_decay`).
    * ``last_mig int32[n_pages]`` — step clock of the page's last tier
      transition; the hysteresis cooldown gates on ``now - last_mig``.
    * scalar counters ``n_migrations`` / ``n_demotions`` / ``n_promotions``.
    """
    pages = jnp.arange(n_pages, dtype=jnp.int32)
    return {
        "home": page_home(pages, n_pages, n_shards, placement),
        "comp": jnp.zeros((n_pages,), jnp.bool_),
        "heat": jnp.zeros((n_pages,), jnp.int32),
        "last_mig": jnp.full((n_pages,), _TIER_NEVER, jnp.int32),
        "n_migrations": jnp.int32(0),
        "n_demotions": jnp.int32(0),
        "n_promotions": jnp.int32(0),
    }


def _tier_scatter_idx(tier: dict, pages: jax.Array, ok: jax.Array) -> jax.Array:
    """Scatter index with invalid entries pushed out of range (dropped)."""
    n_pages = tier["home"].shape[0]
    return jnp.where(ok, jnp.clip(pages, 0, n_pages - 1), n_pages)


def tier_migrate(tier: dict, pages: jax.Array, dests: jax.Array,
                 ok: jax.Array, now: jax.Array) -> dict:
    """Re-home granted migrations and stamp the cooldown clock.

    ``pages``/``dests``/``ok`` are flat same-shape vectors; callers must
    have deduplicated same-step proposals for the same page (the arbiter's
    lowest-``seq``-wins rule) — duplicate granted pages in one call are a
    contract violation (scatter order would pick the winner arbitrarily).
    """
    idx = _tier_scatter_idx(tier, pages, ok)
    tier = dict(tier)
    tier["home"] = tier["home"].at[idx].set(
        dests.astype(jnp.int32), mode="drop")
    tier["last_mig"] = tier["last_mig"].at[idx].set(
        jnp.broadcast_to(jnp.asarray(now, jnp.int32), pages.shape),
        mode="drop")
    tier["n_migrations"] = tier["n_migrations"] + jnp.sum(ok.astype(jnp.int32))
    return tier


def tier_demote(tier: dict, pages: jax.Array, ok: jax.Array,
                now: jax.Array) -> dict:
    """Move cold pages into the compressed tier (metadata; the caller
    applies :func:`repro.runtime.compression.page_roundtrip` to the bytes).
    ``pages`` must be distinct where ``ok`` (selection emits distinct ids).
    """
    idx = _tier_scatter_idx(tier, pages, ok)
    tier = dict(tier)
    tier["comp"] = tier["comp"].at[idx].set(True, mode="drop")
    tier["last_mig"] = tier["last_mig"].at[idx].set(
        jnp.broadcast_to(jnp.asarray(now, jnp.int32), pages.shape),
        mode="drop")
    tier["n_demotions"] = tier["n_demotions"] + jnp.sum(ok.astype(jnp.int32))
    return tier


def tier_promote(tier: dict, pages: jax.Array, ok: jax.Array,
                 comp_pre: jax.Array | None = None) -> tuple[dict, jax.Array]:
    """Clear the compressed bit on pages whose bytes just moved hot-ward.

    Promotion is *on bytes moved* (a demand fetch or a prefetch landing of
    a compressed page), not a separate transfer. Counting reads
    ``comp_pre`` — the **start-of-step** snapshot of ``tier["comp"]`` — so
    two streams touching the same compressed page in one step each count a
    promotion (per-stream attribution) regardless of processing order;
    clearing the bit is idempotent. ``None`` snapshots the current table.
    Returns ``(tier, n_promoted)`` where ``n_promoted`` counts this call's
    promotions (``int32``).
    """
    if comp_pre is None:
        comp_pre = tier["comp"]
    n_pages = tier["home"].shape[0]
    p_safe = jnp.clip(pages, 0, n_pages - 1)
    promoted = ok & comp_pre[p_safe]
    idx = _tier_scatter_idx(tier, pages, ok)
    tier = dict(tier)
    tier["comp"] = tier["comp"].at[idx].set(False, mode="drop")
    n_new = jnp.sum(promoted.astype(jnp.int32))
    tier["n_promotions"] = tier["n_promotions"] + n_new
    return tier, n_new


def tier_heat_decay(tier: dict) -> dict:
    """One step of multiplicative heat decay: ``heat <- (heat*3) >> 2``.

    The ``(h*3) >> 2`` form decays all the way to zero in integer
    arithmetic (``3 -> 2 -> 1 -> 0``), unlike ``h - (h >> 2)`` which stalls
    at 3 — and it is bit-identical between int32 and Python ints, which the
    lock-step twins rely on.
    """
    tier = dict(tier)
    tier["heat"] = (tier["heat"] * 3) >> 2
    return tier


def tier_touch(tier: dict, pages: jax.Array, ok: jax.Array,
               amount: int) -> dict:
    """Scatter-add demand heat onto touched pages (duplicates accumulate —
    two streams touching one page heat it twice; order-independent)."""
    idx = _tier_scatter_idx(tier, pages, ok)
    tier = dict(tier)
    tier["heat"] = tier["heat"].at[idx].add(jnp.int32(amount), mode="drop")
    return tier


def tier_stats(tier: dict) -> dict:
    """Host-side residency summary of the lifecycle state. Not jittable."""
    comp = jnp.asarray(tier["comp"])
    return {
        "n_pages": int(comp.shape[0]),
        "uncompressed": int(jnp.sum(~comp)),
        "compressed": int(jnp.sum(comp)),
        "migrations": int(tier["n_migrations"]),
        "demotions": int(tier["n_demotions"]),
        "promotions": int(tier["n_promotions"]),
    }


def pool_init(n_pages: int, n_slots: int) -> dict:
    """Metadata state for an ``n_pages`` pool cached by ``n_slots`` hot slots.

    Returns a flat dict of fixed-shape int32/bool arrays (jit/scan-safe):
    ``page_slot int32[n_pages]`` (page -> slot or -1), ``slot_page
    int32[n_slots]`` (slot -> page or -1), per-slot ``prefetched`` /
    ``consumed`` flags, the free-slot LIFO stack, the FIFO eviction ring of
    unconsumed prefetches, and scalar int32 counters. Shared by the sync
    (:func:`pool_access`) and async (:func:`pool_issue` / :func:`pool_wait`)
    data paths; ``n_partial_hits`` only ever advances on the async path.
    """
    return {
        "page_slot": jnp.full((n_pages,), NO_SLOT, jnp.int32),
        "slot_page": jnp.full((n_slots,), NO_PAGE, jnp.int32),
        "slot_prefetched": jnp.zeros((n_slots,), jnp.bool_),
        "slot_consumed": jnp.zeros((n_slots,), jnp.bool_),
        "slot_last_use": jnp.zeros((n_slots,), jnp.int32),
        # Free stack: free_stack[:free_top] are free slot ids (LIFO).
        "free_stack": jnp.arange(n_slots, dtype=jnp.int32)[::-1].copy(),
        "free_top": jnp.int32(n_slots),
        # FIFO ring of prefetched-not-yet-consumed slots (eviction order).
        "fifo": jnp.full((n_slots,), NO_SLOT, jnp.int32),
        "fifo_head": jnp.int32(0),   # oldest entry index
        "fifo_count": jnp.int32(0),
        "clock": jnp.int32(0),
        # Counters (paper §3.1 metrics, accumulated on-device).
        "n_hits": jnp.int32(0),
        "n_misses": jnp.int32(0),
        "n_prefetch_issued": jnp.int32(0),
        "n_prefetch_hits": jnp.int32(0),
        "n_pollution": jnp.int32(0),
        "n_alloc_scans": jnp.int32(0),
        # Async-path only: demand accesses that completed a still-in-flight
        # prefetch early (swap-cache partial hits, DESIGN.md §4).
        "n_partial_hits": jnp.int32(0),
        # Budgeted-link only (DESIGN.md §5): prefetches that completed later
        # than their nominal arrival deadline because the shared link budget
        # was spent on demand fetches or earlier-issued prefetches.
        "n_deferred": jnp.int32(0),
    }


def ring_init(capacity: int) -> dict:
    """In-flight ring for the async issue/wait data path (DESIGN.md §4).

    ``capacity`` is the maximum number of prefetch fetches in flight at once
    (the depth of the paper's async RDMA queue). Fields:

    * ``page int32[capacity]``: in-flight page ids, ``-1`` = empty entry.
    * ``ready int32[capacity]``: step-clock *physical* arrival time — when
      the bytes are actually on the wire's far end. :func:`pool_wait` lands
      entries with ``ready <= now``. Under a shared link budget the ready
      time is the earliest possible arrival: budget-gated entries stay in
      the ring past it (DESIGN.md §5).
    * ``deadline int32[capacity]``: the *expected* arrival used purely for
      classification — entries completing past it count ``n_deferred``.
      In the clean fabric ``deadline == ready``; under chaos (DESIGN.md §9)
      the physical delay dilates while the deadline stays at the static
      expectation (or tracks the EWMA estimate when deadlines adapt).
    * ``seq int32[capacity]``: global issue order of each entry — the
      shared-link arbitration layer lands eligible entries across all
      streams in ascending ``seq`` (FIFO over the link). Plain per-stream
      callers can ignore it.
    * ``now int32``: the stream's step clock (owned by the stream layer;
      pool-level callers pass ``now`` explicitly).
    * ``n_drops int32``: issues rejected because the ring was full —
      back-pressure, *not* counted as issued.

    ``capacity == 0`` is the degenerate sync configuration: the stream layer
    bypasses the ring entirely and the async path pins bit-equivalent to
    :func:`pool_access` (tested in ``tests/test_paging.py``).
    """
    return {
        "page": jnp.full((capacity,), NO_PAGE, jnp.int32),
        "ready": jnp.zeros((capacity,), jnp.int32),
        "deadline": jnp.zeros((capacity,), jnp.int32),
        "issued_at": jnp.zeros((capacity,), jnp.int32),
        "seq": jnp.zeros((capacity,), jnp.int32),
        "now": jnp.int32(0),
        "n_drops": jnp.int32(0),
    }


def _free_push(st: dict, slot: jax.Array) -> dict:
    st = dict(st)
    st["free_stack"] = st["free_stack"].at[st["free_top"]].set(slot)
    st["free_top"] = st["free_top"] + 1
    return st


def _fifo_pop_oldest_valid(st: dict) -> tuple[dict, jax.Array]:
    """Pop the oldest FIFO entry that is still an unconsumed prefetch.

    Entries become stale when their slot was consumed (eager-freed) earlier;
    staleness is detected via slot_page/slot_prefetched. Bounded scan over the
    ring (n_slots is small: the hot buffer).
    """
    n = st["fifo"].shape[0]
    # Masked first-live search over ring order: compute each fifo entry's
    # liveness, take the first live one (stale entries skipped for free).
    order = jnp.mod(st["fifo_head"] + jnp.arange(n, dtype=jnp.int32), n)
    slots = st["fifo"][order]
    safe = jnp.maximum(slots, 0)
    live = ((slots >= 0)
            & (st["slot_page"][safe] >= 0)
            & st["slot_prefetched"][safe]
            & ~st["slot_consumed"][safe]
            & (jnp.arange(n) < st["fifo_count"]))
    any_live = jnp.any(live)
    first = jnp.argmax(live)                       # first True in ring order
    victim = jnp.where(any_live, slots[first], NO_SLOT)
    # Advance head past everything up to and including the victim (stale
    # entries are discarded for free).
    advance = jnp.where(any_live, first + 1, st["fifo_count"])
    st = dict(st)
    st["fifo_head"] = jnp.mod(st["fifo_head"] + advance, n)
    st["fifo_count"] = st["fifo_count"] - advance
    return st, victim


def _evict_for_alloc(st: dict, lazy: bool) -> tuple[dict, jax.Array]:
    """Produce one free slot when the free stack is empty."""
    if not lazy:
        st, victim = _fifo_pop_oldest_valid(st)
        # victim == -1 cannot happen if n_slots >= max in-flight prefetches + 1;
        # guard anyway by falling back to slot 0.
        victim = jnp.where(victim >= 0, victim, 0)
        st = dict(st)
        st["n_pollution"] = st["n_pollution"] + 1   # evicted before any hit
        return st, victim
    # Lazy/kswapd baseline: LRU argmin scan over all occupied slots.
    st = dict(st)
    occupied = st["slot_page"] >= 0
    key = jnp.where(occupied, st["slot_last_use"], jnp.iinfo(jnp.int32).max)
    victim = jnp.argmin(key).astype(jnp.int32)
    was_unconsumed_prefetch = (st["slot_prefetched"][victim]
                               & ~st["slot_consumed"][victim])
    st["n_pollution"] = st["n_pollution"] + was_unconsumed_prefetch.astype(jnp.int32)
    st["n_alloc_scans"] = st["n_alloc_scans"] + st["slot_page"].shape[0]
    return st, victim


def _unmap(st: dict, slot: jax.Array) -> dict:
    st = dict(st)
    old_page = st["slot_page"][slot]
    st["page_slot"] = jnp.where(
        old_page >= 0, st["page_slot"].at[jnp.maximum(old_page, 0)].set(NO_SLOT),
        st["page_slot"])
    st["slot_page"] = st["slot_page"].at[slot].set(NO_PAGE)
    st["slot_prefetched"] = st["slot_prefetched"].at[slot].set(False)
    st["slot_consumed"] = st["slot_consumed"].at[slot].set(False)
    return st


def _tree_where(cond: jax.Array, on_true: dict, on_false: dict) -> dict:
    """Select between two structurally identical state dicts elementwise."""
    return jax.tree.map(lambda b, a: jnp.where(cond, b, a), on_true, on_false)


def _check_batch_geometry(st: dict, K: int, lazy: bool, fn: str) -> None:
    """Trace-time enforcement of the per-batch hot-buffer floor.

    * **eager** (``lazy=False``): slots freed during a batch (consumed
      prefetches, demand staging) are only pushed back onto the free stack
      at the end of the call, so a batch of K requests can transiently pin
      up to ``2*K`` slots (K live + K pending frees). A smaller buffer
      forces the allocator to evict a slot whose deferred free is still
      queued — the later push then hands the same slot out twice and the
      page<->slot metadata silently corrupts.
    * **lazy** (LRU): nothing is freed mid-batch, but with fewer than
      ``K`` slots the LRU argmin must evict a slot *mapped earlier in the
      same batch* (everything older is already gone), clobbering data the
      caller was promised to read back — so the floor is ``K``.

    Shapes are static under jit: raises at trace time, never on device.
    """
    n_slots = st["slot_page"].shape[-1]
    if lazy:
        need, why = f"K={K}", "the lazy LRU would re-evict same-batch slots"
    else:
        need = f"2*K={2 * K}"
        why = "a batch can pin 2*K slots (live + deferred eager frees)"
    if n_slots < (K if lazy else 2 * K):
        raise ValueError(
            f"{fn}: n_slots={n_slots} < {need} — {why}; "
            "size the hot buffer up or split the batch")


# ---- payload pytree helpers -------------------------------------------------
# ``hot``/``pool`` payloads are pytrees whose leaves share a leading
# slot/page axis; a bare array is the single-leaf case and ``None`` is the
# metadata-only mode (every helper passes it through untouched).

def _payload_page(pool, page: jax.Array):
    """Read one page's payload from every leaf of the slow tier."""
    return jax.tree.map(lambda p: p[page], pool)


def _payload_store(hot, slot: jax.Array, val):
    """Write a page payload into hot slot ``slot`` across every leaf."""
    return jax.tree.map(lambda h, v: h.at[slot].set(v), hot, val)


def _payload_where(cond: jax.Array, on_true, on_false):
    return jax.tree.map(lambda b, a: jnp.where(cond, b, a), on_true, on_false)


def _alloc_slot(st: dict, lazy: bool) -> tuple[dict, jax.Array]:
    """Unconditionally produce one free, unmapped slot (stack pop or evict).

    Callers gate the returned state with :func:`_tree_where` when the
    allocation is conditional.
    """
    have_free = st["free_top"] > 0
    top_slot = st["free_stack"][jnp.maximum(st["free_top"] - 1, 0)]
    st_ev, victim = _evict_for_alloc(st, lazy)
    st_ev = _unmap(st_ev, victim)
    st = _tree_where(~have_free, st_ev, st)
    slot = jnp.where(have_free, top_slot, victim)
    st = dict(st)
    st["free_top"] = jnp.where(have_free, st["free_top"] - 1, st["free_top"])
    return st, slot


def _map_slot(st: dict, slot: jax.Array, page: jax.Array,
              pref: jax.Array) -> dict:
    """Map ``page`` into ``slot``; prefetches also enter the FIFO ring.

    Shared by the sync and async fetch paths — the bit-equivalence pin
    between them rides on this being the single mapping implementation.
    """
    st = dict(st)
    st["page_slot"] = st["page_slot"].at[page].set(slot)
    st["slot_page"] = st["slot_page"].at[slot].set(page)
    st["slot_prefetched"] = st["slot_prefetched"].at[slot].set(pref)
    st["slot_consumed"] = st["slot_consumed"].at[slot].set(~pref)
    st["slot_last_use"] = st["slot_last_use"].at[slot].set(st["clock"])
    tail = jnp.mod(st["fifo_head"] + st["fifo_count"], st["fifo"].shape[0])
    st["fifo"] = jnp.where(pref, st["fifo"].at[tail].set(slot), st["fifo"])
    st["fifo_count"] = st["fifo_count"] + pref.astype(jnp.int32)
    return st


@functools.partial(jax.jit, static_argnames=("lazy",), donate_argnums=(0, 1))
def pool_access(st: dict, hot: jax.Array, pool: jax.Array,
                pages: jax.Array, is_prefetch: jax.Array, valid: jax.Array,
                lazy: bool = False) -> tuple[dict, jax.Array, jax.Array, dict]:
    """Service a fixed-size batch of page requests against the hot buffer.

    Args:
      st:   metadata from :func:`pool_init`.
      hot:  ``[n_slots, ...]``-leaved payload pytree (donated, updated in
            place); ``None`` runs the transaction metadata-only — the caller
            applies the copy plan (``slots`` where ``info["fetched"]``)
            itself, e.g. through the gather_pages kernels.
      pool: ``[n_pages, ...]``-leaved slow tier (``None`` with ``hot=None``).
      pages: ``int32[K]`` requested page ids (demand first, then candidates).
      is_prefetch: ``bool[K]`` — True for prefetch candidates.
      valid: ``bool[K]`` request mask.

    Returns ``(st, hot, slots, info)``: ``slots[K]`` is where each valid
    request's data now resides in ``hot``; ``info`` has per-request ``hit``,
    ``prefetched_hit`` and ``fetched`` (request moved a page over the link)
    masks.

    Slots eager-freed during this batch (consumed prefetches, demand staging)
    are *unmapped immediately* but only returned to the free stack at the end
    of the batch, so their data stays readable until the next call — the
    caller reads ``hot[slots]`` between calls. Callers must size
    ``n_slots >= 2*K`` (eager; deferred frees can pin a second K) or
    ``>= K`` (lazy; the LRU must never re-evict a same-batch slot) —
    violating geometries raise at trace time instead of silently
    corrupting the page<->slot mapping.
    """
    K = pages.shape[0]
    _check_batch_geometry(st, K, lazy, "pool_access")

    def step(carry, k):
        st, hot = carry
        page = pages[k]
        req_valid = valid[k]
        pref = is_prefetch[k]
        st = dict(st)
        st["clock"] = st["clock"] + req_valid.astype(jnp.int32)

        slot0 = st["page_slot"][jnp.maximum(page, 0)]
        in_range = (page >= 0) & (page < st["page_slot"].shape[0])
        resident = req_valid & in_range & (slot0 >= 0)
        s_safe = jnp.maximum(slot0, 0)
        was_pref_hit = (resident & ~pref
                        & st["slot_prefetched"][s_safe] & ~st["slot_consumed"][s_safe])

        # ---- hit path (demand access only; prefetch of a resident page is a
        # no-op duplicate) ---------------------------------------------------
        demand_hit = resident & ~pref
        st["n_hits"] = st["n_hits"] + demand_hit.astype(jnp.int32)
        st["n_prefetch_hits"] = st["n_prefetch_hits"] + was_pref_hit.astype(jnp.int32)
        st["slot_consumed"] = jnp.where(
            demand_hit, st["slot_consumed"].at[s_safe].set(True), st["slot_consumed"])
        st["slot_last_use"] = jnp.where(
            demand_hit, st["slot_last_use"].at[s_safe].set(st["clock"]),
            st["slot_last_use"])
        if not lazy:
            # Eager eviction (§4.3): first hit of a prefetched slot frees it.
            # Unmap now; the slot id is emitted for a deferred free-stack push.
            un = _unmap(dict(st), s_safe)
            st = jax.tree.map(lambda a, b: jnp.where(was_pref_hit, b, a), st, un)

        # ---- miss path: allocate + map + copy (shared helpers; the sync /
        # async bit-equivalence pin rides on this code path) -------------------
        need_fetch = req_valid & in_range & ~resident
        st_f, slot_new = _alloc_slot(st, lazy)
        st_m = _map_slot(st_f, slot_new, page, pref)
        st_m["n_prefetch_issued"] = (st_m["n_prefetch_issued"]
                                     + pref.astype(jnp.int32))
        st_m["n_misses"] = st_m["n_misses"] + (~pref).astype(jnp.int32)
        st = jax.tree.map(lambda a, b: jnp.where(need_fetch, b, a), st, st_m)
        hot = _payload_where(
            need_fetch,
            _payload_store(hot, slot_new,
                           _payload_page(pool, jnp.maximum(page, 0))), hot)

        # Demand fetch under eager policy: consumed-on-arrival -> unmap now
        # (demand pages are never tracked by the cache, §4.3) and return the
        # staging slot to the free stack at end-of-batch.
        give_back = need_fetch & ~pref & (not lazy)
        if not lazy:
            st_back = _unmap(st, slot_new)
            st = jax.tree.map(lambda a, b: jnp.where(give_back, b, a), st, st_back)

        # Free on prefetched hit only under eager policy: lazy keeps the slot
        # mapped until LRU eviction, so pushing it would hand out a slot whose
        # stale page_slot entry still serves phantom hits.
        freed_slot = jnp.where(was_pref_hit & (not lazy), s_safe,
                               jnp.where(give_back, slot_new, NO_SLOT))
        out_slot = jnp.where(resident, slot0, jnp.where(need_fetch, slot_new, NO_SLOT))
        return (st, hot), (out_slot, resident, was_pref_hit, need_fetch,
                           freed_slot)

    (st, hot), (slots, hits, pref_hits, fetched, freed) = jax.lax.scan(
        step, (st, hot), jnp.arange(K))

    # Deferred free-stack pushes (see docstring).
    def push_body(i, st):
        s = freed[i]
        stp = _free_push(st, jnp.maximum(s, 0))
        return jax.tree.map(lambda a, b: jnp.where(s >= 0, b, a), st, stp)

    st = jax.lax.fori_loop(0, K, push_body, st)
    return st, hot, slots, {"hit": hits, "prefetched_hit": pref_hits,
                            "fetched": fetched}


@functools.partial(jax.jit, static_argnames=("lazy",), donate_argnums=(0, 1))
def pool_issue(st: dict, ring: dict, pages: jax.Array, valid: jax.Array,
               now: jax.Array, delay: jax.Array, lazy: bool = False,
               seq: jax.Array | None = None,
               true_delay: jax.Array | None = None,
               quota: jax.Array | None = None) -> tuple[dict, dict]:
    """Issue-phase of the async data path: enqueue prefetch candidates.

    Args:
      st:    pool metadata from :func:`pool_init`.
      ring:  in-flight ring from :func:`ring_init` (capacity >= 1).
      pages: ``int32[K]`` candidate page ids.
      valid: ``bool[K]`` request mask.
      now:   ``int32`` step clock of the issuing step.
      delay: ``int32`` scalar — or ``int32[K]`` *per-candidate* — steps
             until arrival; entries get ``deadline = now + delay`` and are
             landed by the first :func:`pool_wait` whose ``now`` reaches it
             (``delay=1`` = double-buffered: issued at *t*, consumable at
             *t+1*). The vector form carries the sharded fabric's near/far
             asymmetry (DESIGN.md §7): a candidate homed on the consumer's
             own shard arrives after ``near_delay`` steps, a cross-shard
             candidate after ``far_delay``. Clamped to >= 1: issue runs
             after the step's wait, so no landing can precede the next
             step's wait anyway, and an unreachable deadline in the past
             would miscount every landing as budget-``deferred``.
      seq:   optional ``int32[K]`` global issue-order stamps used by the
             shared-link arbitration layer (ascending across every issue on
             the link; see DESIGN.md §5). ``None`` stamps zeros — fine for
             per-stream callers that never budget-gate landings.
      true_delay: optional ``int32`` scalar or ``int32[K]`` — the *physical*
             steps until arrival when it differs from the expectation
             (chaos slowdown, DESIGN.md §9): entries get
             ``ready = now + true_delay`` while ``deadline = now + delay``
             stays the classification expectation. ``None`` (the clean
             fabric) means ``ready == deadline``. Clamped to >= 1 like
             ``delay``.
      quota: optional ``int32`` scalar — remaining elastic-grant headroom
             for this stream (chaos grants axis). Each take consumes one
             unit; candidates beyond the quota are dropped and counted in
             ``ring["n_drops"]`` exactly like a full ring. ``None`` = no
             grant cap.

    A candidate is enqueued only if it is in range, not hot-resident, and not
    already in flight (``n_prefetch_issued`` counts exactly the enqueued
    ones). A full ring drops the candidate and counts ``ring["n_drops"]``
    instead — issue back-pressure, never a blocking fetch.

    Returns ``(st, ring)``. No data moves here; the copy happens at landing
    time inside :func:`pool_wait`.
    """
    del lazy  # same issue semantics under both eviction policies
    if ring["page"].shape[0] == 0:
        return st, ring
    K = pages.shape[0]
    n_pages = st["page_slot"].shape[0]
    delay = jnp.broadcast_to(jnp.maximum(delay, 1), (K,))
    if true_delay is None:
        true_delay = delay
    else:
        true_delay = jnp.broadcast_to(jnp.maximum(true_delay, 1), (K,))
    if seq is None:
        seq = jnp.zeros((K,), jnp.int32)
    q0 = jnp.int32(1 << 30) if quota is None else jnp.asarray(quota, jnp.int32)

    def body(k, carry):
        st, ring, q = carry
        page = pages[k]
        in_range = (page >= 0) & (page < n_pages)
        p_safe = jnp.clip(page, 0, n_pages - 1)
        resident = st["page_slot"][p_safe] >= 0
        in_flight = jnp.any((ring["page"] == page) & (ring["page"] >= 0))
        want = valid[k] & in_range & ~resident & ~in_flight
        free_mask = ring["page"] < 0
        have_space = jnp.any(free_mask) & (q > 0)
        pos = jnp.argmax(free_mask)
        ring_new = dict(ring)
        ring_new["page"] = ring["page"].at[pos].set(p_safe)
        ring_new["ready"] = ring["ready"].at[pos].set(now + true_delay[k])
        ring_new["deadline"] = ring["deadline"].at[pos].set(now + delay[k])
        ring_new["issued_at"] = ring["issued_at"].at[pos].set(now)
        ring_new["seq"] = ring["seq"].at[pos].set(seq[k])
        take = want & have_space
        ring = _tree_where(take, ring_new, ring)
        st = dict(st)
        ring = dict(ring)
        st["n_prefetch_issued"] = st["n_prefetch_issued"] + take.astype(jnp.int32)
        ring["n_drops"] = ring["n_drops"] + (want & ~have_space).astype(jnp.int32)
        return st, ring, q - take.astype(jnp.int32)

    st, ring, _ = jax.lax.fori_loop(0, K, body, (st, ring, q0))
    return st, ring


def _land_due(st: dict, ring: dict, hot, pool, now: jax.Array, lazy: bool,
              land_ok: jax.Array | None):
    """Phase 1 of the wait path: land every due (and granted) ring entry.

    Returns ``(st, ring, hot, landed_pages, landed_slots, landed_issued)``
    where the three ``int32[capacity]`` arrays record which page landed into
    which hot slot this call and when that entry was issued (``-1`` = no
    landing at that ring position) — the landing half of the copy plan for
    metadata-only callers, plus the raw observations the chaos-deadline
    estimator consumes (``now - issued_at`` = realized delay, DESIGN.md §9).
    """
    R = ring["page"].shape[0]
    landed_pages = jnp.full((R,), NO_PAGE, jnp.int32)
    landed_slots = jnp.full((R,), NO_SLOT, jnp.int32)
    landed_issued = jnp.full((R,), -1, jnp.int32)
    if R == 0:
        return st, ring, hot, landed_pages, landed_slots, landed_issued
    if land_ok is None:
        land_ok = jnp.ones((R,), bool)

    def land(i, carry):
        st, ring, hot, lp, ls, li = carry
        p = ring["page"][i]
        due = (p >= 0) & (ring["ready"][i] <= now) & land_ok[i]
        p_safe = jnp.maximum(p, 0)
        resident = st["page_slot"][p_safe] >= 0
        commit = due & ~resident
        st_c, slot = _alloc_slot(st, lazy)
        st_c = dict(st_c)
        st_c["clock"] = st_c["clock"] + 1
        st_c = _map_slot(st_c, slot, p_safe, jnp.ones((), bool))
        hot_c = _payload_store(hot, slot, _payload_page(pool, p_safe))
        st = _tree_where(commit, st_c, st)
        hot = _payload_where(commit, hot_c, hot)
        lp = lp.at[i].set(jnp.where(commit, p_safe, NO_PAGE))
        ls = ls.at[i].set(jnp.where(commit, slot, NO_SLOT))
        li = li.at[i].set(jnp.where(commit, ring["issued_at"][i], -1))
        # A due entry whose page somehow became resident is dropped and
        # counted as pollution so the issue decomposition still sums.
        st = dict(st)
        st["n_pollution"] = st["n_pollution"] + (due & resident).astype(jnp.int32)
        # Landing past the deadline = deferred (link budget or a straggling
        # shard beat the expectation; classification only, DESIGN.md §5/§9).
        st["n_deferred"] = (st["n_deferred"]
                            + (due & (ring["deadline"][i] < now)).astype(jnp.int32))
        ring = dict(ring)
        ring["page"] = ring["page"].at[i].set(jnp.where(due, NO_PAGE, p))
        return st, ring, hot, lp, ls, li

    return jax.lax.fori_loop(0, R, land,
                             (st, ring, hot, landed_pages, landed_slots,
                              landed_issued))


def _serve_demand(st: dict, ring: dict, hot, pool, page: jax.Array,
                  now: jax.Array, lazy: bool):
    """Phase 2 of the wait path: serve one demand access.

    Shared by :func:`pool_wait` (single demand) and :func:`pool_wait_batch`
    (chunked demand); behavior-preserving extraction of the original
    ``pool_wait`` demand phase.
    """
    R = ring["page"].shape[0]
    n_pages = st["page_slot"].shape[0]
    in_range = (page >= 0) & (page < n_pages)
    p_safe = jnp.clip(page, 0, n_pages - 1)
    st = dict(st)
    st["clock"] = st["clock"] + in_range.astype(jnp.int32)
    slot0 = st["page_slot"][p_safe]
    resident = in_range & (slot0 >= 0)
    s_safe = jnp.maximum(slot0, 0)
    was_pref_hit = (resident & st["slot_prefetched"][s_safe]
                    & ~st["slot_consumed"][s_safe])
    if R > 0:
        match = (ring["page"] == page) & (ring["page"] >= 0)
        partial = in_range & ~resident & jnp.any(match)
        match_i = jnp.argmax(match)
        ring = dict(ring)
        ring["page"] = jnp.where(partial, ring["page"].at[match_i].set(NO_PAGE),
                                 ring["page"])
        # Early completion of an already-overdue (budget-gated) entry still
        # finished later than its nominal deadline: count it deferred.
        st["n_deferred"] = (st["n_deferred"]
                            + (partial
                               & (ring["deadline"][match_i] < now)).astype(jnp.int32))
    else:
        partial = jnp.zeros((), bool)
    miss = in_range & ~resident & ~partial

    # counters (partial hits count as cache hits *and* prefetch hits — the
    # simulator's swap-cache accounting, so both paths stay comparable)
    st["n_hits"] = st["n_hits"] + (resident | partial).astype(jnp.int32)
    st["n_prefetch_hits"] = (st["n_prefetch_hits"]
                             + (was_pref_hit | partial).astype(jnp.int32))
    st["n_partial_hits"] = st["n_partial_hits"] + partial.astype(jnp.int32)
    st["n_misses"] = st["n_misses"] + miss.astype(jnp.int32)

    # resident hit: consume; eager policy frees a prefetched slot on first hit
    st["slot_consumed"] = jnp.where(
        resident, st["slot_consumed"].at[s_safe].set(True), st["slot_consumed"])
    st["slot_last_use"] = jnp.where(
        resident, st["slot_last_use"].at[s_safe].set(st["clock"]),
        st["slot_last_use"])
    if not lazy:
        st_un = _unmap(dict(st), s_safe)
        st = _tree_where(was_pref_hit, st_un, st)

    # partial hit or miss: fetch now (partial = completing the in-flight DMA
    # early; only the residual is on the critical path — see pool_stats)
    need_fetch = partial | miss
    st_f, slot_new = _alloc_slot(st, lazy)
    st_f = _map_slot(st_f, slot_new, p_safe, jnp.zeros((), bool))
    hot_f = _payload_store(hot, slot_new, _payload_page(pool, p_safe))
    st = _tree_where(need_fetch, st_f, st)
    hot = _payload_where(need_fetch, hot_f, hot)

    # eager policy: demand pages are consumed-on-arrival and never tracked —
    # unmap now, return the staging slot at the end of the call
    give_back = need_fetch & (not lazy)
    if not lazy:
        st_back = _unmap(st, slot_new)
        st = _tree_where(give_back, st_back, st)

    freed = jnp.where(was_pref_hit & (not lazy), s_safe,
                      jnp.where(give_back, slot_new, NO_SLOT))
    st_p = _free_push(st, jnp.maximum(freed, 0))
    st = _tree_where(freed >= 0, st_p, st)

    out_slot = jnp.where(resident, slot0,
                         jnp.where(need_fetch, slot_new, NO_SLOT))
    data = jax.tree.map(lambda h: h[jnp.maximum(out_slot, 0)], hot)
    info = {"hit": resident, "prefetched_hit": was_pref_hit,
            "partial_hit": partial, "fetched": need_fetch}
    return st, ring, hot, out_slot, data, info


@functools.partial(jax.jit, static_argnames=("lazy",), donate_argnums=(0, 1, 2))
def pool_wait(st: dict, ring: dict, hot: jax.Array, pool: jax.Array,
              page: jax.Array, now: jax.Array, lazy: bool = False,
              land_ok: jax.Array | None = None,
              ) -> tuple[dict, dict, jax.Array, jax.Array, jax.Array, dict]:
    """Wait-phase of the async data path: land arrivals, serve one demand.

    Args:
      st:   pool metadata from :func:`pool_init`.
      ring: in-flight ring from :func:`ring_init` (capacity >= 1).
      hot:  ``[n_slots, ...]`` hot buffer (updated functionally).
      pool: ``[n_pages, ...]`` slow tier.
      page: ``int32`` demand page id of this step.
      now:  ``int32`` step clock (compared against ring deadlines).
      land_ok: optional ``bool[capacity]`` landing grant from the shared-link
        arbitration layer (DESIGN.md §5): a due entry whose grant is False
        stays in the ring — the link had no spare budget for it this step.
        ``None`` grants everything (the unbudgeted per-stream path).

    Two phases, mirroring the swap-in path over an async queue:

    1. **Land** every ring entry with ``ready <= now`` (and a landing
       grant): allocate a slot (free stack, else eager FIFO / lazy LRU
       eviction), copy the page in, and track it as an unconsumed prefetch —
       this models DMA that completed during the *previous* step's compute.
       An entry landing at ``now > deadline`` completed past its expected
       arrival (budget-deferred, or a straggling shard) and counts
       ``n_deferred``.
    2. **Serve** the demand. Hot-resident -> hit (a first hit on a
       prefetched slot counts ``n_prefetch_hits`` and eager-frees it).
       Still in the ring -> **partial hit**: the entry is completed
       immediately (removed from the ring, data copied), counting both
       ``n_prefetch_hits`` and ``n_partial_hits`` — the consumer blocked on
       the residual transfer only (a partial completing past its deadline
       also counts ``n_deferred``). Otherwise -> demand miss and fetch.

    Returns ``(st, ring, hot, slot, data, info)`` where ``slot`` is the hot
    slot serving the demand (-1 if out of range), ``data`` is
    ``hot[slot]``, and ``info`` has scalar bool ``hit`` (resident full hit),
    ``prefetched_hit`` (full hit on an unconsumed prefetch), ``partial_hit``
    and ``fetched`` (this demand moved a page over the link: miss or
    partial), plus the landing half of the copy plan: ``landed_pages`` /
    ``landed_slots`` ``int32[capacity]`` (``-1`` = no landing) and the
    matching bool mask ``landed``. As with :func:`pool_access`, slots
    eager-freed here are unmapped immediately but stay readable until the
    next pool call. ``hot``/``pool`` may be payload pytrees or ``None``
    (metadata-only) as in :func:`pool_access`.
    """
    st, ring, hot, landed_pages, landed_slots, landed_issued = _land_due(
        st, ring, hot, pool, now, lazy, land_ok)
    st, ring, hot, out_slot, data, info = _serve_demand(
        st, ring, hot, pool, page, now, lazy)
    info = dict(info, landed=landed_pages >= 0, landed_pages=landed_pages,
                landed_slots=landed_slots, landed_issued=landed_issued)
    return st, ring, hot, out_slot, data, info


@functools.partial(jax.jit, static_argnames=("lazy",), donate_argnums=(0, 1, 2))
def pool_wait_batch(st: dict, ring: dict, hot, pool, pages: jax.Array,
                    valid: jax.Array, now: jax.Array, lazy: bool = False,
                    land_ok: jax.Array | None = None,
                    ) -> tuple[dict, dict, jax.Array, jax.Array, dict]:
    """Wait-phase with a *multi-page demand batch* (chunked context sweep).

    Lands due ring arrivals once (exactly :func:`pool_wait` phase 1, with
    the same optional ``land_ok`` budget grants), then serves ``pages``
    (``int32[D]``, masked by ``valid``) as D sequential demand accesses —
    one step of a chunked sweep that touches D context pages at a time
    (DESIGN.md §6). Invalid entries are no-ops that leave every counter
    untouched.

    Returns ``(st, ring, hot, slots, info)``: ``slots int32[D]`` is where
    each valid demand's data now resides; ``info`` has per-demand ``bool[D]``
    masks ``hit`` / ``prefetched_hit`` / ``partial_hit`` / ``fetched`` plus
    the landing copy plan ``landed`` / ``landed_pages`` / ``landed_slots``
    (``[capacity]``). Metadata-only callers (``hot=None``) replay the full
    copy plan themselves: first the landings, then the demand fetches
    (``pages``/``slots`` where ``fetched``), matching the internal order.
    Callers must size ``n_slots >= 2*D`` (eager) / ``>= D`` (lazy) so one
    batch's evictions never race its allocations (see
    :func:`pool_access`); violating geometries raise at trace time.
    """
    _check_batch_geometry(st, pages.shape[0], lazy, "pool_wait_batch")
    st, ring, hot, landed_pages, landed_slots, landed_issued = _land_due(
        st, ring, hot, pool, now, lazy, land_ok)

    def body(carry, d):
        st, ring, hot = carry
        page = jnp.where(valid[d], pages[d], NO_PAGE)
        st, ring, hot, slot, _, info = _serve_demand(
            st, ring, hot, pool, page, now, lazy)
        return (st, ring, hot), (slot, info["hit"], info["prefetched_hit"],
                                 info["partial_hit"], info["fetched"])

    (st, ring, hot), (slots, hit, pref, part, fetched) = jax.lax.scan(
        body, (st, ring, hot), jnp.arange(pages.shape[0]))
    info = {"hit": hit, "prefetched_hit": pref, "partial_hit": part,
            "fetched": fetched, "landed": landed_pages >= 0,
            "landed_pages": landed_pages, "landed_slots": landed_slots,
            "landed_issued": landed_issued}
    return st, ring, hot, slots, info


@functools.partial(jax.jit, donate_argnums=(0, 1))
def pool_invalidate(st: dict, ring: dict, pages: jax.Array,
                    valid: jax.Array) -> tuple[dict, dict]:
    """Drop pages from the hot tier and the in-flight ring (write coherence).

    The tiered-KV write path calls this after mutating a page's cold-tier
    bytes (e.g. ``append_kv`` into the active tail page): a stale hot copy
    or an already-issued fetch of the old bytes must never serve a later
    access. Per valid, in-range page:

    * hot-resident -> unmap + return the slot to the free stack; an
      unconsumed prefetch counts ``n_pollution`` (fetched, never used).
    * still in the in-flight ring -> the entry is removed and counts
      ``n_pollution`` too, so the issued-prefetch decomposition of
      :func:`pool_stats` keeps summing.

    Returns ``(st, ring)``.
    """
    R = ring["page"].shape[0]
    n_pages = st["page_slot"].shape[0]

    def body(k, carry):
        st, ring = carry
        page = pages[k]
        ok = valid[k] & (page >= 0) & (page < n_pages)
        p_safe = jnp.clip(page, 0, n_pages - 1)
        slot = st["page_slot"][p_safe]
        resident = ok & (slot >= 0)
        s_safe = jnp.maximum(slot, 0)
        was_unconsumed = (resident & st["slot_prefetched"][s_safe]
                          & ~st["slot_consumed"][s_safe])
        st_u = _free_push(_unmap(dict(st), s_safe), s_safe)
        st = _tree_where(resident, st_u, st)
        st = dict(st)
        st["n_pollution"] = st["n_pollution"] + was_unconsumed.astype(jnp.int32)
        if R > 0:
            match = (ring["page"] == page) & (ring["page"] >= 0) & ok
            inflight = jnp.any(match)
            mi = jnp.argmax(match)
            ring = dict(ring)
            ring["page"] = jnp.where(
                inflight, ring["page"].at[mi].set(NO_PAGE), ring["page"])
            st["n_pollution"] = st["n_pollution"] + inflight.astype(jnp.int32)
        return st, ring

    return jax.lax.fori_loop(0, pages.shape[0], body, (st, ring))


def link_grants(ring: dict, now: jax.Array, cap: jax.Array) -> jax.Array:
    """Budgeted landing grants across stacked rings (DESIGN.md §5).

    ``ring`` is a leading-``[S]``-axis stack of :func:`ring_init` states,
    ``now`` the ``int32[S]`` per-stream step clocks, ``cap`` the scalar
    int32 number of prefetch landings the shared link can complete this
    step (budget minus last step's demand fetches). Grants go to due
    entries (``ready <= now``: the bytes have physically arrived) in
    ascending global issue order (``seq``, FIFO over the link); everything
    else stays in the ring past its deadline and will count ``n_deferred``
    when it finally lands. Returns ``bool[S, capacity]`` for
    :func:`pool_wait`/:func:`pool_wait_batch`'s ``land_ok``.
    """
    due = (ring["page"] >= 0) & (ring["ready"] <= now[:, None])
    flat_due = due.reshape(-1)
    flat_seq = ring["seq"].reshape(-1)
    rank = jnp.sum(flat_due[None, :]
                   & (flat_seq[None, :] < flat_seq[:, None]), axis=1)
    return (flat_due & (rank < cap)).reshape(due.shape)


def link_grants_sharded(ring: dict, now: jax.Array, caps: jax.Array,
                        homes: jax.Array,
                        mig_src: jax.Array | None = None,
                        mig_valid: jax.Array | None = None,
                        mig_seq: jax.Array | None = None):
    """Per-shard landing grants: one §5 demand-first arbiter per NIC.

    The mesh-sharded cold pool (DESIGN.md §7) has one link *per shard*
    rather than one global link: a prefetch of page p occupies the NIC of
    p's home shard, so grants are ranked and capped independently per
    shard. Args mirror :func:`link_grants` except:

    * ``caps`` is ``int32[n_shards]`` — shard g's landing capacity this
      step (its budget minus last step's demand fetches *on g*).
    * ``homes`` is ``int32[S, capacity]`` — the home shard of each ring
      entry's page (value irrelevant for empty entries: ``due`` masks
      them).

    Within each shard the discipline is exactly :func:`link_grants`: due
    entries in ascending global ``seq`` up to the shard's cap. With
    ``n_shards == 1`` (all homes 0, ``caps = [cap]``) this reduces
    bit-exactly to :func:`link_grants` — the shards=1 equivalence pin
    rides on that reduction. Returns ``bool[S, capacity]``.

    **Third priority class — background migration (DESIGN.md §12).** Pass
    ``mig_src``/``mig_valid``/``mig_seq`` (same-shape vectors over migration
    proposals; ``mig_src`` is each proposed page's *current* home — the NIC
    the move would occupy) and the return value becomes
    ``(grants, mig_ok)``. A migration is granted only out of the capacity
    left on its source NIC **after** every prefetch grant this step:
    ``leftover[g] = caps[g] - prefetch_grants_on[g]``, proposals ranked per
    shard by ``mig_seq``. ``caps`` is already demand-first (budget minus
    last step's demand), so the class order demand > prefetch > migration
    is structural — migration can never displace either. Callers must
    pre-deduplicate same-step proposals for one page (lowest seq wins)
    before building ``mig_valid``; ungranted proposals simply expire.
    """
    due = (ring["page"] >= 0) & (ring["ready"] <= now[:, None])
    flat_due = due.reshape(-1)
    flat_seq = ring["seq"].reshape(-1)
    flat_home = homes.reshape(-1)
    same_shard = flat_home[None, :] == flat_home[:, None]
    rank = jnp.sum(flat_due[None, :] & same_shard
                   & (flat_seq[None, :] < flat_seq[:, None]), axis=1)
    cap_of = caps[jnp.clip(flat_home, 0, caps.shape[0] - 1)]
    grants = (flat_due & (rank < cap_of)).reshape(due.shape)
    if mig_valid is None:
        return grants
    n_shards = caps.shape[0]
    pf_on = jnp.zeros((n_shards,), caps.dtype).at[
        jnp.clip(flat_home, 0, n_shards - 1)].add(
            grants.reshape(-1).astype(caps.dtype))
    leftover = jnp.maximum(caps - pf_on, 0)
    mv = mig_valid.reshape(-1)
    ms = mig_seq.reshape(-1)
    mh = jnp.clip(mig_src.reshape(-1), 0, n_shards - 1)
    mig_same = mh[None, :] == mh[:, None]
    mig_rank = jnp.sum(mv[None, :] & mig_same
                       & (ms[None, :] < ms[:, None]), axis=1)
    mig_ok = (mv & (mig_rank < leftover[mh])).reshape(mig_valid.shape)
    return grants, mig_ok


def pool_stats(st: dict, ring: dict | None = None) -> dict:
    """Python-side counter summary (paper §3.1 + DESIGN.md §4). Not jittable.

    With just ``st`` this reports the sync-path counters. Pass the matching
    ``ring`` to additionally decompose where every issued prefetch ended up:

    ``prefetch_issued == prefetch_hits + pollution + inflight_at_end
    + resident_unused``

    * ``prefetch_hits`` — consumed (``partial_hits`` is the subset consumed
      while still in flight; the rest arrived before first use).
    * ``pollution`` — landed in the hot buffer, evicted before any hit.
    * ``inflight_at_end`` — still in the ring when the run ended.
    * ``resident_unused`` — landed, still resident and unconsumed at the end.

    ``latency_hidden_frac`` is the fraction of consumed prefetches whose
    data had fully arrived before first use — the async path's
    latency-hiding score (1.0 = every prefetch hid its whole transfer).

    **Decode contract** (DESIGN.md §8): these counters are the fold of the
    page-lifecycle event log :mod:`repro.obs.trace` decodes from the
    per-step info arrays. Per event kind — ``hit``/``partial`` increment
    ``hits`` (the ``hit`` mask *excludes* partials; both count into
    ``prefetch_hits`` when prefetched, ``partial`` always does), ``miss``
    increments ``misses`` (= ``fetched`` minus partials), ``issue``/
    ``land``/``defer`` count into ``prefetch_issued``/landed/``deferred``,
    and the timeless end-of-run kinds ``drop``/``evict`` carry
    ``ring_drops``/``pollution``. ``repro.obs.trace.events_to_counts``
    inverts the decode; ``tests/test_obs.py`` pins the round trip and the
    event-granularity form of the decomposition above.
    """
    g = lambda k: int(st[k])
    issued, phits = g("n_prefetch_issued"), g("n_prefetch_hits")
    partial = g("n_partial_hits")
    faults = g("n_hits") + g("n_misses")
    resident_unused = int(jnp.sum((st["slot_page"] >= 0)
                                  & st["slot_prefetched"]
                                  & ~st["slot_consumed"]))
    out = {
        "faults": faults,
        "hits": g("n_hits"),
        "misses": g("n_misses"),
        "prefetch_issued": issued,
        "prefetch_hits": phits,
        "partial_hits": partial,
        "deferred": g("n_deferred"),
        "pollution": g("n_pollution"),
        "resident_unused": resident_unused,
        "alloc_scans": g("n_alloc_scans"),
        "accuracy": phits / issued if issued else 0.0,
        "coverage": phits / faults if faults else 0.0,
        "latency_hidden_frac": (phits - partial) / phits if phits else 1.0,
    }
    if ring is not None:
        out["inflight_at_end"] = int(jnp.sum(ring["page"] >= 0))
        out["ring_drops"] = int(ring["n_drops"])
    return out
