"""Prefetcher effectiveness metrics — the paper's three axes (§3.1) + costs.

* **Accuracy**   = prefetch_hits / prefetch_issued  (useful fraction of cache adds)
* **Coverage**   = prefetch_hits / total_faults     (faults served by prefetch)
* **Timeliness** = distribution of (first-hit time − prefetch-issue time)
* **Pollution**  = prefetched pages evicted (or left) without ever being hit
* **Miss count** = faults that found nothing in the cache (major faults)

Percentile helpers report the p50/p90/p99/avg shapes the paper's figures use.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PrefetchStats:
    faults: int = 0               # all slow-tier accesses (events)
    cache_hits: int = 0           # faults that hit the cache (minor faults)
    misses: int = 0               # faults that missed (major faults)
    prefetch_issued: int = 0      # pages added to cache via prefetch
    prefetch_hits: int = 0        # first hits on prefetched entries
    pollution: int = 0            # prefetched entries never hit
    timeliness: list = dataclasses.field(default_factory=list)
    latencies: list = dataclasses.field(default_factory=list)  # per-fault sim latency

    @property
    def accuracy(self) -> float:
        return self.prefetch_hits / self.prefetch_issued if self.prefetch_issued else 0.0

    @property
    def coverage(self) -> float:
        return self.prefetch_hits / self.faults if self.faults else 0.0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.faults if self.faults else 0.0

    @property
    def miss_rate(self) -> float:
        return self.misses / self.faults if self.faults else 0.0

    def latency_percentiles(self, qs=(50, 90, 99)) -> dict:
        if not self.latencies:
            return {f"p{q}": 0.0 for q in qs} | {"avg": 0.0}
        arr = np.asarray(self.latencies)
        out = {f"p{q}": float(np.percentile(arr, q)) for q in qs}
        out["avg"] = float(arr.mean())
        return out

    def timeliness_percentiles(self, qs=(50, 99)) -> dict:
        if not self.timeliness:
            return {f"p{q}": 0.0 for q in qs}
        arr = np.asarray(self.timeliness)
        return {f"p{q}": float(np.percentile(arr, q)) for q in qs}

    def summary(self) -> dict:
        return {
            "faults": self.faults,
            "hit_rate": round(self.hit_rate, 4),
            "miss_rate": round(self.miss_rate, 4),
            "accuracy": round(self.accuracy, 4),
            "coverage": round(self.coverage, 4),
            "prefetch_issued": self.prefetch_issued,
            "prefetch_hits": self.prefetch_hits,
            "pollution": self.pollution,
            "latency": self.latency_percentiles(),
            "timeliness": self.timeliness_percentiles(),
        }
