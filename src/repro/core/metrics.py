"""Prefetcher effectiveness metrics — the paper's three axes (§3.1) + costs.

* **Accuracy**   = prefetch_hits / prefetch_issued  (useful fraction of cache adds)
* **Coverage**   = prefetch_hits / total_faults     (faults served by prefetch)
* **Timeliness** = distribution of (first-hit time − prefetch-issue time)
* **Pollution**  = prefetched pages evicted (or landed-but-never-hit at end)
* **Miss count** = faults that found nothing in the cache (major faults)
* **Partial hits** = prefetched hits whose transfer was still in flight when
  consumed (swap-cache semantics: the fault blocked on the residual only)
* **In-flight at end** = prefetches whose transfer had not completed when the
  run ended — neither useful nor pollution, reported separately
* **Deferred** = prefetches that completed later than their nominal arrival
  time because the shared link's budget went to demand fetches or
  earlier-issued prefetches first (DESIGN.md §5) — an annotation on the
  other buckets, not a bucket of its own

Percentile helpers report the p50/p90/p99/avg shapes the paper's figures use.
"""

from __future__ import annotations

import dataclasses

from repro.obs.metrics import percentile_ladder


@dataclasses.dataclass
class PrefetchStats:
    faults: int = 0               # all slow-tier accesses (events)
    cache_hits: int = 0           # faults that hit the cache (minor faults)
    misses: int = 0               # faults that missed (major faults)
    prefetch_issued: int = 0      # pages added to cache via prefetch
    prefetch_hits: int = 0        # first hits on prefetched entries
    partial_hits: int = 0         # subset of prefetch_hits still in flight
    deferred: int = 0             # completed past nominal arrival (link budget)
    pollution: int = 0            # prefetched entries never hit
    inflight_at_end: int = 0      # prefetches not yet arrived at end of run
    timeliness: list = dataclasses.field(default_factory=list)
    latencies: list = dataclasses.field(default_factory=list)  # per-fault sim latency

    @property
    def accuracy(self) -> float:
        return self.prefetch_hits / self.prefetch_issued if self.prefetch_issued else 0.0

    @property
    def coverage(self) -> float:
        return self.prefetch_hits / self.faults if self.faults else 0.0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.faults if self.faults else 0.0

    @property
    def latency_hidden_frac(self) -> float:
        """Fraction of consumed prefetches fully arrived before first use."""
        if not self.prefetch_hits:
            return 1.0
        return (self.prefetch_hits - self.partial_hits) / self.prefetch_hits

    @property
    def miss_rate(self) -> float:
        return self.misses / self.faults if self.faults else 0.0

    def latency_percentiles(self, qs=(50, 90, 99)) -> dict:
        # Unified ladder (repro.obs.metrics): NaNs + n=0 for empty samples.
        return percentile_ladder(self.latencies, qs=qs)

    def timeliness_percentiles(self, qs=(50, 99)) -> dict:
        return percentile_ladder(self.timeliness, qs=qs)

    def summary(self) -> dict:
        return {
            "faults": self.faults,
            "hit_rate": round(self.hit_rate, 4),
            "miss_rate": round(self.miss_rate, 4),
            "accuracy": round(self.accuracy, 4),
            "coverage": round(self.coverage, 4),
            "prefetch_issued": self.prefetch_issued,
            "prefetch_hits": self.prefetch_hits,
            "partial_hits": self.partial_hits,
            "deferred": self.deferred,
            "latency_hidden_frac": round(self.latency_hidden_frac, 4),
            "pollution": self.pollution,
            "inflight_at_end": self.inflight_at_end,
            "latency": self.latency_percentiles(),
            "timeliness": self.timeliness_percentiles(),
        }
