"""AccessHistory — per-stream ring buffer of page-access deltas (paper §4.1).

Leap records only the *difference* between consecutive slow-tier page accesses
(``delta = page_t - page_{t-1}``), not raw addresses: the majority-vote trend
detector (``repro.core.trend``) operates on deltas, and storing deltas keeps
the tracker O(H_size) memory per stream.

Two implementations with one semantics:

* :class:`AccessHistory` — plain NumPy/python, used by the trace-driven
  simulator (``repro.core.simulator``) and as the oracle in property tests.
* :func:`init_history` / :func:`push_history` — pure-JAX (fixed-shape,
  jit/vmap-safe) twin used inside ``serve_step``/``train_step``. State is a
  dict of arrays so it threads through ``lax.scan`` untouched.

The ring buffer is FIFO with a head pointer; ``head`` always points at the
most recent delta. Until the first access there is no "previous page", so the
first push records a delta of 0 (matching the paper's example in §4.1 where
accesses 0x2,0x5,... produce deltas 0,+3,...).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_H_SIZE = 32  # paper §5: AccessHistory buffer size H_size = 32


# --------------------------------------------------------------------------
# NumPy reference
# --------------------------------------------------------------------------
class AccessHistory:
    """FIFO circular buffer of the last ``h_size`` access deltas."""

    def __init__(self, h_size: int = DEFAULT_H_SIZE):
        if h_size < 2 or (h_size & (h_size - 1)) != 0:
            raise ValueError(f"h_size must be a power of two >= 2, got {h_size}")
        self.h_size = h_size
        self.deltas = np.zeros(h_size, dtype=np.int64)
        self.head = -1          # index of most recent delta; -1 = empty
        self.count = 0          # number of valid entries (saturates at h_size)
        self.last_page = None   # most recently accessed page id

    def push(self, page: int) -> int:
        """Record an access to ``page``; returns the delta that was stored."""
        delta = 0 if self.last_page is None else int(page) - int(self.last_page)
        self.last_page = int(page)
        self.head = (self.head + 1) % self.h_size
        self.deltas[self.head] = delta
        self.count = min(self.count + 1, self.h_size)
        return delta

    def window(self, w: int) -> np.ndarray:
        """Most recent ``w`` deltas, newest first: H_head, H_head-1, ..."""
        w = min(w, self.count)
        idx = (self.head - np.arange(w)) % self.h_size
        return self.deltas[idx]


# --------------------------------------------------------------------------
# JAX twin
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HistorySpec:
    h_size: int = DEFAULT_H_SIZE


def init_history(h_size: int = DEFAULT_H_SIZE, batch: tuple[int, ...] = ()) -> dict:
    """Fixed-shape history state (optionally batched over leading dims)."""
    z = lambda shape, dt: jnp.zeros(batch + shape, dt)
    return {
        "deltas": z((h_size,), jnp.int32),
        "head": z((), jnp.int32) - 1,
        "count": z((), jnp.int32),
        "last_page": z((), jnp.int32),
        "has_last": z((), jnp.bool_),
    }


def push_history(state: dict, page: jax.Array) -> tuple[dict, jax.Array]:
    """JAX twin of :meth:`AccessHistory.push` (unbatched; vmap for streams)."""
    h_size = state["deltas"].shape[-1]
    page = page.astype(jnp.int32)
    delta = jnp.where(state["has_last"], page - state["last_page"], 0)
    head = jnp.mod(state["head"] + 1, h_size)
    new = {
        "deltas": state["deltas"].at[head].set(delta),
        "head": head,
        "count": jnp.minimum(state["count"] + 1, h_size),
        "last_page": page,
        "has_last": jnp.ones((), jnp.bool_),
    }
    return new, delta


def history_window_gather(state: dict) -> tuple[jax.Array, jax.Array]:
    """Return (deltas newest-first over the full ring, validity mask)."""
    h_size = state["deltas"].shape[-1]
    idx = jnp.mod(state["head"] - jnp.arange(h_size), h_size)
    vals = state["deltas"][idx]
    mask = jnp.arange(h_size) < state["count"]
    return vals, mask
