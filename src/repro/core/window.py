"""GetPrefetchWindowSize — adaptive prefetch-window controller (paper Alg. 2).

The window size PW_t for the next prefetch is set from the *utilization* of
the previous prefetch, measured as C_hit = number of prefetched-cache hits
since the last prefetch was issued:

* ``C_hit == 0`` — previous prefetch unused. If the faulting page still
  follows the current trend, stay minimally on (PW=1); otherwise suspend
  (PW=0) until a new trend appears. No extra pages during irregular phases →
  bounded cache pollution.
* ``C_hit > 0`` — grow to ``roundpow2(C_hit + 1)``, capped at ``PW_max``; but
  never collapse faster than halving ("shrink smoothly", Alg. 2 line 13-14) so
  one bad round doesn't kill an established pattern.

The controller is a 2-word state machine; NumPy and JAX twins below.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

DEFAULT_PW_MAX = 8  # paper §5: maximum prefetch window size PW_max = 8


def round_up_pow2(x: int) -> int:
    """Smallest power of two >= x (x >= 1)."""
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


# --------------------------------------------------------------------------
# Reference
# --------------------------------------------------------------------------
@dataclasses.dataclass
class PrefetchWindow:
    pw_max: int = DEFAULT_PW_MAX
    pw_prev: int = 0   # PW_{t-1}
    c_hit: int = 0     # prefetched-cache hits since last prefetch decision

    def note_prefetch_hit(self) -> None:
        self.c_hit += 1

    def next_size(self, follows_trend: bool) -> int:
        """Alg. 2 GetPrefetchWindowSize; mutates controller state."""
        if self.c_hit == 0:
            pw = 1 if follows_trend else 0
        else:
            pw = min(round_up_pow2(self.c_hit + 1), self.pw_max)
            if pw < self.pw_prev // 2:     # drastic drop -> shrink smoothly
                pw = self.pw_prev // 2
        self.c_hit = 0
        self.pw_prev = pw
        return pw


# --------------------------------------------------------------------------
# JAX twin
# --------------------------------------------------------------------------
def init_window_state(batch: tuple[int, ...] = ()) -> dict:
    return {
        "pw_prev": jnp.zeros(batch, jnp.int32),
        "c_hit": jnp.zeros(batch, jnp.int32),
    }


def _round_up_pow2_jax(x: jax.Array) -> jax.Array:
    """Smallest power of two >= x, elementwise, for x >= 1 (int32)."""
    xm1 = jnp.maximum(x - 1, 0)
    # bit-smearing trick: propagate the MSB down, then +1
    y = xm1
    for shift in (1, 2, 4, 8, 16):
        y = y | (y >> shift)
    return jnp.maximum(y + 1, 1)


def next_window_size(state: dict, follows_trend: jax.Array, pw_max: int = DEFAULT_PW_MAX
                     ) -> tuple[dict, jax.Array]:
    """JAX twin of :meth:`PrefetchWindow.next_size` (unbatched; vmap streams)."""
    c_hit, pw_prev = state["c_hit"], state["pw_prev"]
    cold = jnp.where(follows_trend, 1, 0)
    grown = jnp.minimum(_round_up_pow2_jax(c_hit + 1), pw_max)
    grown = jnp.where(grown < pw_prev // 2, pw_prev // 2, grown)
    pw = jnp.where(c_hit == 0, cold, grown).astype(jnp.int32)
    return {"pw_prev": pw, "c_hit": jnp.zeros_like(c_hit)}, pw


def note_prefetch_hits(state: dict, hits: jax.Array) -> dict:
    """Accumulate prefetched-cache hits observed since last prefetch."""
    return {"pw_prev": state["pw_prev"], "c_hit": state["c_hit"] + hits.astype(jnp.int32)}
