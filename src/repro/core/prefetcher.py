"""Prefetch policies: Leap (paper Alg. 1+2) and the paper's baselines.

All policies implement one interface driven by the trace simulator (and, for
Leap, mirrored by the jittable twin in ``repro.core.leap_jax``):

    on_fault(page, prefetched_hit) -> list[int]   # pages to prefetch now

The event stream is the sequence of *slow-tier accesses* (page faults in the
paper's setting; hot-buffer misses at page-granularity in ours). Policies see
every fault — including minor faults that hit the prefetch cache — exactly as
Leap's page-access tracker does (§4.1: it logs accesses "after I/O requests or
page faults", not the full VM footprint).

Baselines (paper §5.2.3):

* :class:`NextNLinePrefetcher` — on a miss, bring the next N sequential pages.
* :class:`StridePrefetcher` — Baer-Chen-style: confirm a stride from the last
  two faults; aggressiveness tracks past prefetch accuracy.
* :class:`ReadAheadPrefetcher` — model of Linux swap read-ahead per the
  paper's description (§2.3): an *aligned block* containing the faulted page;
  window doubles on consecutive-page faults / prior hits, otherwise shrinks.
* :class:`NoPrefetcher` — demand paging only.
"""

from __future__ import annotations

from .history import AccessHistory, DEFAULT_H_SIZE
from .trend import find_trend, DEFAULT_N_SPLIT
from .window import PrefetchWindow, DEFAULT_PW_MAX, round_up_pow2


class Prefetcher:
    """Base class; subclasses override :meth:`on_fault`."""

    name = "none"

    def on_fault(self, page: int, prefetched_hit: bool) -> list[int]:
        raise NotImplementedError

    def reset(self) -> None:
        pass


class NoPrefetcher(Prefetcher):
    name = "none"

    def on_fault(self, page: int, prefetched_hit: bool) -> list[int]:
        return []


class LeapPrefetcher(Prefetcher):
    """Paper Alg. 2 ``DoPrefetch`` on top of Alg. 1 ``FINDTREND``.

    State: AccessHistory (deltas), the adaptive window controller, and the
    last successfully detected trend (used both for the "follows current
    trend" test and for *speculative* prefetch when no majority currently
    exists — Alg. 2 line 25).
    """

    name = "leap"

    def __init__(self, h_size: int = DEFAULT_H_SIZE, n_split: int = DEFAULT_N_SPLIT,
                 pw_max: int = DEFAULT_PW_MAX):
        self.h_size, self.n_split, self.pw_max = h_size, n_split, pw_max
        self.reset()

    def reset(self) -> None:
        self.history = AccessHistory(self.h_size)
        self.window = PrefetchWindow(self.pw_max)
        self.current_trend: int | None = None   # last Δ_maj found by FINDTREND

    def on_fault(self, page: int, prefetched_hit: bool) -> list[int]:
        if prefetched_hit:
            self.window.note_prefetch_hit()
        delta = self.history.push(page)
        # FINDTREND runs on every fault: the page-access tracker maintains the
        # "current trend" that GetPrefetchWindowSize's follows-test refers to
        # (Alg. 2 line 6). Without this, PW=0 would deadlock bootstrap.
        trend, found = find_trend(self.history, self.n_split)
        if found:
            self.current_trend = trend
        follows = self.current_trend is not None and delta == self.current_trend
        pw = self.window.next_size(follows)
        if pw == 0:
            return []                             # suspended: demand page only
        if found:
            step = trend                          # Alg. 2 line 23: along Δ_maj
        elif self.current_trend is not None:
            step = self.current_trend             # speculative (Alg. 2 line 25)
        else:
            return []
        if step == 0:
            return []                             # repeated page: nothing ahead
        return [page + step * k for k in range(1, pw + 1)]


class NextNLinePrefetcher(Prefetcher):
    """Bring the next N sequentially-mapped pages on every cache miss."""

    name = "next_n_line"

    def __init__(self, n: int = DEFAULT_PW_MAX):
        self.n = n

    def on_fault(self, page: int, prefetched_hit: bool) -> list[int]:
        if prefetched_hit:
            return []                             # only acts on misses
        return [page + k for k in range(1, self.n + 1)]


class StridePrefetcher(Prefetcher):
    """Two-fault stride confirmation; degree adapts to prefetch accuracy.

    A stride is confirmed when the last two faults exhibit the same delta.
    The prefetch degree grows with hits on previously prefetched pages and
    shrinks otherwise (paper: "aggressiveness of this prefetcher depends on
    the accuracy of the past prefetch").
    """

    name = "stride"

    def __init__(self, max_degree: int = DEFAULT_PW_MAX):
        self.max_degree = max_degree
        self.reset()

    def reset(self) -> None:
        self.last_page: int | None = None
        self.last_delta: int | None = None
        self.hits_since = 0

    def on_fault(self, page: int, prefetched_hit: bool) -> list[int]:
        delta = None if self.last_page is None else page - self.last_page
        confirmed = delta is not None and delta == self.last_delta and delta != 0
        self.last_page, self.last_delta = page, delta
        if prefetched_hit:
            # paper §5.2.3: acts only "upon a cache miss"; hits just feed the
            # accuracy signal that sets the next degree.
            self.hits_since += 1
            return []
        if not confirmed:
            self.hits_since = 0
            return []
        degree = min(round_up_pow2(self.hits_since + 1), self.max_degree)
        self.hits_since = 0
        return [page + delta * k for k in range(1, degree + 1)]


class ReadAheadPrefetcher(Prefetcher):
    """Linux swap read-ahead model (paper §2.3 / §5.2.3).

    Reads an *aligned* block of ``window`` pages containing the faulted page.
    The window doubles when the last two faults touch consecutive pages or
    when prior read-ahead got hits, and halves (to a floor of 0) otherwise.
    """

    name = "read_ahead"

    def __init__(self, ra_max: int = DEFAULT_PW_MAX, ra_init: int = 4):
        self.ra_max, self.ra_init = ra_max, ra_init
        self.reset()

    def reset(self) -> None:
        self.window = 0
        self.last_page: int | None = None
        self.hits_since = 0

    def on_fault(self, page: int, prefetched_hit: bool) -> list[int]:
        if prefetched_hit:
            self.hits_since += 1
        sequential = self.last_page is not None and page - self.last_page == 1
        self.last_page = page
        if sequential or self.hits_since > 0:
            self.window = min(max(self.window * 2, self.ra_init), self.ra_max)
        else:
            self.window //= 2
        self.hits_since = 0
        if self.window < 2:
            return []
        start = (page // self.window) * self.window
        return [p for p in range(start, start + self.window) if p != page]


PREFETCHERS = {
    cls.name: cls
    for cls in (NoPrefetcher, LeapPrefetcher, NextNLinePrefetcher,
                StridePrefetcher, ReadAheadPrefetcher)
}


def make_prefetcher(name: str, **kwargs) -> Prefetcher:
    try:
        return PREFETCHERS[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown prefetcher {name!r}; have {sorted(PREFETCHERS)}")
