"""Hot-tier page cache with Leap's eager eviction vs. background-LRU baseline.

Models the kernel page/swap cache of the paper (§2.2, §4.3):

* Entries are pages resident in the fast tier that the paging path still
  tracks: *prefetched-but-unconsumed* pages, and (baseline only) pages that
  were already consumed but linger until a background LRU scan frees them
  (Fig. 4's wasted-cache-area problem).
* **Leap eager policy** (``eviction='eager'``): the moment a prefetched entry
  is hit (page-table updated, in paper terms), it is freed in O(1) from the
  ``PrefetchFifoLruList``; demand-fetched pages are never cached. Under
  pressure, unconsumed prefetches evict FIFO-first (§4.3).
* **Baseline** (``eviction='lru'``): consumed and demand entries stay until a
  kswapd-style scan runs (occupancy ≥ high watermark, or synchronously on a
  full insert). Every scanned entry costs ``scan_cost`` time units, charged to
  the faulting allocation — reproducing the allocation-stall effect Leap's
  eager policy removes (paper: page allocation wait −36% / −750 ns).

The cache also owns the per-policy effectiveness counters (paper §3.1):
accuracy, coverage, timeliness, pollution, miss count.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

from .metrics import PrefetchStats


@dataclasses.dataclass
class _Entry:
    prefetched: bool        # inserted by a prefetch (vs demand fetch)
    consumed: bool          # has been hit at least once
    insert_t: float         # sim time when fetch was issued
    ready_t: float          # sim time when data arrived (in-flight until then)
    last_access_t: float


class PageCache:
    def __init__(self, capacity: int, eviction: str = "eager",
                 high_watermark: float = 0.9, low_watermark: float = 0.7):
        if eviction not in ("eager", "lru"):
            raise ValueError(f"eviction must be 'eager' or 'lru', got {eviction!r}")
        self.capacity = int(capacity)
        self.eviction = eviction
        self.high = high_watermark
        self.low = low_watermark
        self.entries: OrderedDict[int, _Entry] = OrderedDict()  # LRU order
        self.prefetch_fifo: OrderedDict[int, None] = OrderedDict()  # unconsumed prefetches
        self.stats = PrefetchStats()
        self.scanned_entries = 0     # total kswapd-style scan work (baseline)

    # -- lookups ------------------------------------------------------------
    def lookup(self, page: int, now: float) -> tuple[bool, bool, float]:
        """Access ``page`` at time ``now``.

        Returns (hit, prefetched_hit, wait) where ``wait`` is the residual
        in-flight time if the page was fetched but hasn't arrived yet
        (partial hit: the fault blocks only on the remaining transfer).
        """
        e = self.entries.get(page)
        if e is None:
            return False, False, 0.0
        wait = max(0.0, e.ready_t - now)
        prefetched_hit = e.prefetched and not e.consumed
        if prefetched_hit:
            self.stats.prefetch_hits += 1
            if wait > 0.0:
                # swap-cache partial hit: consumed while still in flight —
                # the fault blocks on the residual transfer only.
                self.stats.partial_hits += 1
            self.stats.timeliness.append(max(now, e.ready_t) - e.insert_t)
            self.prefetch_fifo.pop(page, None)
        e.consumed = True
        e.last_access_t = now
        self.entries.move_to_end(page)           # LRU touch
        if self.eviction == "eager" and wait == 0.0:
            # §4.3: page-table updated -> free the cache entry immediately.
            # An entry whose transfer is still in flight (wait > 0) stays
            # resident until ready_t: freeing it would turn a re-access
            # before arrival into a full miss that re-pays the whole fetch,
            # when only the residual transfer is actually outstanding.
            del self.entries[page]
        return True, prefetched_hit, wait

    # -- inserts ------------------------------------------------------------
    def insert_demand(self, page: int, now: float, ready_t: float) -> float:
        """Demand fetch; returns allocation-stall time charged to the fault."""
        stall = self._make_room(now)
        if self.eviction == "lru":
            self.entries[page] = _Entry(False, True, now, ready_t, now)
            self.entries.move_to_end(page)
        # eager: demand pages are mapped and not tracked by the cache at all.
        return stall

    def insert_prefetch(self, page: int, now: float, ready_t: float) -> bool:
        """Prefetch insert; skips duplicates. Returns True if inserted."""
        if page in self.entries:
            return False
        self._make_room(now)
        self.entries[page] = _Entry(True, False, now, ready_t, now)
        self.prefetch_fifo[page] = None
        self.stats.prefetch_issued += 1
        return True

    def __contains__(self, page: int) -> bool:
        return page in self.entries

    @property
    def occupancy(self) -> int:
        return len(self.entries)

    # -- eviction -----------------------------------------------------------
    def _evict_one(self) -> None:
        if self.eviction == "eager":
            if not self.prefetch_fifo:
                # Only consumed-but-still-in-flight entries remain (kept
                # resident until ready_t by lookup). Evicting one forfeits
                # its residual-dedup benefit, not correctness: it was
                # already served and is not pollution.
                self.entries.popitem(last=False)
                return
            # FIFO among unconsumed prefetches (the normally tracked entries).
            page, _ = self.prefetch_fifo.popitem(last=False)
            self.stats.pollution += 1            # evicted before any hit
            del self.entries[page]
            return
        # LRU baseline: evict the least-recently-used entry of any kind.
        page, e = self.entries.popitem(last=False)
        self.prefetch_fifo.pop(page, None)
        if e.prefetched and not e.consumed:
            self.stats.pollution += 1

    def _make_room(self, now: float) -> float:
        """Ensure space for one insert; returns stall charged to the caller."""
        stall = 0.0
        if self.eviction == "eager" and self.occupancy >= self.capacity:
            # Consumed entries kept resident only because their transfer was
            # in flight at hit time are garbage once the transfer completes
            # (eager would have freed them at the hit had they arrived):
            # purge before evicting any *live* prefetch as pollution.
            for page, e in list(self.entries.items()):
                if e.consumed and e.ready_t <= now:
                    del self.entries[page]
        if self.eviction == "lru" and self.occupancy >= self.high * self.capacity:
            # Background kswapd scan: scans the whole list to rank LRU-ness.
            target = int(self.low * self.capacity)
            self.scanned_entries += self.occupancy
            while self.occupancy > target:
                self._evict_one()
        if self.occupancy >= self.capacity:
            if self.eviction == "lru":
                self.scanned_entries += self.occupancy
                stall = float(self.occupancy)    # synchronous scan -> stall units
            while self.occupancy >= self.capacity:
                self._evict_one()
        return stall

    def drain_unconsumed(self, now: float | None = None) -> None:
        """End-of-run accounting for unconsumed prefetches.

        With ``now`` given, entries whose transfer had not completed by
        ``now`` (``ready_t > now``) are counted as ``inflight_at_end`` —
        they are neither useful nor pollution, the run simply ended first.
        Everything else (landed but never hit) is pollution. Without
        ``now`` every unconsumed prefetch counts as pollution (legacy
        accounting, kept for callers without a clock).
        """
        for page in list(self.prefetch_fifo):
            e = self.entries.get(page)
            if now is not None and e is not None and e.ready_t > now:
                self.stats.inflight_at_end += 1
            else:
                self.stats.pollution += 1
            self.prefetch_fifo.pop(page)
            self.entries.pop(page, None)
