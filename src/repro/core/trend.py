"""FINDTREND — Boyer-Moore majority-vote trend detection (paper Alg. 1, §3.2.1).

Given the AccessHistory of deltas, detect the *majority* delta within the most
recent window: a delta is the major trend of a window of size ``w`` iff it
appears at least ``floor(w/2) + 1`` times in it. Detection starts with a small
window (``H_size / N_split``) anchored at the head and doubles the window until
a majority is found or the window exceeds the history (paper: robust to up to
``floor(w/2) - 1`` irregular entries per window).

Implementations:

* :func:`find_trend` — NumPy/python reference, bit-exact to Alg. 1. Used by
  the simulator and as the property-test oracle.
* :func:`find_trend_jax` — fixed-shape JAX version. ``H_size`` is static, so
  the ``log2(N_split …)`` window ladder unrolls at trace time; each rung is a
  masked Boyer-Moore pass expressed as ``lax.scan`` (O(H) total work, exactly
  the paper's complexity bound since rungs share a geometric sum ≤ 2·H).
* :func:`boyer_moore` — the O(w)/O(1) vote+verify primitive.

Both return ``(delta, found)``; ``delta`` is meaningless when ``found`` is
False (JAX version returns 0 there).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .history import AccessHistory

# Smallest detection window = H_size / N_split. The paper's worked example
# (H=8, N_split=2) starts at window 4; with our default H_size=32 that same
# effective minimum window of 4 needs N_split=8. Empirically (benchmarks
# fig9/10) window-4 adapts 1.1-1.2x faster on mixed traces at equal pollution.
DEFAULT_N_SPLIT = 8


# --------------------------------------------------------------------------
# Reference
# --------------------------------------------------------------------------
def boyer_moore(values) -> tuple[int, bool]:
    """Boyer-Moore majority vote + verification pass over ``values``.

    Returns (candidate, is_true_majority). O(len) time, O(1) space.
    """
    values = np.asarray(values)
    n = len(values)
    if n == 0:
        return 0, False
    candidate, votes = 0, 0
    for v in values:
        if votes == 0:
            candidate, votes = int(v), 1
        elif int(v) == candidate:
            votes += 1
        else:
            votes -= 1
    count = int(np.sum(values == candidate))
    return candidate, count >= (n // 2) + 1


def find_trend(history: AccessHistory, n_split: int = DEFAULT_N_SPLIT) -> tuple[int, bool]:
    """Alg. 1: doubling-window majority search, newest-first from H_head.

    The final rung clamps to ``w = h_size``: when ``h_size // n_split`` is not
    a power-of-two divisor of ``h_size`` (e.g. ``h_size=32, n_split=3`` probes
    w=10, 20), pure doubling would overshoot and never examine the full
    history, missing majorities that only exist over all ``h_size`` entries.
    """
    h_size = history.h_size
    w = max(1, h_size // n_split)
    while True:
        window = history.window(w)  # newest-first {H_head, ..., H_head-w+1}
        delta, found = boyer_moore(window)
        if found:
            return delta, True
        if w >= h_size:
            return 0, False
        w = min(w * 2, h_size)


# --------------------------------------------------------------------------
# JAX
# --------------------------------------------------------------------------
def _masked_boyer_moore(vals: jax.Array, mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Vote + verify over ``vals`` where ``mask`` selects window members."""

    def vote(carry, xm):
        cand, votes = carry
        x, m = xm
        is_zero = votes == 0
        new_cand = jnp.where(is_zero, x, cand)
        new_votes = jnp.where(is_zero, 1, jnp.where(x == cand, votes + 1, votes - 1))
        cand = jnp.where(m, new_cand, cand)
        votes = jnp.where(m, new_votes, votes)
        return (cand, votes), None

    (cand, _), _ = jax.lax.scan(vote, (jnp.int32(0), jnp.int32(0)), (vals, mask))
    n = jnp.sum(mask)
    count = jnp.sum(jnp.where(mask, vals == cand, False))
    found = (n > 0) & (count >= (n // 2) + 1)
    return cand, found


def trend_ladder(vals: jax.Array, valid: jax.Array, n_split: int,
                 ) -> tuple[jax.Array, jax.Array]:
    """Static doubling-window ladder over newest-first deltas + validity mask.

    Shared by :func:`find_trend_jax` and the fused controller
    (:mod:`repro.core.leap_jax`), so the twins' ladders cannot drift. The
    widths are static, so the ladder unrolls at trace time; the first rung
    with a verified majority wins (``where`` cascades). As in
    :func:`find_trend`, the final rung clamps to the full history when pure
    doubling from ``h_size // n_split`` would overshoot ``h_size``.
    """
    h_size = vals.shape[-1]
    best_delta = jnp.int32(0)
    best_found = jnp.zeros((), jnp.bool_)
    w = max(1, h_size // n_split)
    while True:
        in_window = (jnp.arange(h_size) < w) & valid
        cand, found = _masked_boyer_moore(vals, in_window)
        take = found & ~best_found
        best_delta = jnp.where(take, cand, best_delta)
        best_found = best_found | found
        if w >= h_size:
            return best_delta, best_found
        w = min(w * 2, h_size)


@functools.partial(jax.jit, static_argnames=("n_split",))
def find_trend_jax(state: dict, n_split: int = DEFAULT_N_SPLIT) -> tuple[jax.Array, jax.Array]:
    """JAX twin of :func:`find_trend` over a jittable history state."""
    h_size = state["deltas"].shape[-1]
    idx = jnp.mod(state["head"] - jnp.arange(h_size), h_size)
    vals = state["deltas"][idx]                      # newest-first
    valid = jnp.arange(h_size) < state["count"]      # entries that exist
    return trend_ladder(vals, valid, n_split)
