"""Serving front-end: request lifecycle CLI over :mod:`repro.serving`.

Two serving disciplines behind one CLI:

* ``--arrival batch`` (default) — the legacy lock-step loop: prefill the
  whole batch, greedy-decode ``--gen`` tokens, and with ``--paged`` replay
  the decode window through the tiered paged-KV data path
  (:func:`repro.serving.batch_driver.serve_batch_tiered`) with the §6.4
  flat/tiered bit-identity pin every step.
* ``--arrival constant|bursty|churn`` — the **continuous-batching engine**
  (:class:`repro.serving.engine.ServingEngine`): requests arrive on a
  seeded :class:`repro.fabric.tenants.ArrivalProcess`, are admitted into
  slots as capacity frees up, prefill in chunks interleaved with in-flight
  decodes, and recycle their pages on finish. The same §6.4 pin runs every
  step over the dynamic batch composition, and the report carries
  per-request TTFT + p50–p99.9 token-latency ladders
  (:mod:`repro.obs.metrics`). ``--gang`` flips admission to the lock-step
  baseline (all slots drain before the next gang enters) for A/B runs.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --paged --async-datapath
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --smoke \
      --arrival bursty --requests 8 --paged --async-datapath
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.models.model import build_model
from repro.obs.export import (write_chrome_trace, write_jsonl,
                              write_request_jsonl)
from repro.obs.metrics import Registry
from repro.runtime.straggler import StepTimeMonitor
from repro.serving.batch_driver import serve_batch_tiered
from repro.serving.engine import ServeConfig, ServingEngine, build_executor

ARRIVALS = ("batch", "constant", "bursty", "churn")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--paged", action="store_true",
                    help="serve decode attention through the tiered paged-KV "
                         "cache (Leap-managed hot pool over the cold paged "
                         "pool) and pin it bit-identical to the flat pool")
    ap.add_argument("--async-datapath", action="store_true",
                    help="with --paged: sweep context pages through the "
                         "issue/wait in-flight ring so prefetch DMA "
                         "overlaps the next chunk instead of blocking this "
                         "one; reports partial hits (DESIGN.md §4/§6)")
    ap.add_argument("--ring-size", type=int, default=8,
                    help="in-flight ring capacity for --async-datapath")
    ap.add_argument("--chunk", type=int, default=4,
                    help="context pages demanded per sweep step (the "
                         "multi-page demand batch of the tiered cache)")
    ap.add_argument("--streams", type=int, default=1,
                    help="with --paged: number of per-request page streams "
                         "(stream s sweeps request s %% batch). Default/1 = "
                         "one stream per request in the batch")
    ap.add_argument("--link-budget", type=int, default=None,
                    help="with --paged: pages/step the shared fabric link "
                         "can move across all streams' prefetches; demand "
                         "chunks are arbitrated first and surplus "
                         "prefetches arrive late (reported as deferred — "
                         "DESIGN.md §5). With --shards > 1 the budget is "
                         "*per shard NIC* (one §5 arbiter each, DESIGN.md "
                         "§7). Default: private infinite links")
    ap.add_argument("--shards", type=int, default=1,
                    help="with --paged: shard the cold paged-KV pool over "
                         "this many devices on a 'fabric' mesh axis "
                         "(DESIGN.md §7): each page lives on a home shard "
                         "behind its own NIC, the sweep runs under "
                         "shard_map, and cross-shard pages move by "
                         "collective permutes. Needs >= this many devices "
                         "(CPU: XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N). Default 1 = flat cold pool")
    ap.add_argument("--placement", choices=("block", "interleave"),
                    default="interleave",
                    help="with --shards: page -> home-shard policy "
                         "(interleave spreads consecutive pages across "
                         "NICs; block keeps contiguous ranges together)")
    ap.add_argument("--far-delay", type=int, default=2,
                    help="with --shards: prefetch arrival delay in chunk "
                         "steps for cross-shard pages (near pages take 1)")
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--attn-kernel", default="ref",
                    choices=("ref", "kernel", "fused", "fused-async"),
                    help="with --paged: decode-attention consumer. "
                         "ref/kernel run over the stacked hot pool (a full "
                         "hot-pool copy per step); fused/fused-async read "
                         "the per-stream hot slots in place through the "
                         "slot table inside the Pallas kernel (fused-async "
                         "adds explicit make_async_copy double-buffering). "
                         "The flat-pool bit-identity pin runs every step "
                         "in all modes")
    ap.add_argument("--chaos", default=None, metavar="SPEC.json",
                    help="with --paged: inject faults from a ChaosSpec JSON "
                         "file (DESIGN.md §9) into a chaos sidecar run over "
                         "the requests' context-page schedules — per-shard "
                         "slowdown, NIC budget degradation, node loss with "
                         "page re-homing, elastic tenant grants. Reports "
                         "per-shard estimated vs true delay (the adaptive-"
                         "deadline EWMA) plus timely-hit counters")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="with --paged: decode the sweep info arrays into "
                         "the page-lifecycle event log and write a Chrome "
                         "trace-event JSON (Perfetto-loadable; per-stream "
                         "tracks + link/NIC counter tracks) plus a .jsonl "
                         "sibling. Decoding is host-side and post-hoc: the "
                         "jitted serving path is unchanged (DESIGN.md §8). "
                         "Continuous-batching runs additionally emit the "
                         "per-request lifecycle track (admit/prefill/"
                         "decode/evict, keyed by request id) and a "
                         ".requests.jsonl sibling")
    # -- continuous-batching engine (DESIGN.md §10) --------------------------
    ap.add_argument("--arrival", choices=ARRIVALS, default="batch",
                    help="request arrival discipline. 'batch' = legacy "
                         "lock-step full-batch loop; the rest drive the "
                         "continuous-batching engine with the named "
                         "fabric/tenants.py arrival process")
    ap.add_argument("--requests", type=int, default=8,
                    help="continuous engine: total requests to serve")
    ap.add_argument("--slots", type=int, default=None,
                    help="continuous engine: concurrent serving slots "
                         "(default: --batch)")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="continuous engine: prompt tokens consumed per "
                         "engine step per slot (chunked prefill)")
    ap.add_argument("--length-jitter", type=float, default=0.0,
                    help="continuous engine: per-request length "
                         "heterogeneity — prompt/gen drawn uniformly from "
                         "[len*(1-jitter), len] (seeded)")
    ap.add_argument("--think-time", type=float, default=1000.0,
                    help="continuous engine: arrival-process mean gap (µs)")
    ap.add_argument("--gang", action="store_true",
                    help="continuous engine: lock-step gang admission "
                         "(the fixed-batch baseline) instead of continuous")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="continuous engine: cold-pool pages (default "
                         "slots * pages-per-request; smaller values make "
                         "admission wait on memory)")
    ap.add_argument("--synthetic", action="store_true",
                    help="continuous engine: synthetic executor (PRNG K/V, "
                         "no model) — real scheduling + data path + pins")
    # -- three-tier page lifecycle (DESIGN.md §12) ---------------------------
    ap.add_argument("--migration", action="store_true",
                    help="continuous engine: online hot/cold page migration "
                         "(DESIGN.md §12). The Leap trend re-homes each "
                         "stream's upcoming pages toward its shard between "
                         "steps; re-homing steers budgets/deadlines/NIC "
                         "accounting only (the data plane is unchanged, so "
                         "all bit-identity pins keep holding). The report "
                         "gains a per-tier residency section")
    ap.add_argument("--compressed-tier", type=int, default=None,
                    metavar="PAGES",
                    help="continuous engine: cap the *uncompressed* far "
                         "tier at PAGES; the coldest pages beyond it are "
                         "demoted through the lossy int8 page codec (one "
                         "roundtrip at demote time) and pay a decompress "
                         "surcharge on promote. Implies --migration")
    ap.add_argument("--mig-cooldown", type=int, default=16,
                    help="with --migration: hysteresis window in steps — a "
                         "page neither re-homes nor demotes again within "
                         "this many steps of its last tier transition")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None) -> dict:
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.trace and not (args.paged or args.arrival != "batch"):
        ap.error("--trace requires --paged (only the tiered data path "
                 "emits the page-lifecycle info arrays)")
    if args.chaos and not args.paged:
        ap.error("--chaos requires --paged (faults are injected into the "
                 "paged-KV sweep's fabric model)")
    if (args.migration or args.compressed_tier is not None) \
            and args.arrival == "batch":
        ap.error("--migration/--compressed-tier need the continuous engine "
                 "(--arrival constant|bursty|churn): the page lifecycle is "
                 "driven between engine steps")
    if args.arrival != "batch":
        return _main_continuous(args)
    return _main_batch(args)


def _main_batch(args) -> dict:
    """Legacy lock-step path: batched prefill + decode (+ tiered replay)."""
    cfg = (cfglib.get_smoke_config(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.PRNGKey(0))
    B, prompt_len = args.batch, args.prompt_len
    max_len = prompt_len + args.gen
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (B, prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, prompt_len, cfg.d_model),
                                            jnp.dtype(cfg.dtype))

    reg = Registry()
    decode = jax.jit(model.decode_step)
    with reg.span("prefill") as sp:
        logits, state = model.prefill(params, batch, max_len)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        sp.sync = tok
    t_prefill = reg.histogram("prefill").samples[-1]

    out = [tok]
    # per-step wall-time straggler detection (runtime satellite): the same
    # EWMA monitor every host runs on a pod feeds off the decode loop here,
    # so compilation stalls / CPU contention show up as flagged steps
    mon = StepTimeMonitor()
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        # span-timed per token (device-sync'd) — feeds the p50–p99.9
        # token-latency ladder in the final report
        with reg.span("token_latency") as sp:
            logits, state = decode(params, tok, state)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            sp.sync = tok
        mon.record(reg.histogram("token_latency").samples[-1])
        out.append(tok)
    t_decode = time.perf_counter() - t0
    tokens = np.stack([np.asarray(t) for t in out], 1)
    tok_ladder = reg.histogram("token_latency").ladder()
    result = {
        "prefill_s": round(t_prefill, 3),
        # TTFT: the first token is emitted by prefill's final logits
        "ttft_s": round(t_prefill, 3),
        "decode_tok_per_s": round(B * (args.gen - 1) / max(t_decode, 1e-9), 1),
        "token_latency": {k: round(v, 5) if isinstance(v, float) else v
                          for k, v in tok_ladder.items()},
        "tokens_shape": list(tokens.shape),
        "step_time_monitor": {k: round(v, 5) if isinstance(v, float) else v
                              for k, v in mon.summary().items()},
    }

    if args.paged:
        result.update(serve_batch_tiered(cfg, state, args, B, prompt_len,
                                         max_len, reg=reg,
                                         trace_path=args.trace))
        if not result["tiered_equiv_ok"]:
            print(result)
            msg = "tiered/flat decode attention mismatch"
            if args.trace:
                msg += (f" (first bad decode step "
                        f"{result['tiered_first_bad_step']}; event trace "
                        f"dumped to {args.trace} — diff it against a good "
                        f"run with repro.obs.diff)")
            raise SystemExit(msg)
        if args.trace and not result["trace_totals_ok"]:
            print(result)
            raise SystemExit("trace event totals diverge from pool counters "
                             "(decode contract violation, DESIGN.md §8.2)")

    print(result)
    return result


def _main_continuous(args) -> dict:
    """Continuous-batching path: request lifecycle over the serving engine."""
    migration = None
    if args.migration or args.compressed_tier is not None:
        from repro.paging.lifecycle import MigrationCfg
        migration = MigrationCfg(
            cooldown=args.mig_cooldown,
            compressed=args.compressed_tier is not None,
            far_capacity=args.compressed_tier)
    scfg = ServeConfig(
        requests=args.requests,
        slots=args.slots if args.slots is not None else args.batch,
        prompt_len=args.prompt_len, gen=args.gen,
        length_jitter=args.length_jitter,
        page_size=args.page_size, prefill_chunk=args.prefill_chunk,
        chunk=args.chunk, ring_size=args.ring_size,
        async_datapath=args.async_datapath, link_budget=args.link_budget,
        shards=args.shards, placement=args.placement,
        far_delay=args.far_delay,
        attn_kernel=args.attn_kernel.replace("-", "_"),
        arrival=args.arrival,
        think_time=args.think_time, seed=args.seed, gang=args.gang,
        pool_pages=args.pool_pages, trace=bool(args.trace),
        migration=migration)
    executor = build_executor(None if args.synthetic else args.arch,
                              smoke=args.smoke, seed=args.seed)
    engine = ServingEngine(scfg, executor)
    result = engine.run()

    if args.trace:
        counters = None
        if engine.link_hist:
            counters = {"link_demand_fetches": np.concatenate(engine.link_hist)}
            if args.shards > 1:
                counters["shard_demand_fetches"] = np.concatenate(
                    engine.shard_hist)
        write_chrome_trace(args.trace, engine.events, counters,
                           request_phases=engine.phases)
        write_jsonl(args.trace + ".jsonl", engine.events)
        write_request_jsonl(args.trace + ".requests.jsonl", engine.phases)
        result["trace_path"] = args.trace

    if not result["tiered_equiv_ok"]:
        print(result)
        raise SystemExit("tiered/flat decode attention mismatch under "
                         "continuous batching (first bad step "
                         f"{result.get('tiered_first_bad_step')})")
    if result["requests_finished"] != args.requests:
        print(result)
        raise SystemExit(f"{result['requests_finished']}/{args.requests} "
                         "requests finished")
    if result["alloc_in_use_end"] != 0:
        print(result)
        raise SystemExit(f"page leak: {result['alloc_in_use_end']} pages "
                         "still allocated after drain")
    if result["pages_allocated"] != result["pages_recycled"]:
        print(result)
        raise SystemExit("page conservation violated: "
                         f"{result['pages_allocated']} allocated vs "
                         f"{result['pages_recycled']} recycled")
    if args.trace and not result["trace_totals_ok"]:
        print(result)
        raise SystemExit("trace event totals diverge from pool counters "
                         "(decode contract violation, DESIGN.md §8.2)")
    print(result)
    return result


if __name__ == "__main__":
    main()
