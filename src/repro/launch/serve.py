"""Batched serving driver: prefill + decode with paged KV and Leap stats.

Serves batched requests against a (smoke-scale on CPU) model: prefill the
prompt batch, then greedy-decode N tokens. ``--paged`` additionally mirrors
every decoded step's KV-page appends into a paged pool and drives the
Leap-prefetched hot-buffer stream over the page access schedule, reporting
the prefetch hit rate — the serving-side integration of the paper.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --paged
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.models.model import build_model
from repro.paging.prefetch_serving import (PrefetchedStream,
                                           multi_stream_consume, stream_stats,
                                           stream_stats_at, stream_consume)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--paged", action="store_true",
                    help="drive the Leap-prefetched page stream alongside "
                         "(see --async-datapath for the issue/wait variant)")
    ap.add_argument("--async-datapath", action="store_true",
                    help="with --paged: fetch prefetch candidates through "
                         "the issue/wait in-flight ring so their DMA "
                         "overlaps the next decode step instead of blocking "
                         "this one; reports partial hits + latency-hidden "
                         "fraction (DESIGN.md §4)")
    ap.add_argument("--ring-size", type=int, default=8,
                    help="in-flight ring capacity for --async-datapath")
    ap.add_argument("--streams", type=int, default=1,
                    help="with --paged: drive this many concurrent page "
                         "streams (one per request, batch-major) instead of "
                         "one concatenated schedule — the paper's Fig. 13 "
                         "multi-stream serving shape")
    ap.add_argument("--link-budget", type=int, default=None,
                    help="with --paged --streams > 1: pages/step the shared "
                         "fabric link can move across all streams; demand "
                         "fetches are arbitrated first and surplus "
                         "prefetches arrive late (reported as deferred — "
                         "DESIGN.md §5). Default: private infinite links")
    ap.add_argument("--page-size", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = (cfglib.get_smoke_config(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.PRNGKey(0))
    B, S = args.batch, args.prompt_len
    max_len = S + args.gen
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, S, cfg.d_model),
                                            jnp.dtype(cfg.dtype))

    decode = jax.jit(model.decode_step)
    t0 = time.perf_counter()
    logits, state = model.prefill(params, batch, max_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    tokens = np.stack([np.asarray(t) for t in out], 1)
    result = {
        "prefill_s": round(t_prefill, 3),
        "decode_tok_per_s": round(B * (args.gen - 1) / max(t_decode, 1e-9), 1),
        "tokens_shape": list(tokens.shape),
    }

    if args.paged:
        # page access schedule of a chunked context sweep per request:
        # sequential page ids — Leap detects, prefetches one step ahead.
        npages = max_len // args.page_size + 1
        geom = PrefetchedStream(n_pages=npages * B,
                                n_slots=min(4 * 8 + 2, npages * B),
                                page_elems=cfg.n_kv_heads * cfg.head_dim
                                * args.page_size,
                                ring_size=args.ring_size)
        pool = jnp.zeros((geom.n_pages, geom.page_elems), jnp.float32)
        if args.streams > 1:
            # one stream per request (round-robin over the batch), all
            # sharing the fabric link under the per-step budget
            S = args.streams
            scheds = jnp.asarray(np.stack(
                [np.arange(npages) + (s % B) * npages for s in range(S)]),
                jnp.int32)
            st, _, info = multi_stream_consume(
                pool, scheds, geom, async_datapath=args.async_datapath,
                link_budget=args.link_budget)
            per = [stream_stats_at(st, i) for i in range(S)]
            result["paged_streams"] = S
            result["paged_prefetch_hit_rate"] = round(
                float(np.mean([p["coverage"] for p in per])), 3)
            result["paged_pollution"] = sum(p["pollution"] for p in per)
            result["paged_partial_hits"] = sum(p["partial_hits"] for p in per)
            result["paged_deferred"] = sum(p["deferred"] for p in per)
            result["paged_ring_drops"] = sum(p["ring_drops"] for p in per)
            if args.link_budget is not None:
                result["paged_link_budget"] = args.link_budget
                result["paged_link_demand_fetches"] = int(
                    np.sum(np.asarray(info["link_demand_fetches"])))
        else:
            st, _, info = stream_consume(pool, jnp.asarray(np.concatenate(
                [np.arange(npages) + b * npages for b in range(B)]),
                jnp.int32), geom, async_datapath=args.async_datapath)
            s = stream_stats(st)
            result["paged_prefetch_hit_rate"] = round(s["coverage"], 3)
            result["paged_pollution"] = s["pollution"]
            if args.async_datapath:
                result["paged_partial_hits"] = s["partial_hits"]
                result["paged_latency_hidden_frac"] = round(
                    s["latency_hidden_frac"], 3)
                result["paged_inflight_at_end"] = s["inflight_at_end"]

    print(result)
    return result


if __name__ == "__main__":
    main()
