"""Production mesh builders (functions — importing never touches devices).

Single pod: (data=16, model=16) = 256 chips (one v5e pod). Multi-pod adds a
leading DCN-class 'pod' axis: (pod=2, data=16, model=16) = 512 chips. The
'model' axis is the ICI-bandwidth-rich TP/EP axis; 'data' carries FSDP +
batch; 'pod' carries pure DP (gradient all-reduce over DCN — the axis
gradient compression targets).

The 'fabric' axis (``make_fabric_mesh``) is the disaggregated-memory
dimension (DESIGN.md §7): the paged cold-KV pool's page axis shards over
it, one NIC per fabric shard, and the sharded sweep's collective permutes
ride it. Serving composes it orthogonally to the compute mesh — a chip can
sit on ('fabric',) for the cold tier while the model runs data/model
parallel; on CPU CI the fabric devices come from
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_fabric_mesh(n_shards: int):
    """1-D ('fabric',) mesh over ``n_shards`` devices — the sharded cold
    pool's home shards (:mod:`repro.paging.sharded_pool`).

    Raises with a hint about ``--xla_force_host_platform_device_count``
    when the process doesn't expose enough devices (the CPU-CI situation).
    """
    if jax.device_count() < n_shards:
        raise ValueError(
            f"need {n_shards} devices for a {n_shards}-shard fabric mesh, "
            f"have {jax.device_count()} — on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_shards}")
    return jax.make_mesh((n_shards,), ("fabric",))


def make_host_mesh(model: int = 1):
    """Tiny mesh on whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
