"""Production mesh builders (functions — importing never touches devices).

Single pod: (data=16, model=16) = 256 chips (one v5e pod). Multi-pod adds a
leading DCN-class 'pod' axis: (pod=2, data=16, model=16) = 512 chips. The
'model' axis is the ICI-bandwidth-rich TP/EP axis; 'data' carries FSDP +
batch; 'pod' carries pure DP (gradient all-reduce over DCN — the axis
gradient compression targets).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh on whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
