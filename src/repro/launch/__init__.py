"""Launch: production mesh, jitted step builders, dry-run, train/serve CLIs."""
