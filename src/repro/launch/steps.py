"""Jitted step builders: train_step / prefill_step / serve_step per cell.

``build_cell(arch, shape, mesh, multi_pod)`` assembles everything one
(architecture x input-shape x mesh) dry-run or run needs: the step function,
its input ShapeDtypeStructs, and in/out shardings resolved through the
logical-axis rules. Train steps are full fwd+bwd+optimizer-update (AdamW;
Adafactor for the 400B MoE so optimizer state fits — DESIGN.md §5); serve
steps are one decode token against the shape's KV context; prefill lowers
the whole-context forward.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfglib
from repro.distributed import (batch_shardings, rules_for,
                               set_activation_sharding, shardings_for_tree)
from repro.models.model import build_model
from repro.optim import make_optimizer

# 400B MoE: AdamW moments would blow the 16 GB/chip budget; Adafactor's
# factored second moment fits (napkin math in DESIGN.md §5).
OPT_FOR_ARCH = {"llama4_maverick_400b": "adafactor"}
LR = 1e-4


def arch_rule_overrides(arch: str, mode: str, multi_pod: bool) -> dict:
    """Per-arch sharding deviations from the default TP+FSDP rules.

    xlstm-350m has no useful TP targets (64-wide head blocks) and a heavy
    per-sequence recurrent state — run it pure-DP: batch over data AND
    model (256-way), activations unsharded on seq.
    """
    if cfglib.canonical(arch) == "xlstm_350m" and mode == "train":
        bax = ("pod", "data", "model") if multi_pod else ("data", "model")
        return {"batch": bax, "act_seq": None}
    # H6 (refuted, see EXPERIMENTS §Perf): dropping SP for the hybrid family
    # cut jamba's collective term ~10% but grew per-chip memory 27% — net
    # negative; the binding fix is the fused selective-scan kernel.
    return {}


def _capture_param_specs(model, rng):
    box = {}

    def f(k):
        p, s = model.init_params(k)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(f, rng)
    return shapes, box["specs"]


def opt_state_shardings(opt_name, pspecs, pshapes, mesh, rules):
    if opt_name == "adamw":
        m = shardings_for_tree(pspecs, pshapes, mesh, rules)
        return {"m": m, "v": jax.tree.map(lambda s: s, m)}
    # adafactor: row drops last dim, col drops second-to-last
    is_spec = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def one(ax, like):
        from repro.distributed.sharding import named_sharding_for
        ax = tuple(ax) + (None,) * (len(like.shape) - len(ax))
        if len(like.shape) >= 2:
            return {"row": named_sharding_for(ax[:-1], like.shape[:-1], mesh, rules),
                    "col": named_sharding_for(ax[:-2] + ax[-1:],
                                              like.shape[:-2] + like.shape[-1:],
                                              mesh, rules)}
        return {"v": named_sharding_for(ax, like.shape, mesh, rules)}

    return {"acc": jax.tree.map(one, pspecs, pshapes, is_leaf=is_spec)}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: Any
    kind: str                      # train | prefill | decode
    step_fn: Callable              # jitted
    args: tuple                    # ShapeDtypeStructs for lower()
    skip: str | None = None


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def build_cell(arch: str, shape: str, mesh, multi_pod: bool = False,
               smoke: bool = False, opt_override: str | None = None,
               extra_rules: dict | None = None) -> Cell:
    spec = cfglib.input_specs(arch, shape, smoke=smoke)
    cfg, sp = spec["cfg"], spec["shape"]
    if spec["skip"]:
        return Cell(arch, shape, cfg, sp.kind, None, (), skip=spec["skip"])
    model = build_model(cfg)
    mode = "train" if sp.kind == "train" else "serve"
    rules = rules_for(mode, multi_pod)
    rules.update(arch_rule_overrides(arch, mode, multi_pod))
    if extra_rules:
        rules.update(extra_rules)
    rng = jax.random.PRNGKey(0)
    pshapes, pspecs = _capture_param_specs(model, rng)
    psh = shardings_for_tree(pspecs, pshapes, mesh, rules)
    bax = rules["batch"]
    # SP constraint for whole-sequence passes; decode steps run unconstrained
    # ([B,1,D] has nothing to sequence-shard).
    set_activation_sharding(
        mesh, P(bax, rules.get("act_seq", "model"), None)
        if sp.kind in ("train", "prefill") else None)

    # H1 hook (perf flag attn_reshard): head-sharded, sequence-gathered
    # q/k/v so attention runs TP-style with ONE reshard per layer instead of
    # per-kv-block collectives. kv_heads fall back to replicated when they
    # don't divide the model axis (GQA kv=8 on model=16).
    from repro.distributed.activations import set_attn_sharding
    from repro.distributed.sharding import named_sharding_for

    def _attn_reshard(q, k, v):
        qs = named_sharding_for(("batch", None, "heads_dim", None),
                                q.shape, mesh,
                                {**rules, "heads_dim": "model"})
        ks = named_sharding_for(("batch", None, "kv_heads_dim", None),
                                k.shape, mesh,
                                {**rules, "kv_heads_dim": "model"})
        return (jax.lax.with_sharding_constraint(q, qs),
                jax.lax.with_sharding_constraint(k, ks),
                jax.lax.with_sharding_constraint(v, ks))

    set_attn_sharding(_attn_reshard if sp.kind in ("train", "prefill")
                      else None)

    # H4 hook (perf flag mm_gather): pre-matmul activations gathered on seq,
    # sharded on batch — weight grads then reduce over 'data' onto FSDP
    # shards instead of full-size ARs over 'model'.
    from repro.distributed.activations import set_matmul_input_sharding

    def _mm_gather(y):
        sh = named_sharding_for(("batch", None, None), y.shape, mesh, rules)
        return jax.lax.with_sharding_constraint(y, sh)

    set_matmul_input_sharding(_mm_gather if sp.kind in ("train", "prefill")
                              else None)

    # H5 hook (perf flag decode_tsh): decode logits [B,Hkv,G,T] keep T
    # sharded over 'model' so softmax combines partial (max,sum) instead of
    # all-gathering KV.
    from repro.distributed.activations import set_decode_logits_sharding

    def _logits_tsh(s):
        sh = named_sharding_for(("batch", None, None, "kv_seq"),
                                s.shape, mesh, rules)
        return jax.lax.with_sharding_constraint(s, sh)

    set_decode_logits_sharding(_logits_tsh if sp.kind == "decode" else None)

    if sp.kind == "train":
        opt_name = opt_override or OPT_FOR_ARCH.get(
            cfglib.canonical(arch), "adamw")
        opt_init, opt_update = make_optimizer(opt_name, LR)
        oshapes = jax.eval_shape(opt_init, pshapes)
        osh = opt_state_shardings(opt_name, pspecs, pshapes, mesh, rules)
        bsh = batch_shardings(spec["batch"], mesh, rules)
        rep = NamedSharding(mesh, P())

        def train_step(params, opt_state, batch, step):
            loss, grads = jax.value_and_grad(model.train_forward)(params, batch)
            params, opt_state, info = opt_update(grads, opt_state, params, step)
            return params, opt_state, {"loss": loss, **info}

        fn = jax.jit(train_step,
                     in_shardings=(psh, osh, bsh, rep),
                     out_shardings=(psh, osh, None),
                     donate_argnums=(0, 1))
        args = (pshapes, oshapes, spec["batch"],
                jax.ShapeDtypeStruct((), jnp.int32))
        return Cell(arch, shape, cfg, sp.kind, fn, args)

    if sp.kind == "prefill":
        bsh = batch_shardings(spec["batch"], mesh, rules)

        def prefill_step(params, batch):
            return model.prefill(params, batch, sp.seq_len)

        fn = jax.jit(prefill_step, in_shardings=(psh, bsh))
        return Cell(arch, shape, cfg, sp.kind, fn, (pshapes, spec["batch"]))

    # decode
    state_specs = model.decode_state_specs()
    state_shapes = spec["batch"]["state"]
    ssh = shardings_for_tree(state_specs, state_shapes, mesh, rules)
    tok_sh = NamedSharding(mesh, P(bax if state_shapes_batch_divisible(
        state_shapes, mesh, bax) else None))

    def serve_step(params, token, state):
        logits, state = model.decode_step(params, token, state)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return next_tok, state

    fn = jax.jit(serve_step,
                 in_shardings=(psh, tok_sh, ssh),
                 out_shardings=(tok_sh, ssh),
                 donate_argnums=(2,))
    args = (pshapes, spec["batch"]["token"], state_shapes)
    return Cell(arch, shape, cfg, sp.kind, fn, args)


def state_shapes_batch_divisible(state_shapes, mesh, bax) -> bool:
    n = (mesh.shape[bax] if isinstance(bax, str)
         else functools.reduce(lambda a, b: a * mesh.shape[b], bax, 1))
    leaves = [l for l in jax.tree.leaves(state_shapes) if len(l.shape) >= 2]
    b = leaves[0].shape[1] if leaves else 1
    return b % n == 0
