"""Loop-aware analysis of partitioned HLO text: collectives + HBM traffic.

XLA prints each computation once, so naive text scans under-count anything
inside a ``while`` body by its trip count (and the period-scan trunk runs
n_periods iterations). This module:

1. splits the module into computations,
2. builds the while-call graph (caller -> body/cond) and extracts each
   loop's trip count (largest s32 constant in the condition computation —
   the canonical `compare(iv, constant(N), LT)` pattern GSPMD emits),
3. propagates execution counts from the entry (entry=1, body = caller x trip),
4. aggregates, weighted by execution count:
   * collective bytes by type (output-shape bytes; `-start/-done` pairs are
     counted once via the start op),
   * an HBM-traffic estimate: sum of op *output* bytes over all non-trivial
     ops (post-fusion, so roughly one write per fused op; reads ~= writes is
     applied as a 2x factor by the roofline, documented there).

These are estimates of a *schedule*, not measurements — but they are
loop-scaled, fusion-aware, and per-device, which is what the roofline needs.
"""

from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# header = "<name> (params...) -> result {" — params may nest tuple types,
# so only anchor on the leading name + '(' (the line must end with '{').
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE = re.compile(r"\bwhile\(")
_CALLED = re.compile(r"(condition|body)=%?([\w\.\-_]+)")
_CONST = re.compile(r"constant\((\d+)\)")
# first `word(` token on the rhs is the op name (shapes never precede '('
# directly; tuple shapes open with a bare '(' not preceded by a word char)
_OPNAME = re.compile(r"([a-z0-9\-]+)\(")

SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "copy", "copy-start", "copy-done", "after-all", "partition-id",
            # TPU-target corrections: bare converts are CPU bf16->f32
            # legalization (the MXU consumes bf16 directly); the `while` op's
            # own output is the donated/aliased loop state.
            "convert", "while"}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{"):
            m = _COMP_HDR.match(stripped.rstrip("{").strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped == "}":
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def loop_structure(comps: dict[str, list[str]]) -> dict[str, int]:
    """Execution count per computation (entry-rooted; bodies x trip count)."""
    # find while ops: caller -> (body, cond); trip from XLA's own
    # known_trip_count backend_config (fallback: condition constants).
    edges: list[tuple[str, str, str]] = []
    trip_of: dict[str, int] = {}
    for name, lines in comps.items():
        for line in lines:
            if "while(" not in line or not _WHILE.search(line):
                continue
            called = dict()
            for kind, target in _CALLED.findall(line):
                called[kind] = target
            if "body" not in called:
                continue
            edges.append((name, called["body"], called.get("condition", "")))
            m = _TRIP.search(line)
            if m:
                trip_of[called["body"]] = int(m.group(1))

    for _, body, cond in edges:
        if body in trip_of:
            continue
        trip = 1
        for line in comps.get(cond, []):
            for c in _CONST.findall(line):
                trip = max(trip, int(c))
        trip_of[body] = trip

    entry = None
    for name in comps:
        if name.startswith("main") or entry is None:
            if name.startswith("main"):
                entry = name
    counts: dict[str, int] = defaultdict(int)
    counts[entry] = 1
    # propagate (few nesting levels; iterate to fixpoint)
    for _ in range(8):
        changed = False
        for caller, body, _ in edges:
            want = counts[caller] * trip_of.get(body, 1)
            if want > counts[body]:
                counts[body] = want
                changed = True
        if not changed:
            break
    return dict(counts)


_CALLS = re.compile(r"calls=%?([\w\.\-_]+)")


def _dus_update_bytes(comps: dict[str, list[str]]) -> dict[str, int]:
    """fused computations containing a dynamic-update-slice -> update bytes.

    In-loop cache/accumulator updates are in-place (XLA aliases the loop
    carry), so such a fusion's real HBM write is the *update slice*, not
    the full buffer our output-shape scan would count (a 32K-token KV
    cache would otherwise be 'written' wholesale every decode step). The
    CPU backend sometimes wraps the dus in a convert (bf16 legalization),
    so any fusion *containing* a dus whose operand resolves is treated as
    in-place — on TPU the convert does not exist and the dus aliases.
    """
    out = {}
    for name, lines in comps.items():
        for line in lines:
            ls = line.strip()
            if "dynamic-update-slice(" not in ls:
                continue
            # operands: (buffer, update, idx...) — update is the 2nd
            ops = ls.split("dynamic-update-slice(", 1)[1]
            names = re.findall(r"%([\w\.\-_]+)", ops)
            if len(names) >= 2:
                upd = names[1]
                for l2 in lines:
                    if re.match(rf"\s*(?:ROOT )?%{re.escape(upd)}\s*=\s*", l2):
                        sm = _SHAPE.search(l2.split("=", 1)[1])
                        if sm:
                            out[name] = _shape_bytes(sm.group(1), sm.group(2))
                        break
            break
    return out


def analyze_hlo(hlo: str) -> dict:
    comps = split_computations(hlo)
    counts = loop_structure(comps)
    dus_fused = _dus_update_bytes(comps)
    coll: dict[str, dict] = {}
    hbm_write_bytes = 0.0
    for name, lines in comps.items():
        mult = counts.get(name, 1)
        for line in lines:
            if "=" not in line:
                continue
            rhs = line.split("=", 1)[1]
            mop = _OPNAME.search(rhs)
            if not mop:
                continue
            opname = mop.group(1)
            if opname in SKIP_OPS or opname.endswith("-done"):
                continue                     # start/done pairs: count start
            # output bytes = all shapes printed before the op name
            b = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE.findall(rhs[: mop.start()]))
            if b == 0:
                continue
            if opname == "fusion":
                mc = _CALLS.search(rhs)
                if mc and mc.group(1) in dus_fused:
                    b = min(b, dus_fused[mc.group(1)])   # in-place update
                elif mc and "wrapped_convert" in mc.group(1):
                    continue                             # CPU legalization
            elif opname == "dynamic-update-slice":
                # bare dus: update operand size unknown here; it aliases, so
                # skip the full-buffer write (update slices are tiny).
                continue
            base = opname[:-6] if opname.endswith("-start") else opname
            if base in COLLECTIVES:
                # start-form tuple outputs repeat the payload (operand+result)
                if opname.endswith("-start"):
                    b //= 2
                d = coll.setdefault(base, {"count": 0, "bytes": 0.0})
                d["count"] += mult
                d["bytes"] += mult * b
            hbm_write_bytes += mult * b
    return {"collectives": coll,
            "hbm_write_bytes": hbm_write_bytes,
            "n_computations": len(comps),
            "loop_counts": {k: v for k, v in counts.items() if v > 1}}
