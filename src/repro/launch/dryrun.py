import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax pins the device count at first
init, and the production meshes need 512 placeholder host devices. Tests
and benchmarks never import this module, so they keep seeing 1 device.

Per cell this script:
  1. builds the jitted step (repro.launch.steps) with production shardings,
  2. ``lower(**ShapeDtypeStructs)`` then ``compile()`` — success proves the
     sharding config is coherent (no mismatched collectives, no OOM at
     compile),
  3. records ``memory_analysis()`` (per-chip bytes — proves it fits 16 GB),
     ``cost_analysis()`` (per-chip FLOPs/bytes for the roofline), and the
     collective mix parsed from the partitioned HLO,
  4. writes one JSON per cell under --out (results are cached: cells
     already present are skipped unless --force).

Usage:
  python -m repro.launch.dryrun --arch qwen2_72b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import json
import math
import re
import time
import traceback

from repro import configs as cfglib
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Per-op collective traffic from the *partitioned* (per-device) HLO.

    Counts each collective's output bytes (the per-chip tensor it
    materializes). The roofline's collective term applies a per-type factor
    (ring all-reduce moves ~2x) downstream in benchmarks.roofline.
    """
    out: dict = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        m = COLLECTIVE_RE.search(line.split("(")[0])
        if not m:
            continue
        op = m.group(1)
        sm = SHAPE_RE.search(line.split("=", 1)[1])
        if not sm:
            continue
        b = _shape_bytes(sm.group(1), sm.group(2))
        d = out.setdefault(op, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    return out


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape,
           "mesh": dict(mesh.shape), "multi_pod": multi_pod,
           "kind": cell.kind}
    if cell.skip:
        rec["skip"] = cell.skip
        return rec
    with mesh:
        lowered = cell.step_fn.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    loop_aware = analyze_hlo(hlo)
    rec.update({
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_nonarg_bytes": ma.temp_size_in_bytes,
        },
        "cost": {
            "flops": ca.get("flops", 0.0),
            "transcendentals": ca.get("transcendentals", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "collectives": parse_collectives(hlo),          # naive (unscaled)
        "collectives_loop_aware": loop_aware["collectives"],
        "hbm_write_bytes": loop_aware["hbm_write_bytes"],
        "loop_counts": loop_aware["loop_counts"],
        "n_chips": math.prod(mesh.shape.values()),
    })
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = cfglib.ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(cfglib.SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for a, s in cells:
        tag = f"{a}__{s}__{'pod2' if args.multi_pod else 'pod1'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[cached] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            rec = run_cell(a, s, args.multi_pod)
        except Exception as e:
            failures += 1
            rec = {"arch": a, "shape": s, "multi_pod": args.multi_pod,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"  FAILED: {e}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if "memory" in rec:
            gb = (rec["memory"]["temp_bytes"]
                  + rec["memory"]["argument_bytes"]) / 2**30
            print(f"  ok: compile={rec['compile_s']}s "
                  f"per-chip args+temp={gb:.2f} GiB "
                  f"flops/chip={rec['cost']['flops']:.3g}")
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
