"""End-to-end training driver with the full substrate stack.

Wires together: model zoo + sharded step (steps.py), synthetic/memmap data
pipeline, AdamW/Adafactor, async checkpointing with restart-on-failure,
straggler monitor, watchdog, optional int8 gradient compression stats. On
real hardware this runs per host under the cluster launcher; on CPU it runs
the smoke configs end-to-end (examples/train_e2e.py drives it).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_3b --smoke \
      --steps 50 --global-batch 8 --seq-len 64 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as cfglib
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.data import make_pipeline
from repro.models.model import build_model
from repro.optim import cosine_warmup, make_optimizer
from repro.runtime import StepTimeMonitor, Watchdog, run_with_restarts
from repro.launch.steps import OPT_FOR_ARCH


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--watchdog-s", type=float, default=300.0)
    args = ap.parse_args(argv)

    cfg = (cfglib.get_smoke_config(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    model = build_model(cfg)
    opt_name = OPT_FOR_ARCH.get(cfglib.canonical(args.arch), "adamw")
    opt_init, opt_update = make_optimizer(
        opt_name, cosine_warmup(args.lr, 10, args.steps))
    pipe = make_pipeline(cfg.vocab_size, args.global_batch, args.seq_len)

    @jax.jit
    def train_step(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(model.train_forward)(params, batch)
        params, opt_state, info = opt_update(grads, opt_state, params, step)
        return params, opt_state, loss, info["grad_norm"]

    ck = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    monitor = StepTimeMonitor()
    watchdog = Watchdog(args.watchdog_s).start()
    history: list[float] = []

    def make_state():
        params, _ = model.init_params(jax.random.PRNGKey(0))
        return {"params": params, "opt": opt_init(params)}

    def one(state, step):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in pipe.peek(step).items()}
        p, o, loss, gn = train_step(state["params"], state["opt"], batch,
                                    jnp.int32(step))
        loss = float(loss)
        history.append(loss)
        watchdog.beat()
        if monitor.record(time.perf_counter() - t0):
            print(f"[straggler] step {step} took "
                  f"{time.perf_counter() - t0:.2f}s (ewma {monitor.ewma:.2f})")
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} gnorm {float(gn):.3f}")
        return {"params": p, "opt": o}

    def save(state, step):
        if ck:
            ck.save(step, state, {"data_step": step})

    def restore():
        if not ck:
            return None
        s = latest_step(args.ckpt_dir)
        if s is None:
            return None
        state, extras = restore_checkpoint(args.ckpt_dir, s, make_state())
        pipe.load_state_dict({"step": extras.get("data_step", s)})
        return jax.tree.map(jnp.asarray, state), s

    state, restarts = run_with_restarts(make_state, one, save, restore,
                                        args.steps, args.save_every)
    if ck:
        ck.wait()
    watchdog.stop()
    print(f"done: final loss {history[-1]:.4f} "
          f"(restarts={restarts}, stragglers={monitor.flags})")
    return {"final_loss": history[-1], "history": history,
            "monitor": monitor.summary()}


if __name__ == "__main__":
    main()
