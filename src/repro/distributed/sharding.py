"""Logical-axis -> mesh-axis sharding rules (TP + FSDP + EP + SP).

Every parameter/state leaf carries a tuple of logical axis names (see
``repro.models.layers``); this module resolves them against a mesh through a
rules table. Resolution is defensive in two ways that make one rules table
serve all ten architectures:

* **divisibility fallback** — if a dim isn't divisible by its mesh axes'
  product, that dim falls back to replicated (e.g. seamless's vocab 256206
  on a 16-way model axis, or the long_500k batch of 1 on the data axis).
* **duplicate-axis drop** — if two dims of one leaf resolve to the same mesh
  axis, the later dim is replicated (e.g. expert weights [E, D, F]:
  E->model, D->data, F->model would reuse 'model'; F becomes None). This is
  what turns the MoE expert stacks into 2-D (EP x FSDP) shards without a
  special case.

Rule sets: TRAIN = TP over 'model' + FSDP over 'data' (+ pure DP over 'pod'
— params replicated across pods, gradients all-reduced over DCN); SERVE =
same weight layout plus decode-state rules (batch over data(+pod), KV
sequence over model = sequence-parallel decode attention).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> mesh axis (or tuple of axes)
RULES_TRAIN = {
    "vocab": "model",
    "ff": "model",
    "expert_ff": "data",             # experts take 'model'; ff spreads FSDP-style
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "inner": "model",
    "embed": "data",                 # FSDP: weights' d_model dim over data
    "layers": None,
    "batch": ("pod", "data"),
    "act_seq": "model",              # SP: activation seq dim
    "kv_seq": "model",
    "kv_heads_s": None,
    "pages": "data",
}

RULES_SERVE = dict(RULES_TRAIN)
# serving: the paged cold-KV pool's page axis goes to the disaggregated
# 'fabric' axis (DESIGN.md §7) when the mesh has one, else to 'data'. A
# *list* is a preference order (exactly one axis is chosen) — unlike a
# tuple, which shards over the product of its axes; splitting pages over
# fabric x data would break the home-major placement invariant (each
# fabric shard must own its whole n_pages/n_shards slice).
RULES_SERVE["pages"] = ["fabric", "data"]


def rules_for(mode: str, multi_pod: bool) -> dict:
    rules = dict(RULES_TRAIN if mode == "train" else RULES_SERVE)
    if not multi_pod:
        rules["batch"] = "data"
    return rules


def _axes_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax]
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    return n


def named_sharding_for(axes: tuple, shape: tuple, mesh: Mesh,
                       rules: dict) -> NamedSharding:
    """Resolve one leaf's logical axes to a NamedSharding (with fallbacks)."""
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, axes):
        ax = rules.get(name) if name else None
        if isinstance(ax, list):
            # preference order: the first axis this mesh actually has
            ax = next((a for a in ax if a in mesh.shape), None)
        if ax is None:
            parts.append(None)
            continue
        ax_t = (ax,) if isinstance(ax, str) else tuple(ax)
        # axes the mesh doesn't have are dropped (e.g. 'fabric' on a pure
        # compute mesh), like the divisibility fallback below
        ax_t = tuple(a for a in ax_t if a not in used and a in mesh.shape)
        size = _axes_size(mesh, ax_t)
        if not ax_t or size <= 1 or dim % size != 0:
            parts.append(None)
            continue
        used.update(ax_t)
        parts.append(ax_t[0] if len(ax_t) == 1 else ax_t)
    # trailing dims beyond len(axes) stay replicated
    return NamedSharding(mesh, P(*parts))


def shardings_for_tree(spec_tree, shape_tree, mesh: Mesh, rules: dict):
    """spec_tree: logical-axis tuples; shape_tree: arrays/ShapeDtypeStructs."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return jax.tree.map(
        lambda ax, like: named_sharding_for(ax, like.shape, mesh, rules),
        spec_tree, shape_tree, is_leaf=is_spec)


def batch_shardings(batch_specs: dict, mesh: Mesh, rules: dict):
    """Shardings for train/prefill batches: dim0 = batch, rest replicated.

    positions3 has batch at dim1 ([3,B,S]); handled by name.
    """
    def one(name, leaf):
        if name == "positions3":
            ax = (None, "batch", None)
        else:
            ax = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return named_sharding_for(ax, leaf.shape, mesh, rules)

    return {k: one(k, v) for k, v in batch_specs.items()}
