"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

For multi-pod deployments where the DCN 'pod' axis is better used as a
pipeline than as data parallelism (very large models, small per-pod batch):
stages hold contiguous layer blocks; microbatches stream through with the
classic GPipe schedule (n_micro + n_stages - 1 ticks); activations hop
stages via ``lax.ppermute`` (DCN-friendly point-to-point instead of
all-reduce). Forward-only here is used by serving; training composes with
``jax.grad`` through the whole pipelined function (XLA differentiates the
ppermutes into reverse hops).

This is deliberately minimal-but-real: the schedule, bubble accounting, and
collective pattern are the deployment-relevant parts; it is exercised by
``tests/test_pipeline.py`` on a host mesh and sized for the (2,16,16) mesh
by reading the 'pod' axis.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(stage_fn, params_stacked, x, *, mesh: Mesh,
                     axis: str = "pod", n_micro: int | None = None):
    """Run ``stage_fn(stage_params, microbatch) -> microbatch`` as a pipeline.

    Args:
      stage_fn: one stage's computation (same signature on every stage).
      params_stacked: pytree with leading [n_stages] axis (stage s's params).
      x: [B, ...] global batch; B must divide into microbatches.
      mesh/axis: the pipeline axis (its size = n_stages).
      n_micro: number of microbatches (default = n_stages, the GPipe
        minimum for full utilization up to the bubble).

    Returns y [B, ...] after all stages. Bubble fraction =
    (n_stages-1)/(n_micro+n_stages-1), reported by :func:`bubble_fraction`.
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    n_micro = n_micro or n_stages
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro

    def body(stage_params, xs):
        # shard_map hands each stage its params slice with a leading 1-axis
        stage_params = jax.tree.map(lambda t: t[0], stage_params)
        idx = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros((mb,) + xs.shape[2:], xs.dtype)

        def tick(carry, t):
            buf, acc = carry
            # stage 0 injects microbatch t (when valid); others use incoming
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(idx == 0, xs[inject], buf)
            y = stage_fn(stage_params, x_in)
            # forward the result to the next stage (ring permute; last
            # stage's output wraps to 0 where it is ignored)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf_next = jax.lax.ppermute(y, axis, perm)
            # last stage accumulates finished microbatch m = t - (S-1)
            m = t - (n_stages - 1)
            take = (idx == n_stages - 1) & (m >= 0)
            acc = jax.lax.cond(
                take,
                lambda a: jax.lax.dynamic_update_slice(
                    a, y[None], (jnp.maximum(m, 0),) + (0,) * y.ndim),
                lambda a: a, acc)
            return (buf_next, acc), None

        acc0 = jnp.zeros((n_micro, mb) + xs.shape[2:], xs.dtype)
        (buf, acc), _ = jax.lax.scan(tick, (buf, acc0), jnp.arange(n_ticks))
        # broadcast the last stage's results to all stages (tiny, or keep
        # sharded: we return from the last stage via psum of masked acc)
        acc = jax.lax.psum(
            jnp.where(idx == n_stages - 1, acc, jnp.zeros_like(acc)), axis)
        return acc

    xs = x.reshape(n_micro, mb, *x.shape[1:])
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),       # params sharded by stage; x replicated
        out_specs=P(),
        check_vma=False)
    y = fn(params_stacked, xs)
    return y.reshape(B, *x.shape[1:])


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
