"""Distribution: logical-axis sharding rules, activation constraints."""

from .sharding import (RULES_SERVE, RULES_TRAIN, named_sharding_for,
                       shardings_for_tree, batch_shardings, rules_for)
from .activations import activation_constraint, set_activation_sharding

__all__ = ["RULES_SERVE", "RULES_TRAIN", "named_sharding_for",
           "shardings_for_tree", "batch_shardings", "rules_for",
           "activation_constraint", "set_activation_sharding"]
