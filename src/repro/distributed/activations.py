"""Activation sharding hook (SP): models call it, launch configures it.

``forward_hidden`` pins the residual stream's sharding at every period
boundary via :func:`activation_constraint`. By default it is the identity;
the launcher installs (mesh, spec) so trunk activations shard as
[batch -> data(+pod), seq -> model, d_model -> replicated]. Without the seq
shard, an 80-period scan saves ~80 full-seq residuals per chip and the 72B
train_4k cell blows past HBM (see DESIGN.md §5 napkin math).
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def set_activation_sharding(mesh, spec: P | None):
    """Install (or clear, with spec=None) the trunk activation constraint."""
    _state.value = None if spec is None else NamedSharding(mesh, spec)


@contextlib.contextmanager
def activation_sharding(mesh, spec: P | None):
    prev = getattr(_state, "value", None)
    set_activation_sharding(mesh, spec)
    try:
        yield
    finally:
        _state.value = prev


def activation_constraint(x: jax.Array) -> jax.Array:
    """Apply the installed constraint to a [B,S,D] trunk activation."""
    sh = getattr(_state, "value", None)
    if sh is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, sh)


def set_attn_sharding(fn) -> None:
    """Install an (q,k,v) -> (q,k,v) resharding hook (perf flag
    ``attn_reshard``; built mesh-aware by launch.steps)."""
    _state.attn = fn


def attn_constraint(q, k, v):
    fn = getattr(_state, "attn", None)
    if fn is None:
        return q, k, v
    return fn(q, k, v)


def set_matmul_input_sharding(fn) -> None:
    """Install the pre-matmul activation constraint (perf flag ``mm_gather``):
    gather the seq dim before weight matmuls so weight gradients reduce over
    the batch/data axis (reduce-scatter onto FSDP shards) instead of
    all-reducing full-size over the model axis (H4). SP still applies at
    period boundaries for the saved residual stream."""
    _state.mm = fn


def matmul_input_constraint(y):
    fn = getattr(_state, "mm", None)
    return y if fn is None else fn(y)


def set_decode_logits_sharding(fn) -> None:
    """Install a constraint for decode-attention logits [B,Hkv,G,T] (perf
    flag ``decode_tsh``): pinning T->model keeps the KV sequence sharded so
    softmax reduces via small cross-shard (max,sum) all-reduces instead of
    GSPMD all-gathering the whole KV cache per layer (hypothesis H5)."""
    _state.decode_logits = fn


def decode_logits_constraint(s):
    fn = getattr(_state, "decode_logits", None)
    return s if fn is None else fn(s)
