"""Observability: page-lifecycle tracing + unified telemetry (DESIGN.md §8).

* :mod:`repro.obs.trace`   — event schema, info-array decoders (both data
  planes), lock-step twin recorder.
* :mod:`repro.obs.export`  — Chrome trace-event (Perfetto) JSON + JSONL.
* :mod:`repro.obs.diff`    — first-divergent-event trace differ.
* :mod:`repro.obs.metrics` — counter/histogram registry, the unified
  percentile ladder, device-sync'd span timers.
"""

from .diff import (Divergence, assert_traces_equal, diff_report,
                   first_divergence)
from .export import (read_jsonl, read_request_jsonl, to_chrome_trace,
                     write_chrome_trace, write_jsonl, write_request_jsonl)
from .metrics import Registry, percentile_ladder
from .trace import (AGGREGATE_KINDS, DEMAND_KINDS, KINDS, REQUEST_PHASES,
                    SUMMARY_KINDS, Event, RequestPhase, TraceRecorder,
                    debug_tap, decode_stream_events, decode_sweep_events,
                    events_to_counts, home_of_host, summary_events)

__all__ = [
    "AGGREGATE_KINDS", "DEMAND_KINDS", "Divergence", "Event", "KINDS",
    "REQUEST_PHASES", "Registry", "RequestPhase", "SUMMARY_KINDS",
    "TraceRecorder", "assert_traces_equal",
    "debug_tap", "decode_stream_events", "decode_sweep_events",
    "diff_report", "events_to_counts", "first_divergence", "home_of_host",
    "percentile_ladder", "read_jsonl", "read_request_jsonl",
    "summary_events", "to_chrome_trace",
    "write_chrome_trace", "write_jsonl", "write_request_jsonl",
]
