"""Trace differ: name the first divergent event, not "counts mismatch".

The equivalence pins (jitted scan vs lock-step twin, flat vs sharded data
plane, tiered vs flat attention) used to fail with a per-stream counter
diff — actionable only by bisection. This module compares two event
streams (:mod:`repro.obs.trace`) in execution order and reports the first
``(step, stream, kind)`` cell — and, when both sides carry page-level
detail, the exact pages — where they part ways (DESIGN.md §8.3).

Granularity rules (one per event-kind class):

* **Demand kinds** (``hit``/``partial``/``miss``/``invalidate``) are
  compared as multisets of ``(kind, page, pref)`` per ``(step, stream)``
  — both producers know the demand page.
* **Aggregate kinds** (``issue``/``land``/``defer``) are compared as
  totals per ``(step, stream)``; when *both* sides carry page-level
  entries for the cell (twin vs twin), the page multisets are compared
  too, so a planted single-page divergence is named by page.
* **Summary kinds** (``drop``/``evict``) cannot be placed in time by the
  info-array decoders, so they compare as per-stream run totals.

The walk order is step-ascending, and within a step: ``land``, ``defer``
(the wait phase), demand kinds, ``issue`` — the execution order of both
data planes — so "first divergence" means first in machine time, and
every cell before it is certified equal.
"""

from __future__ import annotations

import dataclasses
from collections import Counter

from .trace import AGGREGATE_KINDS, DEMAND_KINDS, SUMMARY_KINDS

#: Within-step comparison order = execution order of one lock step
#: (migration grants happen in the wait phase, promote/demote between
#: demand service and the next issue — DESIGN.md §12).
_STEP_KIND_ORDER = ("land", "defer", "migrate", "hit", "partial", "miss",
                    "invalidate", "promote", "demote", "issue")


@dataclasses.dataclass(frozen=True)
class Divergence:
    """First point where two traces disagree.

    ``step = -1`` marks a run-total (summary-kind) divergence. ``pages``
    holds ``(only_in_a, only_in_b)`` page multisets when page-level detail
    exists on both sides, else ``None`` and the counts differ.
    """
    step: int
    stream: int
    kind: str
    count_a: int
    count_b: int
    pages: tuple | None = None

    def __str__(self):
        where = (f"step {self.step}, stream {self.stream}"
                 if self.step >= 0 else f"run total, stream {self.stream}")
        msg = (f"first divergent event: kind={self.kind!r} at {where} — "
               f"count {self.count_a} (a) vs {self.count_b} (b)")
        if self.pages is not None:
            only_a, only_b = self.pages
            msg += (f"; pages only in a: {sorted(only_a)}, "
                    f"only in b: {sorted(only_b)}")
        return msg


def _buckets(events):
    """Index an event stream for cell-wise comparison.

    Returns ``(cells, summary)``:
      cells:   ``{(step, stream, kind): (count, page_multiset|None)}`` —
               the multiset is a Counter of ``(page, pref)`` and is None
               iff any event of the cell is aggregate (``page == -1``).
      summary: ``{(stream, kind): count}`` for summary kinds.
    """
    cells: dict = {}
    summary: dict = {}
    for e in events:
        if e.kind in SUMMARY_KINDS:
            summary[(e.stream, e.kind)] = (
                summary.get((e.stream, e.kind), 0) + e.count)
            continue
        key = (e.step, e.stream, e.kind)
        count, pages = cells.get(key, (0, Counter()))
        count += e.count
        if pages is not None and e.page >= 0:
            pages[(e.page, e.pref)] += e.count
        else:
            pages = None                 # aggregate entry: counts only
        cells[key] = (count, pages)
    return cells, summary


def first_divergence(events_a, events_b) -> Divergence | None:
    """First ``(step, stream, kind)`` cell where the two traces disagree.

    Returns ``None`` when the traces are equivalent at the comparison
    granularity of each kind class (see module docstring).
    """
    cells_a, sum_a = _buckets(events_a)
    cells_b, sum_b = _buckets(events_b)

    kind_rank = {k: i for i, k in enumerate(_STEP_KIND_ORDER)}
    keys = sorted(set(cells_a) | set(cells_b),
                  key=lambda k: (k[0], kind_rank.get(k[2], 99), k[1]))
    for key in keys:
        step, stream, kind = key
        count_a, pages_a = cells_a.get(key, (0, Counter()))
        count_b, pages_b = cells_b.get(key, (0, Counter()))
        page_level = pages_a is not None and pages_b is not None
        if count_a != count_b or (page_level and pages_a != pages_b):
            pages = None
            if page_level:
                pages = (tuple((pages_a - pages_b).elements()),
                         tuple((pages_b - pages_a).elements()))
            return Divergence(step, stream, kind, count_a, count_b, pages)

    for key in sorted(set(sum_a) | set(sum_b)):
        stream, kind = key
        a, b = sum_a.get(key, 0), sum_b.get(key, 0)
        if a != b:
            return Divergence(-1, stream, kind, a, b)
    return None


def diff_report(events_a, events_b, label_a: str = "a",
                label_b: str = "b") -> str:
    """Human-readable one-liner: the first divergence, or equivalence."""
    d = first_divergence(events_a, events_b)
    if d is None:
        return (f"traces equivalent ({len(list(events_a))} vs "
                f"{len(list(events_b))} events)")
    return str(d).replace("(a)", f"({label_a})").replace("(b)", f"({label_b})")


def assert_traces_equal(events_a, events_b, label_a: str = "jitted",
                        label_b: str = "twin", context: str = "") -> None:
    """Raise ``AssertionError`` naming the first divergent event.

    The pin-test hook: call it *instead of* (or before) a bare counter
    compare so a mismatch fails with the exact ``(step, stream, page)``
    to look at rather than two counter dicts.
    """
    d = first_divergence(events_a, events_b)
    if d is not None:
        prefix = f"{context}: " if context else ""
        raise AssertionError(
            prefix + str(d).replace("(a)", f"({label_a})")
                           .replace("(b)", f"({label_b})"))
