"""Page-lifecycle event log: schema, info-array decoders, twin recorder.

Leap's argument is about *where a page spends its time* between fault and
landing, but the jitted data planes only hand back fixed-shape per-step
info arrays and end-of-run counters. This module turns both into one
structured event stream (DESIGN.md §8) without touching the hot path:

* :class:`Event` — one page-lifecycle transition, stamped with
  ``(kind, step, stream, page, shard, seq, count, pref)``.
* :func:`decode_stream_events` — host-side decoder for the mask-granularity
  ``[S, T]`` info of ``stream_consume`` / ``multi_stream_consume`` /
  ``sharded_multi_stream_consume``. Pure post-hoc numpy over arrays the
  scan already returns: tracing costs nothing when it is off, and exactly
  one device→host copy when it is on.
* :func:`decode_sweep_events` — same for the count-granularity
  ``[S, n_chunks]`` info of ``tiered_sweep``.
* :class:`TraceRecorder` — the push-style producer the lock-step twins
  (``fabric.linkstep`` / ``fabric.shardstep``) thread their page-level
  transitions through.
* :func:`debug_tap` — optional ``jax.debug.callback`` bridge for emitting
  events from *inside* a jitted function while debugging interactively.

Decode contract (verified property-by-property in ``tests/test_obs.py``
against ``pool_stats``; see also the docstrings of ``core.pool``):

====================  =======================================================
info field            meaning
====================  =======================================================
``hit``               full resident hit (excludes partial hits)
``partial_hit``       demand completed a still-in-flight prefetch early
``pref_hit``          full hit on a prefetched entry (excludes partial)
``fetched``           demand moved bytes over the link = partial | miss
``issued``            prefetches enqueued this step
``landed``            in-flight prefetches granted + copied this step
``deferred``          completions (land or partial) past their deadline
====================  =======================================================

Identities the event stream preserves exactly:

* ``hits  == #hit + #partial``        (``hit`` excludes partials)
* ``prefetch_hits == #hit[pref] + #partial``
* ``misses == faults - #hit - #partial``   and   ``#miss == #fetched - #partial``
* ``prefetch_issued == Σ issue == Σ land + #partial + inflight_at_end``

``drop`` (ring full at issue) and ``evict`` (pollution: landed, evicted
unused) cannot be placed in time from the info arrays — the decoders emit
them as end-of-run **summary events** (``step = -1``) from the final
counters; the twins record them page-level. The differ compares both kinds
as per-stream run totals for exactly this reason (``obs/diff.py``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

#: Every page-lifecycle transition, in rough lifecycle order. The tier
#: lifecycle (DESIGN.md §12) adds ``migrate`` (home re-assignment granted
#: on leftover link capacity), ``demote`` (page compressed into the cold
#: tier) and ``promote`` (compressed page restored to the uncompressed far
#: tier by bytes moving for it).
KINDS = ("issue", "land", "defer", "drop", "hit", "partial", "miss",
         "invalidate", "evict", "migrate", "demote", "promote")

#: Kinds that carry a demand page and are compared page-by-page.
DEMAND_KINDS = ("hit", "partial", "miss", "invalidate")

#: Kinds the jitted decoders can only count per (step, stream).
AGGREGATE_KINDS = ("issue", "land", "defer", "migrate", "demote", "promote")

#: Kinds that cannot be placed in time host-side: per-stream run totals.
SUMMARY_KINDS = ("drop", "evict")


@dataclasses.dataclass(frozen=True)
class Event:
    """One page-lifecycle transition.

    Attributes:
      kind:   one of :data:`KINDS`.
      step:   global step index (``-1`` for end-of-run summary events).
      stream: owning stream.
      page:   page id; ``-1`` when the producer only knows a count
              (aggregate events decoded from jitted info arrays).
      shard:  the page's home shard (``-1`` when unsharded/unknown).
      seq:    global issue-order stamp (``-1`` when unknown).
      count:  multiplicity — aggregate events decoded from count arrays
              carry ``count > 1``; page-level events always ``count = 1``.
      pref:   the access hit a *prefetched* entry (``hit`` events only;
              ``partial`` implies it).
    """
    kind: str
    step: int
    stream: int
    page: int = -1
    shard: int = -1
    seq: int = -1
    count: int = 1
    pref: bool = False

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}; "
                             f"expected one of {KINDS}")


#: Request-lifecycle phase kinds, in lifecycle order (DESIGN.md §10).
REQUEST_PHASES = ("admit", "prefill_chunk", "decode", "evict")


@dataclasses.dataclass(frozen=True)
class RequestPhase:
    """One span of a request's serving lifecycle, keyed by *request id*.

    The page-lifecycle :class:`Event` stream is keyed by stream/slot index,
    which continuous batching recycles across requests; this record is the
    slot-reuse-proof view — ``req`` is the global request id, so a
    request's admit wait, prefill chunks, decode window and eviction stay
    one contiguous track no matter which slots served it.

    Attributes:
      kind:   one of :data:`REQUEST_PHASES`.
      req:    global request id.
      start:  first engine step of the phase (for ``admit``: arrival step).
      end:    engine step the phase completed (exclusive for spans;
              ``end == start`` renders as an instant, e.g. ``evict``).
      slot:   serving slot during the phase (``-1`` while waiting).
      tokens: tokens processed in the phase (prefill chunk size / decoded
              token count; 0 where meaningless).
    """
    kind: str
    req: int
    start: int
    end: int
    slot: int = -1
    tokens: int = 0

    def __post_init__(self):
        if self.kind not in REQUEST_PHASES:
            raise ValueError(f"unknown request phase {self.kind!r}; "
                             f"expected one of {REQUEST_PHASES}")


def home_of_host(page: int, n_pages: int, n_shards: int,
                 placement: str) -> int:
    """Host-side ``repro.core.pool.page_home`` (same formula, plain ints)."""
    if n_shards <= 1:
        return -1
    p = min(max(int(page), 0), n_pages - 1)
    if placement == "interleave":
        return p % n_shards
    return p // (n_pages // n_shards)


def summary_events(final_stats, step: int = -1) -> list[Event]:
    """End-of-run ``drop``/``evict`` summary events from per-stream stats.

    ``final_stats`` is a list of per-stream counter dicts shaped like
    ``repro.core.pool.pool_stats`` output.
    """
    out = []
    for s, ps in enumerate(final_stats):
        drops = int(ps.get("ring_drops", 0))
        if drops:
            out.append(Event("drop", step, s, count=drops))
        pollution = int(ps.get("pollution", 0))
        if pollution:
            out.append(Event("evict", step, s, count=pollution))
    return out


def decode_stream_events(schedules, info, *, n_pages: int,
                         final_stats=None, n_shards: int = 1,
                         placement: str = "interleave",
                         step_offset: int = 0) -> list[Event]:
    """Expand mask-granularity ``[S, T]`` stream info into events.

    Args:
      schedules: ``[S, T]`` demand page ids (array-like).
      info: the info dict of ``stream_consume`` / ``multi_stream_consume``
        (per-stream ``[S, T]`` arrays; a single stream's ``[T]`` info can
        be passed with ``schedules`` shaped ``[1, T]``).
      n_pages / n_shards / placement: topology, for home-shard stamping.
      final_stats: optional list of per-stream ``pool_stats`` dicts; when
        given, ``drop``/``evict`` run totals are appended as ``step = -1``
        summary events.
      step_offset: added to every step stamp (for stitching multiple
        decode calls into one global clock).

    Returns events in execution order: per step — ``land``/``defer``
    aggregates first (the wait phase), then ``migrate`` grants, then each
    stream's demand event (``hit``/``partial``/``miss``, page-level), then
    ``promote``/``demote`` tier transitions, then ``issue`` aggregates.
    The tier-lifecycle kinds are emitted only when the run carried
    migration info (``info["migrated"]`` et al., DESIGN.md §12).
    """
    sched = np.asarray(schedules)
    if sched.ndim == 1:
        sched = sched[None]
    S, T = sched.shape
    hit = np.asarray(info["hit"]).reshape(S, T)
    pref = np.asarray(info["pref_hit"]).reshape(S, T)
    part = np.asarray(info["partial_hit"]).reshape(S, T)
    issued = np.asarray(info["issued"]).reshape(S, T)
    landed = np.asarray(info["landed"]).reshape(S, T)
    deferred = np.asarray(info["deferred"]).reshape(S, T)
    migrated = promoted = demoted = None
    if "migrated" in info:
        migrated = np.asarray(info["migrated"]).reshape(S, T)
        promoted = np.asarray(info["promoted"]).reshape(S, T)
        demoted = np.asarray(info["demoted"]).reshape(T)
    home = lambda p: home_of_host(p, n_pages, n_shards, placement)

    events = []
    for t in range(T):
        step = step_offset + t
        for s in range(S):
            if landed[s, t]:
                events.append(Event("land", step, s,
                                    count=int(landed[s, t])))
            if deferred[s, t]:
                events.append(Event("defer", step, s,
                                    count=int(deferred[s, t])))
        if migrated is not None:
            for s in range(S):
                if migrated[s, t]:
                    events.append(Event("migrate", step, s,
                                        count=int(migrated[s, t])))
        for s in range(S):
            p = int(sched[s, t])
            if part[s, t]:
                events.append(Event("partial", step, s, page=p,
                                    shard=home(p), pref=True))
            elif hit[s, t]:
                events.append(Event("hit", step, s, page=p, shard=home(p),
                                    pref=bool(pref[s, t])))
            else:
                events.append(Event("miss", step, s, page=p, shard=home(p)))
        if migrated is not None:
            for s in range(S):
                if promoted[s, t]:
                    events.append(Event("promote", step, s,
                                        count=int(promoted[s, t])))
            if demoted[t]:
                # Demotion is a pool-wide capacity decision, not owned by
                # any stream; both decoders attribute it to stream 0.
                events.append(Event("demote", step, 0,
                                    count=int(demoted[t])))
        for s in range(S):
            if issued[s, t]:
                events.append(Event("issue", step, s,
                                    count=int(issued[s, t])))
    if final_stats is not None:
        events.extend(summary_events(final_stats))
    return events


def decode_sweep_events(info, *, final_stats=None,
                        step_offset: int = 0) -> list[Event]:
    """Expand count-granularity ``[S, n_chunks]`` tiered-sweep info.

    The sweep's info is per-chunk *counts* (a chunk bundles ``geom.chunk``
    demand pages), so every event here is an aggregate (``page = -1``)
    with ``count`` = the chunk's tally; ``step`` is the global chunk step
    ``step_offset + chunk_index`` — pass the stream clock (``ring["now"]``
    before the sweep, = decode_step * n_chunks in the serving loop) to
    stitch successive sweeps onto one time axis. Event-count identities
    are the same as :func:`decode_stream_events` (``#miss = fetched -
    partial``; ``hit`` excludes partials).
    """
    hit = np.asarray(info["hit"])
    pref = np.asarray(info["pref_hit"])
    part = np.asarray(info["partial_hit"])
    fetched = np.asarray(info["fetched"])
    issued = np.asarray(info["issued"])
    landed = np.asarray(info["landed"])
    deferred = np.asarray(info["deferred"])
    S, n_chunks = hit.shape

    events = []
    for c in range(n_chunks):
        step = step_offset + c
        for s in range(S):
            if landed[s, c]:
                events.append(Event("land", step, s, count=int(landed[s, c])))
            if deferred[s, c]:
                events.append(Event("defer", step, s,
                                    count=int(deferred[s, c])))
        for s in range(S):
            n_part = int(part[s, c])
            n_full = int(hit[s, c])          # `hit` excludes partials
            n_miss = int(fetched[s, c]) - n_part
            n_pref = int(pref[s, c])
            if n_part:
                events.append(Event("partial", step, s, count=n_part,
                                    pref=True))
            if n_pref:
                events.append(Event("hit", step, s, count=n_pref, pref=True))
            if n_full - n_pref > 0:
                events.append(Event("hit", step, s, count=n_full - n_pref))
            if n_miss > 0:
                events.append(Event("miss", step, s, count=n_miss))
        for s in range(S):
            if issued[s, c]:
                events.append(Event("issue", step, s, count=int(issued[s, c])))
    if final_stats is not None:
        events.extend(summary_events(final_stats))
    return events


class TraceRecorder:
    """Push-style event producer for the host-side lock-step twins.

    ``fabric.linkstep.run_linkstep`` / ``fabric.shardstep.run_shardstep``
    accept ``recorder=TraceRecorder()`` and emit page-level events at every
    transition — the ground-truth side of the trace diff. A recorder is
    also handy in the serving loop for host-known events (``invalidate``).
    """

    def __init__(self):
        self.events: list[Event] = []

    def emit(self, kind: str, step: int, stream: int, page: int = -1,
             shard: int = -1, seq: int = -1, count: int = 1,
             pref: bool = False) -> None:
        self.events.append(Event(kind, int(step), int(stream), int(page),
                                 int(shard), int(seq), int(count),
                                 bool(pref)))

    def __len__(self) -> int:
        return len(self.events)


def debug_tap(recorder: TraceRecorder, kind: str):
    """A jit-safe tap: call the result with traced scalars inside a jitted
    function and the event lands in ``recorder`` host-side via
    ``jax.debug.callback`` (ordered=True keeps program order).

    Interactive-debugging aid only — the production decoders are post-hoc
    and keep the hot path untouched.

    >>> tap = debug_tap(rec, "land")
    >>> tap(step, stream, page)        # inside a jitted fn
    """
    import jax

    def _cb(step, stream, page, count):
        recorder.emit(kind, int(step), int(stream), int(page),
                      count=int(count))

    def tap(step, stream, page, count=1):
        jax.debug.callback(_cb, step, stream, page, count, ordered=True)

    return tap


def events_to_counts(events, n_streams: int) -> list[dict]:
    """Fold an event stream back into per-stream counter dicts.

    Returns one dict per stream with the ``pool_stats``-aligned keys
    ``hits`` / ``misses`` / ``partial_hits`` / ``prefetch_hits`` /
    ``prefetch_issued`` / ``landed`` / ``deferred`` / ``ring_drops`` /
    ``pollution`` / ``invalidated`` — the bridge the event↔counter pins in
    ``tests/test_obs.py`` and ``serve.py``'s trace-totals check walk.
    """
    out = [dict(hits=0, misses=0, partial_hits=0, prefetch_hits=0,
                prefetch_issued=0, landed=0, deferred=0, ring_drops=0,
                pollution=0, invalidated=0, migrations=0, demotions=0,
                promotions=0) for _ in range(n_streams)]
    for e in events:
        c = out[e.stream]
        n = e.count
        if e.kind == "hit":
            c["hits"] += n
            if e.pref:
                c["prefetch_hits"] += n
        elif e.kind == "partial":
            c["hits"] += n
            c["prefetch_hits"] += n
            c["partial_hits"] += n
        elif e.kind == "miss":
            c["misses"] += n
        elif e.kind == "issue":
            c["prefetch_issued"] += n
        elif e.kind == "land":
            c["landed"] += n
        elif e.kind == "defer":
            c["deferred"] += n
        elif e.kind == "drop":
            c["ring_drops"] += n
        elif e.kind == "evict":
            c["pollution"] += n
        elif e.kind == "invalidate":
            c["invalidated"] += n
        elif e.kind == "migrate":
            c["migrations"] += n
        elif e.kind == "demote":
            c["demotions"] += n
        elif e.kind == "promote":
            c["promotions"] += n
    return out
