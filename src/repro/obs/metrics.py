"""Unified counter/histogram registry + the single percentile ladder.

Before this module existed the repo had two independent percentile ladders
(``repro.core.metrics`` for the trace simulator, ``repro.fabric.metrics``
for the event engine) and every driver hand-rolled its own
``time.perf_counter()`` bracketing. This module is the one implementation
all of them now delegate to (DESIGN.md §8.4):

* :func:`percentile_ladder` — p50–p99.9 + avg/max over a sample, with an
  explicit ``n`` field and ``NaN`` (not 0.0) for the empty sample, so "no
  data" can never masquerade as "zero latency" in a downstream report.
* :class:`Registry` — named monotonically increasing counters and
  latency/size histograms; one registry per run, summarized once at the
  end. ``launch/serve.py`` builds its per-request TTFT + token-latency
  report on it.
* :meth:`Registry.span` — wall-clock span timer around device work. JAX
  dispatch is async, so a naive ``perf_counter`` pair times the *enqueue*;
  the span handle's ``sync`` hook blocks on the result inside the timed
  window (``jax.block_until_ready``) so the recorded duration covers the
  device work the caller actually waited for.

Everything here is host-side Python — nothing in this module is jitted or
traced, and nothing touches the hot data path.
"""

from __future__ import annotations

import contextlib
import math
import time

import numpy as np

DEFAULT_QS = (50.0, 90.0, 99.0, 99.9)


def percentile_ladder(samples, qs=DEFAULT_QS) -> dict:
    """``{p50, ..., avg, max, n}`` of a sample; NaNs when ``n == 0``.

    The empty-sample contract is deliberate: an all-zeros ladder is
    indistinguishable from a genuinely zero-latency run, so empty samples
    report ``NaN`` for every statistic plus ``n=0`` — callers that want to
    render something print the ``n`` field or skip the row.
    """
    keys = [f"p{q:g}" for q in qs]
    if samples is None or len(samples) == 0:
        return {k: math.nan for k in keys} | {"avg": math.nan,
                                              "max": math.nan, "n": 0}
    arr = np.asarray(samples, dtype=np.float64)
    out = {k: float(np.percentile(arr, q)) for k, q in zip(keys, qs)}
    out["avg"] = float(arr.mean())
    out["max"] = float(arr.max())
    out["n"] = int(arr.size)
    return out


class Counter:
    """A named monotone counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def add(self, n: int = 1) -> None:
        self.value += int(n)


class Histogram:
    """A named sample accumulator summarized as a percentile ladder."""

    __slots__ = ("name", "samples")

    def __init__(self, name: str):
        self.name = name
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    def extend(self, vs) -> None:
        self.samples.extend(float(v) for v in vs)

    @property
    def total(self) -> float:
        return float(sum(self.samples))

    def ladder(self, qs=DEFAULT_QS) -> dict:
        return percentile_ladder(self.samples, qs)


class _SpanHandle:
    """Mutable box a :meth:`Registry.span` body parks its device result in.

    Setting ``sync`` to a jax array/pytree makes the span block on it
    before stopping the clock, so the measured wall time includes the
    device work rather than just its dispatch.
    """

    __slots__ = ("sync",)

    def __init__(self):
        self.sync = None


class Registry:
    """Named counters + histograms for one run; summarized at the end."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._hists: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if name not in self._counters:
            self._counters[name] = Counter(name)
        return self._counters[name]

    def histogram(self, name: str) -> Histogram:
        if name not in self._hists:
            self._hists[name] = Histogram(name)
        return self._hists[name]

    @contextlib.contextmanager
    def span(self, name: str):
        """Time a block into histogram ``name`` (seconds), device-sync'd.

        >>> with reg.span("attention") as sp:
        ...     out = attention(...)
        ...     sp.sync = out          # block on the device result
        """
        import jax

        handle = _SpanHandle()
        t0 = time.perf_counter()
        try:
            yield handle
        finally:
            if handle.sync is not None:
                jax.block_until_ready(handle.sync)
            self.histogram(name).observe(time.perf_counter() - t0)

    def summary(self, qs=DEFAULT_QS) -> dict:
        """``{"counters": {name: int}, "histograms": {name: ladder}}``."""
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "histograms": {n: h.ladder(qs)
                           for n, h in sorted(self._hists.items())},
        }
