"""Trace export: Chrome trace-event (Perfetto-loadable) JSON and JSONL.

Two sinks for one event stream (:mod:`repro.obs.trace`):

* :func:`to_chrome_trace` / :func:`write_chrome_trace` — the Chrome
  trace-event format (the ``{"traceEvents": [...]}`` JSON object) that
  ``chrome://tracing`` and https://ui.perfetto.dev load directly. Each
  stream gets its own track (thread) of complete events laid out on the
  lock-step clock (one step = ``step_us`` µs of track time), and the
  shared link / per-NIC demand traffic becomes counter tracks.
* :func:`write_jsonl` / :func:`read_jsonl` — one event per line, for
  machine diffing (``obs/diff.py`` on two saved runs) and ad-hoc grep.

Both are lossless over the :class:`repro.obs.trace.Event` fields.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from .trace import Event, RequestPhase

#: Track-time layout of one lock step: wait/land phase, then demand
#: service, then issue. Fractions of ``step_us``.
_PHASE = {"land": 0.0, "defer": 0.05, "migrate": 0.15, "hit": 0.3,
          "partial": 0.3, "miss": 0.3, "invalidate": 0.55, "promote": 0.6,
          "demote": 0.65, "issue": 0.7, "drop": 0.7, "evict": 0.9}
_DUR = {"land": 0.25, "defer": 0.2, "migrate": 0.1, "hit": 0.2,
        "partial": 0.25, "miss": 0.35, "invalidate": 0.1, "promote": 0.05,
        "demote": 0.05, "issue": 0.25, "drop": 0.1, "evict": 0.1}

_STREAM_PID = 0
_LINK_PID = 1
_REQUEST_PID = 2


def _event_name(e: Event) -> str:
    if e.page >= 0:
        return f"{e.kind} p{e.page}"
    if e.count > 1:
        return f"{e.kind} x{e.count}"
    return e.kind


def to_chrome_trace(events, counters: dict | None = None,
                    step_us: float = 1000.0,
                    request_phases=None) -> dict:
    """Build the Chrome trace-event JSON object for an event stream.

    Args:
      events: iterable of :class:`repro.obs.trace.Event`.
      counters: optional ``{name: array}`` of per-step link totals —
        ``[T]`` arrays become one counter track, ``[T, G]`` arrays one
        multi-series counter track (series per NIC/shard). Step ``t``
        samples at ``t * step_us``.
      step_us: track microseconds per lock step.
      request_phases: optional iterable of
        :class:`repro.obs.trace.RequestPhase` — the continuous-batching
        request lifecycle. Each *request id* gets its own thread in a
        third "requests" process (admit / prefill-chunk / decode spans,
        evict instants), so a request's track stays contiguous even when
        slot recycling moves it between page-stream tracks.

    Returns the ``{"traceEvents": [...], ...}`` dict; ``json.dump`` it (or
    use :func:`write_chrome_trace`) and load in Perfetto.
    """
    events = list(events)
    phases = list(request_phases or ())
    max_step = max((e.step for e in events), default=0)
    out = [
        {"ph": "M", "pid": _STREAM_PID, "name": "process_name",
         "args": {"name": "page streams"}},
        {"ph": "M", "pid": _LINK_PID, "name": "process_name",
         "args": {"name": "fabric link"}},
    ]
    if phases:
        out.append({"ph": "M", "pid": _REQUEST_PID, "name": "process_name",
                    "args": {"name": "requests"}})
        for r in sorted({p.req for p in phases}):
            out.append({"ph": "M", "pid": _REQUEST_PID, "tid": r,
                        "name": "thread_name",
                        "args": {"name": f"request {r}"}})
    for s in sorted({e.stream for e in events}):
        out.append({"ph": "M", "pid": _STREAM_PID, "tid": s,
                    "name": "thread_name", "args": {"name": f"stream {s}"}})

    for p in phases:
        args = {"req": p.req, "slot": p.slot, "tokens": p.tokens,
                "start": p.start, "end": p.end}
        name = f"{p.kind} r{p.req}"
        if p.end > p.start:
            out.append({"ph": "X", "pid": _REQUEST_PID, "tid": p.req,
                        "ts": p.start * step_us,
                        "dur": (p.end - p.start) * step_us,
                        "name": name, "cat": p.kind, "args": args})
        else:
            out.append({"ph": "i", "s": "t", "pid": _REQUEST_PID,
                        "tid": p.req, "ts": p.start * step_us,
                        "name": name, "cat": p.kind, "args": args})

    for e in events:
        step = e.step if e.step >= 0 else max_step + 1   # summaries at end
        ts = step * step_us + _PHASE[e.kind] * step_us
        args = {"page": e.page, "shard": e.shard, "seq": e.seq,
                "count": e.count, "pref": e.pref, "step": e.step}
        if e.step < 0:
            out.append({"ph": "i", "s": "t", "pid": _STREAM_PID,
                        "tid": e.stream, "ts": ts, "name": _event_name(e),
                        "cat": e.kind, "args": args})
        else:
            out.append({"ph": "X", "pid": _STREAM_PID, "tid": e.stream,
                        "ts": ts, "dur": _DUR[e.kind] * step_us,
                        "name": _event_name(e), "cat": e.kind, "args": args})

    for name, arr in (counters or {}).items():
        arr = np.asarray(arr)
        for t in range(arr.shape[0]):
            if arr.ndim == 1:
                series = {"value": int(arr[t])}
            else:
                series = {f"nic{g}": int(arr[t, g])
                          for g in range(arr.shape[1])}
            out.append({"ph": "C", "pid": _LINK_PID, "name": name,
                        "ts": t * step_us, "args": series})

    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, events, counters: dict | None = None,
                       step_us: float = 1000.0, request_phases=None) -> None:
    """:func:`to_chrome_trace` straight to ``path``."""
    with open(path, "w") as f:
        json.dump(to_chrome_trace(events, counters, step_us,
                                  request_phases), f)


def write_jsonl(path: str, events) -> None:
    """One ``Event`` per line (its dataclass fields as a JSON object)."""
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(dataclasses.asdict(e)) + "\n")


def read_jsonl(path: str) -> list[Event]:
    """Inverse of :func:`write_jsonl`."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(Event(**json.loads(line)))
    return out


def write_request_jsonl(path: str, phases) -> None:
    """One :class:`repro.obs.trace.RequestPhase` per line."""
    with open(path, "w") as f:
        for p in phases:
            f.write(json.dumps(dataclasses.asdict(p)) + "\n")


def read_request_jsonl(path: str) -> list[RequestPhase]:
    """Inverse of :func:`write_request_jsonl` (lossless round trip)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(RequestPhase(**json.loads(line)))
    return out
