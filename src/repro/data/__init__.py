"""Data pipeline: sharded token streams with checkpointable state."""

from .pipeline import (MemmapSource, PrefetchQueue, SyntheticSource,
                       TokenPipeline, make_pipeline)

__all__ = ["MemmapSource", "PrefetchQueue", "SyntheticSource",
           "TokenPipeline", "make_pipeline"]
