"""Sharded, deterministic, checkpointable token pipeline.

Design constraints for 1000+ node scale:

* **Determinism & restart**: batch contents are a pure function of
  (seed, step, host_id) — the pipeline's full checkpoint state is one
  integer, so restarts resume bit-exact (the checkpoint manifest stores it).
* **Host sharding**: each host materializes only its slice of the global
  batch (global_batch / n_hosts rows); no coordinator.
* **Straggler decoupling**: a bounded background :class:`PrefetchQueue`
  keeps ``depth`` batches in flight; a slow storage fetch stalls the queue,
  not the train step, and a ``timeout`` surfaces persistent stragglers to
  the runtime monitor instead of hanging silently.

Sources: :class:`SyntheticSource` (seeded LCG tokens — used by tests/
examples) and :class:`MemmapSource` (flat uint16/uint32 token files).
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


class SyntheticSource:
    """Deterministic pseudo-corpus: tokens = f(seed, step, host)."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seed = seed

    def batch(self, step: int, host: int, rows: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host]))
        return rng.integers(0, self.vocab_size, (rows, seq + 1),
                            dtype=np.int32)


class MemmapSource:
    """Flat token file (np.memmap); rows strided by (step, host)."""

    def __init__(self, path: str, vocab_size: int, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab_size = vocab_size

    def batch(self, step: int, host: int, rows: int, seq: int) -> np.ndarray:
        n = len(self.tokens)
        out = np.empty((rows, seq + 1), np.int32)
        for r in range(rows):
            start = ((step * 1_000_003 + host * 7919 + r) * (seq + 1)) % max(
                1, n - seq - 1)
            out[r] = self.tokens[start:start + seq + 1]
        return out % self.vocab_size


class PrefetchQueue:
    """Bounded background prefetch with timeout-based straggler surfacing."""

    def __init__(self, fn, depth: int = 2, timeout: float = 60.0):
        self.fn = fn
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.timeout = timeout
        self._stop = threading.Event()
        self._exc: Exception | None = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        i = 0
        while not self._stop.is_set():
            try:
                item = self.fn(i)
            except Exception as e:          # surface in consumer
                self._exc = e
                break
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.5)
                    break
                except queue.Full:
                    continue
            i += 1

    def get(self):
        if self._exc:
            raise self._exc
        try:
            return self.q.get(timeout=self.timeout)
        except queue.Empty:
            raise TimeoutError(
                f"data prefetch stalled > {self.timeout}s (straggler?)")

    def stop(self):
        self._stop.set()


@dataclasses.dataclass
class TokenPipeline:
    """step-indexed batches for one host; state = next step index."""

    source: object
    global_batch: int
    seq_len: int
    n_hosts: int = 1
    host_id: int = 0
    step: int = 0                   # checkpointable

    @property
    def rows(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def peek(self, step: int) -> dict:
        toks = self.source.batch(step, self.host_id, self.rows, self.seq_len)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                "mask": np.ones((self.rows, self.seq_len), np.float32)}

    def __next__(self) -> dict:
        b = self.peek(self.step)
        self.step += 1
        return b

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, st: dict) -> None:
        self.step = int(st["step"])


def make_pipeline(vocab_size: int, global_batch: int, seq_len: int,
                  n_hosts: int = 1, host_id: int = 0, seed: int = 0,
                  path: str | None = None) -> TokenPipeline:
    src = (MemmapSource(path, vocab_size) if path
           else SyntheticSource(vocab_size, seed))
    return TokenPipeline(src, global_batch, seq_len, n_hosts, host_id)
