"""Tiered paged-KV serving (DESIGN.md §6).

Headline equivalence pin: decode attention served from the Leap-managed hot
pool (chunked demand sweep + remapped slot table) is bit-identical to the
flat-pool ``paged_decode_attention`` across hot-fraction {small, full},
ring {0, 8} and sequential + strided page layouts, on both the sync batched
and async issue/wait data paths. Plus the pool-level building blocks:
multi-page demand batches (``pool_wait_batch``) and write-coherence
invalidation (``pool_invalidate``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pool import (pool_init, pool_invalidate, pool_issue,
                             pool_stats, pool_wait_batch, ring_init)
from repro.paging.kv_cache import linear_page_table, paged_decode_attention
from repro.kernels.paged_attention import paged_attention_hot_slots
from repro.paging.tiered_kv import (TieredKV, tiered_attention,
                                    tiered_decode_step, tiered_init,
                                    tiered_invalidate, tiered_min_slots,
                                    tiered_slot_table_local, tiered_stats,
                                    tiered_sweep)

B, NPPS, PS, HKV, HQ, DH = 4, 8, 4, 2, 4, 8
N_PAGES = B * NPPS


def _cold(seed=0):
    k = jax.random.normal(jax.random.PRNGKey(seed), (N_PAGES, PS, HKV, DH),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (N_PAGES, PS, HKV, DH), jnp.float32)
    return {"k": k, "v": v}


def _flat(q, cold, pt, lengths):
    pool = {"k": cold["k"][None], "v": cold["v"][None]}
    return paged_decode_attention(q, pool, jnp.int32(0), pt, lengths)


def _geom(n_slots, ring=8, chunk=2, use_kernel=True):
    return TieredKV(N_PAGES, n_slots, PS, HKV, DH, chunk=chunk, pw_max=4,
                    ring_size=ring, use_kernel=use_kernel)


def _qlen(seed=2):
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, 1, HQ, DH),
                          jnp.float32)
    lengths = jnp.asarray([29, 17, 32, 5], jnp.int32)
    return q, lengths


class TestEquivalencePin:
    """Tiered logits == flat-pool logits, bitwise, for every geometry."""

    @pytest.mark.parametrize("stride", [1, 3])
    @pytest.mark.parametrize("ring,async_dp", [(0, False), (0, True),
                                               (8, False), (8, True)])
    @pytest.mark.parametrize("hot", ["small", "full"])
    def test_bit_identical_to_flat_pool(self, stride, ring, async_dp, hot):
        cold = _cold()
        pt = linear_page_table(B, NPPS, stride)
        q, lengths = _qlen()
        small = tiered_min_slots(NPPS, _geom(1, ring=ring))
        geom = _geom(small if hot == "small" else N_PAGES, ring=ring)
        assert hot == "full" or geom.n_slots < N_PAGES  # genuinely tiered
        st = tiered_init(geom, B, jnp.float32)
        st, out, info, resident = tiered_decode_step(
            st, cold, q, pt, lengths, geom, async_datapath=async_dp)
        assert bool(resident)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(_flat(q, cold, pt, lengths)))
        # the sweep really fetched the rows through the hot tier
        assert int(info["fetched"].sum()) > 0

    def test_second_sweep_all_hits_and_prefetch_covers_first(self):
        cold = _cold()
        pt = linear_page_table(B, NPPS)
        geom = _geom(tiered_min_slots(NPPS, _geom(1)))
        st = tiered_init(geom, B, jnp.float32)
        st, info1 = tiered_sweep(st, cold, pt, geom, async_datapath=True)
        assert int(info1["pref_hit"].sum()) > 0      # Leap ran ahead
        st, info2 = tiered_sweep(st, cold, pt, geom, async_datapath=True)
        assert int(info2["hit"].sum()) == B * NPPS   # fully resident now
        assert int(info2["fetched"].sum()) == 0
        s = tiered_stats(st, 0)
        assert s["prefetch_issued"] == (s["prefetch_hits"] + s["pollution"]
                                        + s["inflight_at_end"]
                                        + s["resident_unused"])

    def test_ragged_chunking_and_jnp_fallback_match_kernel(self):
        cold = _cold()
        pt = linear_page_table(B, NPPS)
        q, lengths = _qlen()
        flat = _flat(q, cold, pt, lengths)
        for chunk, use_kernel in ((3, True), (3, False), (5, False)):
            geom = _geom(N_PAGES, chunk=chunk, use_kernel=use_kernel)
            st = tiered_init(geom, B, jnp.float32)
            st, out, _, resident = tiered_decode_step(
                st, cold, q, pt, lengths, geom, async_datapath=True)
            assert bool(resident)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(flat))

    def test_undersized_hot_pool_rejected(self):
        geom = _geom(4)
        st = tiered_init(geom, B, jnp.float32)
        with pytest.raises(ValueError, match="tiered_min_slots"):
            tiered_sweep(st, _cold(), linear_page_table(B, NPPS), geom)


class TestFusedEquivalencePin:
    """Fused in-place hot-slot attention == unfused stacked path == flat
    pool, bitwise, on the same swept state (§6.4 extended to the fused
    consumer — all three run the identical per-page op sequence)."""

    @pytest.mark.parametrize("async_dp", [False, True])
    @pytest.mark.parametrize("hot", ["small", "full"])
    @pytest.mark.parametrize("mode", ["fused", "fused_async"])
    def test_fused_unfused_flat_bitwise(self, async_dp, hot, mode):
        cold = _cold()
        pt = linear_page_table(B, NPPS)
        q, lengths = _qlen()
        small = tiered_min_slots(NPPS, _geom(1))
        geom = _geom(small if hot == "small" else N_PAGES)
        st = tiered_init(geom, B, jnp.float32)
        st, _ = tiered_sweep(st, cold, pt, geom, async_datapath=async_dp)
        fused, ok_f = tiered_attention(q, st, pt, lengths, attn_kernel=mode)
        unfused, ok_u = tiered_attention(q, st, pt, lengths,
                                         attn_kernel="kernel")
        assert bool(ok_f) and bool(ok_u)
        pool = {"k": cold["k"][None], "v": cold["v"][None]}
        flat = paged_decode_attention(q, pool, jnp.int32(0), pt, lengths,
                                      use_kernel=True)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(flat))

    def test_fused_decode_step_modes(self):
        """tiered_decode_step threads the attn_kernel mode through."""
        cold = _cold()
        pt = linear_page_table(B, NPPS)
        q, lengths = _qlen()
        geom = _geom(tiered_min_slots(NPPS, _geom(1)))
        outs = []
        for mode in ("kernel", "fused", "fused_async"):
            st = tiered_init(geom, B, jnp.float32)
            st, out, _, resident = tiered_decode_step(
                st, cold, q, pt, lengths, geom, async_datapath=True,
                attn_kernel=mode)
            assert bool(resident)
            outs.append(np.asarray(out))
        assert all((o == outs[0]).all() for o in outs[1:])

    @pytest.mark.parametrize("mode", ["fused", "fused_async"])
    def test_non_resident_pages_masked(self, mode):
        """A partially swept context (some pages never made hot) trips the
        all_resident guard, and the fused kernel masks the missing pages —
        matching the masked exact-softmax oracle, deterministically — rather
        than silently reading whatever lives in an unrelated slot."""
        cold = _cold()
        pt = linear_page_table(B, NPPS)
        q, lengths = _qlen()
        geom = _geom(tiered_min_slots(NPPS, _geom(1)))
        st = tiered_init(geom, B, jnp.float32)
        # sweep only the first half of every context row
        st, _ = tiered_sweep(st, cold, pt[:, :NPPS // 2], geom)
        table, resident = tiered_slot_table_local(st, pt)
        assert not bool(resident)
        assert (np.asarray(table) < 0).any()         # genuinely missing
        out, ok = tiered_attention(q, st, pt, lengths, attn_kernel=mode)
        assert not bool(ok)
        hot = st["hot"]
        ref = paged_attention_hot_slots(q, hot["k"], hot["v"], table,
                                        lengths, use_kernel=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)
        out2, _ = tiered_attention(q, st, pt, lengths, attn_kernel=mode)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


class TestWriteCoherence:
    def test_append_then_invalidate_stays_bit_identical(self):
        cold = _cold()
        pt = linear_page_table(B, NPPS)
        q, lengths = _qlen()
        geom = _geom(tiered_min_slots(NPPS, _geom(1)))
        st = tiered_init(geom, B, jnp.float32)
        st, _ = tiered_sweep(st, cold, pt, geom, async_datapath=True)
        # mutate page 3 of request 0's context (in range of length 29)
        new_page = jax.random.normal(jax.random.PRNGKey(9), (PS, HKV, DH))
        cold2 = {"k": cold["k"].at[3].set(new_page), "v": cold["v"]}
        # stale hot copy without invalidation -> shows the bug the API fixes
        st_stale, _ = tiered_sweep(st, cold2, pt, geom, async_datapath=True)
        out_stale, _ = tiered_attention(q, st_stale, pt, lengths)
        flat2 = _flat(q, cold2, pt, lengths)
        assert not np.array_equal(np.asarray(out_stale), np.asarray(flat2))
        # invalidate + resweep -> coherent again
        st = tiered_invalidate(st, jnp.full((B, 1), 3, jnp.int32))
        st, _ = tiered_sweep(st, cold2, pt, geom, async_datapath=True)
        out, resident = tiered_attention(q, st, pt, lengths)
        assert bool(resident)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(flat2))

    def test_pool_invalidate_keeps_decomposition(self):
        st, ring = pool_init(32, 8), ring_init(4)
        # one in-flight prefetch + one landed unconsumed prefetch
        st, ring = pool_issue(st, ring, jnp.asarray([5, 9], jnp.int32),
                              jnp.ones((2,), bool), jnp.int32(0),
                              jnp.int32(1))
        pool = jnp.arange(32 * 2, dtype=jnp.float32).reshape(32, 2)
        hot = jnp.zeros((8, 2))
        st, ring, hot, _, info = pool_wait_batch(
            st, ring, hot, pool, jnp.asarray([-1], jnp.int32),
            jnp.zeros((1,), bool), jnp.int32(1))
        # page 5, 9 both landed; invalidate 5 (resident) and 7 (absent)
        st2, ring2 = pool_invalidate(st, ring,
                                     jnp.asarray([5, 7], jnp.int32),
                                     jnp.ones((2,), bool))
        s = pool_stats(st2, ring2)
        assert s["pollution"] == 1 and s["prefetch_issued"] == 2
        assert s["prefetch_issued"] == (s["prefetch_hits"] + s["pollution"]
                                        + s["inflight_at_end"]
                                        + s["resident_unused"])
        # invalidating an in-flight entry also keeps the sum
        st3, ring3 = pool_issue(st2, ring2, jnp.asarray([11], jnp.int32),
                                jnp.ones((1,), bool), jnp.int32(1),
                                jnp.int32(1))
        st3, ring3 = pool_invalidate(st3, ring3,
                                     jnp.asarray([11], jnp.int32),
                                     jnp.ones((1,), bool))
        s3 = pool_stats(st3, ring3)
        assert s3["inflight_at_end"] == 0
        assert s3["prefetch_issued"] == (s3["prefetch_hits"] + s3["pollution"]
                                         + s3["inflight_at_end"]
                                         + s3["resident_unused"])


class TestPoolWaitBatch:
    def _setup(self, ring_cap=4):
        st, ring = pool_init(64, 8), ring_init(ring_cap)
        pool = jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4)
        hot = jnp.zeros((8, 4))
        return st, ring, hot, pool

    def test_chunk_of_demands_served_in_one_call(self):
        st, ring, hot, pool = self._setup()
        pages = jnp.asarray([3, 4, 5], jnp.int32)
        st, ring, hot, slots, info = pool_wait_batch(
            st, ring, hot, pool, pages, jnp.ones((3,), bool), jnp.int32(0),
            lazy=True)
        assert bool(info["fetched"].all()) and not bool(info["hit"].any())
        for i, p in enumerate([3, 4, 5]):
            np.testing.assert_array_equal(np.asarray(hot[slots[i]]),
                                          np.asarray(pool[p]))
        # lazy retention: all three still mapped after the call
        assert int(jnp.sum(st["page_slot"] >= 0)) == 3

    def test_landings_and_partials_reported_per_demand(self):
        st, ring, hot, pool = self._setup()
        st, ring = pool_issue(st, ring, jnp.asarray([7, 8], jnp.int32),
                              jnp.ones((2,), bool), jnp.int32(0),
                              jnp.int32(1))
        # at now=1 both land; demand [7, 9]: 7 = prefetched hit, 9 = miss
        st, ring, hot, slots, info = pool_wait_batch(
            st, ring, hot, pool, jnp.asarray([7, 9], jnp.int32),
            jnp.ones((2,), bool), jnp.int32(1), lazy=True)
        assert int(info["landed"].sum()) == 2
        landed = set(np.asarray(info["landed_pages"])[
            np.asarray(info["landed"])].tolist())
        assert landed == {7, 8}
        assert bool(info["prefetched_hit"][0]) and bool(info["fetched"][1])
        # at now=0 the same demand would have been a partial hit instead
        st2, ring2, hot2, pool2 = self._setup()
        st2, ring2 = pool_issue(st2, ring2, jnp.asarray([7], jnp.int32),
                                jnp.ones((1,), bool), jnp.int32(0),
                                jnp.int32(1))
        st2, ring2, hot2, slots2, info2 = pool_wait_batch(
            st2, ring2, hot2, pool2, jnp.asarray([7], jnp.int32),
            jnp.ones((1,), bool), jnp.int32(0), lazy=True)
        assert bool(info2["partial_hit"][0])
        np.testing.assert_array_equal(np.asarray(hot2[slots2[0]]),
                                      np.asarray(pool2[7]))

    def test_invalid_entries_touch_nothing(self):
        st, ring, hot, pool = self._setup()
        st, ring, hot, slots, info = pool_wait_batch(
            st, ring, hot, pool, jnp.full((3,), -1, jnp.int32),
            jnp.zeros((3,), bool), jnp.int32(0), lazy=True)
        s = pool_stats(st, ring)
        assert s["faults"] == 0 and int(slots.min()) == -1


class TestBudgetedTieredSweep:
    def test_link_budget_defers_but_stays_correct(self):
        cold = _cold()
        pt = linear_page_table(B, NPPS)
        q, lengths = _qlen()
        geom = _geom(N_PAGES, chunk=1)
        st = tiered_init(geom, B, jnp.float32)
        st, out, info, resident = tiered_decode_step(
            st, cold, q, pt, lengths, geom, async_datapath=True,
            link_budget=1)
        assert bool(resident)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(_flat(q, cold, pt, lengths)))
        assert int(info["deferred"].sum()) > 0       # budget actually bound
        # a huge budget never defers
        st2 = tiered_init(geom, B, jnp.float32)
        st2, info2 = tiered_sweep(st2, cold, pt, geom, async_datapath=True,
                                  link_budget=10_000)
        assert int(info2["deferred"].sum()) == 0


class TestTraceDiff:
    """§8 wiring: the sweep's decoded event log pins its counters, and two
    identical sweeps decode to identical traces — any nondeterminism is
    localized by ``first_divergence`` to an exact (chunk step, stream)."""

    def test_sweep_trace_pins_counters_and_is_deterministic(self):
        from repro.obs import (assert_traces_equal, decode_sweep_events,
                               events_to_counts, summary_events)
        cold = _cold()
        pt = linear_page_table(B, NPPS)
        geom = _geom(tiered_min_slots(NPPS, _geom(1)))
        traces = []
        for _ in range(2):
            st = tiered_init(geom, B, jnp.float32)
            st, info = tiered_sweep(st, cold, pt, geom, async_datapath=True)
            ev = decode_sweep_events(info)
            stats = [tiered_stats(st, i) for i in range(B)]
            counts = events_to_counts(ev + summary_events(stats), B)
            for i, s in enumerate(stats):
                for k in ("hits", "misses", "partial_hits", "prefetch_hits",
                          "prefetch_issued", "deferred", "ring_drops",
                          "pollution"):
                    assert counts[i][k] == s[k], (i, k)
            traces.append(ev)
        assert_traces_equal(traces[0], traces[1], "run A", "run B",
                            context="tiered sweep determinism")
