"""Boyer-Moore majority vote + FINDTREND: properties and paper example."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # deterministic tests still run
    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    st = _StrategyStub()

from repro.core.history import AccessHistory
from repro.core.trend import boyer_moore, find_trend, find_trend_jax
from repro.core.history import init_history, push_history


# -- boyer_moore ------------------------------------------------------------
@given(st.lists(st.integers(-50, 50), min_size=1, max_size=64))
def test_boyer_moore_matches_counting_oracle(values):
    cand, found = boyer_moore(values)
    arr = np.asarray(values)
    counts = {v: int((arr == v).sum()) for v in set(values)}
    true_majority = [v for v, c in counts.items() if c >= len(values) // 2 + 1]
    if true_majority:
        assert found and cand == true_majority[0]
    else:
        assert not found


def test_boyer_moore_empty():
    assert boyer_moore([]) == (0, False)


# -- FINDTREND (paper §3.2.1 worked example, Fig. 5) --------------------------
PAPER_TRACE = [0x48, 0x45, 0x42, 0x3F, 0x3C, 0x02, 0x04, 0x06, 0x08,
               0x0A, 0x0C, 0x10, 0x39, 0x12, 0x14, 0x16]


def test_paper_example_fig5():
    """H=8, N_split=2: trend -3 at t3; none at t7; +2 at t8; +2 at t15."""
    h = AccessHistory(8)
    results = {}
    for i, page in enumerate(PAPER_TRACE):
        h.push(page)
        results[i] = find_trend(h, n_split=2)
    assert results[3] == (-3, True)          # Fig. 5a
    assert results[7][1] is False            # Fig. 5b: no majority
    assert results[8] == (2, True)           # Fig. 5c: adapts to +2
    assert results[15] == (2, True)          # Fig. 5d: ignores t12/t13 noise


def test_trend_tolerates_irregularities():
    """A window of w detects a trend with up to floor(w/2)-1 outliers."""
    h = AccessHistory(8)
    pages = [0, 3, 6, 100, 9, 12, 15]        # one outlier in +3 run
    for p in pages:
        h.push(p)
    delta, found = find_trend(h, n_split=2)
    # within window 4 (newest-first): deltas 3,3,-91?,... -> majority +3
    assert found and delta == 3


def _push_deltas(h_size, deltas):
    """Build twin histories whose ring holds exactly ``deltas`` (oldest first)."""
    import jax.numpy as jnp
    h = AccessHistory(h_size)
    state = init_history(h_size)
    page = 0
    h.push(page)                              # first push records delta 0
    state, _ = push_history(state, jnp.int32(page))
    for d in deltas:
        page += d
        h.push(page)
        state, _ = push_history(state, jnp.int32(page))
    return h, state


def test_final_rung_clamps_to_full_history():
    """Regression: h_size=32, n_split=3 probes w=10,20 — pure doubling would
    skip w=32 and miss a majority that only exists over the full history."""
    # newest 20 deltas: 5 copies of +7 scattered among 15 distinct values
    # (no majority in windows 10 or 20); older 12 all +7 -> 17/32 majority.
    noise = [100 + 13 * i for i in range(15)]
    newest = []
    for i in range(20):
        newest.append(7 if i % 4 == 0 else noise.pop())
    deltas = [7] * 12 + newest[::-1]          # pushed oldest -> newest
    h, state = _push_deltas(32, deltas)
    assert find_trend(h, n_split=3) == (7, True)
    jx = find_trend_jax(state, 3)
    assert bool(jx[1]) and int(jx[0]) == 7
    # sanity: the sub-h_size rungs alone genuinely have no majority
    assert boyer_moore(h.window(10))[1] is False
    assert boyer_moore(h.window(20))[1] is False


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(-20, 20), min_size=0, max_size=40),
       st.sampled_from([2, 3, 4, 5, 6, 7, 8, 12, 16, 31, 32]))
def test_twins_agree_for_non_power_of_two_n_split(deltas, n_split):
    """find_trend_jax == find_trend over random histories, any n_split."""
    h, state = _push_deltas(32, deltas)
    ref = find_trend(h, n_split)
    jx = find_trend_jax(state, n_split)
    assert ref[1] == bool(jx[1])
    if ref[1]:
        assert ref[0] == int(jx[0])


# -- JAX twin equivalence ------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1 << 16), min_size=2, max_size=40),
       st.sampled_from([2, 4, 8]))
def test_find_trend_jax_equals_numpy(pages, n_split):
    h = AccessHistory(16)
    state = init_history(16)
    import jax.numpy as jnp
    for p in pages:
        h.push(p)
        state, _ = push_history(state, jnp.int32(p))
    ref = find_trend(h, n_split)
    jx = find_trend_jax(state, n_split)
    assert ref[1] == bool(jx[1])
    if ref[1]:
        assert ref[0] == int(jx[0])
