"""Boyer-Moore majority vote + FINDTREND: properties and paper example."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.history import AccessHistory
from repro.core.trend import boyer_moore, find_trend, find_trend_jax
from repro.core.history import init_history, push_history


# -- boyer_moore ------------------------------------------------------------
@given(st.lists(st.integers(-50, 50), min_size=1, max_size=64))
def test_boyer_moore_matches_counting_oracle(values):
    cand, found = boyer_moore(values)
    arr = np.asarray(values)
    counts = {v: int((arr == v).sum()) for v in set(values)}
    true_majority = [v for v, c in counts.items() if c >= len(values) // 2 + 1]
    if true_majority:
        assert found and cand == true_majority[0]
    else:
        assert not found


def test_boyer_moore_empty():
    assert boyer_moore([]) == (0, False)


# -- FINDTREND (paper §3.2.1 worked example, Fig. 5) --------------------------
PAPER_TRACE = [0x48, 0x45, 0x42, 0x3F, 0x3C, 0x02, 0x04, 0x06, 0x08,
               0x0A, 0x0C, 0x10, 0x39, 0x12, 0x14, 0x16]


def test_paper_example_fig5():
    """H=8, N_split=2: trend -3 at t3; none at t7; +2 at t8; +2 at t15."""
    h = AccessHistory(8)
    results = {}
    for i, page in enumerate(PAPER_TRACE):
        h.push(page)
        results[i] = find_trend(h, n_split=2)
    assert results[3] == (-3, True)          # Fig. 5a
    assert results[7][1] is False            # Fig. 5b: no majority
    assert results[8] == (2, True)           # Fig. 5c: adapts to +2
    assert results[15] == (2, True)          # Fig. 5d: ignores t12/t13 noise


def test_trend_tolerates_irregularities():
    """A window of w detects a trend with up to floor(w/2)-1 outliers."""
    h = AccessHistory(8)
    pages = [0, 3, 6, 100, 9, 12, 15]        # one outlier in +3 run
    for p in pages:
        h.push(p)
    delta, found = find_trend(h, n_split=2)
    # within window 4 (newest-first): deltas 3,3,-91?,... -> majority +3
    assert found and delta == 3


# -- JAX twin equivalence ------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1 << 16), min_size=2, max_size=40),
       st.sampled_from([2, 4, 8]))
def test_find_trend_jax_equals_numpy(pages, n_split):
    h = AccessHistory(16)
    state = init_history(16)
    import jax.numpy as jnp
    for p in pages:
        h.push(p)
        state, _ = push_history(state, jnp.int32(p))
    ref = find_trend(h, n_split)
    jx = find_trend_jax(state, n_split)
    assert ref[1] == bool(jx[1])
    if ref[1]:
        assert ref[0] == int(jx[0])
