"""Continuous-batching serving engine: lifecycle, conservation, equivalence.

Three layers of coverage for :mod:`repro.serving` (DESIGN.md §10):

* **Control plane** — request state-machine edges, capacity-reserving
  admission, and the conservation invariants (every admitted request
  finishes or is queued, no slot double-occupancy, pages allocated ==
  pages recycled, allocator occupancy back to baseline) driven over random
  arrival/finish schedules — a seeded deterministic loop always runs, and
  a hypothesis property widens the net when the library is installed.
* **Model plane** — chunked prefill through per-request batch-1
  ``decode_step`` states produces the same first-token logits as the
  one-shot ``model.prefill`` (5e-3 model tolerance), for any chunking.
* **Observability** — allocator seq-stamps (recycled pages re-allocated
  to a new request never alias the previous owner's trace events), seeded
  :class:`ArrivalProcess` determinism, and the per-request lifecycle
  Perfetto track + JSONL round trip.
"""

import functools
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:                       # deterministic tests still run
    HAVE_HYPOTHESIS = False

from repro.fabric.tenants import ArrivalProcess, TenantSpec
from repro.obs.export import (read_request_jsonl, to_chrome_trace,
                              write_request_jsonl)
from repro.obs.trace import RequestPhase
from repro.paging.kv_cache import PageAllocator
from repro.serving import (AdmissionQueue, Request, ServeConfig,
                           ServingEngine, SlotScheduler, SyntheticExecutor)
from repro.serving.request import DECODE, FINISHED, PREFILL


# --------------------------------------------------------------------------
# request state machine
# --------------------------------------------------------------------------
class TestRequestLifecycle:
    def test_happy_path_edges(self):
        r = Request(0, prompt_len=5, gen=3, arrival_step=3)
        r.to(PREFILL, 4)
        assert r.admit_step == 4
        assert r.advance_prefill(3, 5) == 3
        assert r.state == PREFILL and r.ttft_steps == -1
        r.advance_prefill(8, 6)          # clamped to the 2 remaining tokens
        assert r.prefilled == 5 and r.state == DECODE
        assert r.decoded == 1            # prefill emits the first token
        assert r.first_token_step == 6 and r.ttft_steps == 3
        assert not r.advance_decode(7)
        assert r.advance_decode(8)       # quota reached
        r.to(FINISHED, 8)
        assert r.finish_step == 8

    def test_illegal_edges_rejected(self):
        r = Request(0, prompt_len=2, gen=1)
        with pytest.raises(ValueError):
            r.to(DECODE, 0)              # WAITING -> DECODE skips PREFILL
        with pytest.raises(ValueError):
            r.advance_decode(0)          # not decoding yet
        r.to(PREFILL, 0)
        with pytest.raises(ValueError):
            r.to(FINISHED, 0)            # PREFILL -> FINISHED skips DECODE

    def test_page_demand(self):
        r = Request(0, prompt_len=5, gen=3)
        assert r.max_len == 8
        assert r.pages_needed(page_size=4) == 2
        assert r.pages_needed(page_size=3) == 3


# --------------------------------------------------------------------------
# scheduler conservation over random arrival/finish schedules
# --------------------------------------------------------------------------
def drive_schedule(seed: int, n_requests: int, n_slots: int, page_size: int,
                   slack_pages: int, gang: bool) -> None:
    """Run a full random schedule through the control plane and assert the
    conservation invariants. Pure Python — no JAX, no model."""
    rng = np.random.default_rng(seed)
    reqs = [Request(i, prompt_len=int(rng.integers(1, 12)),
                    gen=int(rng.integers(1, 6)),
                    arrival_step=int(rng.integers(0, 20)))
            for i in range(n_requests)]
    n_pages = max(r.pages_needed(page_size) for r in reqs) + slack_pages
    alloc = PageAllocator(n_pages)
    sched = SlotScheduler(n_slots, alloc, page_size, gang=gang)
    queue = AdmissionQueue(reqs)
    finished: list[Request] = []
    t = 0
    while len(queue) or sched.active():
        assert t < 10_000, "schedule livelocked"
        sched.admit_ready(queue, t)
        occupants = [r.req_id for r in sched.active()]
        assert len(occupants) == len(set(occupants)), "slot double-occupancy"
        assert sched.reserved >= 0
        assert alloc.in_use + alloc.free_count == n_pages
        for req in list(sched.active()):
            if req.state == PREFILL:
                n = min(int(rng.integers(1, 5)),
                        req.prompt_len - req.prefilled)
                for pos in range(req.prefilled, req.prefilled + n):
                    sched.page_for_position(req, pos)
                req.advance_prefill(n, t)
                if req.state == DECODE and req.decoded >= req.gen:
                    sched.finish(req, t)
                    finished.append(req)
            elif req.state == DECODE:
                sched.page_for_position(req,
                                        req.prefilled + req.decoded - 1)
                if req.advance_decode(t):
                    sched.finish(req, t)
                    finished.append(req)
        t += 1
    # conservation: every request finished exactly once, pool at baseline
    assert sorted(r.req_id for r in finished) == list(range(n_requests))
    assert all(r.state == FINISHED for r in finished)
    assert sched.pages_allocated == sched.pages_recycled > 0
    assert alloc.in_use == 0 and alloc.alive() == ()
    assert alloc.occupancy() == 0.0
    assert sched.reserved == 0
    assert sched.active() == [] and len(queue) == 0


class TestSchedulerConservation:
    @pytest.mark.parametrize("seed", range(8))
    def test_random_schedules_conserve(self, seed):
        rng = np.random.default_rng(1000 + seed)
        drive_schedule(seed,
                       n_requests=int(rng.integers(1, 14)),
                       n_slots=int(rng.integers(1, 5)),
                       page_size=int(rng.integers(1, 6)),
                       slack_pages=int(rng.integers(0, 9)),
                       gang=bool(seed % 2))

    def test_admission_waits_on_memory_not_slots(self):
        """A tight pool stalls admission even with free slots, and the
        head-of-line request enters once pages recycle."""
        alloc = PageAllocator(4)
        sched = SlotScheduler(4, alloc, page_size=1)
        a = Request(0, prompt_len=2, gen=2)          # needs all 4 pages
        b = Request(1, prompt_len=2, gen=2)
        queue = AdmissionQueue([a, b])
        assert sched.admit_ready(queue, 0) == [a]    # b does not fit
        assert sched.free_slots() and len(queue) == 1
        assert sched.headroom() == 0
        # drive a to completion; b admits only after a's pages recycle
        for pos in range(2):
            sched.page_for_position(a, pos)
        a.advance_prefill(2, 0)
        assert sched.admit_ready(queue, 1) == []
        sched.page_for_position(a, 2)
        a.advance_decode(1)
        sched.finish(a, 1)
        assert sched.admit_ready(queue, 2) == [b]

    def test_gang_admission_waits_for_empty_slots(self):
        alloc = PageAllocator(64)
        sched = SlotScheduler(2, alloc, page_size=4, gang=True)
        reqs = [Request(i, prompt_len=4, gen=1, arrival_step=0)
                for i in range(3)]
        queue = AdmissionQueue(reqs)
        assert len(sched.admit_ready(queue, 0)) == 2     # first gang
        assert sched.admit_ready(queue, 1) == []         # slots busy
        for r in list(sched.active()):
            for pos in range(4):
                sched.page_for_position(r, pos)
            r.advance_prefill(4, 1)
            sched.finish(r, 1)
        assert len(sched.admit_ready(queue, 2)) == 1     # next gang

    @pytest.mark.skipif(not HAVE_HYPOTHESIS,
                        reason="hypothesis not installed")
    def test_conservation_property(self):
        @settings(max_examples=60, deadline=None)
        @given(seed=hst.integers(0, 2**31 - 1),
               n_requests=hst.integers(1, 16),
               n_slots=hst.integers(1, 5),
               page_size=hst.integers(1, 6),
               slack_pages=hst.integers(0, 10),
               gang=hst.booleans())
        def prop(seed, n_requests, n_slots, page_size, slack_pages, gang):
            drive_schedule(seed, n_requests, n_slots, page_size,
                           slack_pages, gang)

        prop()


# --------------------------------------------------------------------------
# allocator seq-stamps: recycled pages never alias their previous life
# --------------------------------------------------------------------------
class TestAllocatorStamps:
    def test_recycled_pages_get_strictly_greater_stamps(self):
        a = PageAllocator(8)
        first = a.alloc_seq(1, 4)
        gen1 = {p: a.stamp_of(p) for p in first}
        assert all(s > 0 for s in gen1.values())
        assert a.alive() == (1,) and a.occupancy() == 0.5
        assert a.owner_of(first[0]) == 1
        a.recycle(first)
        assert a.alive() == () and a.in_use == 0
        # free-list determinism re-hands the same physical pages to the
        # next request — the aliasing hazard this guard exists for
        second = a.alloc_seq(2, 4)
        reused = set(first) & set(second)
        assert reused, "free-list should recycle the same physical pages"
        for p in reused:
            assert a.stamp_of(p) > gen1[p]
        assert a.owner_of(second[0]) == 2

    def test_stamps_monotone_across_many_generations(self):
        a = PageAllocator(2)
        last = {0: 0, 1: 0}
        for turn in range(5):
            pages = a.alloc_seq(turn, 2)
            for p in pages:
                assert a.stamp_of(p) > last[p]
                last[p] = a.stamp_of(p)
            a.recycle(pages)

    def test_never_allocated_page_has_zero_stamp(self):
        a = PageAllocator(4)
        a.alloc_seq(0, 1)
        allocated = a.owned[0][0]
        for p in range(4):
            if p != allocated:
                assert a.stamp_of(p) == 0
                assert a.owner_of(p) is None


# --------------------------------------------------------------------------
# arrival process: seeded determinism, shared with fabric tenants
# --------------------------------------------------------------------------
class TestArrivalProcess:
    def test_seeded_determinism(self):
        ap = ArrivalProcess(kind="bursty", think_time=50.0, burst_len=3,
                            idle_time=400.0)
        t1 = ap.arrival_times(32, seed=7)
        t2 = ap.arrival_times(32, seed=7)
        np.testing.assert_array_equal(t1, t2)
        t3 = ap.arrival_times(32, seed=8)
        assert not np.array_equal(t1, t3)
        s1 = ap.arrival_steps(32, seed=7, step_us=100.0)
        s2 = ap.arrival_steps(32, seed=7, step_us=100.0)
        np.testing.assert_array_equal(s1, s2)

    def test_constant_kind_is_exact(self):
        ap = ArrivalProcess(kind="constant", think_time=10.0)
        np.testing.assert_allclose(ap.arrival_times(5, seed=0),
                                   [0.0, 10.0, 20.0, 30.0, 40.0])

    def test_bursty_gaps_only_at_burst_boundaries(self):
        ap = ArrivalProcess(kind="bursty", think_time=1.0, burst_len=4,
                            idle_time=1000.0)
        gaps = np.diff(ap.arrival_times(16, seed=3))
        idx = np.arange(1, 16)
        assert (gaps[idx % 4 != 0] == 1.0).all()
        assert (gaps[idx % 4 == 0] > 1.0).all()

    def test_churn_adds_downtime_and_restart(self):
        ap = ArrivalProcess(kind="churn", think_time=1.0, churn_every=5,
                            churn_downtime=99.0)
        rng = np.random.default_rng(0)
        gap, restart = ap.gap(rng, 5, 20)
        assert restart and gap == 100.0
        gap, restart = ap.gap(rng, 6, 20)
        assert not restart and gap == 1.0

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            ArrivalProcess(kind="poissonish")

    def test_tenant_spec_builds_matching_process(self):
        spec = TenantSpec(name="t", trace=[0, 1, 2], arrival="bursty",
                          think_time=5.0, burst_len=2, idle_time=77.0)
        ap = spec.arrival_process()
        assert ap.kind == "bursty" and ap.burst_len == 2
        assert ap.idle_time == 77.0 and ap.think_time == 5.0


# --------------------------------------------------------------------------
# chunked prefill == one-shot prefill (model plane)
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def _smoke_model_executor():
    from repro import configs as cfglib
    from repro.serving.executor import ModelExecutor
    return ModelExecutor(cfglib.get_smoke_config("qwen2_5_3b"), seed=0)


def _chunked_first_logits(ex, req_id: int, prompt_len: int, chunk: int):
    req = Request(req_id, prompt_len=prompt_len, gen=2)
    req.to(PREFILL, 0)
    ex.begin(req)
    while req.state == PREFILL:
        n = min(chunk, req.prompt_len - req.prefilled)
        ex.prefill_chunk(req, n)
        req.advance_prefill(n, 0)
    chunked = np.asarray(ex.last_logits[req.req_id], np.float32)
    oneshot = np.asarray(ex.oneshot_prefill_logits(req), np.float32)
    ex.end(req)
    return chunked, oneshot


class TestChunkedPrefillEquivalence:
    @pytest.mark.parametrize("chunk", [1, 3, 7])
    def test_matches_oneshot_fixed_chunks(self, chunk):
        ex = _smoke_model_executor()
        chunked, oneshot = _chunked_first_logits(ex, 100 + chunk,
                                                 prompt_len=7, chunk=chunk)
        np.testing.assert_allclose(chunked, oneshot, rtol=5e-3, atol=5e-3)
        # and greedy decoding agrees on the actual first token
        assert int(chunked.argmax()) == int(oneshot.argmax())

    @pytest.mark.skipif(not HAVE_HYPOTHESIS,
                        reason="hypothesis not installed")
    def test_matches_oneshot_property(self):
        @settings(max_examples=6, deadline=None)
        @given(prompt_len=hst.integers(2, 9), chunk=hst.integers(1, 9))
        def prop(prompt_len, chunk):
            ex = _smoke_model_executor()
            chunked, oneshot = _chunked_first_logits(
                ex, 1000 + prompt_len * 16 + chunk, prompt_len, chunk)
            np.testing.assert_allclose(chunked, oneshot, rtol=5e-3,
                                       atol=5e-3)

        prop()


# --------------------------------------------------------------------------
# engine end-to-end (synthetic executor: real data path + pins, no model)
# --------------------------------------------------------------------------
def _run_engine(**overrides):
    cfg = ServeConfig(requests=5, slots=2, prompt_len=8, gen=4, page_size=4,
                      prefill_chunk=4, arrival="bursty", burst_len=2,
                      think_time=1000.0, idle_time=3000.0, seed=3,
                      **overrides)
    ex = SyntheticExecutor(n_kv_heads=2, head_dim=8, seed=0)
    eng = ServingEngine(cfg, ex)
    return eng, eng.run()


class TestEngineEndToEnd:
    def test_continuous_run_drains_clean(self):
        eng, report = _run_engine(trace=True)
        assert report["tiered_equiv_ok"]
        assert report["requests_finished"] == 5
        assert report["alloc_in_use_end"] == 0
        assert report["pages_allocated"] == report["pages_recycled"] > 0
        assert report["trace_totals_ok"]
        assert report["ttft_steps"]["n"] == 5
        # every request leaves a full lifecycle on the request track
        kinds_by_req = {}
        for p in eng.phases:
            kinds_by_req.setdefault(p.req, set()).add(p.kind)
        assert set(kinds_by_req) == set(range(5))
        for kinds in kinds_by_req.values():
            assert kinds == {"admit", "prefill_chunk", "decode", "evict"}

    def test_gang_ttft_never_beats_continuous(self):
        _, cont = _run_engine()
        _, gang = _run_engine(gang=True)
        assert gang["tiered_equiv_ok"] and cont["tiered_equiv_ok"]
        assert cont["mean_ttft_steps"] <= gang["mean_ttft_steps"]
        assert gang["steps"] >= cont["steps"]

    @pytest.mark.parametrize("mode", ["fused", "fused_async", "kernel"])
    def test_fused_attn_kernel_pin_over_dynamic_batches(self, mode):
        """The per-step §6.4 flat pin holds with the fused hot-slot kernel
        across the engine's dynamic batch compositions — including steps
        where some slots are idle (all -1 page rows, length 0) and the
        fused kernel must mask, not read, their slots."""
        eng, report = _run_engine(attn_kernel=mode)
        assert report["tiered_equiv_ok"]
        assert report["requests_finished"] == 5
        assert report["alloc_in_use_end"] == 0
        # requests (5) > slots (2): the run necessarily hit partial batches
        assert report["steps"] > 0


# --------------------------------------------------------------------------
# request-lifecycle export: JSONL round trip + Perfetto track
# --------------------------------------------------------------------------
class TestRequestPhaseExport:
    PHASES = [
        RequestPhase("admit", 0, 0, 2, slot=1),
        RequestPhase("prefill_chunk", 0, 2, 3, slot=1, tokens=4),
        RequestPhase("decode", 0, 3, 7, slot=1, tokens=4),
        RequestPhase("evict", 0, 7, 7, slot=1),
        RequestPhase("admit", 1, 1, 1, slot=0),
    ]

    def test_jsonl_round_trip_lossless(self, tmp_path):
        path = str(tmp_path / "req.jsonl")
        write_request_jsonl(path, self.PHASES)
        assert read_request_jsonl(path) == self.PHASES

    def test_unknown_phase_kind_rejected(self):
        with pytest.raises(ValueError):
            RequestPhase("warmup", 0, 0, 1)

    def test_chrome_trace_request_track_keyed_by_request_id(self):
        doc = to_chrome_trace([], request_phases=self.PHASES)
        json.dumps(doc)                   # serializable
        ev = doc["traceEvents"]
        procs = {e["args"]["name"] for e in ev
                 if e.get("ph") == "M" and e["name"] == "process_name"}
        assert "requests" in procs
        rows = [e for e in ev if e.get("pid") == 2 and e.get("ph") != "M"]
        # spans keyed by request id (tid == req), not slot
        assert {e["tid"] for e in rows} == {0, 1}
        span = next(e for e in rows if e["cat"] == "decode")
        assert span["ph"] == "X" and span["dur"] == 4 * 1000.0
        assert span["args"]["slot"] == 1
        instant = next(e for e in rows if e["cat"] == "evict")
        assert instant["ph"] == "i"       # zero-width phase -> instant
