"""AccessHistory ring buffer + adaptive prefetch window (Alg. 2) properties."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.history import AccessHistory
from repro.core.window import (PrefetchWindow, _round_up_pow2_jax,
                               init_window_state, next_window_size,
                               note_prefetch_hits, round_up_pow2)


@given(st.lists(st.integers(0, 1 << 20), min_size=1, max_size=100))
def test_history_window_returns_newest_first(pages):
    h = AccessHistory(16)
    deltas = []
    last = None
    for p in pages:
        deltas.append(0 if last is None else p - last)
        last = p
        h.push(p)
    got = h.window(min(16, len(pages)))
    expect = list(reversed(deltas))[: min(16, len(pages))]
    assert list(got) == expect


def test_history_requires_pow2():
    with pytest.raises(ValueError):
        AccessHistory(12)


@given(st.integers(1, 1 << 20))
def test_round_up_pow2(x):
    p = round_up_pow2(x)
    assert p >= x and p < 2 * x or (x == 1 and p == 1)
    assert p & (p - 1) == 0
    import jax.numpy as jnp
    assert int(_round_up_pow2_jax(jnp.int32(x))) == p


class TestPrefetchWindow:
    def test_grows_with_hits_capped(self):
        w = PrefetchWindow(pw_max=8)
        for hits in (1, 3, 9, 20):
            for _ in range(hits):
                w.note_prefetch_hit()
            pw = w.next_size(follows_trend=True)
            assert pw == min(round_up_pow2(hits + 1), 8)

    def test_zero_hits_follows_trend_keeps_minimum(self):
        w = PrefetchWindow(pw_max=8)
        assert w.next_size(follows_trend=True) == 1

    def test_zero_hits_off_trend_suspends(self):
        w = PrefetchWindow(pw_max=8)
        assert w.next_size(follows_trend=False) == 0

    def test_smooth_shrink(self):
        """Alg. 2 line 13-14: never collapse below half the previous window."""
        w = PrefetchWindow(pw_max=8)
        for _ in range(10):
            w.note_prefetch_hit()
        assert w.next_size(True) == 8
        w.note_prefetch_hit()          # only 1 hit -> would be 2, floor 4
        assert w.next_size(True) == 4

    @given(st.lists(st.tuples(st.integers(0, 12), st.booleans()),
                    min_size=1, max_size=50))
    def test_window_bounded(self, events):
        w = PrefetchWindow(pw_max=8)
        for hits, follows in events:
            for _ in range(hits):
                w.note_prefetch_hit()
            pw = w.next_size(follows)
            assert 0 <= pw <= 8


class TestTwinEquivalence:
    """``PrefetchWindow.next_size`` and the JAX ``next_window_size`` are
    twins: identical window sequence and identical carried state over any
    hit/trend history — including the shrink-smoothly branch
    (``pw < pw_prev // 2``, Alg. 2 line 13-14) that spot checks only graze.
    """

    @staticmethod
    def _step_both(ref, state, hits, follows, pw_max):
        import jax.numpy as jnp
        for _ in range(hits):
            ref.note_prefetch_hit()
        state = note_prefetch_hits(state, jnp.int32(hits))
        state, pw_j = next_window_size(state, jnp.asarray(follows), pw_max)
        pw_r = ref.next_size(follows)
        assert int(pw_j) == pw_r
        assert int(state["pw_prev"]) == ref.pw_prev
        assert int(state["c_hit"]) == ref.c_hit == 0
        return state, pw_r

    @given(st.lists(st.tuples(st.integers(0, 20), st.booleans()),
                    min_size=1, max_size=60),
           st.sampled_from([4, 8, 16, 64]))
    def test_twins_agree_on_random_histories(self, events, pw_max):
        ref = PrefetchWindow(pw_max=pw_max)
        state = init_window_state()
        for hits, follows in events:
            state, _ = self._step_both(ref, state, hits, follows, pw_max)

    @given(st.integers(7, 40), st.integers(1, 2), st.booleans())
    def test_twins_agree_through_the_shrink_branch(self, big, small,
                                                   follows):
        """Grow to pw_prev == pw_max, then starve: c_hit=1 would collapse to
        2 but must floor at pw_prev // 2 = 4 in BOTH twins (c_hit=2 sits
        exactly on the boundary and must NOT clamp)."""
        ref = PrefetchWindow(pw_max=8)
        state = init_window_state()
        # big >= 7 -> round_up_pow2(big + 1) >= 8 -> window pegged at cap
        state, pw = self._step_both(ref, state, big, True, 8)
        assert pw == 8
        state, pw = self._step_both(ref, state, small, follows, 8)
        # c_hit=1: pow2(2)=2 floored at 4; c_hit=2: pow2(3)=4, boundary,
        # no clamp — both land on 4 through *different* branches
        assert pw == 4
        # and the floor keeps halving smoothly, never cliff-dropping
        state, pw = self._step_both(ref, state, 1, follows, 8)
        assert pw == 2
