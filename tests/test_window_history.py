"""AccessHistory ring buffer + adaptive prefetch window (Alg. 2) properties."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.history import AccessHistory
from repro.core.window import PrefetchWindow, round_up_pow2, _round_up_pow2_jax


@given(st.lists(st.integers(0, 1 << 20), min_size=1, max_size=100))
def test_history_window_returns_newest_first(pages):
    h = AccessHistory(16)
    deltas = []
    last = None
    for p in pages:
        deltas.append(0 if last is None else p - last)
        last = p
        h.push(p)
    got = h.window(min(16, len(pages)))
    expect = list(reversed(deltas))[: min(16, len(pages))]
    assert list(got) == expect


def test_history_requires_pow2():
    with pytest.raises(ValueError):
        AccessHistory(12)


@given(st.integers(1, 1 << 20))
def test_round_up_pow2(x):
    p = round_up_pow2(x)
    assert p >= x and p < 2 * x or (x == 1 and p == 1)
    assert p & (p - 1) == 0
    import jax.numpy as jnp
    assert int(_round_up_pow2_jax(jnp.int32(x))) == p


class TestPrefetchWindow:
    def test_grows_with_hits_capped(self):
        w = PrefetchWindow(pw_max=8)
        for hits in (1, 3, 9, 20):
            for _ in range(hits):
                w.note_prefetch_hit()
            pw = w.next_size(follows_trend=True)
            assert pw == min(round_up_pow2(hits + 1), 8)

    def test_zero_hits_follows_trend_keeps_minimum(self):
        w = PrefetchWindow(pw_max=8)
        assert w.next_size(follows_trend=True) == 1

    def test_zero_hits_off_trend_suspends(self):
        w = PrefetchWindow(pw_max=8)
        assert w.next_size(follows_trend=False) == 0

    def test_smooth_shrink(self):
        """Alg. 2 line 13-14: never collapse below half the previous window."""
        w = PrefetchWindow(pw_max=8)
        for _ in range(10):
            w.note_prefetch_hit()
        assert w.next_size(True) == 8
        w.note_prefetch_hit()          # only 1 hit -> would be 2, floor 4
        assert w.next_size(True) == 4

    @given(st.lists(st.tuples(st.integers(0, 12), st.booleans()),
                    min_size=1, max_size=50))
    def test_window_bounded(self, events):
        w = PrefetchWindow(pw_max=8)
        for hits, follows in events:
            for _ in range(hits):
                w.note_prefetch_hit()
            pw = w.next_size(follows)
            assert 0 <= pw <= 8
