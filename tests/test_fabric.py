"""Fabric engine invariants: determinism, legacy equivalence, contention."""

import numpy as np
import pytest

from repro.core import traces
from repro.core.cache import PageCache
from repro.core.prefetcher import make_prefetcher
from repro.core.simulator import simulate, simulate_legacy
from repro.fabric import (EventEngine, FabricScenario, TenantSpec,
                          jain_index, percentile_summary, run_fabric,
                          slowdowns)


# -- engine primitives --------------------------------------------------------
class TestEngine:
    def test_events_run_in_time_order(self):
        eng = EventEngine(seed=0)
        out = []
        for t in (5.0, 1.0, 3.0):
            eng.schedule_at(t, lambda t=t: out.append(t))
        eng.run()
        assert out == [1.0, 3.0, 5.0] and eng.now == 5.0

    def test_ties_break_by_rank_then_insertion(self):
        eng = EventEngine(seed=0)
        out = []
        eng.schedule_at(1.0, lambda: out.append("b"), rank=1)
        eng.schedule_at(1.0, lambda: out.append("a"), rank=0)
        eng.schedule_at(1.0, lambda: out.append("c"), rank=1)
        eng.run()
        assert out == ["a", "b", "c"]

    def test_actor_ranks_seeded(self):
        a = EventEngine(seed=3).actor_ranks(16)
        b = EventEngine(seed=3).actor_ranks(16)
        c = EventEngine(seed=4).actor_ranks(16)
        assert a == b and sorted(a) == list(range(16)) and a != c

    def test_cannot_schedule_in_past(self):
        eng = EventEngine()
        eng.schedule_at(2.0, lambda: eng.schedule_at(1.0, lambda: None))
        with pytest.raises(ValueError):
            eng.run()


# -- single-tenant equivalence with the legacy loop ---------------------------
@pytest.mark.parametrize("trace_name,policy,model,eviction,think", [
    ("powergraph", "leap", "rdma_lean", "eager", 0.0),
    ("voltdb", "read_ahead", "rdma_block", "lru", 3.0),
    ("sequential", "next_n_line", "disk_block", "lru", 0.0),
    ("memcached", "stride", "disk_lean", "lru", 1.0),
    ("interleaved", "none", "rdma_block", "lru", 0.0),
])
def test_single_tenant_matches_legacy(trace_name, policy, model, eviction,
                                      think):
    tr = traces.TRACES[trace_name](n=2000)
    ref = simulate_legacy(tr, make_prefetcher(policy),
                          PageCache(64, eviction=eviction), model, think,
                          seed=7)
    fab = simulate(tr, make_prefetcher(policy),
                   PageCache(64, eviction=eviction), model, think, seed=7)
    for attr in ("faults", "cache_hits", "misses", "prefetch_issued",
                 "prefetch_hits", "partial_hits", "pollution",
                 "inflight_at_end"):
        assert getattr(fab.stats, attr) == getattr(ref.stats, attr), attr
    assert fab.stats.hit_rate == ref.stats.hit_rate
    assert fab.stats.coverage == ref.stats.coverage
    assert fab.total_time == pytest.approx(ref.total_time, rel=1e-9)
    assert fab.link_busy == pytest.approx(ref.link_busy, rel=1e-9)
    assert fab.scanned_entries == ref.scanned_entries
    assert np.allclose(fab.stats.latencies, ref.stats.latencies)
    assert np.allclose(fab.stats.timeliness, ref.stats.timeliness)


# -- multi-tenant scenarios ---------------------------------------------------
def _victim_spec(n=1500):
    return TenantSpec("victim", traces.sequential(n), policy="leap",
                      cache_capacity=64, model="rdma_lean")


def _noisy_spec(n=1500):
    return TenantSpec("noisy", traces.random_pages(n, seed=5) + (1 << 40),
                      policy="next_n_line", policy_kwargs={"n": 8},
                      cache_capacity=64, eviction="lru", model="rdma_lean",
                      arrival="bursty", burst_len=64, idle_time=100.0)


class TestFabric:
    def test_deterministic_under_fixed_seed(self):
        def go():
            return run_fabric(FabricScenario(
                [_victim_spec(), _noisy_spec()], data_path="isolated",
                arbitration="fifo", seed=11))
        a, b = go(), go()
        assert a.makespan == b.makespan
        for ta, tb in zip(a.tenants, b.tenants):
            assert ta.latency == tb.latency
            assert ta.completion_time == tb.completion_time
            assert (ta.faults, ta.cache_hits, ta.prefetch_hits) == \
                (tb.faults, tb.cache_hits, tb.prefetch_hits)

    def test_noisy_tenant_never_improves_victim_p99_under_fifo(self):
        """Contention invariant: on the shared-FIFO baseline, adding a
        noisy neighbor can only delay the victim's fetches."""
        solo = run_fabric(FabricScenario([_victim_spec()],
                                         data_path="isolated",
                                         arbitration="fifo", seed=0))
        for seed in (0, 1, 2):
            duo = run_fabric(FabricScenario(
                [_victim_spec(), _noisy_spec()], data_path="isolated",
                arbitration="fifo", seed=seed))
            for q in ("p50", "p99", "p99.9"):
                assert duo.tenant("victim").latency[q] >= \
                    solo.tenant("victim").latency[q] - 1e-9, (seed, q)
            assert duo.tenant("victim").completion_time >= \
                solo.tenant("victim").completion_time - 1e-9

    def test_per_tenant_qps_protect_victim_tail(self):
        """Leap §4.4 direction: per-tenant async QPs keep the noisy
        neighbor's burst out of the victim's p99."""
        specs = lambda: [_victim_spec(), _noisy_spec()]
        fifo = run_fabric(FabricScenario(specs(), data_path="isolated",
                                         arbitration="fifo", seed=0))
        qp = run_fabric(FabricScenario(specs(), data_path="isolated",
                                       arbitration="per_tenant_qp", seed=0))
        assert qp.tenant("victim").latency["p99"] < \
            fifo.tenant("victim").latency["p99"]

    def test_isolated_beats_shared_data_path(self):
        """Fig. 13 direction: per-tenant Leap data paths beat the communal
        read-ahead + LRU + FIFO baseline on completion time and p99."""
        def specs():
            return [TenantSpec(a, traces.TRACES[a](n=1200) + (i << 40),
                               policy="leap", cache_capacity=128,
                               model="rdma_lean")
                    for i, a in enumerate(("powergraph", "memcached"))]
        shared = run_fabric(FabricScenario(
            specs(), data_path="shared", shared_model="rdma_block"))
        iso = run_fabric(FabricScenario(specs(), data_path="isolated"))
        for name in ("powergraph", "memcached"):
            assert iso.tenant(name).completion_time < \
                shared.tenant(name).completion_time
            assert iso.tenant(name).latency["p99"] < \
                shared.tenant(name).latency["p99"]

    def test_heterogeneous_tiers_served_independently(self):
        rep = run_fabric(FabricScenario(
            [TenantSpec("fast", traces.sequential(400), model="rdma_lean"),
             TenantSpec("slow", traces.sequential(400, start=1 << 30),
                        model="disk_lean")],
            data_path="isolated"))
        assert set(rep.link_stats) == {"rdma", "disk"}
        assert rep.link_stats["disk"]["busy_time"] > \
            rep.link_stats["rdma"]["busy_time"]

    def test_bursty_and_churn_arrivals_complete(self):
        rep = run_fabric(FabricScenario(
            [TenantSpec("burst", traces.powergraph_like(800),
                        arrival="bursty", burst_len=32, idle_time=50.0),
             TenantSpec("churn", traces.sequential(800, start=1 << 30),
                        arrival="churn", churn_every=200,
                        churn_downtime=100.0)],
            data_path="isolated", seed=2))
        for t in rep.tenants:
            assert t.faults == 800
        # churn restarts force cold misses on an otherwise sequential trace
        assert rep.tenant("churn").misses >= 4

    def test_churn_spares_shared_data_path(self):
        """A churning tenant must not clear the communal tracker/cache."""
        from repro.core.simulator import LATENCY_MODELS
        from repro.fabric.tenants import Tenant
        pf = make_prefetcher("read_ahead")
        cache = PageCache(16, eviction="lru")
        cache.insert_prefetch(1, 0.0, 1.0)
        pf.window = 8
        ten = Tenant(TenantSpec("churner", [], arrival="churn",
                                churn_every=10),
                     pf, cache, LATENCY_MODELS["rdma_block"],
                     np.random.default_rng(0), shared=True)
        ten.cold_restart()
        assert cache.occupancy == 1 and pf.window == 8
        ten.shared = False
        ten.cold_restart()
        assert cache.occupancy == 0 and pf.window == 0

    def test_shared_path_uses_one_link_on_shared_tier(self):
        """Shared data path: every tenant routes over the communal
        model's tier, even if their own specs name other tiers."""
        rep = run_fabric(FabricScenario(
            [TenantSpec("a", traces.sequential(200), model="disk_lean"),
             TenantSpec("b", traces.sequential(200, start=1 << 30),
                        model="rdma_lean")],
            data_path="shared", shared_model="rdma_block"))
        assert set(rep.link_stats) == {"rdma"}

    def test_tenant_start_offsets(self):
        rep = run_fabric(FabricScenario(
            [TenantSpec("late", traces.sequential(200), start_time=500.0)],
            data_path="isolated"))
        assert rep.makespan >= 500.0
        assert rep.tenant("late").completion_time < rep.makespan


# -- metrics helpers ----------------------------------------------------------
class TestMetrics:
    def test_jain_index_bounds(self):
        assert jain_index([1.0, 1.0, 1.0]) == pytest.approx(1.0)
        assert jain_index([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)
        assert jain_index([]) == 1.0

    def test_slowdowns_vs_solo_runs(self):
        contended = run_fabric(FabricScenario(
            [_victim_spec(), _noisy_spec()], data_path="isolated",
            arbitration="fifo", seed=0))
        solo = {"victim": run_fabric(FabricScenario(
            [_victim_spec()], data_path="isolated", arbitration="fifo",
            seed=0)).tenant("victim").completion_time}
        sd = slowdowns(contended, solo)
        assert set(sd) == {"victim"}        # no solo baseline for "noisy"
        assert sd["victim"] >= 1.0          # contention never speeds you up

    def test_percentile_summary_keys(self):
        s = percentile_summary(list(range(1000)))
        assert set(s) == {"p50", "p90", "p99", "p99.9", "avg", "max", "n"}
        assert s["p50"] <= s["p90"] <= s["p99"] <= s["p99.9"] <= s["max"]
        assert s["n"] == 1000

    def test_percentile_summary_empty_is_nan_not_zero(self):
        """No samples must be distinguishable from zero-latency samples."""
        import math
        empty = percentile_summary([])
        assert empty["n"] == 0
        for k in ("p50", "p90", "p99", "p99.9", "avg", "max"):
            assert math.isnan(empty[k]), k


# -- multi-node fabric: per-tenant home nodes (DESIGN.md §7 mirror) -----------
class TestMultiNodeFabric:
    """The event engine's side of the sharded cold pool: pages live on home
    nodes (block/interleave placement), every transfer rides the page's
    node NIC, and cross-node transfers pay ``far_factor``."""

    @staticmethod
    def _spec(name, home, n=600):
        return TenantSpec(name, traces.sequential(n, start=0),
                          policy="leap", cache_capacity=64,
                          model="rdma_lean", home_node=home)

    def test_one_node_is_the_legacy_scenario(self):
        base = run_fabric(FabricScenario([_victim_spec()], seed=3))
        multi = run_fabric(FabricScenario([_victim_spec()], seed=3,
                                          n_nodes=1, n_pages=1 << 20,
                                          far_factor=4.0))
        assert base.makespan == multi.makespan
        assert base.tenants[0].latency == multi.tenants[0].latency

    def test_per_node_links_and_far_penalty(self):
        # block placement over 2 nodes: the whole sequential trace lives on
        # node 0 — the tenant homed there runs faster than the one paying
        # far_factor on every transfer from across the fabric
        n_pages = 2048
        rep = run_fabric(FabricScenario(
            [self._spec("near", 0), self._spec("far", 1)],
            data_path="isolated", arbitration="per_tenant_qp",
            link_width=2, seed=7, n_nodes=2, n_pages=n_pages,
            placement="block", far_factor=3.0))
        near = rep.tenant("near")
        far = rep.tenant("far")
        assert near.completion_time < far.completion_time
        # both NICs exist per tier; only node 0's carried traffic
        assert any(k.endswith("@n0") for k in rep.link_stats)
        assert any(k.endswith("@n1") for k in rep.link_stats)
        moved = {k: v["completed"] for k, v in rep.link_stats.items()}
        assert sum(v for k, v in moved.items() if k.endswith("@n0")) > 0
        assert sum(v for k, v in moved.items() if k.endswith("@n1")) == 0

    def test_multi_node_requires_n_pages(self):
        with pytest.raises(ValueError, match="n_pages"):
            run_fabric(FabricScenario([_victim_spec()], n_nodes=2))

    def test_multi_node_requires_divisible_pool(self):
        # a ragged block split would map the last pages to node n_nodes
        with pytest.raises(ValueError, match="divisible"):
            run_fabric(FabricScenario([_victim_spec()], n_nodes=7,
                                      n_pages=600))

    def test_multi_node_rejects_placement_typo(self):
        # home_of would silently treat an unknown string as "block"
        with pytest.raises(ValueError, match="placement"):
            run_fabric(FabricScenario([_victim_spec()], n_nodes=2,
                                      n_pages=1024,
                                      placement="interleaved"))

    def test_multi_node_rejects_out_of_range_home_node(self):
        # a home outside [0, n_nodes) would silently pay far_factor on
        # every transfer instead of erroring
        with pytest.raises(ValueError, match="home_node"):
            run_fabric(FabricScenario([self._spec("t0", 2)], n_nodes=2,
                                      n_pages=1024))
