"""Optimizers, data pipeline, checkpoint, runtime (FT/straggler/compression)."""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import PrefetchQueue, make_pipeline
from repro.optim import cosine_warmup, linear_warmup, make_optimizer
from repro.runtime import (StepTimeMonitor, Watchdog, compress_int8,
                           decompress_int8, init_error_feedback,
                           run_with_restarts)


class TestOptim:
    @pytest.mark.parametrize("name", ["adamw", "adafactor"])
    def test_converges_on_quadratic(self, name):
        init, upd = make_optimizer(name, 0.05)
        p = {"w": jnp.ones((4, 4)), "nested": ({"b": jnp.ones(3)},)}
        st = init(p)
        for i in range(100):
            g = jax.tree.map(lambda x: 2 * x, p)
            p, st, _ = upd(g, st, p, jnp.int32(i))
        assert sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(p)) < 1.0

    @pytest.mark.parametrize("name", ["adamw", "adafactor"])
    def test_tuple_bearing_tree_structure_preserved(self, name):
        """Regression: params trees contain tuples (period stacks)."""
        init, upd = make_optimizer(name, 0.1)
        p = {"period": ({"w": jnp.ones((2, 3))}, {"w": jnp.ones((4,))})}
        st = init(p)
        g = jax.tree.map(jnp.ones_like, p)
        p2, st2, _ = upd(g, st, p, jnp.int32(0))
        assert jax.tree.structure(p2) == jax.tree.structure(p)
        assert isinstance(p2["period"], tuple) and len(p2["period"]) == 2

    def test_schedules(self):
        lr = cosine_warmup(1.0, 10, 100)
        assert float(lr(jnp.int32(0))) < 0.2
        assert float(lr(jnp.int32(10))) == pytest.approx(1.0, abs=0.1)
        assert float(lr(jnp.int32(99))) < 0.2
        wu = linear_warmup(2.0, 4)
        assert float(wu(jnp.int32(100))) == 2.0


class TestData:
    def test_batches_deterministic_fn_of_step(self):
        p1 = make_pipeline(100, 8, 16, seed=3)
        p2 = make_pipeline(100, 8, 16, seed=3)
        for _ in range(3):
            next(p1)
        p2.load_state_dict(p1.state_dict())
        assert np.array_equal(next(p1)["tokens"], next(p2)["tokens"])

    def test_hosts_get_disjoint_rows(self):
        a = make_pipeline(100, 8, 16, n_hosts=2, host_id=0)
        b = make_pipeline(100, 8, 16, n_hosts=2, host_id=1)
        assert not np.array_equal(next(a)["tokens"], next(b)["tokens"])
        assert a.rows == 4

    def test_prefetch_queue_timeout_surfaces_straggler(self):
        def slow(i):
            time.sleep(10)
            return i
        q = PrefetchQueue(slow, depth=1, timeout=0.2)
        with pytest.raises(TimeoutError):
            q.get()
        q.stop()

    def test_prefetch_queue_delivers_in_order(self):
        q = PrefetchQueue(lambda i: i * i, depth=2, timeout=5)
        assert [q.get() for _ in range(4)] == [0, 1, 4, 9]
        q.stop()


class TestCheckpoint:
    def test_roundtrip_and_atomicity(self):
        with tempfile.TemporaryDirectory() as d:
            tree = {"a": jnp.arange(6).reshape(2, 3),
                    "b": (jnp.ones(3), {"c": jnp.zeros(2)})}
            save_checkpoint(d, 3, tree, {"rng": [0, 7]})
            os.makedirs(os.path.join(d, "step_00000009.tmp"))  # torn write
            assert latest_step(d) == 3
            out, extras = restore_checkpoint(d, 3, tree)
            assert extras == {"rng": [0, 7]}
            for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_async_and_gc(self):
        with tempfile.TemporaryDirectory() as d:
            ck = AsyncCheckpointer(d, keep=2)
            tree = {"w": jnp.ones(4)}
            for s in (1, 2, 3, 4):
                ck.save(s, tree)
            ck.wait()
            steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
            assert steps == [3, 4]

    def test_restore_with_resharding(self):
        with tempfile.TemporaryDirectory() as d:
            from jax.sharding import NamedSharding, PartitionSpec as P
            mesh = jax.make_mesh((1,), ("data",))
            tree = {"w": jnp.arange(8.0)}
            save_checkpoint(d, 1, tree)
            sh = {"w": NamedSharding(mesh, P("data"))}
            out, _ = restore_checkpoint(d, 1, tree, shardings=sh)
            assert out["w"].sharding == sh["w"]


class TestRuntime:
    def test_watchdog_fires_and_recovers(self):
        fired = []
        w = Watchdog(0.15, on_stall=lambda: fired.append(1)).start()
        time.sleep(0.4)
        w.beat()
        assert fired and w.stalled
        w.stop()

    def test_straggler_monitor(self):
        m = StepTimeMonitor(warmup=2)
        flags = [m.record(dt) for dt in [1.0] * 8 + [5.0] + [1.0] * 3]
        assert flags[8] is True and sum(flags) == 1
        assert m.summary()["straggler_steps"] == 1
        assert m.ewma == pytest.approx(1.0, abs=0.01)

    def test_error_feedback_unbiased_over_time(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (256,))
        err = jnp.zeros(256)
        acc = jnp.zeros(256)
        for _ in range(30):
            q, s, err = compress_int8(g, err)
            acc = acc + decompress_int8(q, s)
        rel = float(jnp.linalg.norm(acc - 30 * g) / jnp.linalg.norm(30 * g))
        assert rel < 1e-2

    def test_run_with_restarts_bit_exact(self):
        saved = {}
        fails = {3: True, 7: True}

        def mk():
            return {"x": np.float64(0)}

        def step(s, i):
            if fails.pop(i, False):
                raise RuntimeError("preempted")
            return {"x": s["x"] + np.sin(i)}

        def sv(s, i):
            saved["ck"] = (dict(s), i)

        def rs():
            return (dict(saved["ck"][0]), saved["ck"][1]) if saved else None

        state, restarts = run_with_restarts(mk, step, sv, rs, 12, 2)
        assert restarts == 2
        assert state["x"] == pytest.approx(sum(np.sin(i) for i in range(12)))

    def test_run_with_restarts_gives_up(self):
        def bad(s, i):
            raise RuntimeError("dead node")
        with pytest.raises(RuntimeError):
            run_with_restarts(lambda: {}, bad, lambda s, i: None,
                              lambda: None, 5, 1, max_restarts=2)
