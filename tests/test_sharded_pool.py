"""Mesh-sharded cold pool (DESIGN.md §7).

Pins the four contracts of the sharded fabric:

* **Placement metadata** — ``page_home``/``page_local`` are inverse to the
  home-major permutation ``place_perm``, and the Python mirror
  (``fabric.shardstep.home_of``) agrees with the jitted helpers.
* **shards=1 reduction** — the sharded consume with one shard is
  bit-equivalent to the flat ``multi_stream_consume`` paths (the finite-
  budget reduction is structural: §5 now *delegates* here, so
  ``tests/test_link_budget.py`` gates it too; the unbudgeted case is
  pinned against the vmap path directly), and ``link_grants_sharded``
  with one shard equals ``link_grants``.
* **Fabric mirror** — for shards > 1, per-stream hit / partial / deferred
  / drop counts match the lock-step sharded reference
  (``repro.fabric.run_shardstep``) exactly across placements × budgets ×
  sequential/strided/random traffic, and served bytes stay correct.
* **shard_map data plane** — run in a subprocess with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``: the collective
  ring-permute gather produces bit-identical hot pools, sums and counters
  to the flat data plane, for both the stream consume and the tiered
  sweep (whose logits stay bit-identical to the flat-pool attention).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pool import (link_grants, link_grants_sharded, page_home,
                             page_local, ring_init)
from repro.fabric.shardstep import home_of, run_shardstep
from repro.obs import (TraceRecorder, assert_traces_equal,
                       decode_stream_events)
from repro.paging.prefetch_serving import (PrefetchedStream,
                                           multi_stream_consume,
                                           stream_consume, stream_stats_at)
from repro.paging.sharded_pool import (ShardedPoolCfg, place_perm,
                                       sharded_multi_stream_consume)

N_PAGES = 128
POOL = jnp.arange(N_PAGES * 4, dtype=jnp.float32).reshape(N_PAGES, 4)
GEOM = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES, page_elems=4,
                        ring_size=8)


def _scheds(T: int = 60) -> jnp.ndarray:
    rng = np.random.default_rng(3)
    return jnp.asarray(np.stack([
        np.arange(T) % N_PAGES,
        (np.arange(T) * 3 + 7) % N_PAGES,
        (np.arange(T) * 2 + 50) % N_PAGES,
        rng.integers(0, N_PAGES, T),
    ]), jnp.int32)


class TestPlacement:
    @pytest.mark.parametrize("placement", ["block", "interleave"])
    @pytest.mark.parametrize("n_shards", [1, 2, 4])
    def test_home_local_invert_place_perm(self, placement, n_shards):
        fab = ShardedPoolCfg(n_shards=n_shards, placement=placement)
        perm = place_perm(N_PAGES, fab)
        assert sorted(perm.tolist()) == list(range(N_PAGES))  # a permutation
        pages = jnp.arange(N_PAGES, dtype=jnp.int32)
        home = np.asarray(page_home(pages, N_PAGES, n_shards, placement))
        local = np.asarray(page_local(pages, N_PAGES, n_shards, placement))
        pps = N_PAGES // n_shards
        assert (local < pps).all()
        # placed[home * pps + local] holds exactly page p
        np.testing.assert_array_equal(perm[home * pps + local],
                                      np.arange(N_PAGES))
        # python mirror agrees
        assert [home_of(p, N_PAGES, n_shards, placement)
                for p in range(N_PAGES)] == home.tolist()

    def test_bad_placement_rejected(self):
        with pytest.raises(ValueError, match="placement"):
            ShardedPoolCfg(n_shards=2, placement="striped")
        with pytest.raises(ValueError, match="placement"):
            page_home(jnp.arange(4), 4, 2, "striped")

    def test_indivisible_pool_rejected(self):
        fab = ShardedPoolCfg(n_shards=3)
        with pytest.raises(ValueError, match="divisible"):
            place_perm(N_PAGES, fab)
        with pytest.raises(ValueError, match="divisible"):
            sharded_multi_stream_consume(POOL, _scheds(8), GEOM, fab)


class TestShardsOneReduction:
    def test_one_shard_unbudgeted_matches_vmap_path(self):
        """G=1, budget=None: bit-equivalent to vmap(stream_consume) (modulo
        the ring ``seq`` stamps only the arbiter-capable path assigns)."""
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=1, link_budget=None,
                             near_delay=1, far_delay=1)
        st_s, sums_s, info_s = sharded_multi_stream_consume(
            POOL, scheds, GEOM, fab)
        st_v, sums_v, info_v = jax.vmap(
            lambda s: stream_consume(POOL, s, GEOM, async_datapath=True)
        )(scheds)
        np.testing.assert_array_equal(np.asarray(sums_s), np.asarray(sums_v))
        for k in info_v:
            np.testing.assert_array_equal(np.asarray(info_s[k]),
                                          np.asarray(info_v[k]), err_msg=k)
        for k, v in st_v["pool_meta"].items():
            np.testing.assert_array_equal(np.asarray(st_s["pool_meta"][k]),
                                          np.asarray(v), err_msg=k)
        for k, v in st_v["ring"].items():
            if k == "seq":
                continue
            np.testing.assert_array_equal(np.asarray(st_s["ring"][k]),
                                          np.asarray(v), err_msg=k)
        np.testing.assert_array_equal(np.asarray(st_s["hot"]),
                                      np.asarray(st_v["hot"]))

    def test_one_shard_budgeted_is_the_link_budget_path(self):
        """The §5 budgeted path *is* the one-shard fabric (delegation)."""
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=1, link_budget=3,
                             near_delay=1, far_delay=1)
        st_s, sums_s, info_s = sharded_multi_stream_consume(
            POOL, scheds, GEOM, fab)
        st_b, sums_b, info_b = multi_stream_consume(
            POOL, scheds, GEOM, async_datapath=True, link_budget=3)
        np.testing.assert_array_equal(np.asarray(sums_s), np.asarray(sums_b))
        for k in info_b:
            np.testing.assert_array_equal(np.asarray(info_s[k]),
                                          np.asarray(info_b[k]), err_msg=k)

    def test_link_grants_sharded_one_shard_equals_link_grants(self):
        ring = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (3,) + x.shape).copy(),
            ring_init(6))
        rng = np.random.default_rng(0)
        ring = dict(ring)
        ring["page"] = jnp.asarray(rng.integers(-1, 40, (3, 6)), jnp.int32)
        ring["deadline"] = jnp.asarray(rng.integers(0, 5, (3, 6)), jnp.int32)
        ring["seq"] = jnp.asarray(rng.permutation(18).reshape(3, 6),
                                  jnp.int32)
        now = jnp.full((3,), 3, jnp.int32)
        for cap in (0, 1, 2, 5, 100):
            a = link_grants(ring, now, jnp.int32(cap))
            b = link_grants_sharded(ring, now,
                                    jnp.asarray([cap], jnp.int32),
                                    jnp.zeros((3, 6), jnp.int32))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"cap={cap}")


class TestShardstepCrossValidation:
    """Jitted sharded counts == lock-step sharded fabric, per stream."""

    @pytest.mark.parametrize("placement", ["block", "interleave"])
    @pytest.mark.parametrize("n_shards", [2, 4])
    @pytest.mark.parametrize("budget", [None, 1, 3])
    def test_counts_match_shardstep(self, placement, n_shards, budget):
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=n_shards, placement=placement,
                             link_budget=budget, near_delay=1, far_delay=2)
        st, sums, info = sharded_multi_stream_consume(POOL, scheds, GEOM, fab)
        # served bytes stay correct whatever the topology
        np.testing.assert_allclose(np.asarray(sums),
                                   np.asarray(POOL[scheds].sum(-1)))
        rec = TraceRecorder()
        rep = run_shardstep(np.asarray(scheds), N_PAGES, n_shards, placement,
                            budget, ring_size=GEOM.ring_size,
                            near_delay=1, far_delay=2, pw_max=GEOM.pw_max,
                            h_size=GEOM.h_size, n_split=GEOM.n_split,
                            recorder=rec)
        for i in range(scheds.shape[0]):
            j = stream_stats_at(st, i)
            r = rep.stream_summary(i)
            if {k: j[k] for k in r} != r:
                # §8: name the first divergent event before failing on totals
                assert_traces_equal(
                    decode_stream_events(scheds, info, n_pages=N_PAGES,
                                         n_shards=n_shards,
                                         placement=placement),
                    rec.events,
                    context=f"{placement}, G={n_shards}, budget {budget}")
            assert {k: j[k] for k in r} == r, \
                f"stream {i}, {placement}, G={n_shards}, budget {budget}"

    def test_per_shard_demand_totals_account_every_fetch(self):
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=4, placement="interleave",
                             link_budget=2)
        _, _, info = sharded_multi_stream_consume(POOL, scheds, GEOM, fab)
        shard = np.asarray(info["shard_demand_fetches"])    # [T, G]
        assert shard.shape[1] == 4
        np.testing.assert_array_equal(shard.sum(1),
                                      np.asarray(info["link_demand_fetches"]))
        np.testing.assert_array_equal(
            shard.sum(0).sum(), np.asarray(info["fetched"]).sum())

    def test_far_pages_hide_less_latency(self):
        """Longer far_delay -> more prefetches still in flight at first use
        (partial hits), never more full hits; deferred stays 0 unbudgeted."""
        scheds = _scheds()
        partials = []
        for far in (1, 3):
            fab = ShardedPoolCfg(n_shards=2, placement="interleave",
                                 link_budget=None, near_delay=1,
                                 far_delay=far)
            st, _, info = sharded_multi_stream_consume(POOL, scheds, GEOM,
                                                       fab)
            assert int(np.asarray(info["deferred"]).sum()) == 0
            partials.append(int(np.asarray(info["partial_hit"]).sum()))
        assert partials[1] > partials[0]


class TestShardMapDataPlane:
    """Real multi-device run: forced 4-CPU-device subprocess, collective
    ring-permute gather pinned bit-equal to the flat data plane."""

    SCRIPT = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        assert jax.device_count() == 4, jax.device_count()
        from repro.paging.prefetch_serving import PrefetchedStream
        from repro.paging.sharded_pool import (ShardedPoolCfg,
                                               sharded_multi_stream_consume)
        from repro.paging.kv_cache import (linear_page_table,
                                           paged_decode_attention)
        from repro.paging.tiered_kv import (TieredKV, tiered_attention,
                                            tiered_init, tiered_min_slots,
                                            tiered_sweep)

        N = 64
        pool = jnp.arange(N * 4, dtype=jnp.float32).reshape(N, 4)
        geom = PrefetchedStream(n_pages=N, n_slots=N, page_elems=4,
                                ring_size=8)
        T = 30
        scheds = jnp.asarray(np.stack([np.arange(T) % N,
                                       (np.arange(T) * 3 + 7) % N]),
                             jnp.int32)
        mesh = jax.make_mesh((4,), ("fabric",))
        for placement in ("block", "interleave"):
            fab = ShardedPoolCfg(n_shards=4, placement=placement,
                                 link_budget=2)
            sf, sums_f, info_f = sharded_multi_stream_consume(
                pool, scheds, geom, fab)
            sm, sums_m, info_m = sharded_multi_stream_consume(
                pool, scheds, geom, fab, mesh=mesh)
            np.testing.assert_array_equal(np.asarray(sums_f),
                                          np.asarray(sums_m))
            for k in info_f:
                np.testing.assert_array_equal(np.asarray(info_f[k]),
                                              np.asarray(info_m[k]),
                                              err_msg=k)
            np.testing.assert_array_equal(np.asarray(sf["hot"]),
                                          np.asarray(sm["hot"]))

        # tiered sweep: sharded cold KV, logits bit-identical to flat pool
        B, NPPS, PS, HKV, HQ, DH = 2, 8, 4, 2, 4, 8
        NP = B * NPPS
        k = jax.random.normal(jax.random.PRNGKey(0), (NP, PS, HKV, DH))
        v = jax.random.normal(jax.random.PRNGKey(1), (NP, PS, HKV, DH))
        cold = {"k": k, "v": v}
        q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, HQ, DH))
        lengths = jnp.asarray([29, 17], jnp.int32)
        pt = linear_page_table(B, NPPS, 3)
        tg = TieredKV(NP, tiered_min_slots(
            NPPS, TieredKV(NP, 1, PS, HKV, DH, chunk=2, pw_max=4)),
            PS, HKV, DH, chunk=2, pw_max=4, ring_size=8)
        fab = ShardedPoolCfg(n_shards=4, placement="interleave",
                             link_budget=1)
        st = tiered_init(tg, B, jnp.float32)
        st, info = tiered_sweep(st, cold, pt, tg, async_datapath=True,
                                fabric=fab, mesh=mesh)
        out, ok = tiered_attention(q, st, pt, lengths)
        assert bool(ok)
        flat = paged_decode_attention(q, {"k": k[None], "v": v[None]},
                                      jnp.int32(0), pt, lengths)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(flat))

        # serving 'pages' rule: preference order — one axis, never a
        # fabric x data product (that would split a shard's home slice)
        from jax.sharding import PartitionSpec
        from repro.distributed.sharding import RULES_SERVE, named_sharding_for
        m2 = jax.make_mesh((2, 2), ("fabric", "data"))
        sh = named_sharding_for(("pages", None), (64, 4), m2, RULES_SERVE)
        assert sh.spec == PartitionSpec("fabric", None), sh.spec
        m3 = jax.make_mesh((2, 2), ("data", "model"))
        sh = named_sharding_for(("pages", None), (64, 4), m3, RULES_SERVE)
        assert sh.spec == PartitionSpec("data", None), sh.spec
        print("SHARDED-OK")
    """)

    def test_shard_map_bit_equal_in_forced_multidevice_subprocess(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4")
        env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), os.pardir,
                                          "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        r = subprocess.run([sys.executable, "-c", self.SCRIPT], env=env,
                           capture_output=True, text=True, timeout=600)
        assert r.returncode == 0, r.stdout + "\n" + r.stderr
        assert "SHARDED-OK" in r.stdout


class TestTieredFabricComposition:
    """Tiered sweep under a sharded fabric (flat data plane, metadata model):
    the equivalence pin survives every placement/budget and tight per-NIC
    budgets actually defer."""

    def test_tiered_pin_and_deferral_across_fabrics(self):
        from repro.paging.kv_cache import (linear_page_table,
                                           paged_decode_attention)
        from repro.paging.tiered_kv import (TieredKV, tiered_attention,
                                            tiered_init, tiered_min_slots,
                                            tiered_sweep)
        B, NPPS, PS, HKV, HQ, DH = 4, 8, 4, 2, 4, 8
        NP = B * NPPS
        k = jax.random.normal(jax.random.PRNGKey(0), (NP, PS, HKV, DH))
        v = jax.random.normal(jax.random.PRNGKey(1), (NP, PS, HKV, DH))
        cold = {"k": k, "v": v}
        q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, HQ, DH))
        lengths = jnp.asarray([29, 17, 32, 5], jnp.int32)
        pt = linear_page_table(B, NPPS, 3)
        flat = paged_decode_attention(q, {"k": k[None], "v": v[None]},
                                      jnp.int32(0), pt, lengths)
        geom = TieredKV(NP, tiered_min_slots(
            NPPS, TieredKV(NP, 1, PS, HKV, DH, chunk=1, pw_max=4)),
            PS, HKV, DH, chunk=1, pw_max=4, ring_size=8)
        saw_deferral = False
        for placement in ("block", "interleave"):
            for budget in (None, 1):
                fab = ShardedPoolCfg(n_shards=4, placement=placement,
                                     link_budget=budget, near_delay=1,
                                     far_delay=2)
                st = tiered_init(geom, B, jnp.float32)
                st, info = tiered_sweep(st, cold, pt, geom,
                                        async_datapath=True, fabric=fab)
                out, ok = tiered_attention(q, st, pt, lengths)
                assert bool(ok), (placement, budget)
                np.testing.assert_array_equal(np.asarray(out),
                                              np.asarray(flat))
                if budget == 1:
                    saw_deferral |= int(
                        np.asarray(info["deferred"]).sum()) > 0
        assert saw_deferral   # a 1-page/NIC budget must actually bind
