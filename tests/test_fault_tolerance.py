"""Fault tolerance: restart-from-checkpoint bit-exactness + watchdog.

The contract (``repro.runtime.fault_tolerance``): any worker can die at
any step and the resumed run must produce a bit-exact state trajectory —
checkpoints carry everything, steps are pure functions of (state, step).
A seeded property loop kills the trainer at random steps under random
checkpoint cadences and compares against the undisturbed run; a
hypothesis variant widens the net when the library is installed.
"""

import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:                       # deterministic tests still run
    HAVE_HYPOTHESIS = False

from repro.runtime.fault_tolerance import Watchdog, run_with_restarts


def _step(state: int, step: int) -> int:
    """Deterministic integer trajectory: a cheap stand-in for train_step
    whose every intermediate value depends on all prior steps."""
    return (state * 6364136223846793005 + step + 1) % (1 << 63)


class _Harness:
    """In-memory checkpoint store + fault schedule."""

    def __init__(self, kill_steps):
        self.ckpt = None          # (state, step)
        self.kill_steps = sorted(kill_steps, reverse=True)
        self.saves = 0

    def make_state(self):
        return 1

    def train_one_step(self, state, step):
        if self.kill_steps and step == self.kill_steps[-1]:
            self.kill_steps.pop()
            raise RuntimeError(f"node died at step {step}")
        return _step(state, step)

    def save_state(self, state, step):
        self.saves += 1
        self.ckpt = (state, step)

    def restore_state(self):
        return self.ckpt


def _clean_run(n_steps: int) -> int:
    state = 1
    for step in range(n_steps):
        state = _step(state, step)
    return state


def _check_one(n_steps: int, save_every: int, kills: list[int]) -> None:
    h = _Harness(kills)
    state, restarts = run_with_restarts(
        h.make_state, h.train_one_step, h.save_state, h.restore_state,
        n_steps=n_steps, save_every=save_every,
        max_restarts=len(kills) + 1)
    assert state == _clean_run(n_steps), \
        f"trajectory diverged (kills={kills}, save_every={save_every})"
    assert restarts == len(kills)
    assert h.ckpt == (state, n_steps)     # final checkpoint committed


class TestBitExactResume:
    def test_seeded_property_random_kills(self):
        rng = np.random.default_rng(97)
        for _ in range(30):
            n_steps = int(rng.integers(1, 40))
            save_every = int(rng.integers(1, 10))
            n_kills = int(rng.integers(0, 4))
            # a step may be killed repeatedly (the same node dying twice)
            kills = sorted(int(rng.integers(0, n_steps))
                           for _ in range(n_kills))
            _check_one(n_steps, save_every, kills)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    def test_hypothesis_property(self):
        @settings(max_examples=50, deadline=None)
        @given(n_steps=hst.integers(1, 50), save_every=hst.integers(1, 12),
               kills=hst.lists(hst.integers(0, 49), max_size=3))
        def prop(n_steps, save_every, kills):
            _check_one(n_steps, save_every,
                       [k for k in kills if k < n_steps])
        prop()

    def test_resume_skips_completed_prefix(self):
        """After a kill past a checkpoint, completed steps do not re-run."""
        seen = []

        class H(_Harness):
            def train_one_step(self, state, step):
                seen.append(step)
                return super().train_one_step(state, step)

        h = H([7])
        run_with_restarts(h.make_state, h.train_one_step, h.save_state,
                          h.restore_state, n_steps=10, save_every=5,
                          max_restarts=1)
        # steps 0-6 ran, step 7 died mid-call, resume from the step-5
        # checkpoint — never from step 0
        assert seen == list(range(0, 8)) + list(range(5, 10))


class TestRestartExhaustion:
    def test_reraises_after_max_restarts(self):
        h = _Harness([3, 3, 3, 3, 3])     # dies every attempt
        calls = []
        with pytest.raises(RuntimeError, match="died at step 3"):
            run_with_restarts(h.make_state, h.train_one_step, h.save_state,
                              h.restore_state, n_steps=10, save_every=2,
                              max_restarts=2, on_restart=calls.append)
        # initial attempt + 2 restarts all failed; the 3rd failure re-raises
        assert calls == [1, 2, 3]


class TestWatchdog:
    def test_stop_joins_thread(self):
        wd = Watchdog(timeout=0.05).start()
        wd.beat()
        wd.stop()
        assert not wd._thread.is_alive()
        assert not wd.stalled

    def test_stall_fires_and_stop_is_clean(self):
        fired = threading.Event()
        wd = Watchdog(timeout=0.05, on_stall=fired.set).start()
        assert fired.wait(2.0)
        assert wd.stalled
        wd.stop()
        assert not wd._thread.is_alive()

    def test_beats_prevent_stall(self):
        wd = Watchdog(timeout=0.2).start()
        for _ in range(5):
            time.sleep(0.04)
            wd.beat()
        wd.stop()
        assert not wd.stalled
