"""Pallas kernel sweeps vs pure-jnp oracles (interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.gather_pages import gather_pages
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_hot_slots)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,dh", [
        (1, 32, 32, 4, 4, 32),        # MHA
        (2, 64, 64, 8, 2, 64),        # GQA 4:1
        (1, 16, 48, 4, 1, 32),        # MQA, Sq != Sk
        (1, 64, 64, 4, 2, 120),       # non-128 head dim (danube)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes_vs_oracle(self, B, Sq, Sk, Hq, Hkv, dh, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, Sq, Hq, dh), dtype)
        k = jax.random.normal(ks[1], (B, Sk, Hkv, dh), dtype)
        v = jax.random.normal(ks[2], (B, Sk, Hkv, dh), dtype)
        a = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
        b = flash_attention(q, k, v, use_kernel=False)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=_tol(dtype), rtol=_tol(dtype))

    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 8),
                                               (False, 0)])
    def test_masks(self, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 32, 2, 32))
        k = jax.random.normal(ks[1], (1, 32, 2, 32))
        v = jax.random.normal(ks[2], (1, 32, 2, 32))
        a = flash_attention(q, k, v, causal=causal, window=window,
                            block_q=8, block_k=8, interpret=True)
        b = flash_attention(q, k, v, causal=causal, window=window,
                            use_kernel=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    def test_q_offset_decode_tail(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 8, 2, 32))
        k = jax.random.normal(ks[1], (1, 64, 2, 32))
        v = jax.random.normal(ks[2], (1, 64, 2, 32))
        a = flash_attention(q, k, v, q_offset=56, block_q=8, block_k=16,
                            interpret=True)
        b = flash_attention(q, k, v, q_offset=56, use_kernel=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


class TestGatherPages:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
    def test_exact_gather(self, dtype):
        pool = jnp.arange(32 * 6, dtype=jnp.float32).reshape(32, 6).astype(dtype)
        idx = jnp.array([0, 31, 7, 7, 13], jnp.int32)
        out = gather_pages(pool, idx, interpret=True)
        assert (np.asarray(out) == np.asarray(pool)[np.asarray(idx)]).all()

    def test_clamps_out_of_range(self):
        pool = jnp.arange(16.0).reshape(8, 2)
        out = gather_pages(pool, jnp.array([-5, 100], jnp.int32),
                           interpret=True)
        assert (np.asarray(out[0]) == np.asarray(pool[0])).all()
        assert (np.asarray(out[1]) == np.asarray(pool[7])).all()

    def test_multidim_pages(self):
        pool = jax.random.normal(jax.random.PRNGKey(0), (16, 4, 2, 8))
        idx = jnp.array([3, 0, 15], jnp.int32)
        out = gather_pages(pool, idx, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(pool)[np.asarray(idx)])


class TestGatherPagesAsync:
    """Issue/wait double-buffered gather == the pipelined/oracle gather."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
    def test_matches_ref(self, dtype):
        from repro.kernels.gather_pages import gather_pages_async
        pool = jnp.arange(32 * 6, dtype=jnp.float32).reshape(32, 6).astype(dtype)
        idx = jnp.array([0, 31, 7, 7, 13, 1], jnp.int32)
        out = gather_pages_async(pool, idx, interpret=True)
        assert (np.asarray(out) == np.asarray(pool)[np.asarray(idx)]).all()

    def test_clamps_and_multidim(self):
        from repro.kernels.gather_pages import gather_pages_async
        pool = jax.random.normal(jax.random.PRNGKey(0), (16, 4, 2, 8))
        idx = jnp.array([3, -5, 100], jnp.int32)
        out = gather_pages_async(pool, idx, interpret=True)
        expect = np.asarray(pool)[np.clip(np.asarray(idx), 0, 15)]
        np.testing.assert_allclose(np.asarray(out), expect)

    def test_single_page(self):
        from repro.kernels.gather_pages import gather_pages_async
        pool = jnp.arange(8.0).reshape(4, 2)
        out = gather_pages_async(pool, jnp.array([2], jnp.int32),
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(pool[2:3]))


class TestPagedAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,dh,ps,npps", [
        (2, 8, 2, 64, 16, 4),
        (1, 4, 4, 32, 8, 8),
        (3, 4, 1, 128, 32, 2),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_oracle(self, B, Hq, Hkv, dh, ps, npps, dtype):
        npages = npps * B + 4
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, 1, Hq, dh), dtype)
        kp = jax.random.normal(ks[1], (npages, ps, Hkv, dh), dtype)
        vp = jax.random.normal(ks[2], (npages, ps, Hkv, dh), dtype)
        pt = jax.random.randint(ks[3], (B, npps), 0, npages)
        ln = jnp.asarray(np.random.default_rng(0).integers(1, ps * npps + 1,
                                                           B), jnp.int32)
        a = paged_attention(q, kp, vp, pt, ln, interpret=True)
        b = paged_attention(q, kp, vp, pt, ln, use_kernel=False)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=_tol(dtype), rtol=_tol(dtype))

    def test_matches_dense_decode_attention(self):
        """Paged == contiguous decode attention when pages are linear."""
        from repro.models.attention import decode_attention
        B, Hq, Hkv, dh, ps, npps = 2, 4, 2, 32, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, 1, Hq, dh))
        kd = jax.random.normal(ks[1], (B, ps * npps, Hkv, dh))
        vd = jax.random.normal(ks[2], (B, ps * npps, Hkv, dh))
        kp = kd.reshape(B * npps, ps, Hkv, dh)
        vp = vd.reshape(B * npps, ps, Hkv, dh)
        pt = jnp.arange(B * npps, dtype=jnp.int32).reshape(B, npps)
        ln = jnp.array([20, 32], jnp.int32)
        a = paged_attention(q, kp, vp, pt, ln, interpret=True)
        b = decode_attention(q, kd, vd, ln)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_poisoned_table_masks_not_page0(self, use_kernel):
        """Regression: an invalid table entry *inside* lengths must be
        masked out of the softmax, not silently read as page 0's bytes
        (the old clip-into-range behavior)."""
        B, Hq, Hkv, dh, ps, npps = 2, 4, 2, 16, 4, 4
        npages = 8
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        q = jax.random.normal(ks[0], (B, 1, Hq, dh))
        kp = jax.random.normal(ks[1], (npages, ps, Hkv, dh))
        vp = jax.random.normal(ks[2], (npages, ps, Hkv, dh))
        ln = jnp.full((B,), ps * npps, jnp.int32)   # poison inside lengths
        pt = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
        pois = pt.at[0, 1].set(-1).at[1, 2].set(npages + 50)
        out = paged_attention(q, kp, vp, pois, ln, interpret=True,
                              use_kernel=use_kernel)
        clean = paged_attention(q, kp, vp, pt, ln, interpret=True,
                                use_kernel=use_kernel)
        # the poisoned pages changed the output (they're gone, not read)
        assert (np.asarray(out) != np.asarray(clean)).any()
        # oracle: the same rows with the poisoned page excised by length
        # masking on an explicitly re-packed table
        pack = jnp.asarray([[0, 2, 3, 0], [4, 5, 7, 0]], jnp.int32)
        ln2 = jnp.full((B,), ps * (npps - 1), jnp.int32)
        expect = paged_attention(q, kp, vp, pack, ln2, interpret=True,
                                 use_kernel=use_kernel)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=2e-6)
        # and definitely NOT equal to clip-to-page-0 / clip-to-last reads
        sub0 = pt.at[0, 1].set(0).at[1, 2].set(npages - 1)
        old = paged_attention(q, kp, vp, sub0, ln, interpret=True,
                              use_kernel=use_kernel)
        assert (np.asarray(out) != np.asarray(old)).any()


class TestPagedAttentionHotSlots:
    """Fused hot-slot kernel: in-place slot indirection == stacked flat pool.

    The three kernel variants (pipelined fused, async fused, flat) share one
    per-page online-softmax update, so on the same bytes their outputs are
    *bitwise* equal — the property the tiered §6.4 pin leans on.
    """

    def _mk(self, S, n_slots, ps, Hkv, Hq, dh, npps, dtype, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = jax.random.normal(ks[0], (S, 1, Hq, dh), dtype)
        kh = jax.random.normal(ks[1], (S, n_slots, ps, Hkv, dh), dtype)
        vh = jax.random.normal(ks[2], (S, n_slots, ps, Hkv, dh), dtype)
        st = jax.random.randint(ks[3], (S, npps), 0, n_slots, jnp.int32)
        ln = jnp.asarray(np.random.default_rng(seed).integers(
            1, ps * npps + 1, S), jnp.int32)
        return q, kh, vh, st, ln

    @pytest.mark.parametrize("S,Hq,Hkv,dh,ps,npps", [
        (2, 8, 2, 64, 16, 4),         # GQA 4:1
        (1, 4, 4, 32, 8, 8),          # MHA
        (3, 4, 1, 128, 32, 2),        # MQA, non-trivial page size
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("async_copy", [False, True])
    def test_bitwise_flat_equivalence(self, S, Hq, Hkv, dh, ps, npps,
                                      dtype, async_copy):
        n_slots = npps + 3
        q, kh, vh, st, ln = self._mk(S, n_slots, ps, Hkv, Hq, dh, npps,
                                     dtype)
        out = paged_attention_hot_slots(q, kh, vh, st, ln, interpret=True,
                                        async_copy=async_copy)
        # flat oracle: same bytes via the stacked pool + global table
        fk = kh.reshape((S * n_slots,) + kh.shape[2:])
        fv = vh.reshape((S * n_slots,) + vh.shape[2:])
        gt = st + jnp.arange(S, dtype=jnp.int32)[:, None] * n_slots
        flat = paged_attention(q, fk, fv, gt, ln, interpret=True)
        assert (np.asarray(out) == np.asarray(flat)).all()

    @pytest.mark.parametrize("async_copy", [False, True])
    def test_vs_exact_softmax_ref(self, async_copy):
        q, kh, vh, st, ln = self._mk(2, 6, 8, 2, 4, 32, 4, jnp.float32)
        a = paged_attention_hot_slots(q, kh, vh, st, ln, interpret=True,
                                      async_copy=async_copy)
        b = paged_attention_hot_slots(q, kh, vh, st, ln, use_kernel=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    @pytest.mark.parametrize("async_copy", [False, True])
    def test_non_resident_masked_not_read(self, async_copy):
        """A non-resident (-1 / out-of-range) slot entry is masked out of
        the softmax — never silently read as slot 0's bytes — and only the
        poisoned streams' outputs change."""
        S, n_slots, ps, Hkv, Hq, dh, npps = 3, 8, 4, 2, 4, 16, 4
        q, kh, vh, st, _ = self._mk(S, n_slots, ps, Hkv, Hq, dh, npps,
                                    jnp.float32, seed=1)
        ln = jnp.full((S,), ps * npps, jnp.int32)
        clean = paged_attention_hot_slots(q, kh, vh, st, ln, interpret=True,
                                          async_copy=async_copy)
        pois = st.at[0, 2].set(-1).at[1, 3].set(n_slots + 9)
        out = paged_attention_hot_slots(q, kh, vh, pois, ln, interpret=True,
                                        async_copy=async_copy)
        ref = paged_attention_hot_slots(q, kh, vh, pois, ln,
                                        use_kernel=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)
        assert (np.asarray(out)[:2] != np.asarray(clean)[:2]).any()
        assert (np.asarray(out)[2] == np.asarray(clean)[2]).all()
        # sync and async kernels agree bitwise on the poisoned table too
        other = paged_attention_hot_slots(q, kh, vh, pois, ln,
                                          interpret=True,
                                          async_copy=not async_copy)
        assert (np.asarray(out) == np.asarray(other)).all()
