"""Property tests for the async issue/wait data path (DESIGN.md §4/§5).

Hypothesis-driven: for arbitrary schedules, (a) hit-rate counters never
decrease when the in-flight ring gains slack (eviction pressure off — more
ring capacity can only land a superset of prefetches), (b) the
issued-prefetch decomposition sums for every configuration, and (c) it
keeps summing per stream once the shared-link budget introduces
``deferred`` completions and issue drops. The deterministic slices of
these properties also run without hypothesis in ``tests/test_paging.py``
and ``tests/test_link_budget.py``.
"""

import pytest

pytest.importorskip("hypothesis")
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as hst

from repro.paging.prefetch_serving import (PrefetchedStream,
                                           multi_stream_consume,
                                           stream_consume, stream_stats,
                                           stream_stats_at)

N_PAGES = 64
POOL = jnp.arange(N_PAGES * 4, dtype=jnp.float32).reshape(N_PAGES, 4)


def _stats(sched, ring_size, arrival_delay=1):
    geom = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES, page_elems=4,
                            ring_size=ring_size, arrival_delay=arrival_delay)
    st, sums, _ = stream_consume(POOL, jnp.asarray(sched, jnp.int32), geom,
                                 async_datapath=True)
    return stream_stats(st), np.asarray(sums)


schedules = hst.lists(hst.integers(0, N_PAGES - 1), min_size=10, max_size=80)


@settings(max_examples=25, deadline=None)
@given(sched=schedules,
       rings=hst.tuples(hst.integers(1, 6), hst.integers(0, 10)))
def test_hit_counters_never_decrease_with_ring_slack(sched, rings):
    r_small = rings[0]
    r_big = r_small + rings[1]
    s_small, _ = _stats(sched, r_small)
    s_big, _ = _stats(sched, r_big)
    assert s_big["hits"] >= s_small["hits"]
    assert s_big["prefetch_hits"] >= s_small["prefetch_hits"]


@settings(max_examples=25, deadline=None)
@given(sched=schedules, ring=hst.integers(1, 12),
       delay=hst.integers(1, 3))
def test_decomposition_and_data_for_arbitrary_schedules(sched, ring, delay):
    s, sums = _stats(sched, ring, delay)
    np.testing.assert_allclose(
        sums, np.asarray(POOL[np.asarray(sched)].sum(-1)))
    assert s["prefetch_issued"] == (s["prefetch_hits"] + s["pollution"]
                                    + s["inflight_at_end"]
                                    + s["resident_unused"]), s
    assert 0 <= s["partial_hits"] <= s["prefetch_hits"]
    assert s["faults"] == len(sched)


@settings(max_examples=20, deadline=None)
@given(scheds=hst.lists(hst.lists(hst.integers(0, N_PAGES - 1),
                                  min_size=24, max_size=24),
                        min_size=2, max_size=4),
       budget=hst.integers(0, 12), ring=hst.integers(1, 8))
def test_budgeted_decomposition_still_balances(scheds, budget, ring):
    """DESIGN.md §5: deferred/dropped never unbalance the §4.3 buckets."""
    geom = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES, page_elems=4,
                            ring_size=ring)
    st, sums, info = multi_stream_consume(
        POOL, jnp.asarray(scheds, jnp.int32), geom, async_datapath=True,
        link_budget=budget)
    np.testing.assert_allclose(
        np.asarray(sums), np.asarray(POOL[np.asarray(scheds)].sum(-1)))
    for i in range(len(scheds)):
        s = stream_stats_at(st, i)
        assert s["prefetch_issued"] == (s["prefetch_hits"] + s["pollution"]
                                        + s["inflight_at_end"]
                                        + s["resident_unused"]), s
        assert 0 <= s["partial_hits"] <= s["prefetch_hits"]
        assert 0 <= s["deferred"] <= s["prefetch_issued"]
    # per-step link totals tally with the per-stream info arrays
    assert int(info["link_demand_fetches"].sum()) == int(
        np.asarray(info["fetched"]).sum())
    assert int(info["link_deferred"].sum()) == int(
        np.asarray(info["deferred"]).sum())
