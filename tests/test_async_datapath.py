"""Property tests for the async issue/wait data path (DESIGN.md §4).

Hypothesis-driven: for arbitrary schedules, (a) hit-rate counters never
decrease when the in-flight ring gains slack (eviction pressure off — more
ring capacity can only land a superset of prefetches), and (b) the
issued-prefetch decomposition sums for every configuration. The
deterministic slices of these properties also run without hypothesis in
``tests/test_paging.py``.
"""

import pytest

pytest.importorskip("hypothesis")
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as hst

from repro.paging.prefetch_serving import (PrefetchedStream, stream_consume,
                                           stream_stats)

N_PAGES = 64
POOL = jnp.arange(N_PAGES * 4, dtype=jnp.float32).reshape(N_PAGES, 4)


def _stats(sched, ring_size, arrival_delay=1):
    geom = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES, page_elems=4,
                            ring_size=ring_size, arrival_delay=arrival_delay)
    st, sums, _ = stream_consume(POOL, jnp.asarray(sched, jnp.int32), geom,
                                 async_datapath=True)
    return stream_stats(st), np.asarray(sums)


schedules = hst.lists(hst.integers(0, N_PAGES - 1), min_size=10, max_size=80)


@settings(max_examples=25, deadline=None)
@given(sched=schedules,
       rings=hst.tuples(hst.integers(1, 6), hst.integers(0, 10)))
def test_hit_counters_never_decrease_with_ring_slack(sched, rings):
    r_small = rings[0]
    r_big = r_small + rings[1]
    s_small, _ = _stats(sched, r_small)
    s_big, _ = _stats(sched, r_big)
    assert s_big["hits"] >= s_small["hits"]
    assert s_big["prefetch_hits"] >= s_small["prefetch_hits"]


@settings(max_examples=25, deadline=None)
@given(sched=schedules, ring=hst.integers(1, 12),
       delay=hst.integers(1, 3))
def test_decomposition_and_data_for_arbitrary_schedules(sched, ring, delay):
    s, sums = _stats(sched, ring, delay)
    np.testing.assert_allclose(
        sums, np.asarray(POOL[np.asarray(sched)].sum(-1)))
    assert s["prefetch_issued"] == (s["prefetch_hits"] + s["pollution"]
                                    + s["inflight_at_end"]
                                    + s["resident_unused"]), s
    assert 0 <= s["partial_hits"] <= s["prefetch_hits"]
    assert s["faults"] == len(sched)
