import os
import sys

# Tests must see the real device count (1 CPU) — the 512-device forcing is
# exclusively the dry-run's (repro.launch.dryrun sets it before jax import).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# repo root (for `import benchmarks`) and src (for `import repro`)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
