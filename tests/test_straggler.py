"""StepTimeMonitor: EWMA step-time straggler detection (runtime layer).

The monitor is wired into the serving driver's per-token decode loop
(``repro.launch.serve``); these pins keep its flagging semantics stable:
outliers are flagged but never contaminate the baseline, the warmup
prefix never flags (first steps include compilation), and the summary
reports exactly what the launcher escalates on.
"""

import pytest

from repro.runtime.straggler import StepTimeMonitor


class TestOutlierImmunity:
    def test_spike_is_flagged_but_ewma_unchanged(self):
        mon = StepTimeMonitor(alpha=0.1, threshold=2.0, warmup=3)
        for _ in range(10):
            mon.record(1.0)
        baseline = mon.ewma
        assert baseline == pytest.approx(1.0)
        assert mon.record(10.0) is True
        # the outlier does not move the baseline...
        assert mon.ewma == pytest.approx(baseline)
        # ...so an immediately following normal step is not flagged
        assert mon.record(1.0) is False

    def test_repeated_spikes_all_flagged(self):
        mon = StepTimeMonitor(warmup=2)
        for _ in range(5):
            mon.record(1.0)
        flags = [mon.record(50.0) for _ in range(4)]
        assert flags == [True] * 4
        assert mon.flags == 4
        assert mon.ewma == pytest.approx(1.0)

    def test_gradual_drift_tracks_without_flagging(self):
        mon = StepTimeMonitor(alpha=0.5, threshold=2.0, warmup=2)
        dt = 1.0
        for _ in range(30):
            assert mon.record(dt) is False
            dt *= 1.2            # 20%/step stays under the 2x threshold
        assert mon.ewma > 5.0    # the baseline followed the drift


class TestWarmupSuppression:
    def test_spikes_inside_warmup_not_flagged(self):
        mon = StepTimeMonitor(warmup=5)
        assert mon.record(1.0) is False          # seeds the EWMA
        for _ in range(4):                        # counts 2..5 <= warmup
            assert mon.record(100.0) is False
        assert mon.flags == 0

    def test_first_step_after_warmup_can_flag(self):
        mon = StepTimeMonitor(warmup=2, threshold=2.0)
        mon.record(1.0)
        mon.record(1.0)
        assert mon.record(10.0) is True


class TestSummary:
    def test_counts_and_history(self):
        mon = StepTimeMonitor(warmup=1)
        mon.record(1.0)
        mon.record(1.0)
        mon.record(9.0)
        mon.record(1.0)
        s = mon.summary()
        assert s["steps"] == 4
        assert s["straggler_steps"] == 1
        assert s["ewma"] == pytest.approx(1.0)
        assert mon.history == [1.0, 1.0, 9.0, 1.0]

    def test_empty_monitor(self):
        s = StepTimeMonitor().summary()
        assert s == {"steps": 0, "ewma": None, "straggler_steps": 0}
