"""Per-arch smoke tests (required): reduced config, one train step on CPU,
output shapes + no NaNs; prefill/decode consistency where applicable."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models.model import build_model


@pytest.fixture(scope="module")
def built():
    return {}


def _batch_for(cfg, B=2, S=16):
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    b = {"tokens": toks[:, :-1], "targets": toks[:, 1:],
         "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(key, (B, S, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
    if cfg.rope_type == "mrope":
        b["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        b["positions3"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
    return b, toks


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params, specs = model.init_params(jax.random.PRNGKey(0))
    batch, _ = _batch_for(cfg)
    loss, grads = jax.value_and_grad(model.train_forward)(params, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss)), arch
    finite = all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    assert finite, f"{arch}: non-finite grads"
    # specs resolve to a sharding tree structurally identical to params
    from repro.distributed import rules_for, shardings_for_tree
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = shardings_for_tree(specs, params, mesh, rules_for("train", False))
    assert jax.tree.structure(sh) == jax.tree.structure(params), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.PRNGKey(0))
    batch, toks = _batch_for(cfg)
    B, S = batch["tokens"].shape
    if cfg.family == "encdec":
        lp, st = model.prefill(params, {"frames": batch["frames"],
                                        "tokens": toks[:, :S]}, S + 4)
        lq, st2 = model.prefill(params, {"frames": batch["frames"],
                                         "tokens": toks[:, :S - 1]}, S + 4)
    else:
        lp, st = model.prefill(params, {"tokens": toks[:, :S]}, S + 4)
        lq, st2 = model.prefill(params, {"tokens": toks[:, :S - 1]}, S + 4)
    lg, st2 = model.decode_step(params, toks[:, S - 1], st2)
    err = float(jnp.abs(lg - lp).max() / (jnp.abs(lp).max() + 1e-9))
    assert err < 5e-3, f"{arch}: prefill/decode mismatch {err}"
    assert bool(jnp.isfinite(lg).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_state_specs_match(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    if model.decode_state_specs is None:
        pytest.skip("no decode state specs")
    st = jax.eval_shape(lambda: model.init_decode_state(2, 32, 32))
    specs = model.decode_state_specs()
    # every state leaf has a spec prefix of matching (or shorter) rank
    flat_s, _ = jax.tree.flatten(st)
    is_spec = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    flat_x = jax.tree.leaves(specs, is_leaf=is_spec)
    assert len(flat_s) == len(flat_x)
    for leaf, spec in zip(flat_s, flat_x):
        assert len(spec) <= len(leaf.shape)
