"""End-to-end integration: train a tiny LM with the full substrate stack,
kill it mid-run, restart from checkpoint, and verify the loss trajectory is
bit-exact vs an uninterrupted run (the paper-scale fault-tolerance contract).
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.data import make_pipeline
from repro.models.model import build_model
from repro.optim import make_optimizer
from repro.runtime import run_with_restarts

ARCH = "qwen2_5_3b"
N_STEPS, SAVE_EVERY = 12, 4


def _setup():
    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    init, upd = make_optimizer("adamw", 1e-2)

    @jax.jit
    def train_step(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(model.train_forward)(params, batch)
        params, opt_state, info = upd(grads, opt_state, params, step)
        return params, opt_state, loss

    def make_state():
        params, _ = model.init_params(jax.random.PRNGKey(0))
        return {"params": params, "opt": init(params)}

    pipe = make_pipeline(cfg.vocab_size, global_batch=4, seq_len=16, seed=1)
    return train_step, make_state, pipe


def _run_uninterrupted():
    train_step, make_state, pipe = _setup()
    state = make_state()
    losses = []
    for step in range(N_STEPS):
        batch = {k: jnp.asarray(v) for k, v in pipe.peek(step).items()}
        state["params"], state["opt"], loss = train_step(
            state["params"], state["opt"], batch, jnp.int32(step))
        losses.append(float(loss))
    return losses


def test_loss_decreases_on_fixed_batch():
    """Overfit one batch: loss must drop (uniform-random streams have no
    learnable signal beyond unigram bias, so we pin the batch)."""
    train_step, make_state, pipe = _setup()
    state = make_state()
    batch = {k: jnp.asarray(v) for k, v in pipe.peek(0).items()}
    losses = []
    for step in range(N_STEPS):
        state["params"], state["opt"], loss = train_step(
            state["params"], state["opt"], batch, jnp.int32(step))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5


def test_kill_restart_bit_exact():
    ref_losses = _run_uninterrupted()
    train_step, make_state, pipe = _setup()

    with tempfile.TemporaryDirectory() as ckdir:
        losses = {}
        fail_at = {6: True}          # mid-run "node failure"

        def mk():
            return make_state()

        def one(state, step):
            if fail_at.pop(step, False):
                raise RuntimeError("simulated preemption")
            batch = {k: jnp.asarray(v) for k, v in pipe.peek(step).items()}
            p, o, loss = train_step(state["params"], state["opt"], batch,
                                    jnp.int32(step))
            losses[step] = float(loss)
            return {"params": p, "opt": o}

        def sv(state, step):
            save_checkpoint(ckdir, step, state)

        def rs():
            s = latest_step(ckdir)
            if s is None:
                return None
            like = make_state()
            state, _ = restore_checkpoint(ckdir, s, like)
            state = jax.tree.map(jnp.asarray, state)
            return state, s

        _, restarts = run_with_restarts(mk, one, sv, rs, N_STEPS, SAVE_EVERY)
        assert restarts == 1
        got = [losses[i] for i in range(N_STEPS)]
        np.testing.assert_allclose(got, ref_losses, rtol=0, atol=0)


def test_elastic_restore_to_different_mesh_layout():
    """Checkpoint written unsharded restores under a sharded layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    params, specs = model.init_params(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, params)
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
        out, _ = restore_checkpoint(d, 0, params, shardings=sh)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
