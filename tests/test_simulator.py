"""Trace simulator invariants + latency-model calibration checks."""

import numpy as np

from repro.core import traces
from repro.core.cache import PageCache
from repro.core.prefetcher import make_prefetcher
from repro.core.simulator import LATENCY_MODELS, run_policy_matrix, simulate


def test_hit_plus_miss_equals_faults():
    tr = traces.powergraph_like(3000)
    res = run_policy_matrix(tr, ["leap", "read_ahead"], cache_capacity=64)
    for r in res.values():
        assert r.stats.cache_hits + r.stats.misses == r.stats.faults


def test_lean_path_beats_block_path():
    """Paper Fig. 1/2: ~34us block-layer overhead (mean; high-variance
    lognormal, so the median sits lower) vs ~1.2us lean path."""
    tr = traces.stride(2000, 10)
    lean = simulate(tr, make_prefetcher("none"), PageCache(64), "rdma_lean")
    block = simulate(tr, make_prefetcher("none"), PageCache(64), "rdma_block")
    assert block.stats.latency_percentiles()["p50"] > \
        4 * lean.stats.latency_percentiles()["p50"]
    assert block.stats.latency_percentiles()["avg"] > \
        6 * lean.stats.latency_percentiles()["avg"]


def test_disk_slower_than_rdma():
    tr = traces.random_pages(1000)
    disk = simulate(tr, make_prefetcher("none"), PageCache(64), "disk_block")
    rdma = simulate(tr, make_prefetcher("none"), PageCache(64), "rdma_block")
    assert disk.total_time > rdma.total_time


def test_prefetch_consumes_link_bandwidth():
    """Over-aggressive prefetching delays demand fetches (wasted I/O bw)."""
    tr = traces.random_pages(1500, seed=3)
    greedy = simulate(tr, make_prefetcher("next_n_line", n=8),
                      PageCache(64, eviction="lru"), "rdma_lean")
    none = simulate(tr, make_prefetcher("none"), PageCache(64), "rdma_lean")
    assert greedy.link_busy > 3 * none.link_busy


def test_deterministic_given_seed():
    tr = traces.voltdb_like(500)
    a = simulate(tr, make_prefetcher("leap"), PageCache(64), "rdma_block", seed=7)
    b = simulate(tr, make_prefetcher("leap"), PageCache(64), "rdma_block", seed=7)
    assert a.stats.latencies == b.stats.latencies


def test_latency_models_registered():
    assert {"disk_block", "rdma_block", "disk_lean", "rdma_lean",
            "tpu_ici", "tpu_dcn"} <= set(LATENCY_MODELS)


class TestTraces:
    def test_classify_windows_pure_patterns(self):
        from repro.core.traces import classify_windows
        assert classify_windows(traces.sequential(500), 8)["sequential"] == 1.0
        assert classify_windows(traces.stride(500, 10), 8)["stride"] == 1.0
        r = classify_windows(traces.random_pages(500), 8)
        assert r["other"] > 0.95

    def test_x2_windows_degenerate_to_stride(self):
        """Paper §2.3: at X=2 every non-sequential pair counts as 'stride' —
        the motivating flaw of 2-fault pattern detectors."""
        from repro.core.traces import classify_windows
        r = classify_windows(traces.memcached_like(4000), 2)
        assert r["stride"] > 0.8 and r["other"] < 0.05

    def test_memcached_mostly_irregular_at_x8(self):
        from repro.core.traces import classify_windows
        r = classify_windows(traces.memcached_like(4000), 8)
        assert r["other"] > 0.9                 # paper Fig. 3: ~96%

    def test_voltdb_majority_irregular_at_x8(self):
        from repro.core.traces import classify_windows
        r = classify_windows(traces.voltdb_like(4000), 8)
        assert r["other"] > 0.5                 # paper: ~69% irregular

    def test_generators_deterministic(self):
        for name, gen in traces.TRACES.items():
            a, b = gen(n=256), gen(n=256)
            assert np.array_equal(a, b), name
