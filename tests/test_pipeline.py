"""GPipe pipeline over a forced multi-device host mesh (subprocess)."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.pipeline import bubble_fraction

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_forward

    mesh = jax.make_mesh((4,), ("pod",))
    S, D, B = 4, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(0), S)
    params = jnp.stack([jax.random.normal(k, (D, D)) / np.sqrt(D) for k in ks])
    x = jax.random.normal(jax.random.PRNGKey(9), (B, D))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    y = pipeline_forward(stage_fn, params, x, mesh=mesh, n_micro=4)
    # reference: sequential application of all stages
    ref = x
    for s in range(S):
        ref = stage_fn(params[s], ref)
    err = float(jnp.abs(y - ref).max())
    assert err < 1e-5, f"pipeline mismatch {err}"

    # gradients flow through the pipeline (training viability)
    def loss(params):
        return jnp.sum(pipeline_forward(stage_fn, params, x, mesh=mesh,
                                        n_micro=4) ** 2)
    g = jax.grad(loss)(params)
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).sum()) > 0
    print("PIPELINE_OK", err)
""")


def test_pipeline_matches_sequential_and_differentiates():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=300)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(2, 16) == pytest.approx(1 / 17)
    assert bubble_fraction(1, 8) == 0.0
