"""Hot-buffer pool (jittable) + PageCache (simulator) semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import PageCache
from repro.core.pool import (pool_access, pool_init, pool_stats,
                             pool_wait_batch, ring_init)


def _serve(stp, hot, pool, pages, is_pf, lazy=False):
    pages = jnp.asarray(pages, jnp.int32)
    is_pf = jnp.asarray(is_pf)
    valid = jnp.ones(pages.shape, bool)
    return pool_access(stp, hot, pool, pages, is_pf, valid, lazy=lazy)


class TestPool:
    def setup_method(self):
        self.pool = jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4)

    def test_data_correctness_demand(self):
        st = pool_init(64, 8)
        hot = jnp.zeros((8, 4))
        st, hot, slots, info = _serve(st, hot, self.pool, [5, 9, 5, 9],
                                      [False, False, False, False])
        for i, p in enumerate([5, 9, 5, 9]):
            assert (hot[slots[i]] == self.pool[p]).all()

    def test_prefetch_then_hit_eager_frees(self):
        st = pool_init(64, 8)
        hot = jnp.zeros((8, 4))
        st, hot, _, _ = _serve(st, hot, self.pool, [1, 2, 3], [False, True, True])
        st, hot, slots, info = _serve(st, hot, self.pool, [2, 3], [False, False])
        assert bool(info["prefetched_hit"][0]) and bool(info["prefetched_hit"][1])
        s = pool_stats(st)
        assert s["prefetch_hits"] == 2 and s["pollution"] == 0
        # eager eviction: slots returned; page no longer resident
        assert int(st["page_slot"][2]) == -1 and int(st["page_slot"][3]) == -1

    def test_fifo_eviction_counts_pollution(self):
        st = pool_init(64, 4)
        hot = jnp.zeros((4, 4))
        for base in range(0, 12, 2):
            st, hot, _, _ = _serve(st, hot, self.pool,
                                   [base, base + 1], [True, True])
        s = pool_stats(st)
        assert s["prefetch_issued"] == 12
        assert s["pollution"] == 12 - 4       # only n_slots can remain

    def test_lazy_mode_scans(self):
        st = pool_init(64, 4)
        hot = jnp.zeros((4, 4))
        for p in range(8):
            st, hot, _, _ = _serve(st, hot, self.pool, [p], [False], lazy=True)
        s = pool_stats(st)
        assert s["alloc_scans"] > 0           # kswapd-style LRU scanning

    def test_eager_mode_never_scans(self):
        st = pool_init(64, 4)
        hot = jnp.zeros((4, 4))
        for p in range(16):
            st, hot, _, _ = _serve(st, hot, self.pool, [p], [False])
        assert pool_stats(st)["alloc_scans"] == 0

    def test_lazy_prefetched_hit_keeps_slot_mapped(self):
        """Regression: lazy mode must NOT free the slot on a prefetched hit —
        the mapping stays live until LRU eviction, so a freed slot would be
        reallocated while page_slot still points at it (phantom hit serving
        another page's data)."""
        st = pool_init(64, 8)
        hot = jnp.zeros((8, 4))
        st, hot, _, _ = _serve(st, hot, self.pool, [5], [True], lazy=True)
        st, hot, _, info = _serve(st, hot, self.pool, [5], [False], lazy=True)
        assert bool(info["prefetched_hit"][0])
        # fill remaining free slots so a leaked slot would get reused
        st, hot, _, _ = _serve(st, hot, self.pool, [1, 2, 3, 4, 6, 7, 8],
                               [False] * 7, lazy=True)
        st, hot, slots, info = _serve(st, hot, self.pool, [5], [False],
                                      lazy=True)
        assert bool(info["hit"][0])
        assert (hot[slots[0]] == self.pool[5]).all()

    def test_out_of_range_requests_ignored(self):
        st = pool_init(64, 8)
        hot = jnp.zeros((8, 4))
        st, hot, slots, info = _serve(st, hot, self.pool, [70, -3, 5],
                                      [True, True, False])
        s = pool_stats(st)
        assert s["prefetch_issued"] == 0 and s["misses"] == 1


class TestBatchGeometryPrecondition:
    """The documented per-batch hot-buffer floor is *enforced* at trace
    time instead of silently corrupting slot metadata: ``2*K`` under eager
    eviction (a batch pins K live + K deferred-free slots), ``K`` under
    lazy LRU (fewer and the batch re-evicts its own slots)."""

    def test_pool_access_rejects_undersized_hot_buffer(self):
        st = pool_init(64, 8)                    # 8 slots, K=5 -> needs 10
        hot = jnp.zeros((8, 4))
        pool = jnp.zeros((64, 4))
        pages = jnp.arange(5, dtype=jnp.int32)
        with pytest.raises(ValueError, match="n_slots=8 < 2\\*K=10"):
            pool_access(st, hot, pool, pages, jnp.zeros((5,), bool),
                        jnp.ones((5,), bool))

    def test_pool_wait_batch_rejects_undersized_hot_buffer(self):
        st, ring = pool_init(64, 4), ring_init(4)
        hot = jnp.zeros((4, 4))
        pool = jnp.zeros((64, 4))
        pages = jnp.arange(3, dtype=jnp.int32)   # D=3 -> needs 6 > 4
        with pytest.raises(ValueError, match="n_slots=4 < 2\\*K=6"):
            pool_wait_batch(st, ring, hot, pool, pages,
                            jnp.ones((3,), bool), jnp.int32(0))

    def test_lazy_floor_is_k_not_2k(self):
        # lazy LRU never defers frees: K <= n_slots < 2*K is legal (the
        # tiered sync sweep runs exactly such geometries) ...
        st = pool_init(64, 8)
        hot = jnp.zeros((8, 4))
        pool = jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4)
        st, hot, slots, info = _serve(st, hot, pool, [1, 2, 3, 4, 5, 6],
                                      [False] * 6, lazy=True)
        for i, p in enumerate([1, 2, 3, 4, 5, 6]):
            assert (hot[slots[i]] == pool[p]).all()
        # ... but below K the batch would re-evict its own slots
        st2 = pool_init(64, 4)
        with pytest.raises(ValueError, match="n_slots=4 < K=6"):
            pool_access(st2, jnp.zeros((4, 4)), pool,
                        jnp.arange(6, dtype=jnp.int32),
                        jnp.zeros((6,), bool), jnp.ones((6,), bool),
                        lazy=True)

    def test_boundary_geometry_still_accepted(self):
        st = pool_init(64, 8)                    # exactly 2*K is legal
        hot = jnp.zeros((8, 4))
        pool = jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4)
        st, hot, slots, info = _serve(st, hot, pool, [1, 2, 3, 4],
                                      [False, True, True, True])
        assert bool(info["fetched"].all())


class TestPageCache:
    def test_eager_frees_on_hit(self):
        c = PageCache(8, eviction="eager")
        c.insert_prefetch(5, now=0.0, ready_t=1.0)
        hit, pf, wait = c.lookup(5, now=2.0)
        assert hit and pf and 5 not in c
        assert c.stats.prefetch_hits == 1

    def test_partial_hit_waits_residual(self):
        c = PageCache(8, eviction="eager")
        c.insert_prefetch(5, now=0.0, ready_t=4.0)
        hit, pf, wait = c.lookup(5, now=1.0)
        assert hit and wait == pytest.approx(3.0)
        assert c.stats.partial_hits == 1 and c.stats.prefetch_hits == 1
        assert c.stats.latency_hidden_frac == 0.0

    def test_double_access_while_in_flight_stays_resident(self):
        """Regression: an eager partial hit must NOT delete the in-flight
        entry — a re-access before ready_t previously became a full miss
        that re-paid the entire fabric fetch, when only the residual
        transfer was outstanding."""
        c = PageCache(8, eviction="eager")
        c.insert_prefetch(5, now=0.0, ready_t=10.0)
        hit1, pf1, wait1 = c.lookup(5, now=2.0)
        assert hit1 and pf1 and wait1 == pytest.approx(8.0)
        assert 5 in c                      # still resident until ready_t
        hit2, pf2, wait2 = c.lookup(5, now=6.0)
        assert hit2 and not pf2            # plain hit on the residual
        assert wait2 == pytest.approx(4.0)
        assert c.stats.misses == 0 and c.stats.prefetch_hits == 1
        assert c.stats.partial_hits == 1   # not double-counted
        # after arrival the next hit frees it (normal eager semantics)
        hit3, _, wait3 = c.lookup(5, now=11.0)
        assert hit3 and wait3 == 0.0 and 5 not in c

    def test_arrived_consumed_entries_purged_before_live_prefetches(self):
        """Regression: once a partial-hit entry's transfer completes it is
        garbage under eager — it must be purged under pressure rather than
        squatting on capacity and forcing live prefetches out as
        pollution."""
        c = PageCache(4, eviction="eager")
        for p in range(4):
            c.insert_prefetch(p, now=0.0, ready_t=5.0)
            c.lookup(p, now=1.0)               # partial hits, never re-hit
        assert c.occupancy == 4
        # long after ready_t, new prefetches must displace the stale
        # arrived-consumed entries, not each other
        for p in range(10, 14):
            assert c.insert_prefetch(p, now=20.0, ready_t=21.0)
        assert c.stats.pollution == 0
        assert all(p in c for p in range(10, 14))

    def test_eager_eviction_falls_back_past_inflight_residents(self):
        """Consumed-but-in-flight residents must not crash eviction when the
        unconsumed-prefetch FIFO is empty and the cache is full."""
        c = PageCache(2, eviction="eager")
        for p in (1, 2):
            c.insert_prefetch(p, now=0.0, ready_t=10.0)
            c.lookup(p, now=1.0)           # partial hits: stay resident
        assert c.occupancy == 2 and not c.prefetch_fifo
        assert c.insert_prefetch(3, now=2.0, ready_t=12.0)
        assert c.occupancy <= 2 and 3 in c
        assert c.stats.pollution == 0      # evictees were already served

    def test_arrived_hit_is_not_partial(self):
        c = PageCache(8, eviction="eager")
        c.insert_prefetch(5, now=0.0, ready_t=1.0)
        c.lookup(5, now=2.0)
        assert c.stats.partial_hits == 0 and c.stats.prefetch_hits == 1
        assert c.stats.latency_hidden_frac == 1.0

    def test_lru_scan_stall_charged(self):
        c = PageCache(4, eviction="lru", high_watermark=2.0)  # no bg scan
        for p in range(4):
            c.insert_demand(p, now=float(p), ready_t=float(p))
        stall = c.insert_demand(9, now=5.0, ready_t=5.0)
        assert stall > 0 and c.scanned_entries > 0

    def test_timeliness_recorded(self):
        c = PageCache(8, eviction="eager")
        c.insert_prefetch(1, now=0.0, ready_t=0.5)
        c.lookup(1, now=3.0)
        assert c.stats.timeliness == [pytest.approx(3.0)]

    def test_drain_counts_unconsumed(self):
        c = PageCache(8, eviction="eager")
        c.insert_prefetch(1, 0.0, 0.0)
        c.insert_prefetch(2, 0.0, 0.0)
        c.lookup(1, 1.0)
        c.drain_unconsumed()
        assert c.stats.pollution == 1

    def test_drain_separates_inflight_from_pollution(self):
        c = PageCache(8, eviction="eager")
        c.insert_prefetch(1, now=0.0, ready_t=1.0)    # landed, never hit
        c.insert_prefetch(2, now=0.0, ready_t=9.0)    # still in flight at end
        c.drain_unconsumed(now=5.0)
        assert c.stats.pollution == 1
        assert c.stats.inflight_at_end == 1
        # decomposition: issued == hits + pollution + inflight_at_end
        assert c.stats.prefetch_issued == (c.stats.prefetch_hits
                                           + c.stats.pollution
                                           + c.stats.inflight_at_end)
