"""Prefetch policies on canonical traces: paper §2.2/§5.2 behaviors."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import traces
from repro.core.cache import PageCache
from repro.core.prefetcher import LeapPrefetcher, make_prefetcher
from repro.core.simulator import simulate


def _run(trace, name, capacity=64, **kw):
    pf = make_prefetcher(name, **kw)
    ev = "eager" if name == "leap" else "lru"
    cache = PageCache(capacity, eviction=ev)
    return simulate(trace, pf, cache, model="rdma_lean")


class TestSequential:
    def test_all_policies_cover_sequential(self):
        tr = traces.sequential(2000)
        for name in ("leap", "next_n_line", "stride", "read_ahead"):
            r = _run(tr, name)
            assert r.stats.hit_rate > 0.85, (name, r.stats.hit_rate)


class TestStride:
    """Fig. 2/7: stride access defeats sequential prefetchers, not Leap."""

    def test_leap_and_stride_cover(self):
        tr = traces.stride(2000, 10)
        assert _run(tr, "leap").stats.hit_rate > 0.95
        # stride acts on misses only (paper §5.2.3): steady state d/(d+1)
        assert _run(tr, "stride").stats.hit_rate > 0.85

    def test_nextline_readahead_fail(self):
        tr = traces.stride(2000, 10)
        assert _run(tr, "next_n_line").stats.hit_rate < 0.05
        assert _run(tr, "read_ahead").stats.hit_rate < 0.05

    def test_leap_median_latency_near_hit_time(self):
        tr = traces.stride(2000, 10)
        r = _run(tr, "leap")
        assert r.stats.latency_percentiles()["p50"] <= 1.5  # ~t_hit

    def test_negative_stride(self):
        tr = traces.stride(1000, -7, start=1 << 20)
        assert _run(tr, "leap").stats.hit_rate > 0.95


class TestIrregular:
    def test_leap_throttles_on_random(self):
        """Memcached case (§5.3.4): detect randomness, stop prefetching."""
        tr = traces.random_pages(2000, seed=1)
        r = _run(tr, "leap")
        assert r.stats.prefetch_issued < 0.1 * len(tr)

    def test_nextnline_pollutes_on_random(self):
        tr = traces.random_pages(2000, seed=1)
        r = _run(tr, "next_n_line")
        assert r.stats.pollution > 10 * _run(tr, "leap").stats.pollution


class TestAdaptation:
    def test_phase_shift_recovers(self):
        """Fig. 5: trend flip is re-detected and coverage recovers."""
        tr = traces.phase_shift(2000, deltas=(-3, 2), noise_every=0)
        r = _run(tr, "leap")
        assert r.stats.hit_rate > 0.9

    def test_interleaved_streams_confuse_shared_detector(self):
        """Motivation for per-process isolation (§4.1): one shared detector
        on interleaved strides performs much worse than isolated ones."""
        tr = traces.interleaved(2000, streams=4, step=7)
        shared = _run(tr, "leap").stats.hit_rate
        per = []
        for s in range(4):
            sub = tr[s::4]
            per.append(_run(sub, "leap").stats.hit_rate)
        assert np.mean(per) > shared + 0.2


class TestLeapInvariants:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 1 << 12), min_size=10, max_size=300))
    def test_candidates_follow_contract(self, pages):
        pf = LeapPrefetcher(pw_max=8)
        for p in pages:
            cands = pf.on_fault(p, False)
            assert len(cands) <= 8
            if cands:
                step = cands[0] - p
                assert step != 0
                assert cands == [p + step * (i + 1) for i in range(len(cands))]

    def test_reset(self):
        pf = LeapPrefetcher()
        for p in range(100):
            pf.on_fault(p, p > 0)
        pf.reset()
        assert pf.current_trend is None and pf.on_fault(5, False) == []
