"""Three-tier page lifecycle (DESIGN.md §12): mirrors, hysteresis, reductions.

The §12 refactor threads one lifecycle — HBM hot pool -> far shard
(uncompressed) -> compressed cold tier — through the jitted scan, both
lock-step twins, the event engine, and the serving engine. These tests pin
the contracts the layers share:

* **Cross-validation** — per-stream ``hit/partial/deferred/drop`` counts
  *plus* ``migrations``/``promotions`` (and pool-wide demotions) from the
  jitted scan match the shardstep twin exactly, over budgets x placements,
  and the §8 trace differ reports zero divergent events. The single-link
  linkstep twin mirrors what survives at one shard: the compressed tier.
* **Hysteresis** — an oscillating page (two streams pulling the same pages
  toward different homes, offset in time) ping-pongs without a cooldown and
  migrates exactly once per window with one; bounded migrations per window
  in all cases; pinned identically in scan and twin.
* **Off-flag reduction** — ``migration=None`` and
  ``MigrationCfg(enabled=False)`` are the same compiled two-tier path:
  bit-equal scan info, identical twin reports, identical engine and
  serving reports (modulo wall-clock fields).
* **Chaos composition** (``-m chaos``) — a migration targeting a dead
  shard is dropped and pollution-counted; no migration grant ever occupies
  a dead NIC; the twin stays divergence-free under node loss; the event
  engine counts its dropped migrations.
* **Event engine** — trend-driven migration on the continuous clock is
  sanity-checked (not bit-pinned, same stance as chaos): it re-homes hot
  working sets and cuts makespan where static placement pays far transfers
  forever.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.fabric import FabricScenario, TenantSpec, run_fabric
from repro.fabric.chaos import ChaosSpec
from repro.fabric.linkstep import run_linkstep
from repro.fabric.shardstep import run_shardstep
from repro.obs.diff import assert_traces_equal
from repro.obs.trace import TraceRecorder, decode_stream_events
from repro.paging.lifecycle import MigrationCfg
from repro.paging.prefetch_serving import PrefetchedStream, stream_stats_at
from repro.paging.sharded_pool import (ShardedPoolCfg,
                                       sharded_multi_stream_consume)
from repro.serving import ServeConfig, ServingEngine, SyntheticExecutor

N_PAGES, T = 64, 48
POOL = jnp.arange(N_PAGES * 4, dtype=jnp.float32).reshape(N_PAGES, 4)
GEOM = PrefetchedStream(n_pages=N_PAGES, n_slots=N_PAGES, page_elems=4,
                        ring_size=8, pw_max=4)
MIG = MigrationCfg(mig_per_stream=2, lead=1, cooldown=8)
MIG_COMP = MigrationCfg(mig_per_stream=2, lead=1, cooldown=8,
                        compressed=True, far_capacity=N_PAGES // 2,
                        demote_per_step=2, decompress_delay=2)


def _scheds() -> np.ndarray:
    """Two strided walks that spend most steps off their home shard."""
    t = np.arange(T)
    return np.stack([(16 + 2 * t) % N_PAGES,
                     (40 + 3 * t) % N_PAGES]).astype(np.int32)


def _jitted_summary(st, info, i: int) -> dict:
    """Jitted per-stream counts in the twin's stream_summary vocabulary."""
    return dict(stream_stats_at(st, i),
                migrations=int(np.asarray(info["migrated"])[i].sum()),
                promotions=int(np.asarray(info["promoted"])[i].sum()))


# --------------------------------------------------------------------------
# jitted scan == lock-step twins, counts exact + zero divergent events
# --------------------------------------------------------------------------
class TestMigrationCrossValidation:
    @pytest.mark.parametrize("placement", ["block", "interleave"])
    @pytest.mark.parametrize("budget", [None, 2])
    @pytest.mark.parametrize("cfg", [MIG, MIG_COMP],
                             ids=["uncompressed", "compressed"])
    def test_scan_matches_shardstep_twin(self, placement, budget, cfg):
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=4, placement=placement,
                             link_budget=budget, near_delay=1, far_delay=3)
        st, sums, info = sharded_multi_stream_consume(
            POOL, jnp.asarray(scheds), GEOM, fab, migration=cfg)
        # the data plane is untouched by migration (scheduling metadata
        # only): served bytes stay exact
        np.testing.assert_allclose(np.asarray(sums),
                                   np.asarray(POOL[scheds].sum(-1)))
        rec = TraceRecorder()
        rep = run_shardstep(scheds, N_PAGES, 4, placement, budget,
                            ring_size=GEOM.ring_size, near_delay=1,
                            far_delay=3, pw_max=GEOM.pw_max,
                            h_size=GEOM.h_size, n_split=GEOM.n_split,
                            recorder=rec, migration=cfg)
        for i in range(scheds.shape[0]):
            j = _jitted_summary(st, info, i)
            r = rep.stream_summary(i)
            assert {k: j[k] for k in r} == r, \
                f"stream {i}, {placement}, budget {budget}"
        assert int(np.asarray(info["demoted"]).sum()) == (rep.demotions or 0)
        # §8: the trace differ spans migration — zero divergent events
        assert_traces_equal(
            decode_stream_events(scheds, info, n_pages=N_PAGES, n_shards=4,
                                 placement=placement),
            rec.events,
            context=f"{placement}, budget {budget}")
        # migration actually fired (the pins above are non-vacuous)
        assert int(np.asarray(info["migrated"]).sum()) > 0

    def test_single_link_twin_mirrors_compressed_tier(self):
        """At one shard nothing is ever cross-shard, so migration proper
        never fires; the linkstep twin mirrors what remains — demotion,
        promotion, and the decompress surcharge."""
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=1, placement="block", link_budget=3,
                             near_delay=1, far_delay=1)
        st, _, info = sharded_multi_stream_consume(
            POOL, jnp.asarray(scheds), GEOM, fab, migration=MIG_COMP)
        rep = run_linkstep(scheds, N_PAGES, budget=3,
                           ring_size=GEOM.ring_size, arrival_delay=1,
                           pw_max=GEOM.pw_max, h_size=GEOM.h_size,
                           n_split=GEOM.n_split, migration=MIG_COMP)
        for i in range(scheds.shape[0]):
            j = _jitted_summary(st, info, i)
            r = rep.stream_summary(i)
            assert {k: j[k] for k in r} == r, f"stream {i}"
        assert int(np.asarray(info["migrated"]).sum()) == 0
        assert int(np.asarray(info["demoted"]).sum()) == rep.demotions > 0
        assert int(np.asarray(info["promoted"]).sum()) > 0


# --------------------------------------------------------------------------
# classifier hysteresis: no ping-pong at the hot/cold boundary
# --------------------------------------------------------------------------
class TestHysteresis:
    """Two streams walk the same pages toward different homes, offset by
    ``LAG`` steps — each page is pulled one way, then the other, ``LAG``
    steps later. Without hysteresis every page migrates twice; with
    ``cooldown > LAG`` the second pull lands inside the cooldown window
    and is refused."""

    LAG = 12

    def _run(self, cooldown: int):
        t = np.arange(T)
        scheds = np.stack([(8 + t) % N_PAGES,
                           (8 + t - self.LAG) % N_PAGES]).astype(np.int32)
        fab = ShardedPoolCfg(n_shards=4, placement="block", link_budget=6,
                             near_delay=1, far_delay=3)
        cfg = MigrationCfg(mig_per_stream=2, lead=1, cooldown=cooldown)
        st, _, info = sharded_multi_stream_consume(
            POOL, jnp.asarray(scheds), GEOM, fab, migration=cfg)
        tier = st["tier"]
        migs = int(np.asarray(info["migrated"]).sum())
        stamped = int((np.asarray(tier["last_mig"]) > -(1 << 20)).sum())
        rep = run_shardstep(scheds, N_PAGES, 4, "block", 6,
                            ring_size=GEOM.ring_size, near_delay=1,
                            far_delay=3, pw_max=GEOM.pw_max,
                            h_size=GEOM.h_size, n_split=GEOM.n_split,
                            migration=cfg)
        twin_migs = sum(rep.stream_summary(i)["migrations"]
                        for i in range(2))
        return migs, stamped, twin_migs

    def test_no_ping_pong_with_cooldown_beyond_lag(self):
        migs, stamped, twin = self._run(cooldown=16)
        assert migs == twin                      # pinned in scan AND twin
        assert migs == stamped > 0               # each page at most once

    def test_ping_pong_without_hysteresis(self):
        """cooldown=2 < LAG: the opposing pull is granted — the oscillation
        the cooldown exists to stop (and the bound still holds)."""
        migs, stamped, twin = self._run(cooldown=2)
        assert migs == twin
        assert migs > stamped                    # some pages moved twice
        assert migs <= stamped * (1 + (T - 1) // 2)   # bounded per window

    def test_bounded_migrations_per_window(self):
        for cd in (2, 8, 16):
            migs, stamped, _ = self._run(cooldown=cd)
            assert migs <= stamped * (1 + (T - 1) // cd), f"cooldown {cd}"


# --------------------------------------------------------------------------
# off-flag reduction: enabled=False IS the two-tier path
# --------------------------------------------------------------------------
class TestOffFlagReduction:
    def test_scan_bit_exact(self):
        scheds = jnp.asarray(_scheds())
        fab = ShardedPoolCfg(n_shards=4, placement="interleave",
                             link_budget=2, near_delay=1, far_delay=3)
        st_off, sums_off, info_off = sharded_multi_stream_consume(
            POOL, scheds, GEOM, fab, migration=None)
        st_dis, sums_dis, info_dis = sharded_multi_stream_consume(
            POOL, scheds, GEOM, fab, migration=MigrationCfg(enabled=False))
        np.testing.assert_array_equal(np.asarray(sums_off),
                                      np.asarray(sums_dis))
        assert set(info_off) == set(info_dis)    # no lifecycle keys leak
        for k in info_off:
            np.testing.assert_array_equal(np.asarray(info_off[k]),
                                          np.asarray(info_dis[k]),
                                          err_msg=k)
        assert "tier" not in st_off and "tier" not in st_dis

    def test_twin_reports_identical(self):
        scheds = _scheds()
        for disabled in (None, MigrationCfg(enabled=False)):
            rep = run_shardstep(scheds, N_PAGES, 4, "block", 2,
                                ring_size=GEOM.ring_size, near_delay=1,
                                far_delay=3, pw_max=GEOM.pw_max,
                                h_size=GEOM.h_size, n_split=GEOM.n_split,
                                migration=disabled)
            summaries = [rep.stream_summary(i) for i in range(2)]
            for s in summaries:
                assert "migrations" not in s     # two-tier vocabulary
            if disabled is None:
                base = summaries
            else:
                assert summaries == base

    def test_event_engine_reports_identical(self):
        reps = [run_fabric(_engine_scenario(mig))
                for mig in (None, MigrationCfg(enabled=False))]
        assert all(r.migration is None for r in reps)
        assert reps[0].makespan == reps[1].makespan
        for a, b in zip(reps[0].tenants, reps[1].tenants):
            assert a.__dict__ == b.__dict__

    def test_serving_reports_identical(self):
        off = _run_serving(None)
        dis = _run_serving(MigrationCfg(enabled=False))
        assert "residency" not in off
        for k in off:
            if k in ("wall_s", "token_latency"):  # wall-clock, not modeled
                continue
            same = (np.array_equal(off[k], dis[k])
                    if isinstance(off[k], np.ndarray) else off[k] == dis[k])
            assert same, k


# --------------------------------------------------------------------------
# event engine: continuous-clock mirror (sanity-checked, not bit-pinned)
# --------------------------------------------------------------------------
def _engine_scenario(mig, chaos=None) -> FabricScenario:
    """Two tenants each camped on the *other* node's pages, under cache
    pressure (capacity 16 << 64-page working set) — static placement pays
    far_factor on every transfer, forever; migration re-homes the sets."""
    def walk(lo, hi, n=600):
        return (lo + (np.arange(n) % (hi - lo))).astype(np.int64)
    tenants = [TenantSpec("a", walk(0, 64), policy="leap",
                          cache_capacity=16, eviction="lru", home_node=1),
               TenantSpec("b", walk(64, 128), policy="leap",
                          cache_capacity=16, eviction="lru", home_node=0)]
    return FabricScenario(tenants, n_nodes=2, n_pages=128,
                          placement="block", far_factor=4.0,
                          migration=mig, chaos=chaos, seed=1)


class TestEventEngineMigration:
    def test_migration_rehomes_and_cuts_makespan(self):
        off = run_fabric(_engine_scenario(None))
        on = run_fabric(_engine_scenario(MigrationCfg()))
        assert off.migration is None
        assert on.migration["migrations"] > 0
        assert on.migration["rehomed_pages"] > 0
        assert on.migration["dropped"] == 0
        assert on.makespan < off.makespan

    def test_single_node_fabric_rejected(self):
        spec = TenantSpec("solo", np.arange(64), policy="leap",
                          eviction="lru")
        with pytest.raises(ValueError, match="multi-node"):
            run_fabric(FabricScenario([spec], migration=MigrationCfg()))


# --------------------------------------------------------------------------
# chaos composition (DESIGN.md §9 x §12)
# --------------------------------------------------------------------------
@pytest.mark.chaos
class TestChaosComposition:
    SPEC = ChaosSpec(node_loss=(0, 20))

    def test_migrations_to_dead_shard_dropped_and_pollution_counted(self):
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=4, placement="block", link_budget=2,
                             near_delay=1, far_delay=3)
        st, _, info = sharded_multi_stream_consume(
            POOL, jnp.asarray(scheds), GEOM, fab, chaos=self.SPEC,
            migration=MIG)
        mg = np.asarray(info["mig_on_shard"])
        assert int(mg[:20, 0].sum()) > 0         # the NIC did carry moves
        assert int(mg[20:, 0].sum()) == 0        # none after it died
        st2 = sharded_multi_stream_consume(
            POOL, jnp.asarray(scheds), GEOM, fab, chaos=self.SPEC)[0]
        pol_mig = sum(stream_stats_at(st, i)["pollution"] for i in range(2))
        pol_two = sum(stream_stats_at(st2, i)["pollution"] for i in range(2))
        assert pol_mig > pol_two                 # dropped moves -> pollution

    def test_twin_stays_divergence_free_under_node_loss(self):
        scheds = _scheds()
        fab = ShardedPoolCfg(n_shards=4, placement="block", link_budget=2,
                             near_delay=1, far_delay=3)
        st, _, info = sharded_multi_stream_consume(
            POOL, jnp.asarray(scheds), GEOM, fab, chaos=self.SPEC,
            migration=MIG)
        rep = run_shardstep(scheds, N_PAGES, 4, "block", 2,
                            ring_size=GEOM.ring_size, near_delay=1,
                            far_delay=3, pw_max=GEOM.pw_max,
                            h_size=GEOM.h_size, n_split=GEOM.n_split,
                            chaos=self.SPEC, migration=MIG)
        for i in range(scheds.shape[0]):
            j = _jitted_summary(st, info, i)
            r = rep.stream_summary(i)
            assert {k: j[k] for k in r} == r, f"stream {i}"

    def test_event_engine_counts_dropped_migrations(self):
        rep = run_fabric(_engine_scenario(MigrationCfg(),
                                          chaos=ChaosSpec(
                                              node_loss=(1, 200))))
        assert rep.migration["dropped"] > 0


# --------------------------------------------------------------------------
# serving engine: host lifecycle + compressed demotion under the §6.4 pin
# --------------------------------------------------------------------------
def _run_serving(mig) -> dict:
    cfg = ServeConfig(requests=5, slots=2, prompt_len=8, gen=4, page_size=4,
                      prefill_chunk=4, arrival="bursty", burst_len=2,
                      think_time=1000.0, idle_time=3000.0, seed=3,
                      trace=True, migration=mig)
    return ServingEngine(cfg, SyntheticExecutor(n_kv_heads=2, head_dim=8,
                                                seed=0)).run()


class TestServingMigration:
    def test_compressed_lifecycle_keeps_equivalence_pins(self):
        """Lossy demotion mutates the cold bytes *before* the sweep, so the
        flat reference and the tiered path read identical post-roundtrip
        pages — §6.4 holds with the compressed tier on."""
        rep = _run_serving(MigrationCfg(compressed=True, far_capacity=8,
                                        demote_per_step=2,
                                        decompress_delay=2, cooldown=8))
        assert rep["tiered_equiv_ok"]
        assert rep["trace_totals_ok"]
        assert rep["requests_finished"] == 5
        res = rep["residency"]
        assert res["compressed"] > 0 and res["demotions"] > 0
        assert (res["uncompressed"] + res["compressed"]) == res["n_pages"]
