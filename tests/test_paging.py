"""Paging layer: paged KV, Leap-prefetched streams, expert paging."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.paging import (ExpertPrefetcher, PageAllocator, append_kv,
                          init_paged_kv, linear_page_table,
                          paged_decode_attention)
from repro.paging.prefetch_serving import (PrefetchedStream, multi_stream_consume,
                                           stream_consume, stream_init,
                                           stream_stats)


class TestPagedKV:
    def test_append_then_attend_matches_dense(self):
        from repro.models.attention import decode_attention
        B, Hkv, Hq, dh, ps, npps = 2, 2, 4, 16, 4, 4
        pool = init_paged_kv(1, B * npps, ps, Hkv, dh, jnp.float32)
        pt = linear_page_table(B, npps)
        T = ps * npps
        kd = jax.random.normal(jax.random.PRNGKey(0), (B, T, Hkv, dh))
        vd = jax.random.normal(jax.random.PRNGKey(1), (B, T, Hkv, dh))
        n_tok = 11
        for pos in range(n_tok):
            pool = append_kv(pool, jnp.int32(0), kd[:, pos], vd[:, pos],
                             pt, jnp.int32(pos))
        q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, Hq, dh))
        a = paged_decode_attention(q, pool, jnp.int32(0), pt,
                                   jnp.full((B,), n_tok))
        b = decode_attention(q, kd[:, :], vd[:, :], n_tok)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    def test_allocator_alloc_free(self):
        al = PageAllocator(16)
        p1 = al.alloc_seq(1, 4)
        p2 = al.alloc_seq(2, 4)
        assert len(set(p1) & set(p2)) == 0 and al.in_use == 8
        al.free_seq(1)
        assert al.in_use == 4
        al.alloc_seq(3, 12)
        with pytest.raises(MemoryError):
            al.alloc_seq(4, 1)


class TestPrefetchedStream:
    GEOM = PrefetchedStream(n_pages=128, n_slots=24, page_elems=4)

    def _pool(self):
        return jnp.arange(128 * 4, dtype=jnp.float32).reshape(128, 4)

    def test_sequential_converges_to_prefetch_hits(self):
        sched = jnp.arange(100, dtype=jnp.int32)
        st, sums, info = stream_consume(self._pool(), sched, self.GEOM)
        assert float(info["pref_hit"][20:].mean()) > 0.95
        assert stream_stats(st)["pollution"] == 0

    def test_data_always_correct(self):
        for sched in (jnp.arange(100, dtype=jnp.int32),
                      jax.random.randint(jax.random.PRNGKey(0), (100,), 0, 128),
                      jnp.arange(0, 300, 3, dtype=jnp.int32) % 128):
            st, sums, _ = stream_consume(self._pool(), sched, self.GEOM)
            expect = self._pool()[sched].sum(-1)
            np.testing.assert_allclose(np.asarray(sums), np.asarray(expect))

    def test_random_throttles(self):
        sched = jax.random.randint(jax.random.PRNGKey(1), (150,), 0, 128)
        st, _, _ = stream_consume(self._pool(), sched, self.GEOM)
        assert stream_stats(st)["prefetch_issued"] < 15

    def test_multi_stream_isolation(self):
        """Paper Fig. 13: concurrent streams keep their own detectors."""
        scheds = jnp.stack([jnp.arange(80, dtype=jnp.int32),
                            (jnp.arange(80, dtype=jnp.int32) * 3) % 128])
        (st, sums, info) = multi_stream_consume(self._pool(), scheds, self.GEOM)
        assert float(info["pref_hit"][0, 20:].mean()) > 0.9
        assert float(info["pref_hit"][1, 20:].mean()) > 0.9


class TestExpertPaging:
    def test_skewed_routing_gets_hits_random_throttles(self):
        ep = ExpertPrefetcher(n_experts=16, n_hot=6, block_elems=8)
        weights = jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8)
        st = ep.init()
        cyc = jnp.asarray(np.tile(np.arange(4), 40), jnp.int32)  # cyclic route
        st, info = ep.consume_route_trace(st, weights, cyc)
        from repro.core.pool import pool_stats
        hits_cyc = pool_stats(st["pool_meta"])["prefetch_hits"]
        st2 = ep.init()
        rnd = jax.random.randint(jax.random.PRNGKey(0), (160,), 0, 16)
        st2, _ = ep.consume_route_trace(st2, weights, rnd)
        issued_rnd = pool_stats(st2["pool_meta"])["prefetch_issued"]
        assert hits_cyc > 50           # cyclic stride +1 detected
        assert issued_rnd < 30         # randomness -> throttled
